"""What-if capacity search: replay a trace against candidate configurations.

The planner answers the operator's question directly: *what is the cheapest
fleet/policy configuration that would have served this recorded traffic
within its SLO?* Every candidate is replayed against the trace through the
real serve path — the same columnar ``serve_stream`` the production runtime
uses, via ``ShardedRuntime`` workers — and scored from the resulting record
arrays: actual cloud spend, fleet capacity cost, latency percentiles, and
SLO attainment. Nothing is approximated with queueing formulas; the digital
twin executes the trace.

Two search strategies:

- **grid** — replay every candidate against the full trace. Exhaustive, and
  embarrassingly parallel: each (candidate × app) pair is one independent
  shard, so candidates evaluate concurrently in threads or processes with
  bit-identical results in every mode.
- **halving** — successive halving over trace prefixes: replay all
  candidates on a short prefix, prune the bottom half, double the prefix,
  repeat — the final rung replays the FULL trace, so the winner is always
  verified on everything, never extrapolated from a prefix.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from repro.core.apps import APPS, MEMORY_CONFIGS_MB
from repro.core.multiapp import AppShard, ShardedRuntime
from repro.core.records import SimulationResult
from repro.planner.candidates import Candidate, TwinRuntimeFactory
from repro.trace.format import Trace, TraceError
from repro.trace.replay import TraceChunkFactory

MS_PER_HOUR = 3_600_000.0


@dataclass(frozen=True)
class SLO:
    """Service-level objective: ``target`` fraction of tasks within
    ``latency_ms`` (e.g. 99% of requests under 30 s end-to-end)."""

    latency_ms: float
    target: float = 0.99

    def __post_init__(self):
        if not self.latency_ms > 0:
            raise ValueError(f"SLO latency must be > 0, got {self.latency_ms}")
        if not 0.0 < self.target <= 1.0:
            raise ValueError(
                f"SLO target must be in (0, 1], got {self.target}")


@dataclass
class CandidateScore:
    """One candidate's replay outcome, scored from the record arrays."""

    candidate: Candidate
    n: int                       # tasks replayed (prefix length on early rungs)
    cloud_cost: float            # Σ actual billed cost (edge marginal = 0)
    fleet_cost: float            # device_rate_per_hour × Σspeed × makespan h
    mean_latency_ms: float
    p50_latency_ms: float
    p95_latency_ms: float
    p99_latency_ms: float
    attainment: float            # fraction of tasks within slo.latency_ms
    meets_slo: bool
    makespan_ms: float           # first arrival → last completion, cross-app
    per_app_attainment: dict[str, float] = field(default_factory=dict)

    @property
    def total_cost(self) -> float:
        return self.cloud_cost + self.fleet_cost

    def row(self) -> str:
        flag = "meets" if self.meets_slo else "MISSES"
        return (f"{self.candidate.name:<18} ${self.total_cost:>10.5f} "
                f"(cloud {self.cloud_cost:.5f} + fleet {self.fleet_cost:.5f})"
                f"  p99 {self.p99_latency_ms:>8,.0f} ms"
                f"  attain {self.attainment:7.2%}  {flag}")


def score_candidate(candidate: Candidate,
                    results: dict[str, SimulationResult],
                    slo: SLO) -> CandidateScore:
    """Score one candidate's per-app replay results against the SLO.

    All metrics are array reductions over the concatenated record columns.
    Fleet cost charges the candidate's aggregate relative capacity
    (``Σ device speeds``) at ``device_rate_per_hour`` for the run's makespan
    — so over-provisioned fleets pay for the capacity that bought their
    latency, which is the trade the planner exists to arbitrate.
    """
    lats = [r.records.actual_latency_ms for r in results.values()]
    lat = np.concatenate(lats) if lats else np.zeros(0)
    n = int(lat.shape[0])
    per_app = {
        app: float(np.count_nonzero(
            r.records.actual_latency_ms <= slo.latency_ms)) / max(r.n, 1)
        for app, r in results.items()}
    attain = float(np.count_nonzero(lat <= slo.latency_ms)) / max(n, 1)
    t0 = min((float(np.min(r.records.arrival_ms))
              for r in results.values() if r.n), default=0.0)
    t1 = max((float(np.max(r.records.completion_ms))
              for r in results.values() if r.n), default=0.0)
    makespan = max(t1 - t0, 0.0)
    fleet_cost = (candidate.device_rate_per_hour
                  * candidate.fleet_speed_total * makespan / MS_PER_HOUR)
    return CandidateScore(
        candidate=candidate,
        n=n,
        cloud_cost=float(sum(r.total_actual_cost for r in results.values())),
        fleet_cost=fleet_cost,
        mean_latency_ms=float(np.mean(lat)) if n else 0.0,
        p50_latency_ms=float(np.percentile(lat, 50)) if n else 0.0,
        p95_latency_ms=float(np.percentile(lat, 95)) if n else 0.0,
        p99_latency_ms=float(np.percentile(lat, 99)) if n else 0.0,
        attainment=attain,
        meets_slo=attain >= slo.target,
        makespan_ms=makespan,
        per_app_attainment=per_app,
    )


def _rank_key(s: CandidateScore):
    """SLO-meeting candidates first, cheapest wins; among SLO-missers,
    closest to the target wins (then cheapest). Name breaks exact ties so
    the ranking is a total order — identical across evaluation modes."""
    if s.meets_slo:
        return (0, s.total_cost, s.candidate.name)
    return (1, -s.attainment, s.total_cost, s.candidate.name)


@dataclass
class PlanResult:
    """Outcome of one ``Planner.plan`` search."""

    best: CandidateScore               # verified on the FULL trace
    scores: list[CandidateScore]       # final-rung (full-trace) scores, ranked
    rungs: list[dict]                  # per-rung summaries (halving)
    strategy: str
    mode: str
    replayed_tasks: int                # Σ tasks replayed across all rungs

    def table(self) -> str:
        rows = [s.row() for s in self.scores]
        rows.append(f"best: {self.best.candidate.name} "
                    f"(${self.best.total_cost:.5f}, "
                    f"attain {self.best.attainment:.2%})")
        return "\n".join(rows)


class Planner:
    """Replay a trace against candidate configurations; find the cheapest
    that meets the SLO.

    Each (candidate × app) pair becomes one independent ``AppShard`` — its
    runtime a ``TwinRuntimeFactory`` (rebuilt from seeds, fit-cached), its
    workload the candidate-agnostic per-app sub-trace — so one
    ``ShardedRuntime.serve`` evaluates the whole candidate set through the
    existing worker machinery. Shards share no state; scores are
    bit-identical across sequential, thread, and process modes.
    """

    def __init__(self, trace: Trace, slo: SLO, fit_seed: int = 0,
                 n_inputs: int | None = 120,
                 fit_configs: tuple[int, ...] | None = None,
                 twin_seed: int = 11, max_workers: int | None = None):
        trace.validate()
        if trace.n == 0:
            raise TraceError("cannot plan over an empty trace")
        for app in trace.app_names:
            if app not in APPS:
                raise TraceError(
                    f"trace app {app!r} is not a known application; known "
                    f"apps are {sorted(APPS)}")
        self.trace = trace
        self.slo = slo
        self.fit_seed = fit_seed
        self.n_inputs = n_inputs
        if fit_configs is None:
            fit_configs = tuple(MEMORY_CONFIGS_MB)
        self.fit_configs = tuple(fit_configs)
        self.twin_seed = twin_seed
        self.max_workers = max_workers
        self.last_mode = "none"  # mode of the most recent evaluate()

    # ------------------------------------------------------------- evaluate
    def _shards(self, candidates: list[Candidate],
                prefix_n: int | None) -> list[AppShard]:
        sub = (self.trace if prefix_n is None
               else self.trace.prefix(prefix_n)).split_by_app()
        shards = []
        for cand in candidates:
            for app, t in sub.items():
                shards.append(AppShard(
                    name=f"{cand.name}/{app}",
                    runtime=TwinRuntimeFactory(
                        app=app, candidate=cand, fit_seed=self.fit_seed,
                        n_inputs=self.n_inputs, fit_configs=self.fit_configs,
                        twin_seed=self.twin_seed),
                    workload=TraceChunkFactory(t),
                    chunk_size=cand.chunk_size,
                    keep_tasks=False))
        return shards

    def evaluate(self, candidates, prefix_n: int | None = None,
                 parallel: bool = True,
                 use_processes: bool = False) -> list[CandidateScore]:
        """Replay every candidate against the trace (or its first
        ``prefix_n`` records); return scores ranked best-first."""
        candidates = list(candidates)
        if not candidates:
            raise ValueError("no candidates to evaluate")
        names = [c.name for c in candidates]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate candidate names: {names}")
        sharded = ShardedRuntime(
            self._shards(candidates, prefix_n),
            max_workers=self.max_workers,
        ).serve(parallel=parallel, use_processes=use_processes)
        self.last_mode = sharded.mode
        apps = self.trace.app_names
        scores = [
            score_candidate(
                cand,
                {app: sharded.results[f"{cand.name}/{app}"] for app in apps
                 if f"{cand.name}/{app}" in sharded.results},
                self.slo)
            for cand in candidates]
        return sorted(scores, key=_rank_key)

    # ----------------------------------------------------------------- plan
    def plan(self, candidates, strategy: str = "grid", rungs: int = 3,
             min_rung_n: int = 512, parallel: bool = True,
             use_processes: bool = False) -> PlanResult:
        """The cheapest configuration that serves this trace within SLO.

        ``strategy="grid"`` replays every candidate on the full trace;
        ``"halving"`` prunes the bottom half of the ranking after each
        prefix rung, doubling the prefix each time — the last rung is always
        the full trace, so ``best`` is verified on every record either way.
        If no candidate meets the SLO, the best-attainment one is returned
        (``best.meets_slo`` says which case you are in).
        """
        candidates = list(candidates)
        if strategy not in ("grid", "halving"):
            raise ValueError(
                f"unknown strategy {strategy!r}; expected 'grid' or 'halving'")
        rung_log: list[dict] = []
        replayed = 0
        survivors = candidates
        if strategy == "halving" and rungs > 1 and len(candidates) > 1:
            n = self.trace.n
            for k in range(rungs - 1):
                rung_n = max(min_rung_n, n >> (rungs - 1 - k))
                if rung_n >= n:
                    break  # prefix would not be shorter than the full trace
                ranked = self.evaluate(survivors, prefix_n=rung_n,
                                       parallel=parallel,
                                       use_processes=use_processes)
                replayed += sum(s.n for s in ranked)
                keep = max(1, math.ceil(len(ranked) / 2))
                rung_log.append({
                    "rung": k, "prefix_n": rung_n,
                    "evaluated": [s.candidate.name for s in ranked],
                    "kept": [s.candidate.name for s in ranked[:keep]]})
                survivors = [s.candidate for s in ranked[:keep]]
        final = self.evaluate(survivors, prefix_n=None, parallel=parallel,
                              use_processes=use_processes)
        replayed += sum(s.n for s in final)
        return PlanResult(best=final[0], scores=final, rungs=rung_log,
                          strategy=strategy, mode=self.last_mode,
                          replayed_tasks=replayed)


def plan(trace: Trace, candidates, slo: SLO, strategy: str = "grid",
         **kwargs) -> PlanResult:
    """Convenience: ``Planner(trace, slo).plan(candidates, strategy)``.

    Planner construction kwargs (``fit_seed``, ``n_inputs``, ``twin_seed``,
    ``max_workers``, ``fit_configs``) and plan kwargs (``rungs``,
    ``parallel``, ``use_processes``, ``min_rung_n``) are split automatically.
    """
    plan_keys = {"rungs", "min_rung_n", "parallel", "use_processes"}
    plan_kw = {k: v for k, v in kwargs.items() if k in plan_keys}
    ctor_kw = {k: v for k, v in kwargs.items() if k not in plan_keys}
    return Planner(trace, slo, **ctor_kw).plan(candidates, strategy=strategy,
                                               **plan_kw)
