"""What-if capacity search: replay a trace against candidate configurations.

The planner answers the operator's question directly: *what is the cheapest
fleet/policy configuration that would have served this recorded traffic
within its SLO?* Every candidate is replayed against the trace through the
real serve path — the same columnar ``serve_stream`` the production runtime
uses, via ``ShardedRuntime`` workers — and scored from the resulting record
arrays: actual cloud spend, fleet capacity cost, latency percentiles, and
SLO attainment. Nothing is approximated with queueing formulas; the digital
twin executes the trace.

Two search strategies:

- **grid** — replay every candidate against the full trace. Exhaustive, and
  embarrassingly parallel: each (candidate × app) pair is one independent
  shard, so candidates evaluate concurrently in threads or processes with
  bit-identical results in every mode.
- **halving** — successive halving over trace prefixes: replay all
  candidates on a short prefix, prune the bottom half, double the prefix,
  repeat — the final rung replays the FULL trace, so the winner is always
  verified on everything, never extrapolated from a prefix.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace

import numpy as np

from repro.core.apps import APPS, MEMORY_CONFIGS_MB
from repro.core.multiapp import AppShard, ShardedRuntime
from repro.core.records import SimulationResult
from repro.planner.candidates import Candidate, TwinRuntimeFactory
from repro.trace.format import Trace, TraceError
from repro.trace.replay import TraceChunkFactory

MS_PER_HOUR = 3_600_000.0


@dataclass(frozen=True)
class SLO:
    """Service-level objective: ``target`` fraction of tasks within
    ``latency_ms`` (e.g. 99% of requests under 30 s end-to-end)."""

    latency_ms: float
    target: float = 0.99

    def __post_init__(self):
        if not self.latency_ms > 0:
            raise ValueError(f"SLO latency must be > 0, got {self.latency_ms}")
        if not 0.0 < self.target <= 1.0:
            raise ValueError(
                f"SLO target must be in (0, 1], got {self.target}")


@dataclass
class CandidateScore:
    """One candidate's replay outcome, scored from the record arrays."""

    candidate: Candidate
    n: int                       # tasks replayed (prefix length on early rungs)
    cloud_cost: float            # Σ actual billed cost (edge marginal = 0)
    fleet_cost: float            # device_rate_per_hour × Σspeed × makespan h
    mean_latency_ms: float
    p50_latency_ms: float
    p95_latency_ms: float
    p99_latency_ms: float
    attainment: float            # fraction of tasks within slo.latency_ms
    meets_slo: bool
    makespan_ms: float           # first arrival → last completion, cross-app
    per_app_attainment: dict[str, float] = field(default_factory=dict)

    @property
    def total_cost(self) -> float:
        return self.cloud_cost + self.fleet_cost

    def row(self) -> str:
        flag = "meets" if self.meets_slo else "MISSES"
        return (f"{self.candidate.name:<18} ${self.total_cost:>10.5f} "
                f"(cloud {self.cloud_cost:.5f} + fleet {self.fleet_cost:.5f})"
                f"  p99 {self.p99_latency_ms:>8,.0f} ms"
                f"  attain {self.attainment:7.2%}  {flag}")


def score_candidate(candidate: Candidate,
                    results: dict[str, SimulationResult],
                    slo: SLO) -> CandidateScore:
    """Score one candidate's per-app replay results against the SLO.

    All metrics are array reductions over the concatenated record columns.
    Fleet cost charges the candidate's aggregate relative capacity
    (``Σ device speeds``) at ``device_rate_per_hour`` for the run's makespan
    — so over-provisioned fleets pay for the capacity that bought their
    latency, which is the trade the planner exists to arbitrate.
    """
    lats = [r.records.actual_latency_ms for r in results.values()]
    lat = np.concatenate(lats) if lats else np.zeros(0)
    n = int(lat.shape[0])
    per_app = {
        app: float(np.count_nonzero(
            r.records.actual_latency_ms <= slo.latency_ms)) / max(r.n, 1)
        for app, r in results.items()}
    attain = float(np.count_nonzero(lat <= slo.latency_ms)) / max(n, 1)
    t0 = min((float(np.min(r.records.arrival_ms))
              for r in results.values() if r.n), default=0.0)
    t1 = max((float(np.max(r.records.completion_ms))
              for r in results.values() if r.n), default=0.0)
    makespan = max(t1 - t0, 0.0)
    fleet_cost = (candidate.device_rate_per_hour
                  * candidate.fleet_speed_total * makespan / MS_PER_HOUR)
    return CandidateScore(
        candidate=candidate,
        n=n,
        cloud_cost=float(sum(r.total_actual_cost for r in results.values())),
        fleet_cost=fleet_cost,
        mean_latency_ms=float(np.mean(lat)) if n else 0.0,
        p50_latency_ms=float(np.percentile(lat, 50)) if n else 0.0,
        p95_latency_ms=float(np.percentile(lat, 95)) if n else 0.0,
        p99_latency_ms=float(np.percentile(lat, 99)) if n else 0.0,
        attainment=attain,
        meets_slo=attain >= slo.target,
        makespan_ms=makespan,
        per_app_attainment=per_app,
    )


def _rank_key(s: CandidateScore):
    """SLO-meeting candidates first, cheapest wins; among SLO-missers,
    closest to the target wins (then cheapest). Name breaks exact ties so
    the ranking is a total order — identical across evaluation modes."""
    if s.meets_slo:
        return (0, s.total_cost, s.candidate.name)
    return (1, -s.attainment, s.total_cost, s.candidate.name)


@dataclass
class PlanResult:
    """Outcome of one ``Planner.plan`` search."""

    best: CandidateScore               # verified on the FULL trace
    scores: list[CandidateScore]       # final-rung (full-trace) scores, ranked
    rungs: list[dict]                  # per-rung summaries (halving)
    strategy: str
    mode: str
    replayed_tasks: int                # Σ tasks replayed across all rungs

    def table(self) -> str:
        rows = [s.row() for s in self.scores]
        rows.append(f"best: {self.best.candidate.name} "
                    f"(${self.best.total_cost:.5f}, "
                    f"attain {self.best.attainment:.2%})")
        return "\n".join(rows)


class Planner:
    """Replay a trace against candidate configurations; find the cheapest
    that meets the SLO.

    Each (candidate × app) pair becomes one independent ``AppShard`` — its
    runtime a ``TwinRuntimeFactory`` (rebuilt from seeds, fit-cached), its
    workload the candidate-agnostic per-app sub-trace — so one
    ``ShardedRuntime.serve`` evaluates the whole candidate set through the
    existing worker machinery. Shards share no state; scores are
    bit-identical across sequential, thread, and process modes.
    """

    def __init__(self, trace: Trace, slo: SLO, fit_seed: int = 0,
                 n_inputs: int | None = 120,
                 fit_configs: tuple[int, ...] | None = None,
                 twin_seed: int = 11, max_workers: int | None = None):
        trace.validate()
        if trace.n == 0:
            raise TraceError("cannot plan over an empty trace")
        for app in trace.app_names:
            if app not in APPS:
                raise TraceError(
                    f"trace app {app!r} is not a known application; known "
                    f"apps are {sorted(APPS)}")
        self.trace = trace
        self.slo = slo
        self.fit_seed = fit_seed
        self.n_inputs = n_inputs
        if fit_configs is None:
            fit_configs = tuple(MEMORY_CONFIGS_MB)
        self.fit_configs = tuple(fit_configs)
        self.twin_seed = twin_seed
        self.max_workers = max_workers
        self.last_mode = "none"  # mode of the most recent evaluate()

    # ------------------------------------------------------------- evaluate
    def _shards(self, candidates: list[Candidate],
                prefix_n: int | None) -> list[AppShard]:
        sub = (self.trace if prefix_n is None
               else self.trace.prefix(prefix_n)).split_by_app()
        shards = []
        for cand in candidates:
            for app, t in sub.items():
                shards.append(AppShard(
                    name=f"{cand.name}/{app}",
                    runtime=TwinRuntimeFactory(
                        app=app, candidate=cand, fit_seed=self.fit_seed,
                        n_inputs=self.n_inputs, fit_configs=self.fit_configs,
                        twin_seed=self.twin_seed),
                    workload=TraceChunkFactory(t),
                    chunk_size=cand.chunk_size,
                    keep_tasks=False))
        return shards

    def evaluate(self, candidates, prefix_n: int | None = None,
                 parallel: bool = True,
                 use_processes: bool = False) -> list[CandidateScore]:
        """Replay every candidate against the trace (or its first
        ``prefix_n`` records); return scores ranked best-first."""
        candidates = list(candidates)
        if not candidates:
            raise ValueError("no candidates to evaluate")
        names = [c.name for c in candidates]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate candidate names: {names}")
        sharded = ShardedRuntime(
            self._shards(candidates, prefix_n),
            max_workers=self.max_workers,
        ).serve(parallel=parallel, use_processes=use_processes)
        self.last_mode = sharded.mode
        apps = self.trace.app_names
        scores = [
            score_candidate(
                cand,
                {app: sharded.results[f"{cand.name}/{app}"] for app in apps
                 if f"{cand.name}/{app}" in sharded.results},
                self.slo)
            for cand in candidates]
        return sorted(scores, key=_rank_key)

    # -------------------------------------------------------- budget bisect
    def _refine_budget(self, best: CandidateScore, lo: float, iters: int,
                       rel_tol: float, parallel: bool,
                       use_processes: bool) -> tuple[CandidateScore, list]:
        """Bisect the winner's per-task budget ``c_max`` down to the cheapest
        value that still meets the SLO.

        The structural search picks a *configuration*; ``c_max`` is the one
        continuous knob left on the table, and total cost is (weakly)
        monotone in it — a smaller budget pushes work to the edge, trading
        cloud spend for latency until attainment drops below target. So the
        cheapest SLO-meeting budget sits at a threshold that bisection finds
        in O(log) full-trace replays: the invariant is that ``hi`` always
        meets the SLO (it starts at the verified winner), ``lo`` always
        misses (checked by the first probe — if the floor itself meets, it
        is returned outright). Every probe replays the FULL trace through
        ``evaluate``, so the refined winner is verified on every record,
        never interpolated.
        """
        cand, spec = best.candidate, best.candidate.policy
        if (not best.meets_slo or spec.kind == "min_cost"
                or not spec.c_max > lo):
            return best, []
        probes: list[CandidateScore] = []

        def probe(c_max: float) -> CandidateScore:
            pc = replace(cand, name=f"{cand.name}~cmax{len(probes)}",
                         policy=replace(spec, c_max=c_max))
            s = self.evaluate([pc], parallel=parallel,
                              use_processes=use_processes)[0]
            probes.append(s)
            return s

        hi, winner = spec.c_max, best
        lo_score = probe(lo)
        if lo_score.meets_slo:
            lo_score = replace(lo_score, candidate=replace(
                lo_score.candidate, name=cand.name))
            return (min((lo_score, best), key=_rank_key), probes)
        for _ in range(max(iters, 0)):
            if hi - lo <= rel_tol * max(abs(hi), 1e-12):
                break
            mid = 0.5 * (lo + hi)
            s = probe(mid)
            if s.meets_slo:
                hi, winner = mid, s
            else:
                lo = mid
        if winner is not best:
            winner = replace(winner, candidate=replace(
                winner.candidate, name=cand.name))
            winner = min((winner, best), key=_rank_key)
        return winner, probes

    # ----------------------------------------------------------------- plan
    def plan(self, candidates, strategy: str = "grid", rungs: int = 3,
             min_rung_n: int = 512, parallel: bool = True,
             use_processes: bool = False, budget_strategy: str = "none",
             budget_lo: float = 0.0, budget_iters: int = 8,
             budget_rel_tol: float = 0.02) -> PlanResult:
        """The cheapest configuration that serves this trace within SLO.

        ``strategy="grid"`` replays every candidate on the full trace;
        ``"halving"`` prunes the bottom half of the ranking after each
        prefix rung, doubling the prefix each time — the last rung is always
        the full trace, so ``best`` is verified on every record either way.
        If no candidate meets the SLO, the best-attainment one is returned
        (``best.meets_slo`` says which case you are in).

        ``budget_strategy="bisect"`` then refines the winner's continuous
        ``c_max`` knob (min-latency/hedged policies only): bisect down to the
        cheapest budget that still meets the SLO, ``budget_iters`` probes at
        most, stopping once the bracket is within ``budget_rel_tol`` of the
        meeting endpoint. Probes replay the full trace, and the refined
        winner keeps the original candidate name — it is the same
        configuration with a tighter budget.
        """
        candidates = list(candidates)
        if strategy not in ("grid", "halving"):
            raise ValueError(
                f"unknown strategy {strategy!r}; expected 'grid' or 'halving'")
        if budget_strategy not in ("none", "bisect"):
            raise ValueError(
                f"unknown budget_strategy {budget_strategy!r}; expected "
                f"'none' or 'bisect'")
        rung_log: list[dict] = []
        replayed = 0
        survivors = candidates
        if strategy == "halving" and rungs > 1 and len(candidates) > 1:
            n = self.trace.n
            for k in range(rungs - 1):
                rung_n = max(min_rung_n, n >> (rungs - 1 - k))
                if rung_n >= n:
                    break  # prefix would not be shorter than the full trace
                ranked = self.evaluate(survivors, prefix_n=rung_n,
                                       parallel=parallel,
                                       use_processes=use_processes)
                replayed += sum(s.n for s in ranked)
                keep = max(1, math.ceil(len(ranked) / 2))
                rung_log.append({
                    "rung": k, "prefix_n": rung_n,
                    "evaluated": [s.candidate.name for s in ranked],
                    "kept": [s.candidate.name for s in ranked[:keep]]})
                survivors = [s.candidate for s in ranked[:keep]]
        final = self.evaluate(survivors, prefix_n=None, parallel=parallel,
                              use_processes=use_processes)
        replayed += sum(s.n for s in final)
        best = final[0]
        if budget_strategy == "bisect":
            best, probes = self._refine_budget(
                best, budget_lo, budget_iters, budget_rel_tol, parallel,
                use_processes)
            replayed += sum(s.n for s in probes)
            for i, s in enumerate(probes):
                rung_log.append({
                    "budget_probe": i, "c_max": s.candidate.policy.c_max,
                    "total_cost": s.total_cost, "attainment": s.attainment,
                    "meets_slo": s.meets_slo})
        return PlanResult(best=best, scores=final, rungs=rung_log,
                          strategy=strategy, mode=self.last_mode,
                          replayed_tasks=replayed)


def plan(trace: Trace, candidates, slo: SLO, strategy: str = "grid",
         **kwargs) -> PlanResult:
    """Convenience: ``Planner(trace, slo).plan(candidates, strategy)``.

    Planner construction kwargs (``fit_seed``, ``n_inputs``, ``twin_seed``,
    ``max_workers``, ``fit_configs``) and plan kwargs (``rungs``,
    ``parallel``, ``use_processes``, ``min_rung_n``, ``budget_strategy``,
    ``budget_lo``, ``budget_iters``, ``budget_rel_tol``) are split
    automatically.
    """
    plan_keys = {"rungs", "min_rung_n", "parallel", "use_processes",
                 "budget_strategy", "budget_lo", "budget_iters",
                 "budget_rel_tol"}
    plan_kw = {k: v for k, v in kwargs.items() if k in plan_keys}
    ctor_kw = {k: v for k, v in kwargs.items() if k not in plan_keys}
    return Planner(trace, slo, **ctor_kw).plan(candidates, strategy=strategy,
                                               **plan_kw)
