"""What-if candidates: one serving configuration a trace can replay against.

A ``Candidate`` names everything the platform operator can actually turn:
the edge fleet (device count / speed mix), the placement policy and its
budget or deadline, the cloud memory-configuration set offered to the
policy, and the serve chunk size. ``TwinRuntimeFactory`` turns a candidate
into a live ``PlacementRuntime`` for one application — as a picklable,
zero-argument callable, because that is exactly what
``ShardedRuntime(use_processes=True)`` requires of its shards: the child
process rebuilds the runtime from the spec rather than unpickling live
model state. Fitting is deterministic from seeds and cached per process, so
sequential, thread, and process evaluations of the same candidate produce
bit-identical records.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping

from repro.core.apps import APPS, AWSTwin, MEMORY_CONFIGS_MB
from repro.core.decision import (
    DecisionEngine,
    HedgedPolicy,
    MinCostPolicy,
    MinLatencyPolicy,
    Policy,
)
from repro.core.fit import FittedModels, build_fleet_predictor, fit_app
from repro.core.runtime import PlacementRuntime, TwinBackend

_POLICY_KINDS = ("min_cost", "min_latency", "hedged")


@dataclass(frozen=True)
class PolicySpec:
    """Declarative, picklable spelling of a placement policy.

    Policies carry mutable per-run state (the min-latency surplus bank), so a
    candidate cannot hold a live ``Policy`` — every runtime gets a fresh
    instance from ``build()``.
    """

    kind: str = "min_latency"         # min_cost | min_latency | hedged
    deadline_ms: float = 1000.0       # min_cost: per-task deadline δ
    c_max: float = 0.0                # min_latency/hedged: per-task budget
    alpha: float = 0.0                # surplus carryover factor
    hedge_threshold_ms: float = 0.0   # hedged: tail-risk trigger

    def __post_init__(self):
        if self.kind not in _POLICY_KINDS:
            raise ValueError(
                f"unknown policy kind {self.kind!r}; expected one of "
                f"{_POLICY_KINDS}")

    def build(self) -> Policy:
        if self.kind == "min_cost":
            return MinCostPolicy(deadline_ms=self.deadline_ms)
        inner = MinLatencyPolicy(c_max=self.c_max, alpha=self.alpha)
        if self.kind == "hedged":
            return HedgedPolicy(inner,
                                hedge_threshold_ms=self.hedge_threshold_ms)
        return inner

    @property
    def deadline_for_result(self) -> float | None:
        return self.deadline_ms if self.kind == "min_cost" else None

    @property
    def c_max_for_result(self) -> float | None:
        return self.c_max if self.kind != "min_cost" else None


@dataclass(frozen=True)
class Candidate:
    """One serving configuration the planner can replay a trace against.

    ``fleet`` is a tuple of ``(device_name, relative_speed)`` pairs — the
    hashable/picklable spelling of the ``build_fleet_predictor`` device
    mapping. ``device_rate_per_hour`` prices fleet capacity for the planner's
    total-cost ranking: a device at speed ``s`` costs ``rate × s`` per hour
    (capacity-proportional), on top of the run's actual cloud spend.
    """

    name: str
    fleet: tuple[tuple[str, float], ...]
    policy: PolicySpec = field(default_factory=PolicySpec)
    cloud_configs: tuple[int, ...] = tuple(MEMORY_CONFIGS_MB)
    chunk_size: int = 65536
    device_rate_per_hour: float = 0.0

    def __post_init__(self):
        if not self.name:
            raise ValueError("candidate needs a non-empty name")
        if not self.fleet:
            raise ValueError(f"candidate {self.name!r} has an empty fleet")
        names = [d for d, _ in self.fleet]
        if len(set(names)) != len(names):
            raise ValueError(
                f"candidate {self.name!r} has duplicate fleet devices: {names}")
        if self.chunk_size < 1:
            raise ValueError(
                f"candidate {self.name!r}: chunk_size must be >= 1")

    @classmethod
    def make(cls, name: str, fleet: "int | Mapping[str, float]",
             policy: PolicySpec | None = None, prefix: str = "edge",
             **kwargs) -> "Candidate":
        """Normalize a device count or ``name -> speed`` mapping into a
        candidate (count ``k`` becomes ``prefix0..prefix{k-1}`` at speed 1)."""
        if isinstance(fleet, int):
            if fleet < 1:
                raise ValueError(f"candidate {name!r}: fleet count must be >= 1")
            devices = tuple((f"{prefix}{i}", 1.0) for i in range(fleet))
        else:
            devices = tuple((str(d), float(s)) for d, s in fleet.items())
        return cls(name=name, fleet=devices,
                   policy=policy or PolicySpec(), **kwargs)

    def fleet_dict(self) -> dict[str, float]:
        return dict(self.fleet)

    @property
    def fleet_speed_total(self) -> float:
        """Aggregate relative capacity — what the hourly rate is charged on."""
        return float(sum(s for _, s in self.fleet))


# ---------------------------------------------------------------- fit cache
# Deterministic from its key, so every process (parent or spawned child)
# converges to identical models — the foundation of cross-mode determinism.
# Forked children inherit the parent's cache for free; spawn-based platforms
# re-import this module with an empty dict and lazily refit.
_FIT_CACHE: dict = {}


def fitted(app: str, seed: int = 0, n_inputs: int | None = 120,
           configs: tuple[int, ...] = tuple(MEMORY_CONFIGS_MB),
           ) -> tuple[AWSTwin, FittedModels]:
    """Cached ``fit_app`` — one (twin, models) pair per distinct fit key."""
    if app not in APPS:
        raise ValueError(
            f"unknown app {app!r}; known apps are {sorted(APPS)}")
    key = (app, seed, n_inputs, tuple(configs))
    if key not in _FIT_CACHE:
        _FIT_CACHE[key] = fit_app(app, seed=seed, n_inputs=n_inputs,
                                  configs=tuple(configs))
    return _FIT_CACHE[key]


@dataclass(frozen=True)
class TwinRuntimeFactory:
    """Picklable zero-arg ``PlacementRuntime`` factory: (app, candidate).

    The shard-runtime spelling ``ShardedRuntime`` needs for process mode, and
    equally usable live in thread/sequential mode. Everything is rebuilt from
    seeds via the module fit cache, so two invocations anywhere produce
    runtimes whose serves are bit-identical.
    """

    app: str
    candidate: Candidate
    fit_seed: int = 0
    n_inputs: int | None = 120
    fit_configs: tuple[int, ...] = tuple(MEMORY_CONFIGS_MB)
    twin_seed: int = 11

    def __call__(self) -> PlacementRuntime:
        twin, models = fitted(self.app, seed=self.fit_seed,
                              n_inputs=self.n_inputs,
                              configs=self.fit_configs)
        cand = self.candidate
        fleet = cand.fleet_dict()
        predictor = build_fleet_predictor(models, fleet,
                                          configs=cand.cloud_configs)
        engine = DecisionEngine(predictor=predictor,
                                policy=cand.policy.build(), columnar=True)
        backend = TwinBackend(twin, seed=self.twin_seed,
                              edge_names=tuple(fleet), edge_speed=fleet)
        return PlacementRuntime(engine, backend)
