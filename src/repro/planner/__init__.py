"""What-if capacity planning: replay a trace against candidate configs.

``plan(trace, candidates, slo)`` answers "what is the cheapest fleet/policy
configuration that would have served this recorded traffic within SLO?" —
every candidate replayed through the real serve path (``ShardedRuntime``
workers over per-app sub-traces) and scored from the record arrays.
"""

from repro.planner.candidates import (
    Candidate,
    PolicySpec,
    TwinRuntimeFactory,
    fitted,
)
from repro.planner.search import (
    SLO,
    CandidateScore,
    Planner,
    PlanResult,
    plan,
    score_candidate,
)

__all__ = [
    "SLO",
    "Candidate",
    "CandidateScore",
    "PlanResult",
    "Planner",
    "PolicySpec",
    "TwinRuntimeFactory",
    "fitted",
    "plan",
    "score_candidate",
]
