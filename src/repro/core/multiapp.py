"""Cross-application sharded serving: N independent app streams in parallel.

The ROADMAP's "cross-application fleets" item, first concrete cut: realistic
edge platforms run long-lived *mixes* of applications (EdgeBench's IR+FD+STT
trio), each with its own Predictor (its own fitted component models), its own
policy budget, and its own fleet partition. Placement state never crosses
application boundaries — an IR dispatch cannot warm an STT container, and the
paper's policies are defined per application — so the shards are genuinely
independent and can execute concurrently.

``ShardedRuntime`` runs one ``PlacementRuntime.serve_stream`` per
``AppShard``:

- **threads** (default): the streaming serve path is numpy over chunk-sized
  arrays — block RNG draws, segment cumsums, masked argmins — which release
  the GIL, so independent shards overlap on real cores without any pickling
  or process spawn cost. Results are deterministic regardless of scheduling:
  no state is shared between shards.
- **processes** (``use_processes=True``): full isolation for workloads whose
  Python fraction defeats thread overlap. Shards must then carry *factories*
  (picklable callables building the runtime/workload in the child) rather
  than live objects.
- **sequential** (``parallel=False``): the baseline the speedup floor in
  ``benchmarks/bench_runtime.py`` is measured against.

Shards default to ``keep_tasks=False`` (constant-memory streaming results);
per-shard ``SimulationResult``s merge into a ``ShardedResult`` cross-app
report.
"""

from __future__ import annotations

import time
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np

from repro.core.records import RecordArena, RecordBatch, SimulationResult
from repro.core.runtime import PlacementRuntime


@dataclass
class AppShard:
    """One application stream: its runtime (or a factory) and its workload.

    ``runtime`` and ``workload`` may be live objects or zero-arg callables;
    callables are required for ``use_processes=True`` (the child builds its
    own copies) and are handy in threads too (construction then happens
    inside the worker, off the caller's critical path). A shard must own its
    predictor/policy/backend outright — sharing any of them across shards
    breaks both determinism and the concurrency story.
    """

    name: str
    runtime: "PlacementRuntime | Callable[[], PlacementRuntime]"
    workload: object  # task sequence, chunk iterator, or zero-arg factory
    chunk_size: int = 65536
    keep_tasks: bool = False

    def resolve_runtime(self) -> PlacementRuntime:
        rt = self.runtime() if callable(self.runtime) else self.runtime
        if not isinstance(rt, PlacementRuntime):
            raise TypeError(
                f"shard {self.name!r}: runtime resolved to {type(rt).__name__},"
                " expected PlacementRuntime")
        return rt

    def resolve_workload(self):
        return self.workload() if callable(self.workload) else self.workload


def _serve_shard(shard: AppShard) -> tuple[str, SimulationResult, float, dict]:
    """Top-level so process pools can pickle it; runs one shard end to end."""
    rt = shard.resolve_runtime()
    t0 = time.perf_counter()
    res = rt.serve_stream(shard.resolve_workload(),
                          chunk_size=shard.chunk_size,
                          keep_tasks=shard.keep_tasks)
    return shard.name, res, time.perf_counter() - t0, rt.stream_stats or {}


@dataclass
class ShardedResult:
    """Per-app results of one sharded serve plus the cross-app view."""

    results: dict[str, SimulationResult]
    wall_s: dict[str, float]            # per-shard serve wall time
    stream_stats: dict[str, dict]       # per-shard serve_stream aggregates
    elapsed_s: float                    # end-to-end wall time of the run
    mode: str = "thread"                # thread | process | sequential

    @property
    def n(self) -> int:
        return sum(r.n for r in self.results.values())

    @property
    def total_actual_cost(self) -> float:
        return sum(r.total_actual_cost for r in self.results.values())

    def merged_records(self) -> tuple[RecordBatch, np.ndarray, tuple[str, ...]]:
        """All shards' rows as ONE batch in global arrival order.

        Returns ``(batch, app_codes, app_names)``: the per-shard record
        batches merged through a ``RecordArena`` (target tables unified) and
        stable-sorted by arrival time — ties keep shard declaration order, so
        the merge is deterministic. ``app_codes[i]`` indexes ``app_names``
        (the shard names) for row ``i``. This is the cross-application view a
        recorded multi-app day looks like on the wire, and the natural input
        for capturing a sharded run back into one multi-app trace
        (``repro.trace.capture_sharded`` captures per shard and merges the
        traces the same way).
        """
        arena = RecordArena(keep_tasks=False)
        codes: list[np.ndarray] = []
        names = tuple(self.results)
        for k, res in enumerate(self.results.values()):
            arena.append(res.records)
            codes.append(np.full(len(res.records), k, dtype=np.int64))
        rb = arena.finish()
        code = np.concatenate(codes) if codes else np.zeros(0, np.int64)
        order = np.argsort(rb.arrival_ms, kind="stable") if len(rb) \
            else np.zeros(0, np.int64)
        return rb.take(order), code[order], names

    def table(self) -> str:
        """Human-readable cross-application report."""
        rows = [f"{'app':<8} {'tasks':>9} {'mean ms':>9} {'p99 ms':>10} "
                f"{'edge#':>9} {'cost $':>11} {'wall s':>7}"]
        for name, r in self.results.items():
            rows.append(
                f"{name:<8} {r.n:>9,d} {r.avg_actual_latency_ms:>9.0f} "
                f"{r.p99_actual_latency_ms:>10.0f} {r.n_edge:>9,d} "
                f"{r.total_actual_cost:>11.5f} {self.wall_s[name]:>7.2f}")
        rows.append(
            f"{'TOTAL':<8} {self.n:>9,d} {'':>9} {'':>10} {'':>9} "
            f"{self.total_actual_cost:>11.5f} {self.elapsed_s:>7.2f}")
        return "\n".join(rows)


class ShardedRuntime:
    """N application shards served as one cross-application run."""

    def __init__(self, shards: Sequence[AppShard],
                 max_workers: int | None = None):
        names = [s.name for s in shards]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate shard names: {names}")
        if not shards:
            raise ValueError("at least one shard is required")
        self.shards = list(shards)
        self.max_workers = max_workers

    def serve(self, parallel: bool = True,
              use_processes: bool = False) -> ShardedResult:
        """Serve every shard; merge per-shard results into a cross-app report.

        Per-shard results are identical across all three modes — shards share
        no state, so scheduling cannot perturb a single draw or decision.
        """
        t0 = time.perf_counter()
        if not parallel:
            outs = [_serve_shard(s) for s in self.shards]
            mode = "sequential"
        else:
            workers = self.max_workers or len(self.shards)
            if use_processes:
                for s in self.shards:
                    if not (callable(s.runtime) and callable(s.workload)):
                        raise ValueError(
                            f"shard {s.name!r}: use_processes=True requires "
                            "runtime and workload factories (callables) so "
                            "the child process builds its own copies")
                pool_cls = ProcessPoolExecutor
                mode = "process"
            else:
                pool_cls = ThreadPoolExecutor
                mode = "thread"
            with pool_cls(max_workers=workers) as pool:
                outs = list(pool.map(_serve_shard, self.shards))
        elapsed = time.perf_counter() - t0
        return ShardedResult(
            results={name: res for name, res, _, _ in outs},
            wall_s={name: wall for name, _, wall, _ in outs},
            stream_stats={name: st for name, _, _, st in outs},
            elapsed_s=elapsed,
            mode=mode,
        )


def serve_sharded(shards: Sequence[AppShard], parallel: bool = True,
                  use_processes: bool = False,
                  max_workers: int | None = None) -> ShardedResult:
    """Convenience wrapper: ``ShardedRuntime(shards).serve(...)``."""
    return ShardedRuntime(shards, max_workers=max_workers).serve(
        parallel=parallel, use_processes=use_processes)
