"""Event-driven simulation of the placement framework (paper Sec. VI-A).

Deprecated thin wrapper: the simulation loop now lives in
``repro.core.runtime`` — ``PlacementRuntime`` over a ``TwinBackend`` is the
same serve loop that drives the live prototype. ``Simulation`` is kept so
existing call sites (``Simulation(twin, engine, seed).run(tasks)``) keep
working; new code should construct the runtime directly:

    runtime = PlacementRuntime(engine, TwinBackend(twin, seed=seed))
    result = runtime.serve(tasks)

``TaskRecord``/``SimulationResult`` moved to ``repro.core.records`` and
``GroundTruthCloud`` to ``repro.core.runtime``; both are re-exported here for
backward compatibility.
"""

from __future__ import annotations

from repro.core.decision import DecisionEngine
from repro.core.apps import AWSTwin
from repro.core.pricing import LambdaPricing
from repro.core.records import RecordBatch, SimulationResult, TaskRecord
from repro.core.runtime import GroundTruthCloud, GTContainer, PlacementRuntime, TwinBackend
from repro.core.workload import TaskInput

__all__ = [
    "GTContainer",
    "GroundTruthCloud",
    "RecordBatch",
    "Simulation",
    "SimulationResult",
    "TaskRecord",
]


class Simulation:
    """Drives one workload through the Decision Engine against the twin.

    Deprecated: thin wrapper over ``PlacementRuntime`` + ``TwinBackend``.
    """

    def __init__(self, twin: AWSTwin, engine: DecisionEngine, seed: int = 0,
                 pricing: LambdaPricing | None = None):
        self.twin = twin
        self.engine = engine
        # fleet engines get one (full-speed) twin executor per device; pass
        # per-device speeds to TwinBackend directly for heterogeneous twins
        self.backend = TwinBackend(twin, seed=seed, pricing=pricing,
                                   edge_name=engine.edge_name,
                                   edge_names=engine.edge_names or None)
        self.runtime = PlacementRuntime(engine=engine, backend=self.backend)
        self.gt_cloud = self.backend.gt_cloud  # back-compat alias
        self.pricing = self.backend.pricing

    def run(self, tasks: list[TaskInput], batched: bool = True) -> SimulationResult:
        return self.runtime.serve(tasks, batched=batched)
