"""Event-driven simulation of the placement framework (paper Sec. VI-A).

The simulation feeds a Poisson workload into the Decision Engine. Predictions
come from the fitted models (the framework's view); *actual* execution
latencies, billed costs, and container warm/cold outcomes come from the AWS
digital twin (the provider's ground truth), including:

- a ground-truth container pool per configuration with stochastic per-container
  idle lifetimes — so the Predictor's CIL can mispredict warm/cold starts,
  which is one of the paper's reported metrics;
- a single-slot FIFO edge executor (Greengrass long-lived function model):
  actual queueing delays emerge from actual compute times, while the Decision
  Engine only sees *predicted* queue state.

The Decision Engine is non-blocking (paper Sec. III-A): placement happens at
ingestion time; execution proceeds asynchronously.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.apps import AWSTwin
from repro.core.decision import DecisionEngine
from repro.core.pricing import LambdaPricing
from repro.core.workload import TaskInput


@dataclass
class GTContainer:
    busy_until: float
    last_completion: float
    expires_at: float  # actual reclamation time, sampled per idle period


class GroundTruthCloud:
    """The provider's actual container state (what AWS really does)."""

    def __init__(self, twin: AWSTwin, seed: int = 0):
        self.twin = twin
        self.rng = np.random.default_rng(seed)
        self.pools: dict[str, list[GTContainer]] = {}

    def probe(self, config: str, trigger_time: float) -> bool:
        """Would a function triggered now cold-start? (No mutation.)"""
        pool = self.pools.get(config, [])
        idle = [c for c in pool if c.busy_until <= trigger_time and trigger_time <= c.expires_at]
        return len(idle) == 0

    def commit(self, config: str, trigger_time: float, busy_ms: float) -> bool:
        """Trigger a function occupying a container for ``busy_ms``.
        Returns True if this was an actual cold start."""
        pool = self.pools.setdefault(config, [])
        # reap actually-expired idle containers
        pool[:] = [c for c in pool if c.busy_until > trigger_time or trigger_time <= c.expires_at]
        idle = [c for c in pool if c.busy_until <= trigger_time and trigger_time <= c.expires_at]
        completion = trigger_time + busy_ms
        expiry = completion + self.twin.t_idl_ms(self.rng)
        if idle:
            c = max(idle, key=lambda c: c.last_completion)
            c.busy_until = completion
            c.last_completion = completion
            c.expires_at = expiry
            return False
        pool.append(GTContainer(busy_until=completion, last_completion=completion,
                                expires_at=expiry))
        return True


@dataclass
class TaskRecord:
    task: TaskInput
    target: str
    predicted_latency_ms: float
    predicted_cost: float
    actual_latency_ms: float
    actual_cost: float
    predicted_cold: bool
    actual_cold: bool
    allowed_cost: float
    feasible: bool
    completion_ms: float
    hedged: bool = False

    @property
    def warm_cold_mismatch(self) -> bool:
        return self.target != "edge" and self.predicted_cold != self.actual_cold


@dataclass
class SimulationResult:
    records: list[TaskRecord]
    deadline_ms: float | None = None
    c_max: float | None = None

    # ------------------------------------------------------------- totals
    @property
    def n(self) -> int:
        return len(self.records)

    @property
    def total_actual_cost(self) -> float:
        return sum(r.actual_cost for r in self.records)

    @property
    def total_predicted_cost(self) -> float:
        return sum(r.predicted_cost for r in self.records)

    @property
    def cost_error_pct(self) -> float:
        a = self.total_actual_cost
        return abs(self.total_predicted_cost - a) / max(a, 1e-12) * 100.0

    @property
    def avg_actual_latency_ms(self) -> float:
        return float(np.mean([r.actual_latency_ms for r in self.records]))

    @property
    def avg_predicted_latency_ms(self) -> float:
        return float(np.mean([r.predicted_latency_ms for r in self.records]))

    @property
    def latency_error_pct(self) -> float:
        a = self.avg_actual_latency_ms
        return abs(self.avg_predicted_latency_ms - a) / max(a, 1e-9) * 100.0

    @property
    def p95_actual_latency_ms(self) -> float:
        return float(np.percentile([r.actual_latency_ms for r in self.records], 95))

    @property
    def p99_actual_latency_ms(self) -> float:
        return float(np.percentile([r.actual_latency_ms for r in self.records], 99))

    # ------------------------------------------------- deadline (min-cost)
    @property
    def pct_deadline_violated(self) -> float:
        if self.deadline_ms is None:
            return 0.0
        v = [r for r in self.records if r.actual_latency_ms > self.deadline_ms]
        return len(v) / max(self.n, 1) * 100.0

    @property
    def avg_violation_ms(self) -> float:
        if self.deadline_ms is None:
            return 0.0
        v = [r.actual_latency_ms - self.deadline_ms for r in self.records
             if r.actual_latency_ms > self.deadline_ms]
        return float(np.mean(v)) if v else 0.0

    # ---------------------------------------------------- budget (min-lat)
    @property
    def pct_cost_violated(self) -> float:
        v = [r for r in self.records
             if np.isfinite(r.allowed_cost) and r.actual_cost > r.allowed_cost + 1e-15]
        return len(v) / max(self.n, 1) * 100.0

    @property
    def pct_budget_used(self) -> float:
        if self.c_max is None:
            return 0.0
        return self.total_actual_cost / max(self.c_max * self.n, 1e-12) * 100.0

    @property
    def n_warm_cold_mismatches(self) -> int:
        return sum(1 for r in self.records if r.warm_cold_mismatch)

    @property
    def n_edge(self) -> int:
        return sum(1 for r in self.records if r.target == "edge")

    def configs_used(self) -> set[str]:
        return {r.target for r in self.records}


class Simulation:
    """Drives one workload through the Decision Engine against the twin."""

    def __init__(self, twin: AWSTwin, engine: DecisionEngine, seed: int = 0,
                 pricing: LambdaPricing | None = None):
        self.twin = twin
        self.engine = engine
        self.pricing = pricing or LambdaPricing()
        self.gt_cloud = GroundTruthCloud(twin, seed=seed)
        self.rng = np.random.default_rng(seed + 7)
        # edge executor state (single-slot FIFO)
        self.edge_free_at_actual = 0.0
        self.edge_free_at_predicted = 0.0

    def run(self, tasks: list[TaskInput]) -> SimulationResult:
        records = [self._process(t) for t in tasks]
        policy = self.engine.policy
        deadline = getattr(policy, "deadline_ms", None)
        c_max = getattr(policy, "c_max", None)
        if c_max is None:
            c_max = getattr(getattr(policy, "inner", None), "c_max", None)
        return SimulationResult(records=records, deadline_ms=deadline, c_max=c_max)

    # ------------------------------------------------------------------
    def _process(self, task: TaskInput) -> TaskRecord:
        now = task.arrival_ms
        pred_wait = max(self.edge_free_at_predicted - now, 0.0)
        decision = self.engine.place(task, now, edge_queue_wait_ms=pred_wait)
        hedge = getattr(self.engine.policy, "last_hedge", None)

        if decision.target == "edge":
            rec = self._execute_edge(task, decision.prediction, decision, now)
        else:
            rec = self._execute_cloud(task, decision.prediction, decision, now, decision.target)

        # Hedged duplicate (beyond-paper): first completion wins, both billed.
        if hedge is not None and decision.target != hedge[0]:
            backup_name, backup_pred = hedge
            if backup_name == "edge":
                dup = self._execute_edge(task, backup_pred, decision, now)
            else:
                dup = self._execute_cloud(task, backup_pred, decision, now, backup_name)
            rec = TaskRecord(
                task=task, target=rec.target,
                predicted_latency_ms=min(rec.predicted_latency_ms, backup_pred.latency_ms),
                predicted_cost=rec.predicted_cost + backup_pred.cost,
                actual_latency_ms=min(rec.actual_latency_ms, dup.actual_latency_ms),
                actual_cost=rec.actual_cost + dup.actual_cost,
                predicted_cold=rec.predicted_cold, actual_cold=rec.actual_cold,
                allowed_cost=rec.allowed_cost, feasible=rec.feasible,
                completion_ms=min(rec.completion_ms, dup.completion_ms), hedged=True,
            )
        return rec

    def _execute_cloud(self, task, pred, decision, now, config) -> TaskRecord:
        twin, rng = self.twin, self.rng
        upld = twin.upld_ms(task.bytes, rng)
        trigger = now + upld
        cold = self.gt_cloud.probe(config, trigger)
        start = twin.start_ms(cold, rng)
        comp = twin.comp_cloud_ms(task.size, float(config), rng)
        self.gt_cloud.commit(config, trigger, start + comp)
        store = twin.store_cloud_ms(rng)
        latency = upld + start + comp + store
        cost = self.pricing.cost(comp, float(config))
        return TaskRecord(
            task=task, target=config,
            predicted_latency_ms=pred.latency_ms, predicted_cost=pred.cost,
            actual_latency_ms=latency, actual_cost=cost,
            predicted_cold=pred.cold, actual_cold=cold,
            allowed_cost=decision.allowed_cost, feasible=decision.feasible,
            completion_ms=now + latency,
        )

    def _execute_edge(self, task, pred, decision, now) -> TaskRecord:
        twin, rng = self.twin, self.rng
        comp = twin.comp_edge_ms(task.size, rng)
        start_exec = max(self.edge_free_at_actual, now)
        self.edge_free_at_actual = start_exec + comp
        # advance the *predicted* queue horizon with the predicted comp time
        pred_comp = pred.components.get("comp", comp)
        self.edge_free_at_predicted = max(self.edge_free_at_predicted, now) + pred_comp
        iot = twin.iotup_ms(rng)
        store = twin.store_edge_ms(rng)
        latency = (start_exec - now) + comp + iot + store
        return TaskRecord(
            task=task, target="edge",
            predicted_latency_ms=pred.latency_ms, predicted_cost=pred.cost,
            actual_latency_ms=latency, actual_cost=0.0,
            predicted_cold=False, actual_cold=False,
            allowed_cost=decision.allowed_cost, feasible=decision.feasible,
            completion_ms=now + latency,
        )
