"""Deprecated alias: the simulator IS ``PlacementRuntime`` over ``TwinBackend``.

Kept only so pre-runtime call sites (``Simulation(twin, engine, seed).run(...)``)
keep working; it carries no bookkeeping of its own. New code:

    runtime = PlacementRuntime(engine, TwinBackend(twin, seed=seed))
    result = runtime.serve(tasks)          # or runtime.serve_async(tasks)

``TaskRecord``/``SimulationResult`` live in ``repro.core.records`` and
``GroundTruthCloud`` in ``repro.core.runtime``; both are re-exported here for
backward compatibility.
"""

from __future__ import annotations

import warnings

from repro.core.apps import AWSTwin
from repro.core.decision import DecisionEngine
from repro.core.pricing import LambdaPricing
from repro.core.records import RecordBatch, SimulationResult, TaskRecord  # noqa: F401
from repro.core.runtime import (  # noqa: F401 — re-exports
    GTContainer,
    GroundTruthCloud,
    PlacementRuntime,
    TwinBackend,
)

__all__ = [
    "GTContainer",
    "GroundTruthCloud",
    "RecordBatch",
    "Simulation",
    "SimulationResult",
    "TaskRecord",
]


class Simulation(PlacementRuntime):
    """Deprecated alias of ``PlacementRuntime(engine, TwinBackend(twin))``."""

    def __init__(self, twin: AWSTwin, engine: DecisionEngine, seed: int = 0,
                 pricing: LambdaPricing | None = None):
        warnings.warn(
            "repro.core.simulator.Simulation is deprecated; use "
            "PlacementRuntime(engine, TwinBackend(twin, seed=seed))",
            DeprecationWarning, stacklevel=2)
        super().__init__(engine, TwinBackend(
            twin, seed=seed, pricing=pricing, edge_name=engine.edge_name,
            edge_names=engine.edge_names or None))

    run = PlacementRuntime.serve
    # pre-runtime attribute spellings, all views of the backend
    twin = property(lambda self: self.backend.twin)
    gt_cloud = property(lambda self: self.backend.gt_cloud)
    pricing = property(lambda self: self.backend.pricing)
    runtime = property(lambda self: self)
