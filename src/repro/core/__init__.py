"""The paper's primary contribution: dynamic task placement for edge-cloud serverless.

Components (paper section in parens):

- ``perf_models``  — linear/ridge regression, (quantized-)normal component models (IV-A/B)
- ``gbrt``         — gradient-boosted regression trees, pure JAX/numpy (IV-A compute model)
- ``pricing``      — AWS Lambda / edge / TPU-slice cost models, scalar + vectorized (II-A)
- ``cil``          — Container Information List: warm/cold shadow state (V-A)
- ``predictor``    — Predictor: end-to-end latency+cost prediction per config, per task
                     (``predict``) or vectorized over a whole batch
                     (``predict_batch``/``predict_at``) (V-A)
- ``decision``     — the formal ``Policy`` protocol (``constraints()``/``choose``/
                     ``hedge``/``observe``) and the Decision Engine:
                     min-cost-s.t.-deadline & min-latency-s.t.-cost, per task
                     (``place``) or batched (``place_many``) (III-B, Alg. 1)
- ``workload``     — Poisson/bursty arrival generators, as task lists or
                     streaming columnar ``TaskChunk``s (II-B)
- ``apps``         — AWS digital twin for the paper's IR / FD / STT applications (II-B, IV-C)
- ``records``      — per-task TaskRecord + aggregate SimulationResult metrics (VI)
- ``events``       — the event scheduler behind the async serve path: min-heap of
                     arrival/dispatch/completion events on the virtual clock +
                     the single-slot FIFO worker state machine
- ``faults``       — deterministic chaos twin: declarative, seeded ``FaultSpec``
                     (outages, transient errors, cold-start spikes, stragglers,
                     network blackouts) + the failure policies (retry/failover,
                     circuit breaker, SLO-tiered admission control)
- ``overload``     — overload survival: predictive container pre-warming
                     (streaming burst forecaster + keep-alive spawns ahead of
                     predicted bursts) and fair-share tier reclamation
                     (preempt/downgrade placed lower-tier work under top-tier
                     pressure)
- ``runtime``      — the unified serve loop: ``PlacementRuntime`` over pluggable
                     ``ExecutionBackend``s (``TwinBackend`` here,
                     ``repro.serving.placement.LiveBackend`` live), with the
                     synchronous ``serve``, the event-driven ``serve_async``,
                     and the constant-memory chunked ``serve_stream`` drivers
                     (VI-A/B)
- ``multiapp``     — cross-application sharded serving: N independent app
                     streams (``AppShard``) in parallel workers
- ``simulator``    — deprecated alias kept for backward compatibility
"""

from repro.core.pricing import LambdaPricing, EdgePricing, SlicePricing
from repro.core.perf_models import RidgeModel, NormalModel, ScaledModel, fit_ridge
from repro.core.gbrt import GBRT, GBRTConfig
from repro.core.cil import ContainerInfoList, ContainerRecord
from repro.core.predictor import EdgeFleet, Predictor, Prediction, PredictionBatch
from repro.core.decision import (
    DecisionBatch,
    DecisionEngine,
    EdgeBalancer,
    HedgedPolicy,
    LeastPredictedWaitBalancer,
    MinCostPolicy,
    MinLatencyPolicy,
    PlacementDecision,
    Policy,
    PolicyConstraints,
    PredictedEdgeQueue,
    RandomBalancer,
    RoundRobinBalancer,
)
from repro.core.workload import (
    BurstyWorkload,
    PoissonWorkload,
    TaskChunk,
    TaskInput,
    task_arrays,
)
from repro.core.records import (
    DeviceSummary,
    RecordArena,
    RecordBatch,
    SimulationResult,
    TaskRecord,
)
from repro.core.multiapp import (
    AppShard,
    ShardedResult,
    ShardedRuntime,
    serve_sharded,
)
from repro.core.faults import (
    AdmissionPolicy,
    Blackout,
    CircuitBreaker,
    ColdSpike,
    FaultError,
    FaultSpec,
    OutageWindow,
    RetryPolicy,
    SLOTier,
    Straggler,
    TargetHealth,
    TransientErrors,
)
from repro.core.overload import (
    BurstForecaster,
    OverloadManager,
    PrewarmPolicy,
    ReclamationPolicy,
    select_victims,
)
from repro.core.recurrence import fifo_starts
from repro.core.events import Event, EventHeap, SingleSlotWorker
from repro.core.runtime import (
    ExecutionBackend,
    ExecutionBatch,
    ExecutionOutcome,
    GroundTruthCloud,
    PlacementRuntime,
    TwinBackend,
)
from repro.core.simulator import Simulation

__all__ = [
    "LambdaPricing",
    "EdgePricing",
    "SlicePricing",
    "RidgeModel",
    "NormalModel",
    "ScaledModel",
    "fit_ridge",
    "EdgeFleet",
    "EdgeBalancer",
    "LeastPredictedWaitBalancer",
    "RoundRobinBalancer",
    "RandomBalancer",
    "BurstyWorkload",
    "DeviceSummary",
    "GBRT",
    "GBRTConfig",
    "ContainerInfoList",
    "ContainerRecord",
    "Predictor",
    "Prediction",
    "PredictionBatch",
    "DecisionBatch",
    "DecisionEngine",
    "HedgedPolicy",
    "MinCostPolicy",
    "MinLatencyPolicy",
    "PlacementDecision",
    "Policy",
    "PolicyConstraints",
    "PredictedEdgeQueue",
    "AdmissionPolicy",
    "Blackout",
    "CircuitBreaker",
    "ColdSpike",
    "FaultError",
    "FaultSpec",
    "OutageWindow",
    "RetryPolicy",
    "SLOTier",
    "Straggler",
    "TargetHealth",
    "TransientErrors",
    "BurstForecaster",
    "OverloadManager",
    "PrewarmPolicy",
    "ReclamationPolicy",
    "select_victims",
    "PoissonWorkload",
    "TaskChunk",
    "TaskInput",
    "task_arrays",
    "RecordArena",
    "RecordBatch",
    "SimulationResult",
    "TaskRecord",
    "AppShard",
    "ShardedResult",
    "ShardedRuntime",
    "serve_sharded",
    "Event",
    "EventHeap",
    "SingleSlotWorker",
    "ExecutionBackend",
    "ExecutionBatch",
    "fifo_starts",
    "ExecutionOutcome",
    "GroundTruthCloud",
    "PlacementRuntime",
    "TwinBackend",
    "Simulation",
]
