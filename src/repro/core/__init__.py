"""The paper's primary contribution: dynamic task placement for edge-cloud serverless.

Components (paper section in parens):

- ``perf_models``  — linear/ridge regression, (quantized-)normal component models (IV-A/B)
- ``gbrt``         — gradient-boosted regression trees, pure JAX/numpy (IV-A compute model)
- ``pricing``      — AWS Lambda / edge / TPU-slice cost models (II-A)
- ``cil``          — Container Information List: warm/cold shadow state (V-A)
- ``predictor``    — Predictor: end-to-end latency+cost prediction per config (V-A)
- ``decision``     — Decision Engine: min-cost-s.t.-deadline & min-latency-s.t.-cost (III-B, Alg. 1)
- ``workload``     — Poisson arrival workload generators (II-B)
- ``apps``         — AWS digital twin for the paper's IR / FD / STT applications (II-B, IV-C)
- ``simulator``    — event-driven simulation of the full framework (VI-A)
"""

from repro.core.pricing import LambdaPricing, EdgePricing, SlicePricing
from repro.core.perf_models import RidgeModel, NormalModel, fit_ridge
from repro.core.gbrt import GBRT, GBRTConfig
from repro.core.cil import ContainerInfoList, ContainerRecord
from repro.core.predictor import Predictor, Prediction
from repro.core.decision import (
    DecisionEngine,
    MinCostPolicy,
    MinLatencyPolicy,
    PlacementDecision,
)
from repro.core.workload import PoissonWorkload, TaskInput
from repro.core.simulator import Simulation, SimulationResult

__all__ = [
    "LambdaPricing",
    "EdgePricing",
    "SlicePricing",
    "RidgeModel",
    "NormalModel",
    "fit_ridge",
    "GBRT",
    "GBRTConfig",
    "ContainerInfoList",
    "ContainerRecord",
    "Predictor",
    "Prediction",
    "DecisionEngine",
    "MinCostPolicy",
    "MinLatencyPolicy",
    "PlacementDecision",
    "PoissonWorkload",
    "TaskInput",
    "Simulation",
    "SimulationResult",
]
