"""AWS digital twin for the paper's three applications (Sec. II-B, IV-C).

We have no AWS/Greengrass/Raspberry-Pi access (the repro hardware gate), so
this module is a *generative stand-in for the measurement environment*: it
produces component-latency samples whose statistics are calibrated to the
paper's published numbers (Table I means; end-to-end magnitudes of Tables
III–V; the CPU∝memory AWS container model saturating at the 1792 MB full-vCPU
point; the lognormal comp-time variance the paper highlights for cloud
pipelines vs. the low-variance edge).

The twin plays two roles, mirroring the paper's methodology exactly:
1. *training data collection* (Sec. IV-C): sampled component measurements used
   to fit the performance models — the models never see the generator's form;
2. *ground truth during simulation* (Sec. VI-A): fresh actual latencies for
   each simulated execution, including actual (stochastic) container
   lifetimes, so warm/cold mispredictions occur naturally.

Applications:
- IR  (image resize, Images-of-Groups-like size distribution, 4 inputs/s)
- FD  (dlib face detection, same inputs, 4 inputs/s)
- STT (pocketsphinx transcription, Tatoeba-like clips, 0.1 inputs/s)
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.workload import PoissonWorkload, TaskInput

# The paper's 19 memory configurations: 640 MB … 2944 MB in 128 MB steps.
MEMORY_CONFIGS_MB: tuple[int, ...] = tuple(range(640, 3008, 128))
assert len(MEMORY_CONFIGS_MB) == 19

# AWS grants CPU proportionally to memory; a full vCPU arrives at 1792 MB.
FULL_VCPU_MB = 1792.0


def cpu_share(memory_mb: float) -> float:
    return min(memory_mb, FULL_VCPU_MB) / FULL_VCPU_MB


@dataclass(frozen=True)
class AppSpec:
    """Ground-truth generative parameters for one application."""

    name: str
    arrival_rate_per_s: float
    # cloud compute: comp = (c0 + c1 * size_scaled) / cpu_share(m) * LN(0, comp_sigma)
    c0_ms: float
    c1_ms: float  # per scaled-size unit (Mpix for IR/FD, ms-audio for STT)
    comp_sigma: float
    # edge compute: comp = (e0 + e1 * size_scaled) * LN(0, edge_sigma)
    e0_ms: float
    e1_ms: float
    edge_sigma: float
    # startup (Table I): warm/cold normal means and stds
    warm_mean: float
    warm_std: float
    cold_mean: float
    cold_std: float
    # storage / iot upload (Table I)
    store_cloud_mean: float
    store_cloud_std: float
    store_edge_mean: float
    store_edge_std: float
    iotup_mean: float  # 0 ⇒ not part of pipeline (IR sends directly to S3)
    iotup_std: float
    # network
    upld_base_ms: float
    upld_ms_per_byte: float
    upld_sigma: float
    size_kind: str = "pixels"  # or "bytes"

    def size_scaled(self, size: float) -> float:
        if self.size_kind == "pixels":
            return size / 1e6  # megapixels
        return size / 32.0 / 1000.0  # bytes -> seconds of 16 kHz 16-bit mono audio


# Calibration notes (see DESIGN.md §2):
#  - warm/cold/store/iotup means match Table I;
#  - FD edge comp ≈ 7.7 s reproduces the paper's edge-only 2404 s queue collapse;
#  - IR edge pipeline ≈ 1.3 s (faster than small-memory cloud, paper Fig. 5a);
#  - STT edge comp ≈ 11 s with 10 s arrivals → edge viable at large δ (Fig. 5c).
IR = AppSpec(
    name="IR", arrival_rate_per_s=4.0,
    c0_ms=24.0, c1_ms=36.0, comp_sigma=0.25,        # high cloud variance (paper Fig. 3)
    e0_ms=180.0, e1_ms=290.0, edge_sigma=0.04,
    warm_mean=162.0, warm_std=25.0, cold_mean=741.0, cold_std=90.0,
    store_cloud_mean=549.0, store_cloud_std=250.0,
    store_edge_mean=579.0, store_edge_std=25.0,
    iotup_mean=0.0, iotup_std=0.0,  # IR sends the thumbnail directly to S3
    upld_base_ms=60.0, upld_ms_per_byte=1.0 / 3125.0, upld_sigma=0.25,
    size_kind="pixels",
)

FD = AppSpec(
    name="FD", arrival_rate_per_s=4.0,
    c0_ms=80.0, c1_ms=280.0, comp_sigma=0.18,
    e0_ms=600.0, e1_ms=3600.0, edge_sigma=0.05,
    warm_mean=163.0, warm_std=25.0, cold_mean=1500.0, cold_std=180.0,
    store_cloud_mean=584.0, store_cloud_std=150.0,
    store_edge_mean=583.0, store_edge_std=25.0,
    iotup_mean=25.0, iotup_std=6.0,
    upld_base_ms=60.0, upld_ms_per_byte=1.0 / 3125.0, upld_sigma=0.15,
    size_kind="pixels",
)

STT = AppSpec(
    name="STT", arrival_rate_per_s=0.1,
    c0_ms=150.0, c1_ms=230.0, comp_sigma=0.20,      # per second of audio
    e0_ms=800.0, e1_ms=2500.0, edge_sigma=0.18,
    warm_mean=145.0, warm_std=25.0, cold_mean=1404.0, cold_std=150.0,
    store_cloud_mean=533.0, store_cloud_std=150.0,
    store_edge_mean=579.0, store_edge_std=25.0,
    iotup_mean=27.0, iotup_std=6.0,
    upld_base_ms=60.0, upld_ms_per_byte=1.0 / 3125.0, upld_sigma=0.15,
    size_kind="bytes",
)

APPS: dict[str, AppSpec] = {"IR": IR, "FD": FD, "STT": STT}

# Actual (stochastic) container lifetime in the provider: N(27 min, 2 min).
T_IDL_ACTUAL_MEAN_MS = 27.0 * 60e3
T_IDL_ACTUAL_STD_MS = 2.0 * 60e3


@dataclass
class AWSTwin:
    """Generative ground truth for one application across all configurations."""

    spec: AppSpec
    seed: int = 0
    rng: np.random.Generator = field(init=False)

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)

    # ------------------------------------------------------------- inputs
    def sample_input(self, rng: np.random.Generator) -> tuple[float, float]:
        """Returns (size_feature, payload_bytes)."""
        if self.spec.size_kind == "pixels":
            # Images-of-Groups-like: Flickr photos at standard resolutions
            # (~1.9–2.9 Mpix), JPEG ~0.35 B/px
            pixels = rng.uniform(1.9e6, 2.9e6)
            return float(pixels), float(pixels * 0.35)
        # Tatoeba-like clips: lognormal duration ~3.5 s, 16 kHz 16-bit mono WAV
        dur_s = float(np.clip(rng.lognormal(np.log(3.5), 0.45), 1.0, 12.0))
        nbytes = dur_s * 32_000.0
        return float(nbytes), float(nbytes)

    def sample_input_batch(self, rng: np.random.Generator,
                           n: int) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized ``sample_input``: ``n`` inputs as one block draw.

        Consumes the Generator stream exactly like ``n`` sequential
        ``sample_input`` calls (one uniform / one lognormal per input — numpy
        Generators produce the same values drawn singly or as a block), so
        streaming workload generators built on it are bit-identical to the
        per-task loop. This is what makes 10M-task workloads generable in
        seconds instead of minutes.
        """
        if self.spec.size_kind == "pixels":
            pixels = rng.uniform(1.9e6, 2.9e6, size=n)
            return pixels, pixels * 0.35
        dur_s = np.clip(rng.lognormal(np.log(3.5), 0.45, size=n), 1.0, 12.0)
        nbytes = dur_s * 32_000.0
        return nbytes, nbytes.copy()

    def workload(self, n: int, seed: int = 0) -> list[TaskInput]:
        return self.poisson(seed).generate(n)

    def poisson(self, seed: int = 0) -> PoissonWorkload:
        """The app's Poisson workload source (list via ``generate``, streaming
        ``TaskChunk``s via ``chunks`` — both bit-identical task streams)."""
        return PoissonWorkload(
            rate_per_s=self.spec.arrival_rate_per_s,
            size_sampler=self.sample_input,
            size_sampler_batch=self.sample_input_batch,
            seed=seed,
        )

    # ----------------------------------------------------- actual latencies
    def upld_ms(self, nbytes: float, rng=None) -> float:
        rng = rng or self.rng
        base = self.spec.upld_base_ms + nbytes * self.spec.upld_ms_per_byte
        return float(base * rng.lognormal(0.0, self.spec.upld_sigma))

    def start_ms(self, cold: bool, rng=None) -> float:
        rng = rng or self.rng
        if cold:
            return float(max(rng.normal(self.spec.cold_mean, self.spec.cold_std), 1.0))
        return float(max(rng.normal(self.spec.warm_mean, self.spec.warm_std), 1.0))

    def comp_cloud_ms(self, size: float, memory_mb: float, rng=None) -> float:
        rng = rng or self.rng
        s = self.spec.size_scaled(size)
        base = (self.spec.c0_ms + self.spec.c1_ms * s) / cpu_share(memory_mb)
        return float(base * rng.lognormal(0.0, self.spec.comp_sigma))

    def store_cloud_ms(self, rng=None) -> float:
        rng = rng or self.rng
        return float(max(rng.normal(self.spec.store_cloud_mean, self.spec.store_cloud_std), 1.0))

    def comp_edge_ms(self, size: float, rng=None) -> float:
        rng = rng or self.rng
        s = self.spec.size_scaled(size)
        base = self.spec.e0_ms + self.spec.e1_ms * s
        return float(base * rng.lognormal(0.0, self.spec.edge_sigma))

    def iotup_ms(self, rng=None) -> float:
        if self.spec.iotup_mean <= 0:
            return 0.0
        rng = rng or self.rng
        return float(max(rng.normal(self.spec.iotup_mean, self.spec.iotup_std), 0.0))

    def store_edge_ms(self, rng=None) -> float:
        rng = rng or self.rng
        return float(max(rng.normal(self.spec.store_edge_mean, self.spec.store_edge_std), 1.0))

    def t_idl_ms(self, rng=None) -> float:
        rng = rng or self.rng
        return float(max(rng.normal(T_IDL_ACTUAL_MEAN_MS, T_IDL_ACTUAL_STD_MS), 5 * 60e3))


@dataclass
class Measurements:
    """Training measurements collected by running the pipelines (Sec. IV-C)."""

    # cloud (warm-start collection runs)
    sizes: np.ndarray
    nbytes: np.ndarray
    memory: np.ndarray
    upld: np.ndarray
    comp: np.ndarray
    store: np.ndarray
    start_warm: np.ndarray
    start_cold: np.ndarray
    # edge
    edge_sizes: np.ndarray
    edge_comp: np.ndarray
    iotup: np.ndarray
    edge_store: np.ndarray


def collect_measurements(
    twin: AWSTwin,
    n_inputs: int | None = None,
    configs: tuple[int, ...] = MEMORY_CONFIGS_MB,
    n_cold: int = 100,
    seed: int = 1,
) -> Measurements:
    """Reproduce the paper's data collection (1400 images / 3400 clips; 100 cold
    starts per config; warm-start pipeline runs for every (input, config))."""
    if n_inputs is None:
        n_inputs = 3400 if twin.spec.name == "STT" else 1400
    rng = np.random.default_rng(seed)
    inputs = [twin.sample_input(rng) for _ in range(n_inputs)]

    sizes, nbytes_l, memory, upld, comp, store = [], [], [], [], [], []
    for size, nb in inputs:
        for m in configs:
            sizes.append(size)
            nbytes_l.append(nb)
            memory.append(float(m))
            upld.append(twin.upld_ms(nb, rng))
            comp.append(twin.comp_cloud_ms(size, m, rng))
            store.append(twin.store_cloud_ms(rng))
    start_warm = np.array([twin.start_ms(False, rng) for _ in range(n_inputs)])
    start_cold = np.array([twin.start_ms(True, rng) for _ in range(n_cold * len(configs))])

    edge_sizes = np.array([s for s, _ in inputs])
    edge_comp = np.array([twin.comp_edge_ms(s, rng) for s, _ in inputs])
    iotup = np.array([twin.iotup_ms(rng) for _ in range(n_inputs)])
    edge_store = np.array([twin.store_edge_ms(rng) for _ in range(n_inputs)])

    return Measurements(
        sizes=np.array(sizes), nbytes=np.array(nbytes_l), memory=np.array(memory),
        upld=np.array(upld), comp=np.array(comp), store=np.array(store),
        start_warm=start_warm, start_cold=start_cold,
        edge_sizes=edge_sizes, edge_comp=edge_comp, iotup=iotup, edge_store=edge_store,
    )
