"""The event scheduler behind the event-driven serving runtime.

``PlacementRuntime.serve_async`` and the backends' concurrent drivers share
one discrete-event core: a min-heap of (arrival | dispatch | completion)
events on the *virtual* arrival clock. The heap's ordering contract is what
makes the async serve path deterministic — and therefore testable against the
batched columnar serve:

- events pop in nondecreasing ``time_ms``;
- at equal times, **completions pop before dispatches, dispatches before
  arrivals** (``COMPLETION < DISPATCH < ARRIVAL``). A slot freed at ``t`` is
  visible to a task arriving at ``t`` — exactly the ``start = max(free, now)``
  convention of the FIFO recurrences (``repro.core.recurrence.fifo_starts``),
  so a task never waits on a completion that happens "at the same instant";
- within the same ``(time_ms, kind)``, events pop in push (FIFO) order — the
  ``seq`` counter breaks every remaining tie, so heap order is total and no
  comparison ever falls through to payload objects.

``SingleSlotWorker`` is the one-executor state machine the virtual-clock
drivers build per edge device: tasks enter a FIFO queue on arrival, occupy
the slot for their compute time, and free it at ``start + busy`` — the
event-driven form of the same recurrence ``fifo_starts`` evaluates as segment
cumsums. Both express ``start_j = max(free, now_j); free = start_j + busy_j``,
which is what lets ``TwinBackend.execute_async`` stay bit-identical to the
batched ``execute_many`` while genuinely interleaving per-target workers on
the heap.
"""

from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Iterator

# Tie priority at equal virtual times: a completion frees capacity that a
# simultaneous dispatch/arrival is allowed to use (never the reverse).
# Preemptions (fair-share reclamation revising placements) order after
# arrivals: a victim is only re-placed once everything arriving at the same
# instant has been seen, so the reclaim schedule is a pure function of the
# arrival prefix.
COMPLETION = 0
DISPATCH = 1
ARRIVAL = 2
PREEMPT = 3

KIND_NAMES = {COMPLETION: "completion", DISPATCH: "dispatch",
              ARRIVAL: "arrival", PREEMPT: "preempt"}


@dataclass(frozen=True)
class Event:
    """One scheduled event: ``(time_ms, kind, seq)`` is its total order."""

    time_ms: float
    kind: int          # COMPLETION | DISPATCH | ARRIVAL
    seq: int           # push order — the final, always-distinct tie-break
    payload: Any = None

    @property
    def key(self) -> tuple[float, int, int]:
        return (self.time_ms, self.kind, self.seq)


class EventHeap:
    """Min-heap of ``Event``s with the deterministic ordering contract above."""

    def __init__(self) -> None:
        self._heap: list[tuple[float, int, int, Event]] = []
        self._seq = 0

    def push(self, time_ms: float, kind: int, payload: Any = None) -> Event:
        if kind not in KIND_NAMES:
            raise ValueError(f"unknown event kind {kind!r}")
        ev = Event(time_ms=float(time_ms), kind=kind, seq=self._seq,
                   payload=payload)
        self._seq += 1
        heapq.heappush(self._heap, (ev.time_ms, ev.kind, ev.seq, ev))
        return ev

    def pop(self) -> Event:
        return heapq.heappop(self._heap)[3]

    def peek(self) -> Event:
        return self._heap[0][3]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def drain(self) -> Iterator[Event]:
        """Pop until empty. Events pushed while draining are drained too."""
        while self._heap:
            yield self.pop()


@dataclass
class SingleSlotWorker:
    """One single-slot FIFO executor driven by heap events.

    The virtual-clock equivalent of one edge device: ``arrive`` queues a task
    (and starts it if the slot is free), ``complete`` frees the slot and
    starts the next queued task. Start times follow ``start = max(free, now)``
    — bit-identical to ``repro.core.recurrence.fifo_starts`` over the same
    (arrival, busy) sequence, which the parity tests assert.
    """

    free_at: float = 0.0
    queue: deque = field(default_factory=deque)
    in_flight: Any = None

    def arrive(self, now: float, item: Any) -> tuple[float, Any] | None:
        """A task arrives. Returns ``(start_ms, item)`` if it starts now
        (i.e. the slot is free), else ``None`` (queued behind the backlog)."""
        if self.in_flight is None:
            self.in_flight = item
            return (max(self.free_at, now), item)
        self.queue.append(item)
        return None

    def complete(self, free_ms: float) -> tuple[float, Any] | None:
        """The running task frees the slot at ``free_ms``. Returns
        ``(start_ms, item)`` for the next queued task, if any."""
        self.free_at = free_ms
        self.in_flight = None
        if self.queue:
            item = self.queue.popleft()
            self.in_flight = item
            return (free_ms, item)
        return None
