"""Pricing models for execution cost.

The paper uses the AWS Lambda pricing model: billed duration is the function
execution time rounded up to the nearest 100 ms, priced proportionally to the
container memory. The paper's text quotes ``$1.667e-6 per GB-s`` but the C_max
values in Tables IV/V are only consistent with the actual AWS rate of
``$1.66667e-5 per GB-s`` (e.g. FD at 1536 MB with ~1.2 s billed ≈ 2.9e-5 $ ≈
the paper's C_max = 2.97e-5). We therefore use the real AWS rate and note the
paper's typo in DESIGN.md.

Edge executions are free under the paper's amortization argument (fixed yearly
Greengrass registration fee, zero marginal cost per execution).

For the TPU-fleet adaptation, ``SlicePricing`` bills slice-seconds at a
$/chip-hour rate with a per-second billing quantum.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

# Real AWS Lambda rate (the paper's table values are consistent with this, not
# with the 1.667e-6 typo in the text).
AWS_GB_SECOND_RATE = 1.66667e-5
AWS_REQUEST_RATE = 0.20 / 1_000_000  # $0.20 per 1M requests
AWS_BILLING_QUANTUM_MS = 100.0


@dataclass(frozen=True)
class LambdaPricing:
    """AWS Lambda execution pricing (the paper's cost model)."""

    gb_second_rate: float = AWS_GB_SECOND_RATE
    request_rate: float = AWS_REQUEST_RATE
    quantum_ms: float = AWS_BILLING_QUANTUM_MS
    include_request_charge: bool = False  # paper studies execution cost only

    def billed_ms(self, comp_ms: float) -> float:
        """Round execution time to nearest ms, then up to the billing quantum."""
        ms = round(float(comp_ms))
        if ms <= 0:
            ms = 1
        return math.ceil(ms / self.quantum_ms) * self.quantum_ms

    def cost(self, comp_ms: float, memory_mb: float) -> float:
        """Execution cost in $ for ``comp_ms`` of compute in an ``memory_mb`` container."""
        gb = memory_mb / 1024.0
        c = (self.billed_ms(comp_ms) / 1000.0) * gb * self.gb_second_rate
        if self.include_request_charge:
            c += self.request_rate
        return c

    def billed_ms_batch(self, comp_ms: np.ndarray) -> np.ndarray:
        """Vectorized ``billed_ms`` (np.round matches round(): half-to-even)."""
        ms = np.maximum(np.round(np.asarray(comp_ms, dtype=np.float64)), 1.0)
        return np.ceil(ms / self.quantum_ms) * self.quantum_ms

    def cost_batch(self, comp_ms: np.ndarray, memory_mb: float) -> np.ndarray:
        """Vectorized ``cost`` over an array of compute times."""
        gb = memory_mb / 1024.0
        c = (self.billed_ms_batch(comp_ms) / 1000.0) * gb * self.gb_second_rate
        if self.include_request_charge:
            c = c + self.request_rate
        return c


@dataclass(frozen=True)
class EdgePricing:
    """Edge executions have zero amortized marginal cost (paper Sec. II-A.2b)."""

    def cost(self, comp_ms: float) -> float:  # noqa: ARG002 - interface parity
        return 0.0

    def cost_batch(self, comp_ms: np.ndarray) -> np.ndarray:
        return np.zeros(np.asarray(comp_ms).shape[0], dtype=np.float64)


@dataclass(frozen=True)
class SlicePricing:
    """TPU-fleet adaptation: $/chip-hour, billed per second, per slice dispatch.

    ``chips`` is the slice size; billing covers the task's occupancy of the
    slice (comp time only — provisioning is amortized like the paper amortizes
    container lifetime).
    """

    chip_hour_rate: float = 1.20  # $/chip-hour (v5e on-demand ballpark)
    quantum_s: float = 1.0

    def cost(self, comp_ms: float, chips: int) -> float:
        seconds = math.ceil(max(comp_ms, 1.0) / 1000.0 / self.quantum_s) * self.quantum_s
        return seconds * chips * self.chip_hour_rate / 3600.0

    def cost_batch(self, comp_ms: np.ndarray, chips: int) -> np.ndarray:
        ms = np.maximum(np.asarray(comp_ms, dtype=np.float64), 1.0)
        seconds = np.ceil(ms / 1000.0 / self.quantum_s) * self.quantum_s
        return seconds * chips * self.chip_hour_rate / 3600.0
