"""Deterministic fault injection for the twin, plus failure-policy config.

``FaultSpec`` is a declarative, seeded description of what goes wrong in a
run: per-target crash/outage windows, per-cloud-config transient dispatch
errors with probability ``p``, cold-start multiplier spikes, straggler
slowdown windows, and network-leg blackouts. ``TwinBackend`` consults it on
every dispatch — but NEVER through the ground-truth RNG streams:

- window faults (outages, spikes, stragglers, blackouts) are pure functions
  of the dispatch time, so they are deterministic and identical no matter
  which serve path replays them;
- probabilistic faults (transient errors) draw from a dedicated COUNTER-BASED
  stream: a splitmix64-style hash of ``(fault seed, target, task idx,
  dispatch-time bits)`` mapped to [0, 1). The draw is stateless, so it is
  order-independent — the batched, streaming, and event-driven paths see the
  identical fault schedule by construction — and it can never perturb the
  per-(substrate, leg) ground-truth streams. An empty spec takes exactly the
  existing code path: bit-identical output, zero extra draws.

The module also carries the failure-policy configuration the runtime consumes
(``RetryPolicy``, ``CircuitBreaker``/``TargetHealth``, ``SLOTier``/
``AdmissionPolicy``) so every knob of the failure-aware serve loop lives in
one importable place. Validation raises ``FaultError`` with the offending
entry indexed and named, in the style of ``repro.trace.TraceError``.
"""

from __future__ import annotations

import json
import zlib
from dataclasses import dataclass, fields

import numpy as np

# failure kinds, as they appear in ``ExecutionOutcome.fail_kind`` /
# ``ExecutionBatch.fail_kind`` (0 = the dispatch succeeded)
OK = 0
TRANSIENT = 1   # dispatch error mid-flight: legs ran, result lost, retryable
OUTAGE = 2      # target down at dispatch time: fail-fast, nothing ran
BLACKOUT = 3    # network leg dark: upload fails fast / result upload lost
BREAKER = 4     # circuit open: the runtime failed fast without dispatching

FAIL_NAMES = {OK: "ok", TRANSIENT: "transient", OUTAGE: "outage",
              BLACKOUT: "blackout", BREAKER: "breaker"}

BLACKOUT_LEGS = ("upld", "iot")


class FaultError(ValueError):
    """An invalid ``FaultSpec`` / failure-policy configuration, with the
    offending entry indexed (the ``TraceError`` convention)."""


# ------------------------------------------------------- counter-based stream
_MASK = np.uint64(0xFFFFFFFFFFFFFFFF)
_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)


def _mix64(z: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer (wrapping uint64 arithmetic — the overflow IS
    the hash, so the numpy overflow warning is suppressed)."""
    with np.errstate(over="ignore"):
        z = (z + _GOLDEN) & _MASK
        z = ((z ^ (z >> np.uint64(30))) * _MIX1) & _MASK
        z = ((z ^ (z >> np.uint64(27))) * _MIX2) & _MASK
    return z ^ (z >> np.uint64(31))


def fault_uniform(seed: int, target: str, idx, t_ms) -> np.ndarray:
    """Stateless uniform [0, 1) draw for fault decisions.

    Keyed by ``(seed, crc32(target), task idx, float64 bits of the dispatch
    time)`` — the same per-target keying as the ground-truth streams
    (``edge_stream_key``), but through a counter-based hash instead of a
    sequential Generator, so the value depends only on the key, never on how
    many draws happened before it. A retry of the same task on the same
    target redraws because its dispatch time moved (backoff > 0).
    Vectorized: ``idx``/``t_ms`` may be arrays (broadcast together).
    """
    idx = np.asarray(idx, dtype=np.int64).astype(np.uint64)
    bits = np.asarray(t_ms, dtype=np.float64).view(np.uint64)
    key = np.uint64((seed ^ zlib.crc32(target.encode("utf-8"))) & 0xFFFFFFFF)
    z = _mix64(_mix64(_mix64(key) ^ idx) ^ bits)
    return (z >> np.uint64(11)).astype(np.float64) * (2.0 ** -53)


# ------------------------------------------------------------- fault entries
@dataclass(frozen=True)
class OutageWindow:
    """``target`` is hard-down for dispatches in ``[start_ms, end_ms)``:
    they fail fast (nothing runs, no draws consumed, no queue occupancy)."""

    target: str
    start_ms: float
    end_ms: float


@dataclass(frozen=True)
class TransientErrors:
    """Dispatches to ``target`` fail mid-flight with probability ``p``: every
    attempted leg runs (and bills), the result is lost. Retryable."""

    target: str
    p: float


@dataclass(frozen=True)
class ColdSpike:
    """Cold starts of cloud config ``target`` triggered inside the window are
    ``factor``× slower (a deploy storm / image-pull stampede)."""

    target: str
    start_ms: float
    end_ms: float
    factor: float


@dataclass(frozen=True)
class Straggler:
    """Compute on ``target`` dispatched inside the window runs ``factor``×
    slower (thermal throttling, noisy neighbor)."""

    target: str
    start_ms: float
    end_ms: float
    factor: float


@dataclass(frozen=True)
class Blackout:
    """Network leg ``leg`` is dark in the window: ``"upld"`` fails a cloud
    dispatch fast (payload never leaves), ``"iot"`` loses an edge result
    after compute ran (the executor was still occupied). ``target=None``
    applies to every target using that leg."""

    leg: str
    start_ms: float
    end_ms: float
    target: str | None = None


def _check_window(kind: str, i: int, start_ms: float, end_ms: float) -> None:
    if not np.isfinite(start_ms) or start_ms < 0.0:
        raise FaultError(
            f"{kind}[{i}]: negative or non-finite start_ms {start_ms!r} — "
            f"windows are on the arrival clock, which starts at 0")
    if not end_ms > start_ms:
        raise FaultError(
            f"{kind}[{i}]: empty window — end_ms {end_ms!r} must be > "
            f"start_ms {start_ms!r}")


def _windows_by_target(kind: str, entries) -> dict[str | None, np.ndarray]:
    """Group window entries per target as sorted ``(k, 2)`` float arrays,
    rejecting overlaps within a target (the offending entry indexed)."""
    order: dict[str | None, list[tuple[float, float, int]]] = {}
    for i, w in enumerate(entries):
        _check_window(kind, i, w.start_ms, w.end_ms)
        order.setdefault(w.target, []).append((w.start_ms, w.end_ms, i))
    out: dict[str | None, np.ndarray] = {}
    for tgt, ws in order.items():
        ws.sort()
        for (s0, e0, i0), (s1, _e1, i1) in zip(ws, ws[1:]):
            if s1 < e0:
                raise FaultError(
                    f"{kind}[{i1}]: window [{s1}, ...) for target {tgt!r} "
                    f"overlaps {kind}[{i0}] [{s0}, {e0}) — merge them or "
                    f"make the windows disjoint")
        out[tgt] = np.array([(s, e) for s, e, _ in ws], dtype=np.float64)
    return out


def _in_windows(windows: np.ndarray | None, t_ms) -> np.ndarray:
    """Boolean mask: which times fall inside any ``[start, end)`` window."""
    t = np.asarray(t_ms, dtype=np.float64)
    hit = np.zeros(t.shape, dtype=bool)
    if windows is not None:
        for s, e in windows:
            hit |= (t >= s) & (t < e)
    return hit


@dataclass(frozen=True)
class FaultSpec:
    """The declarative fault schedule for one run. Immutable, validated at
    construction, JSON round-trippable (``to_json``/``from_json``) so a
    fault schedule can be captured alongside a trace and replayed.

    ``seed`` keys the dedicated transient-error hash stream (never the
    ground-truth streams). ``detect_ms`` is the failure-detection latency
    charged to a fail-fast dispatch (outage / upld blackout / lost result).
    """

    seed: int = 0
    outages: tuple[OutageWindow, ...] = ()
    transient: tuple[TransientErrors, ...] = ()
    cold_spikes: tuple[ColdSpike, ...] = ()
    stragglers: tuple[Straggler, ...] = ()
    blackouts: tuple[Blackout, ...] = ()
    detect_ms: float = 5.0

    def __post_init__(self):
        object.__setattr__(self, "outages", tuple(self.outages))
        object.__setattr__(self, "transient", tuple(self.transient))
        object.__setattr__(self, "cold_spikes", tuple(self.cold_spikes))
        object.__setattr__(self, "stragglers", tuple(self.stragglers))
        object.__setattr__(self, "blackouts", tuple(self.blackouts))
        if not np.isfinite(self.detect_ms) or self.detect_ms < 0.0:
            raise FaultError(
                f"detect_ms must be a finite non-negative duration, got "
                f"{self.detect_ms!r}")
        for i, t in enumerate(self.transient):
            if not 0.0 <= t.p <= 1.0:
                raise FaultError(
                    f"transient[{i}]: probability p must be in [0, 1], got "
                    f"{t.p!r} for target {t.target!r}")
        for kind, entries in (("cold_spikes", self.cold_spikes),
                              ("stragglers", self.stragglers)):
            for i, s in enumerate(entries):
                if not np.isfinite(s.factor) or s.factor <= 0.0:
                    raise FaultError(
                        f"{kind}[{i}]: factor must be a positive finite "
                        f"multiplier, got {s.factor!r} for target "
                        f"{s.target!r}")
        for i, b in enumerate(self.blackouts):
            if b.leg not in BLACKOUT_LEGS:
                raise FaultError(
                    f"blackouts[{i}]: unknown network leg {b.leg!r} — "
                    f"expected one of {BLACKOUT_LEGS}")
        # grouped window tables (validated: overlaps rejected with the index)
        object.__setattr__(self, "_outage_w",
                           _windows_by_target("outages", self.outages))
        object.__setattr__(self, "_spike_w",
                           _windows_by_target("cold_spikes", self.cold_spikes))
        object.__setattr__(self, "_strag_w",
                           _windows_by_target("stragglers", self.stragglers))
        bo: dict[str, list[Blackout]] = {}
        for b in self.blackouts:
            bo.setdefault(b.leg, []).append(b)
        object.__setattr__(self, "_blackout_w", {
            leg: _windows_by_target(f"blackouts[leg={leg!r}]", entries)
            for leg, entries in bo.items()})
        object.__setattr__(self, "_transient_p",
                           {t.target: float(t.p) for t in self.transient
                            if t.p > 0.0})

    # ------------------------------------------------------------- queries
    def __bool__(self) -> bool:
        return bool(self.outages or self._transient_p or self.cold_spikes
                    or self.stragglers or self.blackouts)

    def outage_mask(self, target: str, t_ms) -> np.ndarray:
        return _in_windows(self._outage_w.get(target), t_ms)

    def blackout_mask(self, leg: str, target: str, t_ms) -> np.ndarray:
        w = self._blackout_w.get(leg, {})
        return _in_windows(w.get(target), t_ms) | _in_windows(w.get(None), t_ms)

    def transient_p(self, target: str) -> float:
        return self._transient_p.get(target, 0.0)

    def transient_mask(self, target: str, idx, t_ms) -> np.ndarray:
        """Which dispatches of ``target`` fail transiently — the dedicated
        counter-based stream, so the answer is path-independent."""
        p = self.transient_p(target)
        t = np.asarray(t_ms, dtype=np.float64)
        if p <= 0.0:
            return np.zeros(t.shape, dtype=bool)
        return fault_uniform(self.seed, target, idx, t) < p

    def _factor(self, table, target: str, t_ms, entries, attr) -> np.ndarray:
        t = np.asarray(t_ms, dtype=np.float64)
        out = np.ones(t.shape, dtype=np.float64)
        if table.get(target) is not None:
            for e in entries:
                if e.target == target:
                    out = np.where((t >= e.start_ms) & (t < e.end_ms),
                                   out * getattr(e, attr), out)
        return out

    def cold_factor(self, target: str, trigger_ms) -> np.ndarray:
        """Cold-start multiplier per trigger time (1.0 outside spikes)."""
        return self._factor(self._spike_w, target, trigger_ms,
                            self.cold_spikes, "factor")

    def straggler_factor(self, target: str, t_ms) -> np.ndarray:
        """Compute multiplier per dispatch time (1.0 outside windows)."""
        return self._factor(self._strag_w, target, t_ms,
                            self.stragglers, "factor")

    # --------------------------------------------------------------- (de)ser
    def to_json(self) -> str:
        def row(e):
            return {f.name: getattr(e, f.name) for f in fields(e)}
        return json.dumps({
            "version": 1, "seed": self.seed, "detect_ms": self.detect_ms,
            "outages": [row(e) for e in self.outages],
            "transient": [row(e) for e in self.transient],
            "cold_spikes": [row(e) for e in self.cold_spikes],
            "stragglers": [row(e) for e in self.stragglers],
            "blackouts": [row(e) for e in self.blackouts],
        })

    @classmethod
    def from_json(cls, payload: str) -> "FaultSpec":
        d = json.loads(payload)
        v = d.get("version", 1)
        if v != 1:
            raise FaultError(
                f"unsupported fault-spec version {v!r} (this build reads "
                f"version 1) — re-export the spec or upgrade")
        return cls(
            seed=int(d.get("seed", 0)),
            detect_ms=float(d.get("detect_ms", 5.0)),
            outages=tuple(OutageWindow(**e) for e in d.get("outages", [])),
            transient=tuple(TransientErrors(**e) for e in d.get("transient", [])),
            cold_spikes=tuple(ColdSpike(**e) for e in d.get("cold_spikes", [])),
            stragglers=tuple(Straggler(**e) for e in d.get("stragglers", [])),
            blackouts=tuple(Blackout(**e) for e in d.get("blackouts", [])),
        )


# --------------------------------------------------------- failure policies
@dataclass(frozen=True)
class RetryPolicy:
    """How the runtime reacts to failed dispatches.

    A transient failure retries the SAME target after exponential backoff
    (``backoff_ms * backoff_mult**(retry-1)``); an outage/blackout/breaker
    failure (or exhausted same-target retries) fails over to the next-best
    surviving target immediately. ``max_attempts`` bounds total dispatches
    per task (first attempt included); ``timeout_ms`` gives up once the
    failure-detection time exceeds ``arrival + timeout_ms``. The default
    ``timeout_ms=inf`` means a retry-configured runtime over an empty
    ``FaultSpec`` never changes behavior: nothing fails, nothing fires.
    """

    max_attempts: int = 3
    backoff_ms: float = 50.0
    backoff_mult: float = 2.0
    timeout_ms: float = float("inf")
    failover: bool = True

    def __post_init__(self):
        if self.max_attempts < 1:
            raise FaultError(
                f"max_attempts must be >= 1 (the first dispatch counts), "
                f"got {self.max_attempts!r}")
        if not self.backoff_ms >= 0.0 or not np.isfinite(self.backoff_ms):
            raise FaultError(
                f"backoff_ms must be a finite non-negative duration, got "
                f"{self.backoff_ms!r}")
        if not self.backoff_mult >= 1.0 or not np.isfinite(self.backoff_mult):
            raise FaultError(
                f"backoff_mult must be finite and >= 1 (non-shrinking "
                f"backoff), got {self.backoff_mult!r}")
        if not self.timeout_ms > 0.0:
            raise FaultError(
                f"timeout_ms must be a positive duration (inf = no "
                f"timeout), got {self.timeout_ms!r}")

    def backoff_for(self, retry: int) -> float:
        """Backoff before same-target retry number ``retry`` (1-based)."""
        return self.backoff_ms * self.backoff_mult ** (retry - 1)


@dataclass(frozen=True)
class CircuitBreaker:
    """Per-target consecutive-failure circuit breaker configuration.

    After ``threshold`` consecutive failures the circuit opens: the runtime
    fails new dispatches to the target fast (no draws, no occupancy) and
    fails them over. ``probation_ms`` after opening, the circuit goes
    half-open: ONE probe dispatch is admitted — success closes the circuit,
    failure re-opens it for another probation period.
    """

    threshold: int = 3
    probation_ms: float = 30_000.0

    def __post_init__(self):
        if self.threshold < 1:
            raise FaultError(
                f"breaker threshold must be >= 1, got {self.threshold!r}")
        if not self.probation_ms > 0.0:
            raise FaultError(
                f"probation_ms must be a positive duration, got "
                f"{self.probation_ms!r}")


class TargetHealth:
    """Mutable per-target health state driven by a ``CircuitBreaker`` spec.

    Lives on the runtime (like the predicted edge queues) and advances on
    the virtual clock: every dispatch outcome is reported in dispatch order,
    so the open/closed schedule is deterministic and identical across the
    batched / streaming / event-driven paths.
    """

    CLOSED, OPEN, HALF_OPEN = 0, 1, 2

    def __init__(self, breaker: CircuitBreaker):
        self.breaker = breaker
        self.consecutive: dict[str, int] = {}
        self.state: dict[str, int] = {}
        self.opened_at: dict[str, float] = {}
        self.n_opens = 0

    def any_open(self) -> bool:
        """Cheap hot-path gate: is any circuit not CLOSED? (No mutation.)"""
        return any(s != self.CLOSED for s in self.state.values())

    def dirty(self) -> bool:
        """Would success bookkeeping change anything? False when every
        circuit is closed and every consecutive-failure count is zero — the
        batched serve path uses this to skip the per-row success walk on
        all-healthy rounds (the faults-off overhead floor)."""
        return self.any_open() or any(self.consecutive.values())

    def is_open(self, target: str, now: float) -> bool:
        """True when dispatches to ``target`` should fail fast at ``now``.
        A probation-expired circuit transitions to half-open and admits the
        caller as its single probe."""
        st = self.state.get(target, self.CLOSED)
        if st == self.CLOSED:
            return False
        if st == self.OPEN and \
                now >= self.opened_at[target] + self.breaker.probation_ms:
            self.state[target] = self.HALF_OPEN
            return False  # the probe dispatch
        return st == self.OPEN

    def would_fail_fast(self, target: str, now: float) -> bool:
        """Non-mutating ``is_open``: True while the circuit is OPEN and its
        probation window has not expired (an expired circuit would admit the
        caller as its half-open probe, so it does NOT fail fast). Failover
        placement uses this to exclude open targets without burning probes."""
        st = self.state.get(target, self.CLOSED)
        return st == self.OPEN and \
            now < self.opened_at[target] + self.breaker.probation_ms

    def record_failure(self, target: str, now: float) -> None:
        n = self.consecutive.get(target, 0) + 1
        self.consecutive[target] = n
        st = self.state.get(target, self.CLOSED)
        if st == self.HALF_OPEN or \
                (st == self.CLOSED and n >= self.breaker.threshold):
            self.state[target] = self.OPEN
            self.opened_at[target] = now
            self.n_opens += 1

    def record_success(self, target: str) -> None:
        self.consecutive[target] = 0
        self.state[target] = self.CLOSED


@dataclass(frozen=True)
class SLOTier:
    """One SLO class: tasks of this tier should finish within ``deadline_ms``
    of arrival; ``sheddable`` tiers may be dropped under predicted overload
    (the top tier is typically not)."""

    deadline_ms: float
    sheddable: bool = True

    def __post_init__(self):
        if not self.deadline_ms > 0.0:
            raise FaultError(
                f"SLO tier deadline_ms must be a positive duration, got "
                f"{self.deadline_ms!r}")


@dataclass(frozen=True)
class AdmissionPolicy:
    """SLO-tiered admission control: after placement, a task whose PREDICTED
    latency already exceeds its tier's deadline headroom is shed (if its
    tier is sheddable) instead of executed — queues degrade by dropping the
    lowest classes first, not by growing without bound (LaSS-style).

    ``tiers[i]`` is the SLO class of tasks carrying ``tier == i``; tier 0 is
    the highest class. Tasks with a tier index outside the table are treated
    as the last (lowest) tier. ``headroom`` scales the deadline the shed
    test uses (``shed iff predicted > deadline * headroom``): < 1 sheds
    earlier, leaving slack for actual-vs-predicted error.
    """

    tiers: tuple[SLOTier, ...]
    headroom: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "tiers", tuple(self.tiers))
        if not self.tiers:
            raise FaultError(
                "AdmissionPolicy needs at least one SLOTier — an empty tier "
                "table would shed nothing and class nothing")
        if not self.headroom > 0.0:
            raise FaultError(
                f"headroom must be a positive scale factor, got "
                f"{self.headroom!r}")
        for i in range(1, len(self.tiers)):
            if self.tiers[i].deadline_ms >= self.tiers[i - 1].deadline_ms:
                raise FaultError(
                    f"tier deadlines must be strictly decreasing down the "
                    f"table (lower SLO classes carry tighter shed thresholds "
                    f"so they degrade first): tiers[{i}].deadline_ms="
                    f"{self.tiers[i].deadline_ms!r} >= tiers[{i - 1}]."
                    f"deadline_ms={self.tiers[i - 1].deadline_ms!r}")

    def shed_mask(self, tier: np.ndarray,
                  predicted_latency_ms: np.ndarray) -> np.ndarray:
        """Vectorized shed decision per task (True = drop, bill nothing)."""
        t = np.clip(np.asarray(tier, dtype=np.int64), 0, len(self.tiers) - 1)
        deadlines = np.array([s.deadline_ms for s in self.tiers])
        sheddable = np.array([s.sheddable for s in self.tiers], dtype=bool)
        return sheddable[t] & (np.asarray(predicted_latency_ms)
                               > deadlines[t] * self.headroom)

    def deadline_of(self, tier: int) -> float:
        return self.tiers[min(max(tier, 0), len(self.tiers) - 1)].deadline_ms
