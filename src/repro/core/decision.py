"""The Decision Engine (paper Sec. III-B, V-B, Alg. 1).

``Policy`` is the formal contract every placement policy implements:

- ``choose(preds, edge_name)`` picks a target from per-target predictions;
- ``constraints()`` exposes the policy's declarative constraints
  (``PolicyConstraints``: deadline and/or per-task budget) so the runtime can
  report the right metrics without inspecting policy internals;
- ``hedge(preds, chosen, allowed, edge_name)`` is a first-class hook for
  duplicate dispatch: a policy may nominate a backup target after ``choose``;
- ``observe(chosen)`` feeds the decision back into policy state (Alg. 1's
  surplus bank).

Two placement policies from the paper:

- ``MinCostPolicy(deadline_ms)``: minimize execution cost subject to a per-task
  end-to-end deadline δ. Feasible set M = targets whose *predicted* latency
  (edge latency includes predicted FIFO queue wait) meets δ; pick the cheapest.
  If M is empty, the task is queued on the edge to save cost (paper Sec. V-B).

- ``MinLatencyPolicy(c_max, alpha)``: minimize latency subject to a per-task
  budget C(k) ≤ C_max + α·surplus(k), where surplus(k) = Σ_{i<k}(C_max − C(i))
  is the banked unused budget (paper Eqn. 4, Alg. 1). The edge costs $0, so M
  is never empty and surplus never goes negative.

Beyond-paper extension: ``HedgedPolicy`` wraps MinLatency and duplicates the
dispatch to a second config when the predicted tail latency of the primary
exceeds a hedging threshold (classic tail-at-scale hedging; evaluated in
benchmarks as a beyond-paper experiment). It implements the ``hedge`` hook,
so composition is explicit — no engine-side introspection.

``DecisionEngine.place()`` handles one task; ``DecisionEngine.place_many()``
is the batched path: one vectorized ``Predictor.predict_batch`` pass over all
tasks × targets, then the (cheap) sequential policy/CIL walk.

Fleet placement: when the Predictor carries a multi-device ``EdgeFleet``, an
``EdgeBalancer`` first nominates ONE device to stand in as "the edge" for the
policy (the paper's policies are defined against a single λ_edge), from the
per-device predicted queue waits. ``LeastPredictedWaitBalancer`` is the
default; ``RoundRobinBalancer``/``RandomBalancer`` are the classic baselines
it is benchmarked against. The engine then runs the unchanged paper policy
over {cloud configs} ∪ {nominated device}.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from repro.core.predictor import EDGE as EDGE_NAME
from repro.core.predictor import Prediction, Predictor


@dataclass(frozen=True)
class PolicyConstraints:
    """Declarative constraints a policy enforces (``None`` = unconstrained)."""

    deadline_ms: float | None = None
    c_max: float | None = None


@dataclass(frozen=True)
class PlacementDecision:
    task_idx: int
    target: str
    prediction: Prediction
    feasible: bool  # False when min-cost fell back to the edge queue
    allowed_cost: float  # budget in force at decision time (min-latency)
    hedge_target: str | None = None
    hedge_prediction: Prediction | None = None
    edge_device: str | None = None  # the balancer's nominated edge device


class Policy(abc.ABC):
    """The placement-policy contract consumed by ``DecisionEngine``."""

    @abc.abstractmethod
    def constraints(self) -> PolicyConstraints:
        """The constraints this policy enforces, for result reporting."""

    @abc.abstractmethod
    def choose(self, preds: dict[str, Prediction],
               edge_name: str = EDGE_NAME) -> tuple[str, bool, float]:
        """Pick a target. Returns (name, feasible, allowed_cost)."""

    def hedge(self, preds: dict[str, Prediction], chosen: str, allowed: float,
              edge_name: str = EDGE_NAME) -> tuple[str, Prediction] | None:
        """Optional backup dispatch for the decision just made by ``choose``.

        Called by the engine immediately after ``choose``; returns
        ``(backup_name, backup_prediction)`` or ``None``. The default policy
        never hedges.
        """
        return None

    @abc.abstractmethod
    def observe(self, chosen: Prediction) -> None:
        """Feed the chosen prediction back into policy state."""


class MinCostPolicy(Policy):
    """Minimize cost s.t. per-task deadline δ."""

    def __init__(self, deadline_ms: float):
        self.deadline_ms = deadline_ms

    def constraints(self) -> PolicyConstraints:
        return PolicyConstraints(deadline_ms=self.deadline_ms)

    def choose(self, preds: dict[str, Prediction], edge_name: str = EDGE_NAME):
        feasible = {n: p for n, p in preds.items() if p.latency_ms <= self.deadline_ms}
        if not feasible:
            # No configuration satisfies the deadline: queue on the edge to
            # save cost (paper Sec. V-B).
            return edge_name, False, float("inf")
        name = min(feasible, key=lambda n: (feasible[n].cost, feasible[n].latency_ms))
        return name, True, float("inf")

    def observe(self, chosen: Prediction) -> None:  # stateless
        pass


class MinLatencyPolicy(Policy):
    """Minimize latency s.t. cost ≤ C_max + α·surplus (Alg. 1)."""

    def __init__(self, c_max: float, alpha: float = 0.0):
        if not 0.0 <= alpha <= 1.0:
            raise ValueError(f"alpha must be in [0,1], got {alpha}")
        self.c_max = c_max
        self.alpha = alpha
        self.surplus = 0.0

    @property
    def allowed(self) -> float:
        return self.c_max + self.alpha * self.surplus

    def constraints(self) -> PolicyConstraints:
        return PolicyConstraints(c_max=self.c_max)

    def choose(self, preds: dict[str, Prediction], edge_name: str = EDGE_NAME):
        allowed = self.allowed
        feasible = {n: p for n, p in preds.items() if p.cost <= allowed}
        # λ_edge costs 0, so feasible is never empty when an edge target exists.
        if not feasible:
            feasible = {edge_name: preds[edge_name]} if edge_name in preds else preds
        name = min(feasible, key=lambda n: (feasible[n].latency_ms, feasible[n].cost))
        return name, True, allowed

    def observe(self, chosen: Prediction) -> None:
        # Line 9 of Alg. 1: surplus accumulates the *predicted* unused budget.
        self.surplus += self.c_max - chosen.cost


class HedgedPolicy(Policy):
    """Beyond-paper: hedge high-tail-risk placements with a backup dispatch.

    Wraps MinLatencyPolicy. If the chosen target's predicted latency exceeds
    ``hedge_threshold_ms`` and a second, faster-on-tail config fits the
    *remaining* budget, a duplicate dispatch is issued; the effective latency
    is the min of the two (first-completion-wins). The hedge's cost draws down
    the surplus bank, so hedging can never spend budget the policy has not
    earned.
    """

    def __init__(self, inner: MinLatencyPolicy, hedge_threshold_ms: float):
        self.inner = inner
        self.hedge_threshold_ms = hedge_threshold_ms
        self.last_hedge: tuple[str, Prediction] | None = None

    @property
    def surplus(self) -> float:
        return self.inner.surplus

    @property
    def allowed(self) -> float:
        return self.inner.allowed

    def constraints(self) -> PolicyConstraints:
        return self.inner.constraints()

    def choose(self, preds: dict[str, Prediction], edge_name: str = EDGE_NAME):
        name, feasible, allowed = self.inner.choose(preds, edge_name)
        self.last_hedge = None
        primary = preds[name]
        if primary.latency_ms > self.hedge_threshold_ms:
            remaining = allowed - primary.cost
            candidates = {
                n: p for n, p in preds.items()
                if n != name and p.cost <= remaining and p.latency_ms < primary.latency_ms * 1.5
            }
            if candidates:
                backup = min(candidates, key=lambda n: candidates[n].latency_ms)
                self.last_hedge = (backup, candidates[backup])
        return name, feasible, allowed

    def hedge(self, preds: dict[str, Prediction], chosen: str, allowed: float,
              edge_name: str = EDGE_NAME) -> tuple[str, Prediction] | None:
        return self.last_hedge

    def observe(self, chosen: Prediction) -> None:
        self.inner.observe(chosen)
        if self.last_hedge is not None:
            # the hedge's cost also draws down the budget bank
            self.inner.surplus -= self.last_hedge[1].cost


@dataclass
class PredictedEdgeQueue:
    """The Decision Engine's shadow of one single-slot edge FIFO queue.

    The framework never sees the edge's *actual* queue; it advances a
    predicted busy-horizon with each predicted compute time it sends there
    (paper Sec. V-B). Shared by the step-wise and batched decision loops;
    fleets keep one of these per device.
    """

    horizon_ms: float = 0.0

    def wait_ms(self, now: float) -> float:
        return max(self.horizon_ms - now, 0.0)

    def push(self, now: float, comp_ms: float) -> None:
        self.horizon_ms = max(self.horizon_ms, now) + comp_ms


# ------------------------------------------------------------- edge balancing
class EdgeBalancer(abc.ABC):
    """Nominates ONE fleet device to stand in as "the edge" for the policy."""

    @abc.abstractmethod
    def pick(self, names: Sequence[str], waits: Mapping[str, float],
             preds: Mapping[str, Prediction]) -> str:
        """Pick a device name. ``names`` is the fleet order; ``waits`` maps
        device → predicted FIFO queue wait (ms); ``preds`` holds the full
        per-target predictions for richer strategies."""


class LeastPredictedWaitBalancer(EdgeBalancer):
    """Default: the device with the smallest predicted queue wait (ties break
    by fleet order, so a single-device fleet reduces to the paper exactly)."""

    def pick(self, names, waits, preds):
        return min(names, key=lambda n: waits.get(n, 0.0))


class RoundRobinBalancer(EdgeBalancer):
    """Classic baseline: cycle through devices regardless of backlog."""

    def __init__(self):
        self._i = 0

    def pick(self, names, waits, preds):
        name = names[self._i % len(names)]
        self._i += 1
        return name


class RandomBalancer(EdgeBalancer):
    """Classic baseline: uniform random device (deterministic per seed)."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def pick(self, names, waits, preds):
        return names[int(self.rng.integers(len(names)))]


_POLICY_METHODS = ("choose", "observe", "constraints", "hedge")


@dataclass
class DecisionEngine:
    """Binds a Predictor to a placement policy; one ``place()`` call per input.

    With a multi-device edge fleet, ``balancer`` nominates the device the
    policy sees as "the edge" (default: least predicted queue wait).
    ``edge_name`` survives as the deprecated single-device convenience — it is
    only consulted when the Predictor carries no edge fleet at all.
    """

    predictor: Predictor
    policy: Policy
    edge_name: str = EDGE_NAME
    balancer: EdgeBalancer = field(default_factory=LeastPredictedWaitBalancer)
    decisions: list = field(default_factory=list)

    def __post_init__(self):
        missing = [m for m in _POLICY_METHODS if not hasattr(self.policy, m)]
        if missing:
            raise TypeError(
                f"{type(self.policy).__name__} does not implement the Policy "
                f"protocol (missing {', '.join(missing)}); subclass "
                "repro.core.decision.Policy")
        names = self.edge_names
        if len(names) == 1:
            self.edge_name = names[0]

    @property
    def edge_names(self) -> tuple[str, ...]:
        """Fleet device names (empty when the Predictor has no edge)."""
        return self.predictor.edge_names

    def place(self, task, now: float, edge_queue_wait_ms: float = 0.0,
              edge_waits: Mapping[str, float] | None = None) -> PlacementDecision:
        waits = (dict(edge_waits) if edge_waits is not None
                 else {n: edge_queue_wait_ms for n in self.edge_names})
        preds = self.predictor.predict(task, now, edge_waits=waits)
        return self._decide(task, now, preds, waits)

    def place_many(self, tasks: list,
                   edge_queue: PredictedEdgeQueue | None = None,
                   edge_queues: dict[str, PredictedEdgeQueue] | None = None,
                   ) -> list[PlacementDecision]:
        """Batched placement: one vectorized prediction pass over all tasks ×
        targets, then the sequential policy/CIL/edge-queue walk.

        Decisions are identical to a ``place()`` loop — the models are
        evaluated in one numpy pass instead of per task, which is what makes
        large-N workloads fast (see ``benchmarks/bench_runtime.py``).

        ``edge_queues`` maps device → ``PredictedEdgeQueue`` (one per fleet
        device, created fresh when omitted); ``edge_queue`` is the deprecated
        single-device spelling.
        """
        batch = self.predictor.predict_batch(tasks)
        names = self.edge_names
        if edge_queues is None:
            if edge_queue is not None:
                if len(names) != 1:
                    raise ValueError(
                        "edge_queue is single-device only; pass edge_queues "
                        f"for a {len(names)}-device fleet")
                edge_queues = {names[0]: edge_queue}
            else:
                edge_queues = {n: PredictedEdgeQueue() for n in names}
        out = []
        for i, task in enumerate(tasks):
            now = task.arrival_ms
            waits = {n: q.wait_ms(now) for n, q in edge_queues.items()}
            preds = self.predictor.predict_at(batch, i, now, edge_waits=waits)
            d = self._decide(task, now, preds, waits)
            if d.target in edge_queues:
                edge_queues[d.target].push(now, d.prediction.comp_ms)
            if d.hedge_target is not None and d.hedge_target in edge_queues \
                    and d.hedge_prediction is not None:
                edge_queues[d.hedge_target].push(now, d.hedge_prediction.comp_ms)
            out.append(d)
        return out

    # ------------------------------------------------------------------
    def _decide(self, task, now: float, preds: dict[str, Prediction],
                waits: Mapping[str, float] | None = None) -> PlacementDecision:
        names = self.edge_names
        if len(names) > 1:
            edge_choice = self.balancer.pick(names, waits or {}, preds)
            # the policy is defined against ONE λ_edge: it sees the cloud
            # configs plus the balancer's nominated device only
            policy_view = {n: p for n, p in preds.items()
                           if n == edge_choice or n not in names}
        else:
            edge_choice = names[0] if names else self.edge_name
            policy_view = preds
        name, feasible, allowed = self.policy.choose(policy_view, edge_choice)
        chosen = preds[name]
        hedge = self.policy.hedge(policy_view, name, allowed, edge_choice)
        if hedge is not None and hedge[0] == name:
            hedge = None  # a duplicate of the primary is not a hedge
        self.policy.observe(chosen)
        self.predictor.update_cil(name, now, chosen)
        if hedge is not None:
            # the duplicate dispatch occupies a container too
            self.predictor.update_cil(hedge[0], now, hedge[1])
        d = PlacementDecision(
            task_idx=getattr(task, "idx", -1),
            target=name,
            prediction=chosen,
            feasible=feasible,
            allowed_cost=allowed,
            hedge_target=hedge[0] if hedge is not None else None,
            hedge_prediction=hedge[1] if hedge is not None else None,
            edge_device=edge_choice if names else None,
        )
        self.decisions.append(d)
        return d
