"""The Decision Engine (paper Sec. III-B, V-B, Alg. 1).

``Policy`` is the formal contract every placement policy implements:

- ``choose(preds, edge_name)`` picks a target from per-target predictions;
- ``constraints()`` exposes the policy's declarative constraints
  (``PolicyConstraints``: deadline and/or per-task budget) so the runtime can
  report the right metrics without inspecting policy internals;
- ``hedge(preds, chosen, allowed, edge_name)`` is a first-class hook for
  duplicate dispatch: a policy may nominate a backup target after ``choose``;
- ``observe(chosen)`` feeds the decision back into policy state (Alg. 1's
  surplus bank).

Two placement policies from the paper:

- ``MinCostPolicy(deadline_ms)``: minimize execution cost subject to a per-task
  end-to-end deadline δ. Feasible set M = targets whose *predicted* latency
  (edge latency includes predicted FIFO queue wait) meets δ; pick the cheapest.
  If M is empty, the task is queued on the edge to save cost (paper Sec. V-B).

- ``MinLatencyPolicy(c_max, alpha)``: minimize latency subject to a per-task
  budget C(k) ≤ C_max + α·surplus(k), where surplus(k) = Σ_{i<k}(C_max − C(i))
  is the banked unused budget (paper Eqn. 4, Alg. 1). The edge costs $0, so M
  is never empty and surplus never goes negative.

Beyond-paper extension: ``HedgedPolicy`` wraps MinLatency and duplicates the
dispatch to a second config when the predicted tail latency of the primary
exceeds a hedging threshold (classic tail-at-scale hedging; evaluated in
benchmarks as a beyond-paper experiment). It implements the ``hedge`` hook,
so composition is explicit — no engine-side introspection.

``DecisionEngine.place()`` handles one task; ``DecisionEngine.place_many()``
is the batched path: one vectorized ``Predictor.predict_batch`` pass over all
tasks × targets, then the (cheap) sequential policy/CIL walk.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass, field

from repro.core.predictor import EDGE as EDGE_NAME
from repro.core.predictor import Prediction, Predictor


@dataclass(frozen=True)
class PolicyConstraints:
    """Declarative constraints a policy enforces (``None`` = unconstrained)."""

    deadline_ms: float | None = None
    c_max: float | None = None


@dataclass(frozen=True)
class PlacementDecision:
    task_idx: int
    target: str
    prediction: Prediction
    feasible: bool  # False when min-cost fell back to the edge queue
    allowed_cost: float  # budget in force at decision time (min-latency)
    hedge_target: str | None = None
    hedge_prediction: Prediction | None = None


class Policy(abc.ABC):
    """The placement-policy contract consumed by ``DecisionEngine``."""

    @abc.abstractmethod
    def constraints(self) -> PolicyConstraints:
        """The constraints this policy enforces, for result reporting."""

    @abc.abstractmethod
    def choose(self, preds: dict[str, Prediction],
               edge_name: str = EDGE_NAME) -> tuple[str, bool, float]:
        """Pick a target. Returns (name, feasible, allowed_cost)."""

    def hedge(self, preds: dict[str, Prediction], chosen: str, allowed: float,
              edge_name: str = EDGE_NAME) -> tuple[str, Prediction] | None:
        """Optional backup dispatch for the decision just made by ``choose``.

        Called by the engine immediately after ``choose``; returns
        ``(backup_name, backup_prediction)`` or ``None``. The default policy
        never hedges.
        """
        return None

    @abc.abstractmethod
    def observe(self, chosen: Prediction) -> None:
        """Feed the chosen prediction back into policy state."""


class MinCostPolicy(Policy):
    """Minimize cost s.t. per-task deadline δ."""

    def __init__(self, deadline_ms: float):
        self.deadline_ms = deadline_ms

    def constraints(self) -> PolicyConstraints:
        return PolicyConstraints(deadline_ms=self.deadline_ms)

    def choose(self, preds: dict[str, Prediction], edge_name: str = EDGE_NAME):
        feasible = {n: p for n, p in preds.items() if p.latency_ms <= self.deadline_ms}
        if not feasible:
            # No configuration satisfies the deadline: queue on the edge to
            # save cost (paper Sec. V-B).
            return edge_name, False, float("inf")
        name = min(feasible, key=lambda n: (feasible[n].cost, feasible[n].latency_ms))
        return name, True, float("inf")

    def observe(self, chosen: Prediction) -> None:  # stateless
        pass


class MinLatencyPolicy(Policy):
    """Minimize latency s.t. cost ≤ C_max + α·surplus (Alg. 1)."""

    def __init__(self, c_max: float, alpha: float = 0.0):
        if not 0.0 <= alpha <= 1.0:
            raise ValueError(f"alpha must be in [0,1], got {alpha}")
        self.c_max = c_max
        self.alpha = alpha
        self.surplus = 0.0

    @property
    def allowed(self) -> float:
        return self.c_max + self.alpha * self.surplus

    def constraints(self) -> PolicyConstraints:
        return PolicyConstraints(c_max=self.c_max)

    def choose(self, preds: dict[str, Prediction], edge_name: str = EDGE_NAME):
        allowed = self.allowed
        feasible = {n: p for n, p in preds.items() if p.cost <= allowed}
        # λ_edge costs 0, so feasible is never empty when an edge target exists.
        if not feasible:
            feasible = {edge_name: preds[edge_name]} if edge_name in preds else preds
        name = min(feasible, key=lambda n: (feasible[n].latency_ms, feasible[n].cost))
        return name, True, allowed

    def observe(self, chosen: Prediction) -> None:
        # Line 9 of Alg. 1: surplus accumulates the *predicted* unused budget.
        self.surplus += self.c_max - chosen.cost


class HedgedPolicy(Policy):
    """Beyond-paper: hedge high-tail-risk placements with a backup dispatch.

    Wraps MinLatencyPolicy. If the chosen target's predicted latency exceeds
    ``hedge_threshold_ms`` and a second, faster-on-tail config fits the
    *remaining* budget, a duplicate dispatch is issued; the effective latency
    is the min of the two (first-completion-wins). The hedge's cost draws down
    the surplus bank, so hedging can never spend budget the policy has not
    earned.
    """

    def __init__(self, inner: MinLatencyPolicy, hedge_threshold_ms: float):
        self.inner = inner
        self.hedge_threshold_ms = hedge_threshold_ms
        self.last_hedge: tuple[str, Prediction] | None = None

    @property
    def surplus(self) -> float:
        return self.inner.surplus

    @property
    def allowed(self) -> float:
        return self.inner.allowed

    def constraints(self) -> PolicyConstraints:
        return self.inner.constraints()

    def choose(self, preds: dict[str, Prediction], edge_name: str = EDGE_NAME):
        name, feasible, allowed = self.inner.choose(preds, edge_name)
        self.last_hedge = None
        primary = preds[name]
        if primary.latency_ms > self.hedge_threshold_ms:
            remaining = allowed - primary.cost
            candidates = {
                n: p for n, p in preds.items()
                if n != name and p.cost <= remaining and p.latency_ms < primary.latency_ms * 1.5
            }
            if candidates:
                backup = min(candidates, key=lambda n: candidates[n].latency_ms)
                self.last_hedge = (backup, candidates[backup])
        return name, feasible, allowed

    def hedge(self, preds: dict[str, Prediction], chosen: str, allowed: float,
              edge_name: str = EDGE_NAME) -> tuple[str, Prediction] | None:
        return self.last_hedge

    def observe(self, chosen: Prediction) -> None:
        self.inner.observe(chosen)
        if self.last_hedge is not None:
            # the hedge's cost also draws down the budget bank
            self.inner.surplus -= self.last_hedge[1].cost


@dataclass
class PredictedEdgeQueue:
    """The Decision Engine's shadow of the single-slot edge FIFO queue.

    The framework never sees the edge's *actual* queue; it advances a
    predicted busy-horizon with each predicted compute time it sends there
    (paper Sec. V-B). Shared by the step-wise and batched decision loops.
    """

    horizon_ms: float = 0.0

    def wait_ms(self, now: float) -> float:
        return max(self.horizon_ms - now, 0.0)

    def push(self, now: float, comp_ms: float) -> None:
        self.horizon_ms = max(self.horizon_ms, now) + comp_ms


_POLICY_METHODS = ("choose", "observe", "constraints", "hedge")


@dataclass
class DecisionEngine:
    """Binds a Predictor to a placement policy; one ``place()`` call per input."""

    predictor: Predictor
    policy: Policy
    edge_name: str = EDGE_NAME
    decisions: list = field(default_factory=list)

    def __post_init__(self):
        missing = [m for m in _POLICY_METHODS if not hasattr(self.policy, m)]
        if missing:
            raise TypeError(
                f"{type(self.policy).__name__} does not implement the Policy "
                f"protocol (missing {', '.join(missing)}); subclass "
                "repro.core.decision.Policy")

    def place(self, task, now: float, edge_queue_wait_ms: float = 0.0) -> PlacementDecision:
        preds = self.predictor.predict(task, now, edge_queue_wait_ms)
        return self._decide(task, now, preds)

    def place_many(self, tasks: list,
                   edge_queue: PredictedEdgeQueue | None = None) -> list[PlacementDecision]:
        """Batched placement: one vectorized prediction pass over all tasks ×
        targets, then the sequential policy/CIL/edge-queue walk.

        Decisions are identical to a ``place()`` loop — the models are
        evaluated in one numpy pass instead of per task, which is what makes
        large-N workloads fast (see ``benchmarks/bench_runtime.py``).
        """
        batch = self.predictor.predict_batch(tasks)
        queue = edge_queue if edge_queue is not None else PredictedEdgeQueue()
        out = []
        for i, task in enumerate(tasks):
            now = task.arrival_ms
            preds = self.predictor.predict_at(batch, i, now, queue.wait_ms(now))
            d = self._decide(task, now, preds)
            if d.target == self.edge_name:
                queue.push(now, d.prediction.comp_ms)
            if d.hedge_target == self.edge_name and d.hedge_prediction is not None:
                queue.push(now, d.hedge_prediction.comp_ms)
            out.append(d)
        return out

    # ------------------------------------------------------------------
    def _decide(self, task, now: float, preds: dict[str, Prediction]) -> PlacementDecision:
        name, feasible, allowed = self.policy.choose(preds, self.edge_name)
        chosen = preds[name]
        hedge = self.policy.hedge(preds, name, allowed, self.edge_name)
        if hedge is not None and hedge[0] == name:
            hedge = None  # a duplicate of the primary is not a hedge
        self.policy.observe(chosen)
        self.predictor.update_cil(name, now, chosen)
        if hedge is not None:
            # the duplicate dispatch occupies a container too
            self.predictor.update_cil(hedge[0], now, hedge[1])
        d = PlacementDecision(
            task_idx=getattr(task, "idx", -1),
            target=name,
            prediction=chosen,
            feasible=feasible,
            allowed_cost=allowed,
            hedge_target=hedge[0] if hedge is not None else None,
            hedge_prediction=hedge[1] if hedge is not None else None,
        )
        self.decisions.append(d)
        return d
