"""The Decision Engine (paper Sec. III-B, V-B, Alg. 1).

Two placement policies:

- ``MinCostPolicy(deadline_ms)``: minimize execution cost subject to a per-task
  end-to-end deadline δ. Feasible set M = targets whose *predicted* latency
  (edge latency includes predicted FIFO queue wait) meets δ; pick the cheapest.
  If M is empty, the task is queued on the edge to save cost (paper Sec. V-B).

- ``MinLatencyPolicy(c_max, alpha)``: minimize latency subject to a per-task
  budget C(k) ≤ C_max + α·surplus(k), where surplus(k) = Σ_{i<k}(C_max − C(i))
  is the banked unused budget (paper Eqn. 4, Alg. 1). The edge costs $0, so M
  is never empty and surplus never goes negative.

Beyond-paper extension: ``HedgedPolicy`` wraps MinLatency and duplicates the
dispatch to a second config when the predicted tail latency of the primary
exceeds a hedging threshold (classic tail-at-scale hedging; evaluated in
benchmarks as a beyond-paper experiment).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.predictor import Prediction, Predictor


@dataclass(frozen=True)
class PlacementDecision:
    task_idx: int
    target: str
    prediction: Prediction
    feasible: bool  # False when min-cost fell back to the edge queue
    allowed_cost: float  # budget in force at decision time (min-latency)
    hedge_target: str | None = None
    hedge_prediction: Prediction | None = None


class MinCostPolicy:
    """Minimize cost s.t. per-task deadline δ."""

    def __init__(self, deadline_ms: float):
        self.deadline_ms = deadline_ms

    def choose(self, preds: dict[str, Prediction], edge_name: str = "edge"):
        feasible = {n: p for n, p in preds.items() if p.latency_ms <= self.deadline_ms}
        if not feasible:
            # No configuration satisfies the deadline: queue on the edge to
            # save cost (paper Sec. V-B).
            return edge_name, False, float("inf")
        name = min(feasible, key=lambda n: (feasible[n].cost, feasible[n].latency_ms))
        return name, True, float("inf")

    def observe(self, chosen: Prediction) -> None:  # stateless
        pass


class MinLatencyPolicy:
    """Minimize latency s.t. cost ≤ C_max + α·surplus (Alg. 1)."""

    def __init__(self, c_max: float, alpha: float = 0.0):
        if not 0.0 <= alpha <= 1.0:
            raise ValueError(f"alpha must be in [0,1], got {alpha}")
        self.c_max = c_max
        self.alpha = alpha
        self.surplus = 0.0

    @property
    def allowed(self) -> float:
        return self.c_max + self.alpha * self.surplus

    def choose(self, preds: dict[str, Prediction], edge_name: str = "edge"):
        allowed = self.allowed
        feasible = {n: p for n, p in preds.items() if p.cost <= allowed}
        # λ_edge costs 0, so feasible is never empty when an edge target exists.
        if not feasible:
            feasible = {edge_name: preds[edge_name]} if edge_name in preds else preds
        name = min(feasible, key=lambda n: (feasible[n].latency_ms, feasible[n].cost))
        return name, True, allowed

    def observe(self, chosen: Prediction) -> None:
        # Line 9 of Alg. 1: surplus accumulates the *predicted* unused budget.
        self.surplus += self.c_max - chosen.cost


@dataclass
class DecisionEngine:
    """Binds a Predictor to a placement policy; one ``place()`` call per input."""

    predictor: Predictor
    policy: object
    edge_name: str = "edge"
    decisions: list = field(default_factory=list)

    def place(self, task, now: float, edge_queue_wait_ms: float = 0.0) -> PlacementDecision:
        preds = self.predictor.predict(task, now, edge_queue_wait_ms)
        name, feasible, allowed = self.policy.choose(preds, self.edge_name)
        chosen = preds[name]
        self.policy.observe(chosen)
        self.predictor.update_cil(name, now, chosen)
        d = PlacementDecision(
            task_idx=getattr(task, "idx", -1),
            target=name,
            prediction=chosen,
            feasible=feasible,
            allowed_cost=allowed,
        )
        self.decisions.append(d)
        return d


class HedgedPolicy:
    """Beyond-paper: hedge high-tail-risk placements with a backup dispatch.

    Wraps MinLatencyPolicy. If the chosen target's predicted latency exceeds
    ``hedge_threshold_ms`` and a second, faster-on-tail config fits the
    *remaining* budget, a duplicate dispatch is issued; the effective latency
    is the min of the two (first-completion-wins).
    """

    def __init__(self, inner: MinLatencyPolicy, hedge_threshold_ms: float):
        self.inner = inner
        self.hedge_threshold_ms = hedge_threshold_ms
        self.last_hedge: tuple[str, Prediction] | None = None

    @property
    def surplus(self) -> float:
        return self.inner.surplus

    @property
    def allowed(self) -> float:
        return self.inner.allowed

    def choose(self, preds: dict[str, Prediction], edge_name: str = "edge"):
        name, feasible, allowed = self.inner.choose(preds, edge_name)
        self.last_hedge = None
        primary = preds[name]
        if primary.latency_ms > self.hedge_threshold_ms:
            remaining = allowed - primary.cost
            candidates = {
                n: p for n, p in preds.items()
                if n != name and p.cost <= remaining and p.latency_ms < primary.latency_ms * 1.5
            }
            if candidates:
                backup = min(candidates, key=lambda n: candidates[n].latency_ms)
                self.last_hedge = (backup, candidates[backup])
        return name, feasible, allowed

    def observe(self, chosen: Prediction) -> None:
        self.inner.observe(chosen)
        if self.last_hedge is not None:
            # the hedge's cost also draws down the budget bank
            self.inner.surplus -= self.last_hedge[1].cost
