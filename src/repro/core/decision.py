"""The Decision Engine (paper Sec. III-B, V-B, Alg. 1) — with a columnar core.

``Policy`` is the formal contract every placement policy implements:

- ``choose(preds, edge_name)`` picks a target from per-target predictions;
- ``constraints()`` exposes the policy's declarative constraints
  (``PolicyConstraints``: deadline and/or per-task budget) so the runtime can
  report the right metrics without inspecting policy internals;
- ``hedge(preds, chosen, allowed, edge_name)`` is a first-class hook for
  duplicate dispatch: a policy may nominate a backup target after ``choose``;
- ``observe(chosen)`` feeds the decision back into policy state (Alg. 1's
  surplus bank).

Two placement policies from the paper:

- ``MinCostPolicy(deadline_ms)``: minimize execution cost subject to a per-task
  end-to-end deadline δ. Feasible set M = targets whose *predicted* latency
  (edge latency includes predicted FIFO queue wait) meets δ; pick the cheapest.
  If M is empty, the task is queued on the edge to save cost (paper Sec. V-B).

- ``MinLatencyPolicy(c_max, alpha)``: minimize latency subject to a per-task
  budget C(k) ≤ C_max + α·surplus(k), where surplus(k) = Σ_{i<k}(C_max − C(i))
  is the banked unused budget (paper Eqn. 4, Alg. 1). The edge costs $0, so M
  is never empty and surplus never goes negative.

Beyond-paper extension: ``HedgedPolicy`` wraps MinLatency and duplicates the
dispatch to a second config when the predicted tail latency of the primary
exceeds a hedging threshold (classic tail-at-scale hedging; evaluated in
benchmarks as a beyond-paper experiment). It implements the ``hedge`` hook,
so composition is explicit — no engine-side introspection.

``DecisionEngine.place()`` handles one task; ``DecisionEngine.place_many()``
is the batched path. For the paper policies (exactly ``MinCostPolicy`` /
``MinLatencyPolicy``) it runs the COLUMNAR core: policy ``choose`` becomes a
masked lexicographic argmin over the ``(n_tasks, n_targets)`` prediction
arrays, the balancer becomes an argmin over per-device wait arrays, and the
three sequential recurrences that couple consecutive decisions — the surplus
bank, the CIL warm/cold feedback, and the predicted edge-queue horizons — run
speculate-and-repair: assume the speculated placements hold for a chunk,
recompute every induced state trajectory exactly (segment cumsums, event
walks), find the first decision the exact state would change, repair there,
resume. Decisions are BIT-IDENTICAL to the per-task ``step`` path; hedged or
custom policies/balancers fall back to the per-task walk automatically. The
result is a struct-of-arrays ``DecisionBatch`` (lazy ``PlacementDecision``
views) that flows straight into the vectorized execution backends.

Fleet placement: when the Predictor carries a multi-device ``EdgeFleet``, an
``EdgeBalancer`` first nominates ONE device to stand in as "the edge" for the
policy (the paper's policies are defined against a single λ_edge), from the
per-device predicted queue waits. ``LeastPredictedWaitBalancer`` is the
default; ``RoundRobinBalancer``/``RandomBalancer`` are the classic baselines
it is benchmarked against. The engine then runs the unchanged paper policy
over {cloud configs} ∪ {nominated device}.
"""

from __future__ import annotations

import abc
from dataclasses import dataclass
from typing import Callable, Iterator, Mapping, Sequence

import numpy as np

from repro.core.cil import ContainerInfoList
from repro.core.predictor import EDGE as EDGE_NAME
from repro.core.predictor import Prediction, PredictionBatch, Predictor
from repro.core.recurrence import horizon_before, surplus_trajectory
from repro.core.workload import task_arrays

# Columnar speculate-and-repair tuning — all correctness-neutral (only wall
# time changes): the max/min speculation span (the span tracks a few multiples
# of the observed accept-run EMA, so repair cost stays proportional to how far
# speculation actually reaches); the run length below which speculation is
# judged losing (tight edge/cloud oscillation) and the scalar-on-arrays loop
# decides a stretch instead; and the minimum such stretch.
COLUMNAR_CHUNK = 4096
COLUMNAR_MIN_CHUNK = 128
COLUMNAR_MIN_RUN = 24
COLUMNAR_WALK_STRETCH = 512


@dataclass(frozen=True)
class PolicyConstraints:
    """Declarative constraints a policy enforces (``None`` = unconstrained)."""

    deadline_ms: float | None = None
    c_max: float | None = None


@dataclass(frozen=True)
class PlacementDecision:
    task_idx: int
    target: str
    prediction: Prediction
    feasible: bool  # False when min-cost fell back to the edge queue
    allowed_cost: float  # budget in force at decision time (min-latency)
    hedge_target: str | None = None
    hedge_prediction: Prediction | None = None
    edge_device: str | None = None  # the balancer's nominated edge device


class Policy(abc.ABC):
    """The placement-policy contract consumed by ``DecisionEngine``."""

    @abc.abstractmethod
    def constraints(self) -> PolicyConstraints:
        """The constraints this policy enforces, for result reporting."""

    @abc.abstractmethod
    def choose(self, preds: dict[str, Prediction],
               edge_name: str = EDGE_NAME) -> tuple[str, bool, float]:
        """Pick a target. Returns (name, feasible, allowed_cost)."""

    def hedge(self, preds: dict[str, Prediction], chosen: str, allowed: float,
              edge_name: str = EDGE_NAME) -> tuple[str, Prediction] | None:
        """Optional backup dispatch for the decision just made by ``choose``.

        Called by the engine immediately after ``choose``; returns
        ``(backup_name, backup_prediction)`` or ``None``. The default policy
        never hedges.
        """
        return None

    @abc.abstractmethod
    def observe(self, chosen: Prediction) -> None:
        """Feed the chosen prediction back into policy state."""


class MinCostPolicy(Policy):
    """Minimize cost s.t. per-task deadline δ."""

    def __init__(self, deadline_ms: float):
        self.deadline_ms = deadline_ms

    def constraints(self) -> PolicyConstraints:
        return PolicyConstraints(deadline_ms=self.deadline_ms)

    def choose(self, preds: dict[str, Prediction], edge_name: str = EDGE_NAME):
        feasible = {n: p for n, p in preds.items() if p.latency_ms <= self.deadline_ms}
        if not feasible:
            # No configuration satisfies the deadline: queue on the edge to
            # save cost (paper Sec. V-B).
            return edge_name, False, float("inf")
        name = min(feasible, key=lambda n: (feasible[n].cost, feasible[n].latency_ms))
        return name, True, float("inf")

    def observe(self, chosen: Prediction) -> None:  # stateless
        pass


class MinLatencyPolicy(Policy):
    """Minimize latency s.t. cost ≤ C_max + α·surplus (Alg. 1)."""

    def __init__(self, c_max: float, alpha: float = 0.0):
        if not 0.0 <= alpha <= 1.0:
            raise ValueError(f"alpha must be in [0,1], got {alpha}")
        self.c_max = c_max
        self.alpha = alpha
        self.surplus = 0.0

    @property
    def allowed(self) -> float:
        return self.c_max + self.alpha * self.surplus

    def constraints(self) -> PolicyConstraints:
        return PolicyConstraints(c_max=self.c_max)

    def choose(self, preds: dict[str, Prediction], edge_name: str = EDGE_NAME):
        allowed = self.allowed
        feasible = {n: p for n, p in preds.items() if p.cost <= allowed}
        # λ_edge costs 0, so feasible is never empty when an edge target exists.
        if not feasible:
            feasible = {edge_name: preds[edge_name]} if edge_name in preds else preds
        name = min(feasible, key=lambda n: (feasible[n].latency_ms, feasible[n].cost))
        return name, True, allowed

    def observe(self, chosen: Prediction) -> None:
        # Line 9 of Alg. 1: surplus accumulates the *predicted* unused budget.
        self.surplus += self.c_max - chosen.cost


class HedgedPolicy(Policy):
    """Beyond-paper: hedge high-tail-risk placements with a backup dispatch.

    Wraps MinLatencyPolicy. If the chosen target's predicted latency exceeds
    ``hedge_threshold_ms`` and a second, faster-on-tail config fits the
    *remaining* budget, a duplicate dispatch is issued; the effective latency
    is the min of the two (first-completion-wins). The hedge's cost draws down
    the surplus bank, so hedging can never spend budget the policy has not
    earned.
    """

    def __init__(self, inner: MinLatencyPolicy, hedge_threshold_ms: float):
        self.inner = inner
        self.hedge_threshold_ms = hedge_threshold_ms
        self.last_hedge: tuple[str, Prediction] | None = None

    @property
    def surplus(self) -> float:
        return self.inner.surplus

    @property
    def allowed(self) -> float:
        return self.inner.allowed

    def constraints(self) -> PolicyConstraints:
        return self.inner.constraints()

    def choose(self, preds: dict[str, Prediction], edge_name: str = EDGE_NAME):
        name, feasible, allowed = self.inner.choose(preds, edge_name)
        self.last_hedge = None
        primary = preds[name]
        if primary.latency_ms > self.hedge_threshold_ms:
            remaining = allowed - primary.cost
            candidates = {
                n: p for n, p in preds.items()
                if n != name and p.cost <= remaining and p.latency_ms < primary.latency_ms * 1.5
            }
            if candidates:
                backup = min(candidates, key=lambda n: candidates[n].latency_ms)
                self.last_hedge = (backup, candidates[backup])
        return name, feasible, allowed

    def hedge(self, preds: dict[str, Prediction], chosen: str, allowed: float,
              edge_name: str = EDGE_NAME) -> tuple[str, Prediction] | None:
        return self.last_hedge

    def observe(self, chosen: Prediction) -> None:
        self.inner.observe(chosen)
        if self.last_hedge is not None:
            # the hedge's cost also draws down the budget bank
            self.inner.surplus -= self.last_hedge[1].cost


@dataclass
class PredictedEdgeQueue:
    """The Decision Engine's shadow of one single-slot edge FIFO queue.

    The framework never sees the edge's *actual* queue; it advances a
    predicted busy-horizon with each predicted compute time it sends there
    (paper Sec. V-B). Shared by the step-wise and batched decision loops;
    fleets keep one of these per device.
    """

    horizon_ms: float = 0.0

    def wait_ms(self, now: float) -> float:
        return max(self.horizon_ms - now, 0.0)

    def push(self, now: float, comp_ms: float) -> None:
        self.horizon_ms = max(self.horizon_ms, now) + comp_ms


# ------------------------------------------------------------- edge balancing
class EdgeBalancer(abc.ABC):
    """Nominates ONE fleet device to stand in as "the edge" for the policy."""

    @abc.abstractmethod
    def pick(self, names: Sequence[str], waits: Mapping[str, float],
             preds: Mapping[str, Prediction]) -> str:
        """Pick a device name. ``names`` is the fleet order; ``waits`` maps
        device → predicted FIFO queue wait (ms); ``preds`` holds the full
        per-target predictions for richer strategies."""


class LeastPredictedWaitBalancer(EdgeBalancer):
    """Default: the device with the smallest predicted queue wait (ties break
    by fleet order, so a single-device fleet reduces to the paper exactly).

    On the columnar path this is ``argmin`` over the per-device wait arrays
    (``np.argmin`` returns the first minimum — the same fleet-order
    tie-break)."""

    def pick(self, names, waits, preds):
        return min(names, key=lambda n: waits.get(n, 0.0))


class RoundRobinBalancer(EdgeBalancer):
    """Classic baseline: cycle through devices regardless of backlog."""

    def __init__(self):
        self._i = 0

    def pick(self, names, waits, preds):
        name = names[self._i % len(names)]
        self._i += 1
        return name


class RandomBalancer(EdgeBalancer):
    """Classic baseline: uniform random device (deterministic per seed)."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def pick(self, names, waits, preds):
        return names[int(self.rng.integers(len(names)))]


def failover_choice(policy: Policy, preds: Mapping[str, "Prediction"],
                    exclude: "set[str] | frozenset[str]",
                    edge_names: Sequence[str],
                    waits: Mapping[str, float],
                    ) -> "tuple[str, Prediction] | None":
    """Next-best surviving target after a failed dispatch: re-enter the
    placement path with the failed/tried/tripped targets masked out.

    Mirrors ``DecisionEngine._decide`` exactly — the surviving fleet device
    with the least predicted wait stands in as "the edge" for the policy,
    which then chooses over the cloud configs plus that device — but WITHOUT
    the ``observe``/CIL side effects: the failure-aware runtime applies the
    failover's state accounting itself (surplus drawdown like a hedge leg,
    ``update_cil`` for the extra container). Returns ``None`` when no target
    survives the mask (the task fails permanently).
    """
    view = {n: p for n, p in preds.items() if n not in exclude}
    if not view:
        return None
    edges = [n for n in edge_names if n in view]
    if edges:
        edge_choice = min(edges, key=lambda n: waits.get(n, 0.0))
        policy_view = {n: p for n, p in view.items()
                       if n == edge_choice or n not in edges}
    else:
        edge_choice = next(iter(view))  # no surviving edge: cloud-only view
        policy_view = view
    name, _feasible, _allowed = policy.choose(policy_view, edge_choice)
    if name not in view:
        return None  # the policy's edge fallback is itself masked out
    return name, view[name]


_POLICY_METHODS = ("choose", "observe", "constraints", "hedge")
# Policies whose choose/observe the columnar kernels replicate exactly.
# Subclasses are NOT eligible (they may override behavior) — exact type only.
_COLUMNAR_POLICIES = (MinCostPolicy, MinLatencyPolicy)
_COLUMNAR_BALANCERS = (LeastPredictedWaitBalancer, RoundRobinBalancer,
                       RandomBalancer)


@dataclass(eq=False)
class DecisionBatch(Sequence):
    """Struct-of-arrays placement decisions (the columnar ``place_many`` path).

    ``target_codes`` indexes ``names`` = cloud targets (predictor order) then
    fleet devices (fleet order); codes ≥ ``n_cloud`` are edge placements.
    Indexing/iterating materializes lazy ``PlacementDecision`` views (the
    columnar policies never hedge, so views carry no hedge); the vectorized
    runtime consumes the arrays directly and never builds a view.

    ``batch`` may be ``None`` when the decisions came from the device-resident
    jax core, which never runs the host prediction pass — ``batch_factory``
    then rebuilds the ``PredictionBatch`` on first view access (only per-task
    consumers pay it; the vectorized runtime reads arrays only).
    """

    batch: PredictionBatch | None   # source predictions, for lazy components
    names: tuple[str, ...]
    n_cloud: int
    task_idx: np.ndarray            # (n,) int64
    target_codes: np.ndarray        # (n,) int64
    latency_ms: np.ndarray          # chosen predicted latency
    cost: np.ndarray                # chosen predicted cost
    cold: np.ndarray                # chosen predicted cold (bool)
    comp_ms: np.ndarray             # chosen predicted compute
    queue_wait_ms: np.ndarray       # predicted wait of the chosen edge device
    feasible: np.ndarray            # bool
    allowed_cost: np.ndarray
    edge_device_codes: np.ndarray | None  # (n,) device idx, None = no fleet
    batch_factory: "Callable[[], PredictionBatch] | None" = None

    def __len__(self) -> int:
        return self.target_codes.shape[0]

    def __bool__(self) -> bool:
        return len(self) > 0

    @property
    def targets(self) -> np.ndarray:
        """Chosen target names as an object array (diagnostics)."""
        return np.array(self.names, dtype=object)[self.target_codes]

    def target_list(self) -> list[str]:
        """Chosen target names as a plain list (what ``execute_many`` eats)."""
        table = list(self.names)
        return [table[c] for c in self.target_codes.tolist()]

    def rows_by_target(self) -> dict[str, np.ndarray]:
        """Row indices per chosen target, in arrival order — the partition
        the async drivers' per-target workers serve (each driver derives its
        own copy inline from ``target_codes``; this is the inspection view
        for tests, examples, and fan-out diagnostics). Concatenating the
        queues back by row index recovers the batch."""
        return {self.names[c]: np.nonzero(self.target_codes == c)[0]
                for c in np.unique(self.target_codes).tolist()}

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        i = int(i)
        if self.batch is None:
            if self.batch_factory is None:
                raise RuntimeError(
                    "DecisionBatch carries no PredictionBatch (device-resident "
                    "placement) and no batch_factory to rebuild one; per-task "
                    "views are unavailable")
            self.batch = self.batch_factory()
        code = int(self.target_codes[i])
        name = self.names[code]
        if code >= self.n_cloud:
            tb = self.batch.edges[name]
            comps = {k: float(v[i]) for k, v in tb.warm.items()}
            comps["queue"] = float(self.queue_wait_ms[i])
        else:
            tb = self.batch.cloud[name]
            src = tb.cold if self.cold[i] else tb.warm
            comps = {k: float(v[i]) for k, v in src.items()}
        pred = Prediction(target=name, latency_ms=float(self.latency_ms[i]),
                          cost=float(self.cost[i]), cold=bool(self.cold[i]),
                          components=comps)
        device = None
        if self.edge_device_codes is not None:
            d = int(self.edge_device_codes[i])
            device = self.names[self.n_cloud + d] if d >= 0 else None
        return PlacementDecision(
            task_idx=int(self.task_idx[i]), target=name, prediction=pred,
            feasible=bool(self.feasible[i]),
            allowed_cost=float(self.allowed_cost[i]), edge_device=device)

    def __iter__(self) -> Iterator[PlacementDecision]:
        for i in range(len(self)):
            yield self[i]


def _warm_any(busy: np.ndarray, last: np.ndarray, t_idl: float,
              times: np.ndarray) -> np.ndarray:
    """Vectorized CIL warm probe: is any container idle-and-unexpired at each
    query time? (``busy ≤ t ≤ last + t_idl`` — the ``will_warm_start`` test.)"""
    if busy.shape[0] == 0:
        return np.zeros(times.shape[0], dtype=bool)
    t = times[:, None]
    return ((busy[None, :] <= t) & (t <= last[None, :] + t_idl)).any(axis=1)


class _ColumnarContext:
    """Shared arrays + running exact state for one columnar ``place_many``."""

    def __init__(self, engine: "DecisionEngine", tasks: list,
                 batch: PredictionBatch, edge_queues: dict):
        self.engine = engine
        self.batch = batch
        self.cloud_names = list(batch.cloud)
        self.dev_names = list(batch.edges)
        self.n_cloud = len(self.cloud_names)
        self.n_dev = len(self.dev_names)
        self.has_edge = self.n_dev > 0
        self.T = self.n_cloud + (1 if self.has_edge else 0)
        self.edge_col = self.T - 1 if self.has_edge else -1
        self.task_idx, self.nows, _, _ = task_arrays(tasks, "ia")
        self.cwarm = [batch.cloud[nm].warm_latency for nm in self.cloud_names]
        self.ccold = [batch.cloud[nm].cold_latency for nm in self.cloud_names]
        self.ccost = [batch.cloud[nm].cost for nm in self.cloud_names]
        self.ccomp = [batch.cloud[nm].warm["comp"] for nm in self.cloud_names]
        if self.has_edge:
            self.e_lat = np.stack(
                [batch.edges[nm].warm_latency for nm in self.dev_names], axis=1)
            self.e_cost = np.stack(
                [batch.edges[nm].cost for nm in self.dev_names], axis=1)
            self.e_comp = np.stack(
                [batch.edges[nm].warm["comp"] for nm in self.dev_names], axis=1)
        # running exact state
        self.queues = edge_queues  # device name -> PredictedEdgeQueue
        self.cil: ContainerInfoList = engine.predictor.cil
        self.t_idl = self.cil.t_idl_ms
        policy = engine.policy
        self.is_minlat = type(policy) is MinLatencyPolicy


class DecisionEngine:
    """Binds a Predictor to a placement policy; one ``place()`` call per input.

    With a multi-device edge fleet, ``balancer`` nominates the device the
    policy sees as "the edge" (default: least predicted queue wait).
    ``edge_name`` survives as the deprecated single-device convenience — it is
    only consulted when the Predictor carries no edge fleet at all.

    ``record_decisions`` is OFF by default: a long-running serve would
    otherwise accumulate every ``PlacementDecision`` forever. Turn it on to
    audit the decision stream through ``engine.decisions``.

    ``columnar`` gates the vectorized ``place_many`` core (see module
    docstring); with it off — or with a policy/balancer the kernels cannot
    replicate, or out-of-order arrival times — ``place_many`` runs the
    per-task walk over the same batched predictions. ``columnar_stats``
    reports the last columnar run's speculate-and-repair behavior:
    ``{"chunks": speculation segments opened, "repairs": mispredicted
    decisions repaired, "walked": rows decided by the scalar-on-arrays
    fallback, "n": batch size}``.

    ``array_backend`` selects the chunk pipeline implementation:
    ``"numpy"`` (default, the oracle), ``"jax"`` (jit-compiled
    device-resident ``repro.core.jax_core`` — decision-identical, float
    agreement at tolerance), or ``"jax_interpret"`` (op-by-op float64 jax —
    bit-identical to numpy, the parity-test mode). Anything the jax core
    cannot replicate (hedged/custom policies, quantile prediction,
    out-of-order arrivals, ``record_decisions``, custom target/model types)
    silently takes the numpy path, chunk by chunk.
    """

    def __init__(self, predictor: Predictor, policy: Policy,
                 edge_name: str = EDGE_NAME,
                 balancer: EdgeBalancer | None = None,
                 record_decisions: bool = False,
                 columnar: bool = True,
                 array_backend: str = "numpy"):
        self.predictor = predictor
        self.policy = policy
        self.edge_name = edge_name
        self.balancer = balancer if balancer is not None \
            else LeastPredictedWaitBalancer()
        self.record_decisions = record_decisions
        self.columnar = columnar
        if array_backend not in ("numpy", "jax", "jax_interpret"):
            raise ValueError(
                f"array_backend must be 'numpy', 'jax' or 'jax_interpret', "
                f"got {array_backend!r}")
        self.array_backend = array_backend
        self.decisions: list[PlacementDecision] = []
        self.columnar_stats: dict | None = None
        # the speculate-and-repair accept-run EMA, persisted across
        # ``place_many`` calls so a chunked stream resumes speculation at the
        # span the workload has already earned instead of re-slow-starting
        # every chunk (correctness-neutral: only wall time changes)
        self._spec_ema: float | None = None
        missing = [m for m in _POLICY_METHODS if not hasattr(self.policy, m)]
        if missing:
            raise TypeError(
                f"{type(self.policy).__name__} does not implement the Policy "
                f"protocol (missing {', '.join(missing)}); subclass "
                "repro.core.decision.Policy")
        names = self.edge_names
        if len(names) == 1:
            self.edge_name = names[0]

    @property
    def edge_names(self) -> tuple[str, ...]:
        """Fleet device names (empty when the Predictor has no edge)."""
        return self.predictor.edge_names

    def _sync_device_state(self) -> None:
        """Materialize any device-resident stream state before a host-side
        read/mutation of CIL / surplus / horizons (no-op when none held)."""
        _jc = self.__dict__.get("_jax_core_cache")
        if _jc is not None and _jc[1] is not None:
            _jc[1].sync_host("fallback")

    def place(self, task, now: float, edge_queue_wait_ms: float = 0.0,
              edge_waits: Mapping[str, float] | None = None) -> PlacementDecision:
        self._sync_device_state()
        waits = (dict(edge_waits) if edge_waits is not None
                 else {n: edge_queue_wait_ms for n in self.edge_names})
        preds = self.predictor.predict(task, now, edge_waits=waits)
        return self._decide(task, now, preds, waits)

    def place_many(self, tasks: list,
                   edge_queue: PredictedEdgeQueue | None = None,
                   edge_queues: dict[str, PredictedEdgeQueue] | None = None,
                   ) -> "DecisionBatch | list[PlacementDecision]":
        """Batched placement: one vectorized prediction pass over all tasks ×
        targets, then the columnar decision core (paper policies) or the
        per-task policy/CIL/edge-queue walk (hedged/custom policies).

        Decisions are bit-identical to a ``place()`` loop either way. The
        columnar path returns a struct-of-arrays ``DecisionBatch`` (iterable
        as lazy ``PlacementDecision`` views); the walk returns the familiar
        list. See ``benchmarks/bench_runtime.py`` for the throughput gap.

        ``edge_queues`` maps device → ``PredictedEdgeQueue`` (one per fleet
        device, created fresh when omitted); ``edge_queue`` is the deprecated
        single-device spelling.
        """
        names = self.edge_names
        if edge_queues is None:
            if edge_queue is not None:
                if len(names) != 1:
                    raise ValueError(
                        "edge_queue is single-device only; pass edge_queues "
                        f"for a {len(names)}-device fleet")
                edge_queues = {names[0]: edge_queue}
            else:
                edge_queues = {n: PredictedEdgeQueue() for n in names}
        # device-resident route, BEFORE the (expensive) host prediction pass
        # it exists to avoid; record_decisions stays on the numpy path (its
        # views would rebuild the prediction batch anyway)
        if tasks and self.columnar and self.array_backend != "numpy" \
                and not self.record_decisions and self._columnar_eligible():
            from repro.core import jax_core

            core = jax_core.core_for(self)
            if core is not None:
                out = core.place_chunk(
                    self, tasks, edge_queues,
                    interpret=self.array_backend == "jax_interpret")
                if out is not None:
                    return out
        # fallback (hedged/custom policy, record_decisions, force-walk, core
        # refusal): the host paths below read CIL/surplus/horizons, so any
        # device-resident stream state must land first — place_chunk syncs
        # on its own refusals; this covers routes that never reached it
        self._sync_device_state()
        batch = self.predictor.predict_batch(tasks)
        if tasks and self.columnar and self._columnar_eligible():
            out = self._place_columnar(tasks, batch, edge_queues)
            if out is not None:
                if self.record_decisions:
                    self.decisions.extend(out)
                return out
        return self._place_walk(tasks, batch, edge_queues)

    def _place_walk(self, tasks, batch, edge_queues) -> list[PlacementDecision]:
        """The per-task decision walk over batched predictions (fallback)."""
        out = []
        for i, task in enumerate(tasks):
            now = task.arrival_ms
            waits = {n: q.wait_ms(now) for n, q in edge_queues.items()}
            preds = self.predictor.predict_at(batch, i, now, edge_waits=waits)
            d = self._decide(task, now, preds, waits)
            if d.target in edge_queues:
                edge_queues[d.target].push(now, d.prediction.comp_ms)
            if d.hedge_target is not None and d.hedge_target in edge_queues \
                    and d.hedge_prediction is not None:
                edge_queues[d.hedge_target].push(now, d.hedge_prediction.comp_ms)
            out.append(d)
        return out

    # --------------------------------------------------------- columnar core
    def _columnar_eligible(self) -> bool:
        """Can the vectorized kernels replicate this engine bit-for-bit?

        Exact-type checks only: a subclass may override ``choose``/``pick``/
        CIL semantics, and the contract is bit-parity with the step path —
        anything the kernels don't provably replicate takes the walk.
        """
        if type(self.policy) not in _COLUMNAR_POLICIES:
            return False
        if type(self.policy) is MinCostPolicy and not self.edge_names:
            return False  # all-infeasible would KeyError mid-run on the walk
        if type(self.predictor) is not Predictor:
            return False
        if type(self.predictor.cil) is not ContainerInfoList:
            return False
        if len(self.edge_names) > 1 \
                and type(self.balancer) not in _COLUMNAR_BALANCERS:
            return False
        return True

    def _place_columnar(self, tasks, batch, edge_queues) -> DecisionBatch | None:
        ctx = _ColumnarContext(self, tasks, batch, edge_queues)
        n = batch.n
        policy = self.policy
        if not ctx.has_edge and type(policy) is MinLatencyPolicy \
                and not ctx.cloud_names:
            return None  # nothing to choose from — let the walk raise
        if n > 1 and not bool(np.all(np.diff(ctx.nows) >= 0.0)):
            # Out-of-order arrivals: the walk's per-task cil.reap(now) at a
            # far-future task PERMANENTLY drops expired containers before
            # earlier-timed tasks are decided, which the columnar snapshot
            # cannot replicate without replaying every reap — take the walk
            # (all shipped workload generators emit sorted arrivals).
            return None

        # balancer nominations: wait-independent balancers are one precomputed
        # sequence (they never cause a repair); least-predicted-wait is the
        # argmin over the induced wait arrays inside each pass.
        nom_fixed: np.ndarray | None = None
        if ctx.n_dev == 1:
            nom_fixed = np.zeros(n, dtype=np.int64)
        elif ctx.n_dev > 1:
            bal = self.balancer
            if type(bal) is RoundRobinBalancer:
                nom_fixed = (bal._i + np.arange(n, dtype=np.int64)) % ctx.n_dev
                bal._i += n
            elif type(bal) is RandomBalancer:
                # one block draw == n scalar draws on numpy Generators
                nom_fixed = bal.rng.integers(ctx.n_dev, size=n).astype(np.int64)
        ctx.nom_fixed = nom_fixed

        out_code = np.empty(n, dtype=np.int64)
        out_lat = np.empty(n)
        out_cost = np.empty(n)
        out_cold = np.zeros(n, dtype=bool)
        out_comp = np.empty(n)
        out_wait = np.zeros(n)
        out_feas = np.ones(n, dtype=bool)
        out_allowed = np.full(n, np.inf)
        out_dev = np.full(n, -1, dtype=np.int64) if ctx.has_edge else None

        out = (out_code, out_lat, out_cost, out_cold, out_comp, out_wait,
               out_feas, out_allowed, out_dev)
        # Run-length-adaptive speculation: a repair costs one pass over the
        # remaining span, so the span tracks a few multiples of the observed
        # accept-run length (EMA). When runs collapse below COLUMNAR_MIN_RUN
        # — tight edge/cloud oscillation where almost every choice depends on
        # the immediately preceding one — speculation cannot pay, and the
        # scalar-on-arrays loop decides a stretch before speculation retries.
        # slow-start the span: clean regimes double their way up to the full
        # chunk within a few segments, while oscillating regimes never pay a
        # full-chunk pass per repair. A chunked stream resumes from the EMA
        # the previous chunk converged to (see ``_spec_ema``).
        if self._spec_ema is not None:
            run_ema = self._spec_ema
            span = min(float(COLUMNAR_CHUNK),
                       max(float(COLUMNAR_MIN_CHUNK), 8.0 * run_ema))
        else:
            run_ema = float(COLUMNAR_WALK_STRETCH // 8)
            span = 8.0 * run_ema
        repairs_streak = 0
        inner = 0
        end = 0
        guess_code = None  # speculated policy choices for rows [inner, end)
        stats = {"chunks": 0, "repairs": 0, "walked": 0, "n": n}
        while inner < n:
            if repairs_streak >= 3 and run_ema < COLUMNAR_MIN_RUN:
                stretch = min(n, inner + max(COLUMNAR_WALK_STRETCH, int(span)))
                self._cw_scalar_rows(ctx, inner, stretch, out)
                stats["walked"] += stretch - inner
                inner = stretch
                guess_code = None
                repairs_streak = 0
                run_ema = float(COLUMNAR_MIN_RUN)  # neutral: re-measure
                continue
            if guess_code is None:
                # open a speculation segment with the frozen-state guess
                end = min(n, inner + max(COLUMNAR_MIN_CHUNK, int(span)))
                guess_code = self._cw_pass(ctx, inner, end, None)["code"]
                stats["chunks"] += 1
            res = self._cw_pass(ctx, inner, end, guess_code)
            code = res["code"]
            # only the policy choice is speculative: balancer nominations are
            # computed EXACTLY from the speculated edge/cloud pattern, so a
            # matching choice prefix implies a fully exact prefix
            hit = np.nonzero(code != guess_code)[0]
            a = (int(hit[0]) + 1) if hit.size else (end - inner)
            self._cw_accept(ctx, res, inner, a, out)
            inner += a
            run_ema = 0.7 * run_ema + 0.3 * a
            span = min(float(COLUMNAR_CHUNK),
                       max(float(COLUMNAR_MIN_CHUNK), 8.0 * run_ema))
            if hit.size:
                repairs_streak += 1
                stats["repairs"] += 1
                # the corrected tail is the best available guess for the rest
                # of the segment (exact until state next diverges); a repair
                # on the segment's last row leaves nothing to re-verify
                guess_code = code[a:].copy() if inner < end else None
            else:
                repairs_streak = 0
                guess_code = None
        # the walk reaps the CIL at every task's predict; one final reap at
        # the last arrival leaves the identical observable end state
        ctx.cil.reap(float(ctx.nows[-1]))
        self.columnar_stats = stats
        self._spec_ema = run_ema
        return DecisionBatch(
            batch=batch,
            names=tuple(ctx.cloud_names) + tuple(ctx.dev_names),
            n_cloud=ctx.n_cloud,
            task_idx=ctx.task_idx,
            target_codes=out_code,
            latency_ms=out_lat, cost=out_cost, cold=out_cold, comp_ms=out_comp,
            queue_wait_ms=out_wait, feasible=out_feas, allowed_cost=out_allowed,
            edge_device_codes=out_dev,
        )

    def _cw_pass(self, ctx: _ColumnarContext, lo: int, hi: int, spec_code):
        """One vectorized decision pass over rows [lo, hi).

        ``spec_code=None`` is the frozen-state speculation that opens a window
        (state at ``lo`` assumed to hold throughout); an array is a
        verification pass: the three recurrences are replayed EXACTLY under
        the speculated policy choices (segment cumsums for the surplus bank,
        the least-wait assignment walk / segment cumsums for the edge
        horizons, an event walk for the CIL), and the decisions are recomputed
        from that induced state. The first row where they disagree with the
        speculation is where the caller repairs. Balancer nominations are
        *derived* from the speculated edge/cloud pattern, never speculated
        themselves — so a matching choice prefix is a fully exact prefix.
        """
        r = hi - lo
        nows = ctx.nows[lo:hi]

        # --- edge horizons (before each row), nominations, induced waits ----
        HB = None
        nom = None
        ew = None
        if ctx.has_edge:
            if spec_code is not None and ctx.nom_fixed is None and ctx.n_dev > 1:
                # least-predicted-wait on a fleet: the assignment recurrence
                # (argmin over waits, push the winner) is evaluated exactly by
                # a compact scalar walk over the speculated edge rows
                nom, HB = self._lpw_assign(ctx, lo, hi, spec_code)
            else:
                HB = np.empty((r, ctx.n_dev))
                for d, nm in enumerate(ctx.dev_names):
                    h0 = ctx.queues[nm].horizon_ms
                    if spec_code is None:
                        HB[:, d] = h0  # frozen: no pushes assumed
                    else:
                        mask = spec_code == ctx.edge_col
                        if ctx.nom_fixed is not None and ctx.n_dev > 1:
                            mask = mask & (ctx.nom_fixed[lo:hi] == d)
                        rows = np.nonzero(mask)[0]
                        hb, _ = horizon_before(
                            h0, nows[rows], ctx.e_comp[lo:hi][rows, d], rows, r)
                        HB[:, d] = hb
            waits = np.maximum(HB - nows[:, None], 0.0)
            if nom is None:
                if ctx.nom_fixed is not None:
                    nom = ctx.nom_fixed[lo:hi]
                else:  # frozen LPW: first-min argmin == fleet-order ties
                    nom = waits.argmin(axis=1)

        # --- CIL warm/cold flags under the speculated dispatches ------------
        cold_flags = np.empty((r, ctx.n_cloud), dtype=bool)
        events: list[tuple[int, str, float, float]] = []  # (row, name, now, completion)
        for t, nm in enumerate(ctx.cloud_names):
            recs = ctx.cil.containers.get(nm, [])
            busy_l = [c.busy_until for c in recs]
            last_l = [c.last_completion for c in recs]
            ev = (np.nonzero(spec_code == t)[0].tolist()
                  if spec_code is not None else [])
            if not ev:
                cold_flags[:, t] = ~_warm_any(
                    np.asarray(busy_l), np.asarray(last_l), ctx.t_idl, nows)
                continue
            col = np.empty(r, dtype=bool)
            tb = ctx.batch.cloud[nm]
            tgt = ctx.engine.predictor._target(nm)
            t_idl = ctx.t_idl
            prev = 0
            for j in ev:
                if j > prev:
                    col[prev:j] = ~_warm_any(
                        np.asarray(busy_l), np.asarray(last_l), t_idl,
                        nows[prev:j])
                tnow = float(nows[j])
                best = -1
                best_last = -np.inf
                for i2 in range(len(busy_l)):
                    if busy_l[i2] <= tnow <= last_l[i2] + t_idl:
                        if last_l[i2] > best_last:
                            best_last = last_l[i2]
                            best = i2
                is_cold = best < 0
                col[j] = is_cold
                src = tb.cold if is_cold else tb.warm
                comps = {k: float(v[lo + j]) for k, v in src.items()}
                completion = tnow + tgt.occupancy_ms(comps)
                if is_cold:
                    busy_l.append(completion)
                    last_l.append(completion)
                else:
                    busy_l[best] = completion
                    last_l[best] = completion
                events.append((j, nm, tnow, completion))
                prev = j + 1
            if prev < r:
                col[prev:] = ~_warm_any(
                    np.asarray(busy_l), np.asarray(last_l), ctx.t_idl,
                    nows[prev:])
            cold_flags[:, t] = col

        # --- (r, T) latency/cost matrices in the policy-view column order ---
        LAT = np.empty((r, ctx.T))
        COST = np.empty((r, ctx.T))
        COMP = np.empty((r, ctx.T))
        for t in range(ctx.n_cloud):
            cf = cold_flags[:, t]
            LAT[:, t] = np.where(cf, ctx.ccold[t][lo:hi], ctx.cwarm[t][lo:hi])
            COST[:, t] = ctx.ccost[t][lo:hi]
            COMP[:, t] = ctx.ccomp[t][lo:hi]
        if ctx.has_edge:
            rr = np.arange(r)
            ew = waits[rr, nom]
            LAT[:, ctx.edge_col] = ew + ctx.e_lat[lo:hi][rr, nom]
            COST[:, ctx.edge_col] = ctx.e_cost[lo:hi][rr, nom]
            COMP[:, ctx.edge_col] = ctx.e_comp[lo:hi][rr, nom]

        # --- the policy kernel: masked lexicographic argmin -----------------
        policy = self.policy
        if ctx.is_minlat:
            c_max, alpha = policy.c_max, policy.alpha
            if spec_code is None:
                s_traj = np.full(r + 1, policy.surplus)
            else:
                rr0 = np.arange(r)
                s_traj = surplus_trajectory(
                    policy.surplus, c_max, COST[rr0, spec_code])
            allowed = c_max + alpha * s_traj[:-1]
            feas = COST <= allowed[:, None]
            none_f = ~feas.any(axis=1)
            if none_f.any():
                if ctx.has_edge:
                    # fallback set is exactly {nominated edge device}
                    feas[none_f] = False
                    feas[none_f, ctx.edge_col] = True
                else:
                    feas[none_f] = True  # fallback set is all targets
            l1 = np.where(feas, LAT, np.inf)
            lmin = l1.min(axis=1)
            tie = feas & (LAT == lmin[:, None])
            c2 = np.where(tie, COST, np.inf)
            cmin = c2.min(axis=1)
            final = tie & (COST == cmin[:, None])
            code = final.argmax(axis=1).astype(np.int64)
            feas_out = np.ones(r, dtype=bool)
        else:  # MinCostPolicy (always has an edge column — see eligibility)
            deadline = policy.deadline_ms
            feas = LAT <= deadline
            any_f = feas.any(axis=1)
            c1 = np.where(feas, COST, np.inf)
            cmin = c1.min(axis=1)
            tie = feas & (COST == cmin[:, None])
            l2 = np.where(tie, LAT, np.inf)
            lmin = l2.min(axis=1)
            final = tie & (LAT == lmin[:, None])
            code = final.argmax(axis=1).astype(np.int64)
            if ctx.has_edge:
                code[~any_f] = ctx.edge_col
            allowed = np.full(r, np.inf)
            feas_out = any_f
            s_traj = None

        rr = np.arange(r)
        lat_ch = LAT[rr, code]
        cost_ch = COST[rr, code]
        comp_ch = COMP[rr, code]
        if ctx.has_edge:
            is_edge_ch = code == ctx.edge_col
            cold_ch = np.zeros(r, dtype=bool)
            cl = ~is_edge_ch
            cold_ch[cl] = cold_flags[rr[cl], code[cl]]
            wait_ch = np.where(is_edge_ch, ew, 0.0)
        else:
            cold_ch = cold_flags[rr, code]
            wait_ch = np.zeros(r)

        return {
            "code": code, "nom": nom,
            "lat": lat_ch, "cost": cost_ch, "cold": cold_ch, "comp": comp_ch,
            "wait": wait_ch, "allowed": allowed, "feas": feas_out,
            "s_traj": s_traj, "HB": HB, "events": events,
        }

    def _cw_scalar_rows(self, ctx: _ColumnarContext, lo: int, hi: int,
                        out) -> None:
        """Decide rows [lo, hi) one at a time on the columnar arrays.

        Bit-identical to the per-task walk — the same comparisons in the same
        order — but over pre-gathered float lists instead of per-task
        ``Prediction`` dicts, so it is still several times faster. Used when
        a window's choices oscillate too fast for speculation to pay.
        """
        (out_code, out_lat, out_cost, out_cold, out_comp, out_wait,
         out_feas, out_allowed, out_dev) = out
        policy = self.policy
        is_minlat = ctx.is_minlat
        cil = ctx.cil
        t_idl = ctx.t_idl
        nc = ctx.n_cloud
        nd = ctx.n_dev
        has_edge = ctx.has_edge
        edge_col = ctx.edge_col
        nows_l = ctx.nows[lo:hi].tolist()
        cwarm_l = [c[lo:hi].tolist() for c in ctx.cwarm]
        ccold_l = [c[lo:hi].tolist() for c in ctx.ccold]
        ccost_l = [c[lo:hi].tolist() for c in ctx.ccost]
        ccomp_l = [c[lo:hi].tolist() for c in ctx.ccomp]
        if has_edge:
            e_lat_l = [ctx.e_lat[lo:hi, d].tolist() for d in range(nd)]
            e_cost_l = [ctx.e_cost[lo:hi, d].tolist() for d in range(nd)]
            e_comp_l = [ctx.e_comp[lo:hi, d].tolist() for d in range(nd)]
            queues = [ctx.queues[nm] for nm in ctx.dev_names]
        nom_fixed = ctx.nom_fixed
        targets = [self.predictor._target(nm) for nm in ctx.cloud_names]
        tbs = [ctx.batch.cloud[nm] for nm in ctx.cloud_names]

        for i in range(hi - lo):
            now = nows_l[i]
            g = lo + i
            # balancer nomination + nominated-device wait
            if has_edge:
                if nom_fixed is not None:
                    d_nom = int(nom_fixed[g])
                    wait = queues[d_nom].horizon_ms - now
                    if wait < 0.0:
                        wait = 0.0
                else:
                    d_nom = 0
                    wait = queues[0].horizon_ms - now
                    if wait < 0.0:
                        wait = 0.0
                    for d in range(1, nd):
                        w = queues[d].horizon_ms - now
                        if w < 0.0:
                            w = 0.0
                        if w < wait:
                            wait = w
                            d_nom = d
                edge_lat = wait + e_lat_l[d_nom][i]
                edge_cost = e_cost_l[d_nom][i]
            # per-column (lat, cost) with induced CIL warm/cold
            lats = [0.0] * ctx.T
            costs = [0.0] * ctx.T
            colds = [False] * ctx.T
            for t in range(nc):
                warm = False
                for c in cil.containers.get(ctx.cloud_names[t], ()):
                    if c.busy_until <= now <= c.last_completion + t_idl:
                        warm = True
                        break
                colds[t] = not warm
                lats[t] = ccold_l[t][i] if not warm else cwarm_l[t][i]
                costs[t] = ccost_l[t][i]
            if has_edge:
                lats[edge_col] = edge_lat
                costs[edge_col] = edge_cost
            # the policy's lexicographic min, first-wins (dict order == columns)
            if is_minlat:
                allowed = policy.c_max + policy.alpha * policy.surplus
                best = -1
                for t in range(ctx.T):
                    if costs[t] <= allowed and (
                            best < 0 or lats[t] < lats[best]
                            or (lats[t] == lats[best] and costs[t] < costs[best])):
                        best = t
                if best < 0:
                    best = edge_col if has_edge else min(
                        range(ctx.T), key=lambda t: (lats[t], costs[t]))
                feasible = True
            else:
                allowed = float("inf")
                deadline = policy.deadline_ms
                best = -1
                for t in range(ctx.T):
                    if lats[t] <= deadline and (
                            best < 0 or costs[t] < costs[best]
                            or (costs[t] == costs[best] and lats[t] < lats[best])):
                        best = t
                feasible = best >= 0
                if not feasible:
                    best = edge_col  # min-cost always has an edge column
            # outputs + state effects
            out_lat[g] = lats[best]
            out_cost[g] = costs[best]
            out_allowed[g] = allowed
            out_feas[g] = feasible
            if is_minlat:
                policy.surplus += policy.c_max - costs[best]
            if has_edge and best == edge_col:
                out_code[g] = nc + d_nom
                out_cold[g] = False
                out_comp[g] = e_comp_l[d_nom][i]
                out_wait[g] = wait
                q = queues[d_nom]
                h = q.horizon_ms
                q.horizon_ms = (h if h > now else now) + e_comp_l[d_nom][i]
            else:
                out_code[g] = best
                out_cold[g] = colds[best]
                out_comp[g] = ccomp_l[best][i]
                out_wait[g] = 0.0
                tb = tbs[best]
                src = tb.cold if colds[best] else tb.warm
                comps = {k: float(v[g]) for k, v in src.items()}
                cil.record_dispatch(ctx.cloud_names[best], now,
                                    now + targets[best].occupancy_ms(comps))
            if has_edge:
                out_dev[g] = d_nom

    def _lpw_assign(self, ctx: _ColumnarContext, lo: int, hi: int,
                    spec_code: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Exact least-predicted-wait assignment under the speculated
        edge/cloud pattern: per row, argmin over per-device waits (ties break
        by fleet order, like ``LeastPredictedWaitBalancer.pick``), pushing the
        winner's horizon when the row is speculated onto the edge.

        A compact scalar walk over plain float lists — the recurrence's
        winner feeds back into the next row's argmin, so there is no segment
        form; the per-row work is a handful of float ops over ``n_dev``
        devices, orders of magnitude cheaper than the per-task predict walk.
        Returns ``(nominations, horizons_before)``.
        """
        r = hi - lo
        nd = ctx.n_dev
        nows_l = ctx.nows[lo:hi].tolist()
        spec_l = spec_code.tolist()
        edge_col = ctx.edge_col
        h = [ctx.queues[nm].horizon_ms for nm in ctx.dev_names]
        comp_cols = [ctx.e_comp[lo:hi, d].tolist() for d in range(nd)]
        hb_cols = [[0.0] * r for _ in range(nd)]
        nom_l = [0] * r
        for i in range(r):
            now = nows_l[i]
            best = 0
            bw = h[0] - now
            if bw < 0.0:
                bw = 0.0
            hb_cols[0][i] = h[0]
            for d in range(1, nd):
                hv = h[d]
                hb_cols[d][i] = hv
                w = hv - now
                if w < 0.0:
                    w = 0.0
                if w < bw:
                    bw = w
                    best = d
            nom_l[i] = best
            if spec_l[i] == edge_col:
                hv = h[best]
                h[best] = (hv if hv > now else now) + comp_cols[best][i]
        return np.array(nom_l, dtype=np.int64), np.array(hb_cols).T

    def _cw_accept(self, ctx: _ColumnarContext, res: dict, lo: int, a: int,
                   out) -> None:
        """Commit ``a`` verified rows starting at absolute row ``lo``.

        Rows ``[0, a-1)`` of the pass matched their speculation, so every
        induced trajectory through them is the true execution; row ``a-1``
        carries the *recomputed* (exact) decision, whose state effects are
        applied explicitly here — the repair step of speculate-and-repair.
        """
        (out_code, out_lat, out_cost, out_cold, out_comp, out_wait,
         out_feas, out_allowed, out_dev) = out
        code = res["code"]
        sl = slice(lo, lo + a)
        out_lat[sl] = res["lat"][:a]
        out_cost[sl] = res["cost"][:a]
        out_cold[sl] = res["cold"][:a]
        out_comp[sl] = res["comp"][:a]
        out_wait[sl] = res["wait"][:a]
        out_feas[sl] = res["feas"][:a]
        out_allowed[sl] = res["allowed"][:a]
        acc_code = code[:a]
        if ctx.has_edge:
            nom = res["nom"]
            out_dev[sl] = nom[:a]
            # map policy-view codes to the global table: edge → n_cloud + dev
            gc = acc_code.copy()
            em = gc == ctx.edge_col
            gc[em] = ctx.n_cloud + nom[:a][em]
            out_code[sl] = gc
        else:
            out_code[sl] = acc_code

        k = a - 1  # the repaired (or final) row — exact decision, fresh effects
        # surplus bank
        policy = self.policy
        if ctx.is_minlat:
            s_traj = res["s_traj"]
            policy.surplus = float(s_traj[k] + (policy.c_max - res["cost"][k]))
        # edge horizons: the speculated trajectory is exact through row k-1
        # (all matched), so commit the horizon *before* row k and then apply
        # row k's push with its corrected choice — never the speculated one.
        if ctx.has_edge:
            HB = res["HB"]
            for d, nm in enumerate(ctx.dev_names):
                ctx.queues[nm].horizon_ms = float(HB[k, d])
            if code[k] == ctx.edge_col:
                d = int(res["nom"][k])
                q = ctx.queues[ctx.dev_names[d]]
                q.horizon_ms = max(float(HB[k, d]), float(ctx.nows[lo + k])) \
                    + float(ctx.e_comp[lo + k, d])
        # CIL: replay speculated dispatches at rows < k, then row k's own
        for row, nm, tnow, completion in sorted(res["events"]):
            if row < k:
                ctx.cil.record_dispatch(nm, tnow, completion)
        if (not ctx.has_edge) or code[k] != ctx.edge_col:
            t = int(code[k])
            nm = ctx.cloud_names[t]
            tb = ctx.batch.cloud[nm]
            src = tb.cold if res["cold"][k] else tb.warm
            comps = {kk: float(v[lo + k]) for kk, v in src.items()}
            tnow = float(ctx.nows[lo + k])
            completion = tnow + ctx.engine.predictor._target(nm).occupancy_ms(comps)
            ctx.cil.record_dispatch(nm, tnow, completion)

    # ------------------------------------------------------------------
    def _decide(self, task, now: float, preds: dict[str, Prediction],
                waits: Mapping[str, float] | None = None) -> PlacementDecision:
        names = self.edge_names
        if len(names) > 1:
            edge_choice = self.balancer.pick(names, waits or {}, preds)
            # the policy is defined against ONE λ_edge: it sees the cloud
            # configs plus the balancer's nominated device only
            policy_view = {n: p for n, p in preds.items()
                           if n == edge_choice or n not in names}
        else:
            edge_choice = names[0] if names else self.edge_name
            policy_view = preds
        name, feasible, allowed = self.policy.choose(policy_view, edge_choice)
        chosen = preds[name]
        hedge = self.policy.hedge(policy_view, name, allowed, edge_choice)
        if hedge is not None and hedge[0] == name:
            hedge = None  # a duplicate of the primary is not a hedge
        self.policy.observe(chosen)
        self.predictor.update_cil(name, now, chosen)
        if hedge is not None:
            # the duplicate dispatch occupies a container too
            self.predictor.update_cil(hedge[0], now, hedge[1])
        d = PlacementDecision(
            task_idx=getattr(task, "idx", -1),
            target=name,
            prediction=chosen,
            feasible=feasible,
            allowed_cost=allowed,
            hedge_target=hedge[0] if hedge is not None else None,
            hedge_prediction=hedge[1] if hedge is not None else None,
            edge_device=edge_choice if names else None,
        )
        if self.record_decisions:
            self.decisions.append(d)
        return d
