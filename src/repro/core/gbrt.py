"""Gradient Boosted Regression Trees, from scratch (paper Sec. IV-A compute model).

scikit-learn is not available in this environment, so we implement least-squares
gradient boosting with depth-limited regression trees ourselves:

- trees are complete binary trees in heap layout (root 0, children 2i+1/2i+2),
  which makes prediction a fixed-depth, fully-vectorizable index walk — the
  same representation the Pallas serving kernel (``repro.kernels.gbrt_predict``)
  consumes directly;
- splits are found with histogram scans over per-feature quantile bins;
- nodes that cannot improve SSE become pass-through (threshold=+inf ⇒ all
  samples go left) so every tree keeps the complete-tree shape.

``GBRT.predict`` is numpy (fast scalar calls for the event simulator);
``GBRT.predict_jax`` is a jit-able jnp path used by benchmarks and as the
oracle for the Pallas kernel.
"""

from __future__ import annotations

import threading
import weakref
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

_JAX = None  # cached import probe: () = unavailable, (jax, jnp) = ready


def _jax_modules():
    global _JAX
    if _JAX is None:
        try:
            import jax
            import jax.numpy as jnp

            _JAX = (jax, jnp)
        except Exception:  # pragma: no cover - jax is part of the toolchain
            _JAX = ()
    return _JAX if _JAX else None


# Device-resident (feats, thrs, lvs) scan operands per model identity — the
# same weakref-guard pattern as predictor._CONST1_TABLES: a refit swaps in a
# fresh model object, which misses the cache and hosts its own operands; a
# recycled id is caught by the weakref before stale arrays are served.
_JAX_OPS: dict[int, tuple] = {}
_JAX_OPS_LOCK = threading.Lock()


def _jax_operands(model: "GBRT"):
    _, jnp = _jax_modules()
    key = id(model)
    with _JAX_OPS_LOCK:
        hit = _JAX_OPS.get(key)
        if hit is not None:
            ref, ops = hit
            if ref() is model:
                return ops
            _JAX_OPS.pop(key, None)  # id recycled by a swap: stale
    ops = (jnp.asarray(model.features), jnp.asarray(model.thresholds),
           jnp.asarray(model.leaves))
    with _JAX_OPS_LOCK:
        if len(_JAX_OPS) > 256:  # drop entries whose model is gone
            for k in [k for k, (r, _) in _JAX_OPS.items() if r() is None]:
                _JAX_OPS.pop(k, None)
        _JAX_OPS[key] = (weakref.ref(model), ops)
    return ops


@dataclass(frozen=True)
class GBRTConfig:
    n_trees: int = 150
    max_depth: int = 3
    learning_rate: float = 0.1
    n_bins: int = 64
    min_samples_leaf: int = 4
    min_gain: float = 1e-12


@dataclass
class GBRT:
    config: GBRTConfig
    base: float = 0.0
    # Stacked tree arrays: (T, n_internal) and (T, n_leaves)
    features: np.ndarray = field(default_factory=lambda: np.zeros((0, 0), np.int32))
    thresholds: np.ndarray = field(default_factory=lambda: np.zeros((0, 0), np.float64))
    leaves: np.ndarray = field(default_factory=lambda: np.zeros((0, 0), np.float64))

    # ------------------------------------------------------------------ fit
    @classmethod
    def fit(cls, x: np.ndarray, y: np.ndarray, config: GBRTConfig | None = None) -> "GBRT":
        config = config or GBRTConfig()
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if x.ndim == 1:
            x = x[:, None]
        n, d = x.shape
        depth = config.max_depth
        n_internal = 2**depth - 1
        n_leaves = 2**depth

        # Per-feature quantile bin edges (candidate thresholds).
        edges = []
        for j in range(d):
            qs = np.quantile(x[:, j], np.linspace(0, 1, config.n_bins + 1)[1:-1])
            edges.append(np.unique(qs))

        base = float(np.mean(y))
        pred = np.full(n, base)
        feats = np.zeros((config.n_trees, n_internal), np.int32)
        thrs = np.full((config.n_trees, n_internal), np.inf)
        lvs = np.zeros((config.n_trees, n_leaves), np.float64)

        for t in range(config.n_trees):
            resid = y - pred
            f_t, th_t, lv_t = _fit_tree(x, resid, edges, config)
            feats[t], thrs[t], lvs[t] = f_t, th_t, lv_t
            pred += config.learning_rate * _predict_tree(x, f_t, th_t, lv_t, depth)
        return cls(config=config, base=base, features=feats, thresholds=thrs, leaves=lvs)

    # -------------------------------------------------------------- predict
    def predict(self, x) -> np.ndarray:
        """Vectorized numpy prediction; accepts (n,d), (d,), or scalar (d=1)."""
        x = np.asarray(x, dtype=np.float64)
        scalar = x.ndim == 0
        if x.ndim == 0:
            x = x[None, None]
        elif x.ndim == 1:
            # Ambiguity: 1-feature batch vs single multi-feature row. Our models
            # always pass batches of rows, so treat (k,) as k rows of 1 feature
            # when the model has 1 feature, else as one row.
            if self.features.size and self.n_features == 1:
                x = x[:, None]
            else:
                x = x[None, :]
        depth = self.config.max_depth
        out = np.full(x.shape[0], self.base)
        for t in range(self.features.shape[0]):
            out += self.config.learning_rate * _predict_tree(
                x, self.features[t], self.thresholds[t], self.leaves[t], depth
            )
        return float(out[0]) if scalar else out

    def const1_table(self, c: float) -> tuple[np.ndarray, np.ndarray]:
        """The (breaks, values) step table of ``predict_const1`` for feature 1
        fixed at ``c`` — built once per (model, c) and cached on the model.

        Exposed so serving-side caches (``repro.core.predictor``'s
        per-(model, comp_feature) table cache) can hold the table without
        re-deriving it per call. A refit must swap in a FRESH model object
        (never mutate a fitted one): both this cache and the serving cache key
        on the model's identity, so mutation would serve stale tables.
        """
        key = float(c)
        cache = self.__dict__.setdefault("_const1_tables", {})
        tab = cache.get(key)
        if tab is None:
            # segment boundaries: every finite feature-0 threshold. Predicates
            # are ``x > thr`` (right), so values are constant on (b_{i-1}, b_i]
            # and b_i is an exact representative; +inf represents the last
            # open segment (x > every finite threshold).
            mask = (self.features == 0) & np.isfinite(self.thresholds)
            breaks = np.unique(self.thresholds[mask])
            reps = np.concatenate([breaks, [np.inf]])
            pts = np.stack([reps, np.full(reps.shape[0], key)], axis=1)
            tab = (breaks, self.predict(pts))
            cache[key] = tab
        return tab

    def predict_const1(self, x0: np.ndarray, c: float) -> np.ndarray:
        """Fast path for 2-feature models whose feature 1 is fixed at ``c``.

        The serving pipeline evaluates the compute GBRT over (size, memory_mb)
        with ONE memory value per cloud target, so for a fixed ``c`` every
        feature-1 predicate is a constant and the whole ensemble collapses to
        a step function of feature 0. The table is built once per (model, c)
        by running the ordinary tree walk at one representative point per
        threshold segment — predictions are therefore BIT-IDENTICAL to
        ``predict`` (identical leaf paths, identical accumulation order) at a
        searchsorted's cost instead of a 150-tree walk per row.
        """
        breaks, vals = self.const1_table(c)
        return vals[np.searchsorted(breaks, np.asarray(x0, np.float64),
                                    side="left")]

    def predict_jax(self, x):
        """jit-able jnp prediction path. ``x``: (n, d) array.

        The jax import sits behind the module-level cached probe and the
        ``(feats, thrs, lvs)`` scan operands are hosted once per model
        identity (``_JAX_OPS``), so repeated calls — the bench loop, a jit
        retrace — neither re-import nor re-transfer the ensemble. Refit by
        swapping in a fresh model object; the weakref guard keeps recycled
        ids from serving stale operands.
        """
        mods = _jax_modules()
        if mods is None:  # pragma: no cover - jax is part of the toolchain
            raise RuntimeError("predict_jax requires jax")
        jax, jnp = mods
        feats, thrs, lvs = _jax_operands(self)
        depth = self.config.max_depth
        lr = self.config.learning_rate
        base = self.base

        def one_tree(carry, tree):
            f, th, lv = tree
            node = jnp.zeros(x.shape[0], dtype=jnp.int32)
            for _ in range(depth):
                go_right = x[jnp.arange(x.shape[0]), f[node]] > th[node]
                node = 2 * node + 1 + go_right.astype(jnp.int32)
            leaf = node - (2**depth - 1)
            return carry + lr * lv[leaf], None

        x = jnp.asarray(x, dtype=jnp.float64 if x.dtype == np.float64 else jnp.float32)
        init = jnp.full(x.shape[0], base, dtype=x.dtype)
        out, _ = jax.lax.scan(one_tree, init, (feats, thrs, lvs))
        return out

    @property
    def n_features(self) -> int:
        return int(self.features.max()) + 1 if self.features.size else 1

    def mape(self, x: np.ndarray, y: np.ndarray) -> float:
        pred = self.predict(x)
        y = np.asarray(y, dtype=np.float64)
        return float(np.mean(np.abs(pred - y) / np.maximum(np.abs(y), 1e-9))) * 100.0


def _fit_tree(x, resid, edges, config: GBRTConfig):
    """Fit one depth-limited regression tree to residuals. Heap array layout."""
    n, d = x.shape
    depth = config.max_depth
    n_internal = 2**depth - 1
    n_leaves = 2**depth
    feature = np.zeros(n_internal, np.int32)
    threshold = np.full(n_internal, np.inf)  # +inf = pass-through (all left)
    node_value = np.zeros(2**(depth + 1) - 1)  # value at every heap node
    node_value[0] = resid.mean() if n else 0.0

    assign = np.zeros(n, np.int64)  # heap node id per sample
    for level in range(depth):
        level_nodes = range(2**level - 1, 2**(level + 1) - 1)
        new_assign = assign.copy()
        for node in level_nodes:
            mask = assign == node
            cnt = int(mask.sum())
            node_value[2 * node + 1] = node_value[node]
            node_value[2 * node + 2] = node_value[node]
            if cnt < 2 * config.min_samples_leaf:
                continue  # pass-through node
            xs, rs = x[mask], resid[mask]
            best = _best_split(xs, rs, edges, config)
            if best is None:
                continue
            j, thr, left_mean, right_mean = best
            feature[node] = j
            threshold[node] = thr
            go_right = xs[:, j] > thr
            idx = np.nonzero(mask)[0]
            new_assign[idx[~go_right]] = 2 * node + 1
            new_assign[idx[go_right]] = 2 * node + 2
            node_value[2 * node + 1] = left_mean
            node_value[2 * node + 2] = right_mean
        assign = new_assign

    leaves = node_value[n_internal : n_internal + n_leaves].copy()
    return feature, threshold, leaves


def _best_split(xs, rs, edges: Sequence[np.ndarray], config: GBRTConfig):
    """Best (feature, threshold) by SSE reduction via cumulative-sum scan."""
    n = xs.shape[0]
    total_sum = rs.sum()
    best_gain, best = config.min_gain, None
    parent_sse_term = total_sum**2 / n
    for j, ed in enumerate(edges):
        if ed.size == 0:
            continue
        # bucket samples by threshold: side[i, b] = xs[i, j] > ed[b]
        order = np.argsort(xs[:, j], kind="stable")
        xj = xs[order, j]
        rj = rs[order]
        csum = np.cumsum(rj)
        # position of last element <= threshold
        pos = np.searchsorted(xj, ed, side="right")
        valid = (pos >= config.min_samples_leaf) & (n - pos >= config.min_samples_leaf)
        if not valid.any():
            continue
        pos_v = pos[valid]
        left_sum = csum[pos_v - 1]
        right_sum = total_sum - left_sum
        gain = left_sum**2 / pos_v + right_sum**2 / (n - pos_v) - parent_sse_term
        k = int(np.argmax(gain))
        if gain[k] > best_gain:
            best_gain = float(gain[k])
            thr = float(ed[np.nonzero(valid)[0][k]])
            lmean = float(left_sum[k] / pos_v[k])
            rmean = float(right_sum[k] / (n - pos_v[k]))
            best = (j, thr, lmean, rmean)
    return best


def _predict_tree(x, feature, threshold, leaves, depth):
    node = np.zeros(x.shape[0], np.int64)
    for _ in range(depth):
        go_right = x[np.arange(x.shape[0]), feature[node]] > threshold[node]
        node = 2 * node + 1 + go_right.astype(np.int64)
    return leaves[node - (2**depth - 1)]


def grid_search_cv(
    x: np.ndarray,
    y: np.ndarray,
    grid: Sequence[GBRTConfig],
    k: int = 3,
    seed: int = 0,
) -> tuple[GBRTConfig, float]:
    """Paper Sec. IV-C3: grid search with k-fold CV; returns (best config, cv MAPE)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    if x.ndim == 1:
        x = x[:, None]
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    folds = np.array_split(perm, k)
    best_cfg, best_err = None, np.inf
    for cfg in grid:
        errs = []
        for i in range(k):
            test_idx = folds[i]
            train_idx = np.concatenate([folds[j] for j in range(k) if j != i])
            model = GBRT.fit(x[train_idx], y[train_idx], cfg)
            errs.append(model.mape(x[test_idx], y[test_idx]))
        err = float(np.mean(errs))
        if err < best_err:
            best_cfg, best_err = cfg, err
    return best_cfg, best_err
