"""Overload survival: predictive container pre-warming + fair-share tier
reclamation.

Two policies, both optional, both off by default (``PlacementRuntime(...,
prewarm=None, reclamation=None)`` is bit-identical per record to a runtime
built without them):

**Predictive pre-warming** (context-aware orchestration, PAPERS.md): a
streaming burst forecaster watches the arrival-gap process — a fast EWMA of
recent inter-arrival gaps against a slow quiet-regime baseline — and flags
the quiet→burst regime switch of an MMPP source (``BurstyWorkload``) a few
arrivals into the burst, while the cold-start storm is still ahead. On each
trigger the runtime spawns ``PrewarmPolicy.count`` containers per cloud
configuration via ``ContainerInfoList.prewarm`` (client-side shadow) and
``GroundTruthCloud.spinup`` (twin ground truth), warm for
``keepalive_ms`` past their spin-up; the idle keep-alive retainer is debited
from the Alg. 1 surplus bank exactly once per container, at spawn.

**Fair-share reclamation** (LaSS, PAPERS.md): when a device's predicted
queue horizon pushes top-tier (tier 0) predicted latencies past their
deadline headroom, lower-tier work already *placed* on that device — not
just new arrivals at the admission door — is preempted and re-placed through
the columnar ``failover_choice`` path with the pressured device masked.
Each tier owns a share of a device's compute; only compute *beyond* a
tier's fair share is reclaimable, lowest class first. Preempted tasks are
demoted one SLO class when the move (or forced stay) costs them their old
deadline — recorded first-class as ``RecordBatch.downgraded``.

Determinism contract (PR 8's, extended): the forecaster is a pure scalar
fold over arrival gaps with its state carried across chunks, so feeding one
chunk of N arrivals or N chunks of 1 produces bit-identical state and the
identical spawn schedule — which is what makes the prewarm/preempt/downgrade
schedule reproducible across ``serve`` / ``serve_stream`` (any chunking) /
``serve_async`` for a fixed seed. Victim selection is a pure function of
the (deterministic) placement batch. Nothing here draws randomness.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.faults import FaultError, SLOTier


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise FaultError(msg)


@dataclass(frozen=True)
class PrewarmPolicy:
    """Configuration of the predictive pre-warmer.

    ``count`` containers are spawned per target on every burst trigger;
    ``targets=None`` means every cloud configuration the predictor knows.
    ``spinup_ms=None`` asks the runtime for the backend's cold-start mean
    (the honest "containers take this long to come up" figure). The
    remaining fields parameterize the ``BurstForecaster``.
    """

    count: int = 2
    targets: tuple[str, ...] | None = None
    keepalive_ms: float = 60_000.0
    spinup_ms: float | None = None
    # forecaster knobs — see BurstForecaster
    alpha: float = 0.2
    baseline_alpha: float = 0.02
    ratio: float = 3.0
    exit_ratio: float = 1.5
    min_gaps: int = 16
    cooldown_ms: float = 1_000.0

    def __post_init__(self):
        if self.targets is not None:
            object.__setattr__(self, "targets", tuple(self.targets))
        _require(self.count >= 1,
                 f"prewarm count must be >= 1 container per trigger, got "
                 f"{self.count!r}")
        _require(np.isfinite(self.keepalive_ms) and self.keepalive_ms > 0.0,
                 f"keepalive_ms must be a finite positive duration, got "
                 f"{self.keepalive_ms!r}")
        _require(self.spinup_ms is None
                 or (np.isfinite(self.spinup_ms) and self.spinup_ms >= 0.0),
                 f"spinup_ms must be None (use the backend's cold-start "
                 f"mean) or a finite non-negative duration, got "
                 f"{self.spinup_ms!r}")
        for nm, v in (("alpha", self.alpha),
                      ("baseline_alpha", self.baseline_alpha)):
            _require(0.0 < v <= 1.0,
                     f"{nm} must be an EWMA weight in (0, 1], got {v!r}")
        _require(np.isfinite(self.ratio) and self.ratio > 1.0,
                 f"ratio must be finite and > 1 (gaps must shrink below the "
                 f"baseline to signal a burst), got {self.ratio!r}")
        _require(np.isfinite(self.exit_ratio)
                 and 1.0 <= self.exit_ratio < self.ratio,
                 f"exit_ratio must satisfy 1 <= exit_ratio < ratio "
                 f"(hysteresis — exiting must be easier than entering), got "
                 f"exit_ratio={self.exit_ratio!r} vs ratio={self.ratio!r}")
        _require(self.min_gaps >= 1,
                 f"min_gaps must be >= 1 warm-up gap, got {self.min_gaps!r}")
        _require(np.isfinite(self.cooldown_ms) and self.cooldown_ms >= 0.0,
                 f"cooldown_ms must be a finite non-negative duration, got "
                 f"{self.cooldown_ms!r}")


@dataclass(frozen=True)
class ReclamationPolicy:
    """Per-``SLOTier`` fair shares for overload reclamation.

    ``tiers[i]`` is the SLO class of tasks carrying ``tier == i`` (0 =
    highest, deadlines strictly decreasing down the table, exactly as
    ``AdmissionPolicy``). ``shares[i]`` is tier i's claim on each device's
    compute: only a tier's compute *beyond* ``shares[i] / sum(shares)`` of
    the device total may be reclaimed when tier 0 is pressured.
    ``headroom`` scales the tier-0 deadline the pressure test uses (< 1
    reclaims earlier).
    """

    tiers: tuple[SLOTier, ...]
    shares: tuple[float, ...]
    headroom: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "tiers", tuple(self.tiers))
        object.__setattr__(
            self, "shares", tuple(float(s) for s in self.shares))
        _require(len(self.tiers) >= 2,
                 f"ReclamationPolicy needs at least two SLOTiers — with one "
                 f"class there is nothing to reclaim from, got "
                 f"{len(self.tiers)}")
        _require(len(self.shares) == len(self.tiers),
                 f"shares must give one weight per tier: got "
                 f"{len(self.shares)} shares for {len(self.tiers)} tiers")
        for i, s in enumerate(self.shares):
            _require(np.isfinite(s) and s > 0.0,
                     f"shares[{i}] must be a finite positive weight, got "
                     f"{s!r}")
        _require(np.isfinite(self.headroom) and self.headroom > 0.0,
                 f"headroom must be a finite positive scale factor, got "
                 f"{self.headroom!r}")
        for i in range(1, len(self.tiers)):
            _require(
                self.tiers[i].deadline_ms < self.tiers[i - 1].deadline_ms,
                f"tier deadlines must be strictly decreasing down the table "
                f"(lower SLO classes carry tighter thresholds so they "
                f"degrade first): tiers[{i}].deadline_ms="
                f"{self.tiers[i].deadline_ms!r} >= tiers[{i - 1}]."
                f"deadline_ms={self.tiers[i - 1].deadline_ms!r}")

    def deadline_of(self, tier: int) -> float:
        return self.tiers[min(max(tier, 0), len(self.tiers) - 1)].deadline_ms


@dataclass
class BurstForecaster:
    """Streaming quiet/burst regime detector over inter-arrival gaps.

    Two EWMAs of the gap sequence: ``fast`` (weight ``alpha``) tracks the
    current arrival rate, ``slow`` (weight ``baseline_alpha``) tracks the
    quiet-regime baseline and is FROZEN while a burst is in progress (so a
    long burst cannot drag the baseline down and mask itself). Quiet →
    burst when ``fast * ratio < slow`` after at least ``min_gaps`` gaps;
    burst → quiet when ``fast * exit_ratio >= slow``. Each quiet→burst
    transition emits one spawn trigger, rate-limited by ``cooldown_ms``.

    ``feed`` is a plain scalar fold: state after feeding one chunk of N
    arrivals is bit-identical to feeding the same arrivals in any chunking
    — the property the cross-serve-path schedule-identity contract rests
    on. (A vectorized closed-form EWMA would drift from the fold in the
    last ulp and could flip a threshold crossing at one chunking but not
    another.) The fold only runs when pre-warming is armed; policies-off
    serves never construct one.
    """

    alpha: float = 0.2
    baseline_alpha: float = 0.02
    ratio: float = 3.0
    exit_ratio: float = 1.5
    min_gaps: int = 16
    cooldown_ms: float = 1_000.0
    # streaming state (carried across chunks / serve calls)
    last_t: float | None = None
    fast: float | None = None
    slow: float | None = None
    n_gaps: int = 0
    in_burst: bool = False
    last_spawn: float = float("-inf")
    n_triggers: int = 0

    @classmethod
    def from_policy(cls, p: PrewarmPolicy) -> "BurstForecaster":
        return cls(alpha=p.alpha, baseline_alpha=p.baseline_alpha,
                   ratio=p.ratio, exit_ratio=p.exit_ratio,
                   min_gaps=p.min_gaps, cooldown_ms=p.cooldown_ms)

    def feed(self, arrival_ms) -> list[float]:
        """Fold a chunk of arrival times (nondecreasing within and across
        chunks); returns the spawn-trigger times fired inside this chunk."""
        times = np.asarray(arrival_ms, dtype=np.float64)
        if times.size == 0:
            return []
        triggers: list[float] = []
        # locals for the hot fold (only runs when pre-warming is armed)
        a, b = self.alpha, self.baseline_alpha
        ratio, exit_ratio = self.ratio, self.exit_ratio
        min_gaps, cooldown = self.min_gaps, self.cooldown_ms
        last_t, fast, slow = self.last_t, self.fast, self.slow
        n_gaps, in_burst, last_spawn = \
            self.n_gaps, self.in_burst, self.last_spawn
        for t in times.tolist():
            if last_t is None:
                last_t = t
                continue
            g = t - last_t
            if g < 0.0:
                g = 0.0  # defensive: out-of-order feed degrades gracefully
            last_t = t
            if fast is None:
                fast = slow = g  # seed both EWMAs with the first gap
                n_gaps = 1
                continue
            fast += a * (g - fast)
            n_gaps += 1
            if in_burst:
                if fast * exit_ratio >= slow:
                    in_burst = False
                continue
            slow += b * (g - slow)
            if n_gaps >= min_gaps and fast * ratio < slow:
                in_burst = True
                if t - last_spawn >= cooldown:
                    last_spawn = t
                    triggers.append(t)
        self.last_t, self.fast, self.slow = last_t, fast, slow
        self.n_gaps, self.in_burst, self.last_spawn = \
            n_gaps, in_burst, last_spawn
        self.n_triggers += len(triggers)
        return triggers


def select_victims(policy: ReclamationPolicy, *, codes: np.ndarray,
                   tier: np.ndarray, latency_ms: np.ndarray,
                   comp_ms: np.ndarray, active: np.ndarray,
                   n_cloud: int, n_targets: int) -> np.ndarray:
    """Pick the rows fair-share reclamation preempts from a placement batch.

    Pure function of the (deterministic) columnar decision — no state, no
    randomness — which is what makes the preempt schedule reproducible
    across serve paths. Per edge device (fleet order):

    - the device is *pressured* when any tier-0 row placed on it predicts
      latency beyond ``tiers[0].deadline_ms * headroom``;
    - the relief target is the worst such overshoot;
    - eligible victims are lower-tier rows placed on the device that arrive
      no later than the last pressured row (work behind the pressure point
      cannot relieve it);
    - tiers are drained lowest class first, each capped at its compute
      beyond its fair share of the device total, earliest arrivals first.

    Returns victim row indices, ascending (= arrival order).
    """
    nt = len(policy.tiers)
    t = np.clip(np.asarray(tier, dtype=np.int64), 0, nt - 1)
    pressure_ms = policy.tiers[0].deadline_ms * policy.headroom
    shares = np.asarray(policy.shares, dtype=np.float64)
    share_frac = shares / shares.sum()
    victims: list[int] = []
    for dev_code in range(n_cloud, n_targets):
        rows = np.nonzero(active & (codes == dev_code))[0]
        if rows.size == 0:
            continue
        rt = t[rows]
        pressured = rows[(rt == 0) & (latency_ms[rows] > pressure_ms)]
        if pressured.size == 0:
            continue
        relief = float(np.max(latency_ms[pressured])) - pressure_ms
        eligible = rows[rows <= pressured[-1]]
        total_comp = float(np.sum(comp_ms[rows]))
        for tv in range(nt - 1, 0, -1):
            if relief <= 0.0:
                break
            cand = eligible[t[eligible] == tv]
            if cand.size == 0:
                continue
            cap = float(np.sum(comp_ms[cand])) - share_frac[tv] * total_comp
            for r in cand.tolist():
                if relief <= 0.0 or cap <= 0.0:
                    break
                victims.append(r)
                c = float(comp_ms[r])
                relief -= c
                cap -= c
    return np.array(sorted(victims), dtype=np.int64)


@dataclass
class _PrewarmEntry:
    """Live bookkeeping for one speculatively spawned container."""

    target: str
    spawned_ms: float
    ready_ms: float
    expires_ms: float
    cost: float
    cil_rec: object  # the ContainerRecord (stable identity in the CIL)


class OverloadManager:
    """Runtime-side holder of the overload policies and their audit trails.

    Owns the forecaster (streaming state) plus two append-only ledgers the
    schedule-identity tests compare across serve paths:

    - ``prewarm_log``: ``(trigger_ms, target, ready_ms, expires_ms, cost)``
      per spawned container (cost already debited from the surplus bank —
      exactly once, at spawn);
    - ``reclaim_log``: ``(now_ms, task_idx, src, dst, tier_from, tier_to,
      moved, downgraded)`` per preempted task (``dst == src`` and
      ``moved=False`` when every alternative was excluded and the task was
      forcibly kept in place, demoted).
    """

    def __init__(self, prewarm: PrewarmPolicy | None = None,
                 reclamation: ReclamationPolicy | None = None):
        if prewarm is None and reclamation is None:
            raise FaultError(
                "OverloadManager needs a PrewarmPolicy, a ReclamationPolicy, "
                "or both — with neither it would do nothing")
        self.prewarm = prewarm
        self.reclamation = reclamation
        self.forecaster = (BurstForecaster.from_policy(prewarm)
                           if prewarm is not None else None)
        self.prewarm_log: list[tuple] = []
        self.reclaim_log: list[tuple] = []
        self.active_prewarms: list[_PrewarmEntry] = []
        self.n_extensions = 0

    def feed_arrivals(self, arrival_ms) -> list[float]:
        """Advance the burst forecaster; returns spawn-trigger times."""
        if self.forecaster is None:
            return []
        return self.forecaster.feed(arrival_ms)

    def record_spawn(self, trigger_ms: float, target: str, ready_ms: float,
                     expires_ms: float, cost: float, cil_rec) -> None:
        """Ledger one spawned container (the runtime already debited it)."""
        self.prewarm_log.append(
            (trigger_ms, target, ready_ms, expires_ms, cost))
        self.active_prewarms.append(_PrewarmEntry(
            target=target, spawned_ms=trigger_ms, ready_ms=ready_ms,
            expires_ms=expires_ms, cost=cost, cil_rec=cil_rec))

    def reap_prewarms(self, now: float) -> None:
        """Drop bookkeeping for keep-alive windows that have passed (the CIL
        reaps its own records; this trims the extension candidates)."""
        if self.active_prewarms:
            self.active_prewarms = [
                e for e in self.active_prewarms if e.expires_ms > now]
