"""Workload generation (paper Sec. II-B, VI-A).

Inputs are ingested at a fixed rate from the data source; the simulator feeds
them at Poisson-process intervals (paper Sec. VI-A): 4 inputs/s for IR and FD
(traffic/smart camera), one input per 10 s for STT (smart speaker).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np


@dataclass
class TaskInput:
    idx: int
    arrival_ms: float
    size: float   # model feature: pixels (IR/FD) or bytes (STT) or tokens (LLM)
    bytes: float  # payload size for network transfer
    meta: dict = field(default_factory=dict)


@dataclass
class PoissonWorkload:
    """Poisson arrivals with app-specific input size sampling."""

    rate_per_s: float
    size_sampler: Callable[[np.random.Generator], tuple[float, float]]
    seed: int = 0

    def generate(self, n: int) -> list[TaskInput]:
        rng = np.random.default_rng(self.seed)
        gaps_ms = rng.exponential(1000.0 / self.rate_per_s, size=n)
        arrivals = np.cumsum(gaps_ms)
        tasks = []
        for i in range(n):
            size, nbytes = self.size_sampler(rng)
            tasks.append(TaskInput(idx=i, arrival_ms=float(arrivals[i]), size=size, bytes=nbytes))
        return tasks
