"""Workload generation (paper Sec. II-B, VI-A) — list, columnar, and streaming.

Inputs are ingested at a fixed rate from the data source; the simulator feeds
them at Poisson-process intervals (paper Sec. VI-A): 4 inputs/s for IR and FD
(traffic/smart camera), one input per 10 s for STT (smart speaker).

Three forms of the same workload:

- ``generate(n)`` — the familiar ``list[TaskInput]`` (per-task objects);
- ``TaskChunk`` — the struct-of-arrays form of a span of tasks: one float64
  column per field instead of N objects. The batched serve path
  (``predict_batch``, the columnar decision core, ``execute_many``) reads the
  columns directly, so a chunk never materializes a single ``TaskInput`` on
  the hot path — and the numpy work it feeds releases the GIL, which is what
  lets ``ShardedRuntime`` overlap independent application streams in threads;
- ``chunks(n, chunk_size)`` — a generator of ``TaskChunk``s for streaming
  serves (``PlacementRuntime.serve_stream``): O(chunk) live tasks instead of
  O(n). For ``PoissonWorkload`` the chunk stream is BIT-IDENTICAL to
  ``generate(n)`` (the gap block is drawn exactly as ``generate`` draws it,
  and per-chunk size blocks consume the Generator stream exactly like the
  per-task sampler loop — numpy Generators produce the same values drawn one
  at a time or as a block). ``BurstyWorkload.chunks`` runs the identical
  scalar phase walk and is therefore also bit-identical to its ``generate``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterator, Sequence

import numpy as np


@dataclass
class TaskInput:
    idx: int
    arrival_ms: float
    size: float   # model feature: pixels (IR/FD) or bytes (STT) or tokens (LLM)
    bytes: float  # payload size for network transfer
    meta: dict = field(default_factory=dict)
    tier: int = 0  # SLO class (0 = highest) — admission control, see core.faults


@dataclass(eq=False)
class TaskChunk(Sequence):
    """Struct-of-arrays form of a span of ``TaskInput``s.

    Indexing / iterating materializes ``TaskInput`` views lazily (so every
    per-task consumer keeps working); the vectorized serve path reads the
    columns directly and never builds a view. Slicing returns a ``TaskChunk``
    over array views — what ``serve_stream`` does to walk a big chunk.
    """

    idx: np.ndarray         # (n,) int64 — position in the source workload
    arrival_ms: np.ndarray  # (n,) float64
    size: np.ndarray        # (n,) float64
    bytes: np.ndarray       # (n,) float64
    tier: np.ndarray | None = None  # (n,) int64 SLO class; None = all tier 0
    # arrival-regime ground truth (``BurstyWorkload.chunks``): True where the
    # MMPP phase walk was in its burst phase — what ``generate`` carries as
    # ``meta["burst"]``, columnar so forecaster tests have per-task truth at
    # any chunk size. None = untracked (Poisson sources, hand-built chunks).
    burst: np.ndarray | None = None  # (n,) bool

    @classmethod
    def from_tasks(cls, tasks: Sequence[TaskInput]) -> "TaskChunk":
        tiers = np.array([getattr(t, "tier", 0) for t in tasks], dtype=np.int64)
        return cls(
            idx=np.array([t.idx for t in tasks], dtype=np.int64),
            arrival_ms=np.array([t.arrival_ms for t in tasks], dtype=np.float64),
            size=np.array([t.size for t in tasks], dtype=np.float64),
            bytes=np.array([t.bytes for t in tasks], dtype=np.float64),
            tier=tiers if tiers.any() else None,
        )

    def tier_codes(self) -> np.ndarray:
        """The SLO-class column, materialized (zeros when untiered)."""
        if self.tier is not None:
            return self.tier
        return np.zeros(len(self), dtype=np.int64)

    def __len__(self) -> int:
        return self.arrival_ms.shape[0]

    def __bool__(self) -> bool:
        return len(self) > 0

    def __getitem__(self, i):
        if isinstance(i, slice):
            return TaskChunk(idx=self.idx[i], arrival_ms=self.arrival_ms[i],
                             size=self.size[i], bytes=self.bytes[i],
                             tier=None if self.tier is None else self.tier[i],
                             burst=None if self.burst is None else self.burst[i])
        i = int(i)
        return TaskInput(idx=int(self.idx[i]), arrival_ms=float(self.arrival_ms[i]),
                         size=float(self.size[i]), bytes=float(self.bytes[i]),
                         meta={"burst": bool(self.burst[i])}
                         if self.burst is not None else {},
                         tier=int(self.tier[i]) if self.tier is not None else 0)

    def __iter__(self) -> Iterator[TaskInput]:
        for i in range(len(self)):
            yield self[i]


def task_tiers(tasks) -> np.ndarray:
    """The SLO-class column of any task container (int64, 0 = highest).

    ``TaskChunk`` hands back its (possibly synthesized) tier column; task
    lists gather the per-object ``tier`` attribute. Used by the runtime's
    admission-control pass (``repro.core.faults.AdmissionPolicy``).
    """
    if isinstance(tasks, TaskChunk):
        return tasks.tier_codes()
    return np.array([getattr(t, "tier", 0) for t in tasks], dtype=np.int64)


def first_disorder(arrival_ms) -> int:
    """Index of the first out-of-arrival-order element, ``-1`` if sorted.

    The serve paths treat a non-monotone arrival stream as a signal to fall
    back to the per-task walk; trace ingestion (``repro.trace``) instead
    REJECTS unsorted traces up front — this is the shared detector, so the
    error can name the exact offending record.
    """
    a = np.asarray(arrival_ms, dtype=np.float64)
    if a.shape[0] < 2:
        return -1
    bad = np.nonzero(np.diff(a) < 0.0)[0]
    return int(bad[0]) + 1 if bad.size else -1


def task_arrays(tasks, fields: str = "iasb",
                ) -> tuple[np.ndarray | None, np.ndarray | None,
                           np.ndarray | None, np.ndarray | None]:
    """``(idx, arrival_ms, size, bytes)`` columns for any task container.

    ``TaskChunk`` hands its columns back for free; a ``list[TaskInput]`` is
    gathered with one comprehension per column — but only for the columns
    named in ``fields`` (``i``/``a``/``s``/``b``; the rest come back as
    ``None``), so callers that need two columns don't pay four O(n) Python
    gathers. Every vectorized stage (``predict_batch``, the columnar decision
    core, ``execute_many``) goes through here, so the object→array churn
    exists in exactly one place — and vanishes entirely on the streaming
    chunk path.
    """
    if isinstance(tasks, TaskChunk):
        return tasks.idx, tasks.arrival_ms, tasks.size, tasks.bytes
    return (
        np.array([getattr(t, "idx", -1) for t in tasks], dtype=np.int64)
        if "i" in fields else None,
        np.array([t.arrival_ms for t in tasks], dtype=np.float64)
        if "a" in fields else None,
        np.array([t.size for t in tasks], dtype=np.float64)
        if "s" in fields else None,
        np.array([t.bytes for t in tasks], dtype=np.float64)
        if "b" in fields else None,
    )


@dataclass
class PoissonWorkload:
    """Poisson arrivals with app-specific input size sampling.

    ``size_sampler_batch`` is the optional vectorized form of
    ``size_sampler``: ``(rng, n) -> (sizes, nbytes)`` arrays whose draws
    consume the Generator stream exactly like ``n`` sequential
    ``size_sampler`` calls (``AWSTwin.sample_input_batch`` satisfies this).
    With it, ``chunks()`` generates million-task streams without a Python
    loop per task.
    """

    rate_per_s: float
    size_sampler: Callable[[np.random.Generator], tuple[float, float]]
    seed: int = 0
    size_sampler_batch: Callable[[np.random.Generator, int],
                                 tuple[np.ndarray, np.ndarray]] | None = None

    def generate(self, n: int) -> list[TaskInput]:
        rng = np.random.default_rng(self.seed)
        gaps_ms = rng.exponential(1000.0 / self.rate_per_s, size=n)
        arrivals = np.cumsum(gaps_ms)
        tasks = []
        for i in range(n):
            size, nbytes = self.size_sampler(rng)
            tasks.append(TaskInput(idx=i, arrival_ms=float(arrivals[i]), size=size, bytes=nbytes))
        return tasks

    def chunks(self, n: int, chunk_size: int = 65536) -> Iterator[TaskChunk]:
        """Stream the workload as ``TaskChunk``s of ``chunk_size`` tasks.

        Bit-identical to ``generate(n)``: the full gap block is drawn first
        (exactly as ``generate`` draws it — O(n) float64s, the only O(n)
        state), then sizes are drawn in arrival order, per chunk — as one
        block when ``size_sampler_batch`` is available, else per task.
        """
        rng = np.random.default_rng(self.seed)
        arrivals = np.cumsum(rng.exponential(1000.0 / self.rate_per_s, size=n))
        for lo in range(0, n, chunk_size):
            hi = min(lo + chunk_size, n)
            m = hi - lo
            if self.size_sampler_batch is not None:
                sizes, nbytes = self.size_sampler_batch(rng, m)
                sizes = np.asarray(sizes, dtype=np.float64)
                nbytes = np.asarray(nbytes, dtype=np.float64)
            else:
                sizes = np.empty(m)
                nbytes = np.empty(m)
                for j in range(m):
                    sizes[j], nbytes[j] = self.size_sampler(rng)
            yield TaskChunk(idx=np.arange(lo, hi, dtype=np.int64),
                            arrival_ms=arrivals[lo:hi],
                            size=sizes, bytes=nbytes)


@dataclass
class BurstyWorkload:
    """Markov-modulated Poisson arrivals: quiet/burst phases (skewed arrivals).

    The process alternates between a quiet phase at ``rate_per_s`` and a burst
    phase at ``rate_per_s × burst_multiplier``; phase durations are
    exponential. Exponential gaps are memoryless, so re-drawing the gap at a
    phase switch is exact. This is the skewed-arrival scenario edge-fleet
    balancers are judged on (least-predicted-wait vs round-robin): bursts pile
    queueing onto whichever devices a backlog-blind balancer keeps feeding.
    """

    rate_per_s: float
    size_sampler: Callable[[np.random.Generator], tuple[float, float]]
    burst_multiplier: float = 8.0
    mean_quiet_s: float = 20.0
    mean_burst_s: float = 5.0
    seed: int = 0

    def _walk(self, n: int) -> Iterator[tuple[float, float, float, bool]]:
        """The scalar phase walk shared by ``generate`` and ``chunks`` —
        gap/phase/size draws interleave per task, so there is no block form."""
        rng = np.random.default_rng(self.seed)
        t = 0.0
        in_burst = False
        phase_end = rng.exponential(self.mean_quiet_s * 1e3)
        emitted = 0
        while emitted < n:
            rate = self.rate_per_s * (self.burst_multiplier if in_burst else 1.0)
            gap = rng.exponential(1000.0 / rate)
            if t + gap >= phase_end:
                t = phase_end
                in_burst = not in_burst
                mean_s = self.mean_burst_s if in_burst else self.mean_quiet_s
                phase_end = t + rng.exponential(mean_s * 1e3)
                continue
            t += gap
            size, nbytes = self.size_sampler(rng)
            yield t, size, nbytes, in_burst
            emitted += 1

    def generate(self, n: int) -> list[TaskInput]:
        return [TaskInput(idx=i, arrival_ms=t, size=size, bytes=nbytes,
                          meta={"burst": burst})
                for i, (t, size, nbytes, burst) in enumerate(self._walk(n))]

    def chunks(self, n: int, chunk_size: int = 65536) -> Iterator[TaskChunk]:
        """Stream the workload as ``TaskChunk``s — the identical scalar phase
        walk as ``generate`` (bit-identical arrivals/sizes), retaining
        O(chunk) tasks at a time. Each chunk carries the per-task regime
        flag ``generate`` puts in ``meta['burst']`` as its columnar
        ``burst`` array, so burst-forecaster tests have ground truth at any
        chunk size."""
        walk = self._walk(n)
        done = 0
        while done < n:
            m = min(chunk_size, n - done)
            arrivals = np.empty(m)
            sizes = np.empty(m)
            nbytes = np.empty(m)
            burst = np.empty(m, dtype=bool)
            for j in range(m):
                arrivals[j], sizes[j], nbytes[j], burst[j] = next(walk)
            yield TaskChunk(idx=np.arange(done, done + m, dtype=np.int64),
                            arrival_ms=arrivals, size=sizes, bytes=nbytes,
                            burst=burst)
            done += m
