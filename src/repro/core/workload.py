"""Workload generation (paper Sec. II-B, VI-A).

Inputs are ingested at a fixed rate from the data source; the simulator feeds
them at Poisson-process intervals (paper Sec. VI-A): 4 inputs/s for IR and FD
(traffic/smart camera), one input per 10 s for STT (smart speaker).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np


@dataclass
class TaskInput:
    idx: int
    arrival_ms: float
    size: float   # model feature: pixels (IR/FD) or bytes (STT) or tokens (LLM)
    bytes: float  # payload size for network transfer
    meta: dict = field(default_factory=dict)


@dataclass
class PoissonWorkload:
    """Poisson arrivals with app-specific input size sampling."""

    rate_per_s: float
    size_sampler: Callable[[np.random.Generator], tuple[float, float]]
    seed: int = 0

    def generate(self, n: int) -> list[TaskInput]:
        rng = np.random.default_rng(self.seed)
        gaps_ms = rng.exponential(1000.0 / self.rate_per_s, size=n)
        arrivals = np.cumsum(gaps_ms)
        tasks = []
        for i in range(n):
            size, nbytes = self.size_sampler(rng)
            tasks.append(TaskInput(idx=i, arrival_ms=float(arrivals[i]), size=size, bytes=nbytes))
        return tasks


@dataclass
class BurstyWorkload:
    """Markov-modulated Poisson arrivals: quiet/burst phases (skewed arrivals).

    The process alternates between a quiet phase at ``rate_per_s`` and a burst
    phase at ``rate_per_s × burst_multiplier``; phase durations are
    exponential. Exponential gaps are memoryless, so re-drawing the gap at a
    phase switch is exact. This is the skewed-arrival scenario edge-fleet
    balancers are judged on (least-predicted-wait vs round-robin): bursts pile
    queueing onto whichever devices a backlog-blind balancer keeps feeding.
    """

    rate_per_s: float
    size_sampler: Callable[[np.random.Generator], tuple[float, float]]
    burst_multiplier: float = 8.0
    mean_quiet_s: float = 20.0
    mean_burst_s: float = 5.0
    seed: int = 0

    def generate(self, n: int) -> list[TaskInput]:
        rng = np.random.default_rng(self.seed)
        tasks: list[TaskInput] = []
        t = 0.0
        in_burst = False
        phase_end = rng.exponential(self.mean_quiet_s * 1e3)
        while len(tasks) < n:
            rate = self.rate_per_s * (self.burst_multiplier if in_burst else 1.0)
            gap = rng.exponential(1000.0 / rate)
            if t + gap >= phase_end:
                t = phase_end
                in_burst = not in_burst
                mean_s = self.mean_burst_s if in_burst else self.mean_quiet_s
                phase_end = t + rng.exponential(mean_s * 1e3)
                continue
            t += gap
            size, nbytes = self.size_sampler(rng)
            tasks.append(TaskInput(idx=len(tasks), arrival_ms=t, size=size,
                                   bytes=nbytes, meta={"burst": in_burst}))
        return tasks
