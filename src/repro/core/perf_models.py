"""Component performance models (paper Sec. IV).

The paper models each latency component separately:

- upload / edge-compute: (ridge) linear regression on input size,
- warm/cold startup, storage, IoT-upload: normal random variables, predicted by
  the training-set mean (storage is additionally quantized by S3's 1 s
  timestamp granularity, which only affects measurement, not the model form),
- cloud compute: gradient-boosted regression trees (see ``repro.core.gbrt``).

These are small models fit on CPU with closed-form or histogram methods; the
prediction paths are vectorizable and also exposed through JAX (and, for the
serving hot path, through a Pallas kernel — ``repro.kernels.gbrt_predict``).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


def fit_ridge(x: np.ndarray, y: np.ndarray, l2: float = 1e-6) -> np.ndarray:
    """Closed-form ridge regression with bias: returns theta for [1, x...] features.

    ``x``: (n,) or (n, d) features, ``y``: (n,) targets.
    """
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    if x.ndim == 1:
        x = x[:, None]
    n = x.shape[0]
    X = np.concatenate([np.ones((n, 1)), x], axis=1)
    d = X.shape[1]
    reg = l2 * np.eye(d)
    reg[0, 0] = 0.0  # don't penalize the bias
    theta = np.linalg.solve(X.T @ X + reg, X.T @ y)
    return theta


@dataclass
class RidgeModel:
    """Linear model ``y = theta_0 + theta_1 * x_1 + ...`` (paper: upld(k), edge comp(k))."""

    theta: np.ndarray = field(default_factory=lambda: np.zeros(2))

    @classmethod
    def fit(cls, x: np.ndarray, y: np.ndarray, l2: float = 1e-6) -> "RidgeModel":
        return cls(theta=fit_ridge(x, y, l2=l2))

    def predict(self, x) -> np.ndarray:
        """Elementwise affine map — deliberately NOT a BLAS matmul.

        ``X @ theta`` routes through gemv, whose reduction order (FMA,
        blocking) may depend on the batch size, so the same row could predict
        differently in a 1-row and a 10k-row batch — a last-ULP wobble that
        would break the streaming serve's bit-parity across chunk sizes.
        A fixed left-fold of elementwise ops gives the identical float for
        every element at every batch size.
        """
        x = np.asarray(x, dtype=np.float64)
        scalar = x.ndim == 0
        if x.ndim <= 1:
            x = np.atleast_1d(x)[:, None]
        out = self.theta[0] + x[:, 0] * self.theta[1]
        for j in range(1, x.shape[1]):
            out = out + x[:, j] * self.theta[j + 1]
        return float(out[0]) if scalar else out

    def mape(self, x: np.ndarray, y: np.ndarray) -> float:
        pred = self.predict(x)
        y = np.asarray(y, dtype=np.float64)
        return float(np.mean(np.abs(pred - y) / np.maximum(np.abs(y), 1e-9))) * 100.0


@dataclass
class NormalModel:
    """Normal-random-variable component model, predicted by its mean.

    Used for start_w(m)/start_c(m), store(k), iotup(k). ``quantum`` reproduces
    the S3 coarse-timestamp quantization the paper observed (measurement-side).
    Quantile prediction (``predict_quantile``) powers the beyond-paper
    variance-aware placement policy.
    """

    mean: float = 0.0
    std: float = 0.0
    quantum: float = 0.0

    @classmethod
    def fit(cls, samples: np.ndarray, quantum: float = 0.0) -> "NormalModel":
        s = np.asarray(samples, dtype=np.float64)
        if quantum > 0:
            s = np.round(s / quantum) * quantum
        return cls(mean=float(np.mean(s)), std=float(np.std(s)), quantum=quantum)

    def predict(self) -> float:
        return self.mean

    def predict_quantile(self, q: float) -> float:
        """Mean + z_q * std via Acklam's inverse-normal approximation (no scipy)."""
        return self.mean + _norm_ppf(q) * self.std

    def sample(self, rng: np.random.Generator, n: int | None = None):
        out = rng.normal(self.mean, self.std, size=n)
        return np.maximum(out, 0.0)


def _norm_ppf(q: float) -> float:
    """Inverse standard-normal CDF (Acklam's rational approximation, |err| < 1.15e-9)."""
    if not 0.0 < q < 1.0:
        raise ValueError(f"quantile must be in (0,1), got {q}")
    a = [-3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
         1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00]
    b = [-5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
         6.680131188771972e01, -1.328068155288572e01]
    c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
         -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00]
    d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
         3.754408661907416e00]
    plow, phigh = 0.02425, 1 - 0.02425
    if q < plow:
        ql = np.sqrt(-2 * np.log(q))
        return (((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4]) * ql + c[5]) / \
               ((((d[0] * ql + d[1]) * ql + d[2]) * ql + d[3]) * ql + 1)
    if q > phigh:
        ql = np.sqrt(-2 * np.log(1 - q))
        return -(((((c[0] * ql + c[1]) * ql + c[2]) * ql + c[3]) * ql + c[4]) * ql + c[5]) / \
                ((((d[0] * ql + d[1]) * ql + d[2]) * ql + d[3]) * ql + 1)
    ql = q - 0.5
    r = ql * ql
    return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * ql / \
           (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)


def mape(pred: np.ndarray, actual: np.ndarray) -> float:
    """Mean absolute percentage error (paper Table II metric)."""
    pred = np.asarray(pred, dtype=np.float64)
    actual = np.asarray(actual, dtype=np.float64)
    return float(np.mean(np.abs(pred - actual) / np.maximum(np.abs(actual), 1e-9))) * 100.0


@dataclass(frozen=True)
class ScaledModel:
    """A component model whose predictions are multiplied by a constant factor.

    Heterogeneous edge fleets reuse one fitted compute model per device class:
    a device running at relative speed ``s`` predicts ``base.predict(x) / s``
    (``scale = 1/s``). Works for scalars and arrays, so both the per-task and
    the batched prediction paths stay in parity.
    """

    base: object
    scale: float = 1.0

    def predict(self, x):
        out = self.base.predict(x)
        if np.ndim(out) == 0:
            return float(out) * self.scale
        return np.asarray(out) * self.scale
