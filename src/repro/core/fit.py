"""Model training & evaluation (paper Sec. IV-C): fit the component models from
collected measurements, 80:20 split, and build a ready-to-use Predictor.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.apps import AWSTwin, Measurements, MEMORY_CONFIGS_MB, collect_measurements
from repro.core.cil import ContainerInfoList, DEFAULT_T_IDL_MS
from repro.core.gbrt import GBRT, GBRTConfig
from repro.core.perf_models import NormalModel, RidgeModel, mape
from repro.core.predictor import EdgeFleet, EdgeTarget, LambdaTarget, Predictor
from repro.core.pricing import LambdaPricing


@dataclass
class FittedModels:
    upld: RidgeModel
    comp_cloud: GBRT
    start_warm: NormalModel
    start_cold: NormalModel
    store_cloud: NormalModel
    comp_edge: RidgeModel
    iotup: NormalModel
    store_edge: NormalModel
    cloud_comp_std_frac: float
    edge_comp_std_frac: float
    # Table II evaluation on held-out test split:
    cloud_e2e_mape: float = float("nan")
    edge_e2e_mape: float = float("nan")


def split_indices(n: int, frac: float = 0.8, seed: int = 0):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    cut = int(n * frac)
    return perm[:cut], perm[cut:]


def fit_models(
    meas: Measurements,
    gbrt_config: GBRTConfig | None = None,
    seed: int = 0,
) -> FittedModels:
    """Fit every component model on an 80% split; evaluate end-to-end MAPE on 20%."""
    gbrt_config = gbrt_config or GBRTConfig(n_trees=150, max_depth=3, learning_rate=0.1)

    n_cloud = meas.sizes.shape[0]
    tr, te = split_indices(n_cloud, 0.8, seed)

    upld = RidgeModel.fit(meas.nbytes[tr], meas.upld[tr])
    x_comp = np.stack([meas.sizes, meas.memory], axis=1)
    comp_cloud = GBRT.fit(x_comp[tr], meas.comp[tr], gbrt_config)
    start_warm = NormalModel.fit(meas.start_warm)
    start_cold = NormalModel.fit(meas.start_cold)
    store_cloud = NormalModel.fit(meas.store[tr], quantum=0.0)

    comp_pred_tr = comp_cloud.predict(x_comp[tr])
    cloud_std_frac = float(np.std((meas.comp[tr] - comp_pred_tr) / np.maximum(comp_pred_tr, 1e-9)))

    n_edge = meas.edge_sizes.shape[0]
    etr, ete = split_indices(n_edge, 0.8, seed + 1)
    comp_edge = RidgeModel.fit(meas.edge_sizes[etr], meas.edge_comp[etr])
    iotup = NormalModel.fit(meas.iotup[etr])
    store_edge = NormalModel.fit(meas.edge_store[etr])
    edge_pred_tr = comp_edge.predict(meas.edge_sizes[etr])
    edge_std_frac = float(np.std((meas.edge_comp[etr] - edge_pred_tr) / np.maximum(edge_pred_tr, 1e-9)))

    # ---- Table II: end-to-end MAPE on the held-out test split (warm start) ----
    cloud_pred = (
        upld.predict(meas.nbytes[te])
        + start_warm.predict()
        + comp_cloud.predict(x_comp[te])
        + store_cloud.predict()
    )
    # Actual end-to-end for the same rows, with a fresh warm-start draw per row
    rng = np.random.default_rng(seed + 2)
    cloud_actual = (
        meas.upld[te]
        + np.maximum(rng.normal(start_warm.mean, start_warm.std, te.shape[0]), 1.0)
        + meas.comp[te]
        + meas.store[te]
    )
    cloud_e2e_mape = mape(cloud_pred, cloud_actual)

    edge_pred = comp_edge.predict(meas.edge_sizes[ete]) + iotup.predict() + store_edge.predict()
    edge_actual = meas.edge_comp[ete] + meas.iotup[ete] + meas.edge_store[ete]
    edge_e2e_mape = mape(edge_pred, edge_actual)

    return FittedModels(
        upld=upld, comp_cloud=comp_cloud, start_warm=start_warm, start_cold=start_cold,
        store_cloud=store_cloud, comp_edge=comp_edge, iotup=iotup, store_edge=store_edge,
        cloud_comp_std_frac=cloud_std_frac, edge_comp_std_frac=edge_std_frac,
        cloud_e2e_mape=cloud_e2e_mape, edge_e2e_mape=edge_e2e_mape,
    )


def build_predictor(
    models: FittedModels,
    configs: tuple[int, ...] = MEMORY_CONFIGS_MB,
    pricing: LambdaPricing | None = None,
    t_idl_ms: float = DEFAULT_T_IDL_MS,
    quantile: float | None = None,
) -> Predictor:
    pricing = pricing or LambdaPricing()
    cloud_targets = [
        LambdaTarget(
            name=str(m), memory_mb=float(m),
            upld_model=models.upld,
            start_warm=models.start_warm, start_cold=models.start_cold,
            comp_model=models.comp_cloud, store_model=models.store_cloud,
            pricing=pricing, comp_std_frac=models.cloud_comp_std_frac,
        )
        for m in configs
    ]
    edge_target = EdgeTarget(
        comp_model=models.comp_edge, iotup_model=models.iotup,
        store_model=models.store_edge, comp_std_frac=models.edge_comp_std_frac,
    )
    return Predictor(
        cloud_targets=cloud_targets, edge_target=edge_target,
        cil=ContainerInfoList(t_idl_ms=t_idl_ms), quantile=quantile,
    )


def build_fleet_predictor(
    models: FittedModels,
    edge_devices: int | dict[str, float],
    configs: tuple[int, ...] = MEMORY_CONFIGS_MB,
    pricing: LambdaPricing | None = None,
    t_idl_ms: float = DEFAULT_T_IDL_MS,
    quantile: float | None = None,
    prefix: str = "edge",
) -> Predictor:
    """``build_predictor`` over a multi-device edge fleet.

    ``edge_devices`` is either a device count (homogeneous fleet named
    ``{prefix}0..{prefix}{n-1}``) or a mapping ``name -> relative speed``
    (arbitrary device names; a device at speed ``s`` predicts ``comp/s``).
    The matching twin is ``TwinBackend(..., edge_names=..., edge_speed=...)``.
    """
    base = build_predictor(models, configs=configs, pricing=pricing,
                           t_idl_ms=t_idl_ms, quantile=quantile)
    template = base.edge_target
    if isinstance(edge_devices, int):
        fleet = EdgeFleet.replicate(template, edge_devices, prefix=prefix)
    else:
        fleet = EdgeFleet.from_speeds(template, edge_devices)
    return Predictor(cloud_targets=base.cloud_targets, edge_fleet=fleet,
                     cil=ContainerInfoList(t_idl_ms=t_idl_ms), quantile=quantile)


def fit_app(app_name: str, seed: int = 0, n_inputs: int | None = None,
            configs: tuple[int, ...] = MEMORY_CONFIGS_MB) -> tuple[AWSTwin, FittedModels]:
    """Convenience: twin + measurements + fitted models for one paper app."""
    from repro.core.apps import APPS

    twin = AWSTwin(spec=APPS[app_name], seed=seed)
    meas = collect_measurements(twin, n_inputs=n_inputs, configs=configs, seed=seed + 1)
    models = fit_models(meas, seed=seed + 2)
    return twin, models
