"""Sequential recurrences evaluated as vectorized segment passes.

The serve path contains a handful of genuinely sequential recurrences — the
single-slot FIFO busy horizon, the surplus bank, the CIL warm/cold shadow —
that would otherwise force a per-task Python walk. The trick shared by all of
them: between "reset" events the recurrence is a plain running sum, and
``np.cumsum`` accumulates float64 strictly sequentially (``np.add.accumulate``
is a sequential loop), so each segment can be evaluated as one vectorized pass
that is BIT-IDENTICAL to the scalar loop.

``fifo_starts`` is the canonical instance (used by both the twin's ground-truth
executors and the Decision Engine's predicted edge queues);
``surplus_trajectory`` applies the same concat-then-cumsum device to Alg. 1's
budget bank. The columnar decision core (``repro.core.decision``) builds its
speculate-and-repair passes out of these.
"""

from __future__ import annotations

import numpy as np


def fifo_starts(free: float, nows: np.ndarray,
                comp: np.ndarray) -> tuple[np.ndarray, float]:
    """Execution start times on one single-slot FIFO executor.

    Bitwise-identical to the scalar recurrence ``start_j = max(F, now_j);
    F = start_j + comp_j``: between idle periods the busy horizon is a plain
    running sum, and ``np.cumsum`` accumulates in the same sequential order,
    so each busy segment is one vectorized pass. Falls back to the scalar
    loop if the device goes idle many times (quiet workloads — cheap anyway).

    Returns ``(starts, final_free)``.
    """
    nd = nows.shape[0]
    start = np.empty(nd)
    pos = 0
    segments = 0
    while pos < nd and segments < 32:
        segments += 1
        f_trial = np.cumsum(np.concatenate(([free], comp[pos:])))
        viol = np.nonzero(nows[pos:] > f_trial[:-1])[0]
        if viol.size == 0:  # never idle again: the trial horizon is exact
            start[pos:] = f_trial[:-1]
            return start, float(f_trial[-1])
        k = int(viol[0])  # first idle gap: horizon resets to the arrival
        if k:
            start[pos:pos + k] = f_trial[:k]
        j = pos + k
        s = float(nows[j])
        start[j] = s
        free = s + float(comp[j])
        pos = j + 1
    if pos < nd:  # many idle periods: scalar recurrence for the tail
        nows_l = nows[pos:].tolist()
        comp_l = comp[pos:].tolist()
        for j in range(nd - pos):
            now_j = nows_l[j]
            s = free if free > now_j else now_j
            start[pos + j] = s
            free = s + comp_l[j]
    return start, float(free)


def horizon_before(free: float, nows: np.ndarray, comp: np.ndarray,
                   push_rows: np.ndarray, n_rows: int) -> tuple[np.ndarray, float]:
    """Busy horizon *before* each of ``n_rows`` decision rows, given pushes at
    ``push_rows`` (sorted row indices) with arrival/compute ``nows``/``comp``
    (both already gathered to the push subsequence).

    The horizon only advances at push rows (``h ← max(h, now) + comp``, the
    ``PredictedEdgeQueue.push`` recurrence == the FIFO start recurrence), so
    the trajectory is ``fifo_starts`` on the subsequence plus a forward fill
    across all rows. Returns ``(h_before, final_free)``.
    """
    if push_rows.size == 0:
        return np.full(n_rows, free), free
    starts, final = fifo_starts(free, nows, comp)
    horizons = starts + comp  # horizon right after each push
    counts = np.searchsorted(push_rows, np.arange(n_rows), side="left")
    h_before = np.concatenate(([free], horizons))[counts]
    return h_before, final


def surplus_trajectory(s0: float, c_max: float,
                       chosen_cost: np.ndarray) -> np.ndarray:
    """Alg. 1's surplus bank as one sequential-order cumsum.

    ``out[i]`` is the bank *before* decision ``i`` and ``out[-1]`` the bank
    after the last one — bit-identical to repeating
    ``surplus += c_max - cost`` because the initial value is folded into the
    cumsum (float addition is not associative; ``cumsum`` keeps the scalar
    loop's exact association).
    """
    return np.cumsum(np.concatenate(([s0], c_max - chosen_cost)))


def maxplus_combine(x, y, maximum=np.maximum):
    """Associative combine for the FIFO/edge-horizon recurrence in (max, +).

    ``h_i = max(h_{i-1}, now_i) + comp_i`` (a push) and ``h_i = h_{i-1}`` (no
    push) are both affine maps in the max-plus semiring, ``f(h) = max(h + a,
    b)`` with ``(a, b) = (comp, now + comp)`` resp. ``(0, -inf)``. Composition
    stays in that family — ``(f2 ∘ f1)(h) = max(h + (a1 + a2), max(b1 + a2,
    b2))`` — which is exactly this combine, so the whole horizon trajectory is
    one ``associative_scan`` over ``(a, b)`` pairs with no segment fallback.
    Reassociating float sums is NOT bit-stable, so the device core only uses
    this form under its decision-equality contract (``SCAN_MODE="assoc"``);
    the sequential folds stay the bit-parity path. Pass ``jnp.maximum`` to use
    it inside a jit trace.
    """
    a1, b1 = x
    a2, b2 = y
    return a1 + a2, maximum(b1 + a2, b2)
