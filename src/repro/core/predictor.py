"""The Predictor (paper Sec. V-A).

Given an input, the Predictor returns predicted end-to-end latency and cost for
every execution target: the N cloud configurations Φ = {λ_m} and the edge
executor λ_edge. Cold-vs-warm start is decided by consulting the CIL. The
Decision Engine then calls ``update_cil`` with the chosen configuration.

Targets are pluggable so the same Predictor drives both the AWS reproduction
(LambdaTarget/EdgeTarget, models from Sec. IV) and the TPU-fleet adaptation
(``repro.serving.placement.SliceTarget``).

Two prediction paths:

- ``predict(task, now)`` — the paper's per-task call: consult the CIL, return
  one ``Prediction`` per target;
- ``predict_batch(tasks)`` + ``predict_at(batch, i, now)`` — the batched API:
  every component model (ridge/normal/GBRT — all accept arrays) is evaluated
  ONCE over all tasks × targets, for both the warm and the cold start variant;
  ``predict_at`` then assembles the per-task view by consulting the CIL, which
  is the only genuinely sequential part. ``DecisionEngine.place_many`` builds
  on this; results are identical to per-task ``predict`` (same models, same
  arithmetic, vectorized).

The ``quantile`` option is a beyond-paper extension (the paper's stated future
work): predict a latency quantile instead of the mean, so placement can hedge
against the high variance the paper observed in cloud pipelines.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Protocol

import numpy as np

from repro.core.cil import ContainerInfoList
from repro.core.perf_models import NormalModel, RidgeModel, _norm_ppf
from repro.core.pricing import EdgePricing, LambdaPricing

EDGE = "edge"


@dataclass(frozen=True)
class Prediction:
    target: str
    latency_ms: float
    cost: float
    cold: bool
    components: Mapping[str, float]

    @property
    def comp_ms(self) -> float:
        return self.components.get("comp", 0.0)


class ExecutionTarget(Protocol):
    """A place a task can run: a cloud config λ_m, the edge device, a TPU slice."""

    name: str
    is_edge: bool

    def predict_components(self, task, cold: bool, quantile: float | None) -> dict[str, float]:
        """Latency components in ms. Must include a 'comp' entry."""
        ...

    def predict_components_batch(self, sizes: np.ndarray, nbytes: np.ndarray,
                                 quantile: float | None) -> tuple[dict, dict | None]:
        """Vectorized components for n tasks: (warm, cold) dicts of (n,) arrays.

        ``cold`` is ``None`` for always-warm targets (the edge). Optional —
        ``Predictor.predict_batch`` falls back to per-task calls when absent.
        """
        ...

    def cost(self, comp_ms: float) -> float:
        ...

    def cost_batch(self, comp_ms: np.ndarray) -> np.ndarray:
        """Vectorized ``cost`` over an array of compute times. Optional."""
        ...

    def occupancy_ms(self, components: dict[str, float]) -> float:
        """How long the executor/container is held busy (for CIL bookkeeping)."""
        ...


@dataclass(frozen=True)
class TargetBatch:
    """Vectorized predictions for one target across a batch of tasks."""

    warm: dict[str, np.ndarray]          # component -> (n,) ms
    cold: dict[str, np.ndarray] | None   # None for always-warm targets
    warm_latency: np.ndarray             # (n,) — sum of warm components
    cold_latency: np.ndarray | None
    cost: np.ndarray                     # (n,) — cost depends on comp only


@dataclass(frozen=True)
class PredictionBatch:
    """All component-model evaluations for a batch of tasks, both start modes.

    Warm/cold selection and edge queueing are *not* baked in — they depend on
    sequential CIL / edge-queue state and are resolved per task by
    ``Predictor.predict_at``.
    """

    n: int
    cloud: dict[str, TargetBatch]
    edge: TargetBatch | None
    edge_name: str | None


def cloud_components_batch(sizes: np.ndarray, nbytes: np.ndarray, *,
                           comp_feature: float, comp_model, upld_model,
                           start_warm: NormalModel, start_cold: NormalModel,
                           store_model: NormalModel, comp_std_frac: float,
                           quantile: float | None) -> tuple[dict, dict]:
    """Shared vectorized cloud pipeline: upld + start + comp + store.

    One source of truth for the batch variant of the cloud-target component
    math (``LambdaTarget`` with ``memory_mb``, ``SliceTarget`` with
    ``chips``), so the scalar/batch parity guarantee has a single place to
    break — and a parity test to catch it.
    """
    n = sizes.shape[0]
    feats = np.stack([sizes, np.full(n, comp_feature)], axis=1)
    comp = np.asarray(comp_model.predict(feats), dtype=np.float64)
    if quantile is not None:
        z = _norm_ppf(quantile)
        comp = comp * (1.0 + z * comp_std_frac)
        warm_start = start_warm.predict_quantile(quantile)
        cold_start = start_cold.predict_quantile(quantile)
        store_ms = store_model.predict_quantile(quantile)
    else:
        warm_start = start_warm.predict()
        cold_start = start_cold.predict()
        store_ms = store_model.predict()
    warm = {
        "upld": np.maximum(np.asarray(upld_model.predict(nbytes)), 0.0),
        "start": np.full(n, max(warm_start, 0.0)),
        "comp": np.maximum(comp, 0.0),
        "store": np.full(n, max(store_ms, 0.0)),
    }
    cold = dict(warm, start=np.full(n, max(cold_start, 0.0)))
    return warm, cold


def edge_components_batch(sizes: np.ndarray, *, comp_model,
                          store_model: NormalModel, comp_std_frac: float,
                          quantile: float | None,
                          iotup_model: NormalModel | None = None) -> tuple[dict, None]:
    """Shared vectorized edge pipeline: comp + iotup + store (always warm).

    ``iotup_model=None`` means the pipeline has no IoT upload leg (the
    TPU-slice edge); the component is emitted as zeros for shape parity.
    """
    n = sizes.shape[0]
    comp = np.asarray(comp_model.predict(sizes), dtype=np.float64)
    if quantile is not None:
        z = _norm_ppf(quantile)
        comp = comp * (1.0 + z * comp_std_frac)
        iot = iotup_model.predict_quantile(quantile) if iotup_model else 0.0
        store = store_model.predict_quantile(quantile)
    else:
        iot = iotup_model.predict() if iotup_model else 0.0
        store = store_model.predict()
    warm = {"comp": np.maximum(comp, 0.0),
            "iotup": np.full(n, max(iot, 0.0)),
            "store": np.full(n, max(store, 0.0))}
    return warm, None


def _stack_components(tgt, sizes: np.ndarray, nbytes: np.ndarray,
                      quantile: float | None) -> tuple[dict, dict | None]:
    """Per-task fallback for targets without ``predict_components_batch``."""

    @dataclass
    class _Row:
        size: float
        bytes: float

    def rows(cold: bool) -> dict[str, np.ndarray]:
        per = [tgt.predict_components(_Row(float(s), float(b)), cold, quantile)
               for s, b in zip(sizes, nbytes)]
        return {k: np.array([p[k] for p in per]) for k in per[0]}

    warm = rows(False)
    cold = None if tgt.is_edge else rows(True)
    return warm, cold


@dataclass
class Predictor:
    """predict() + update_cil(), exactly the two methods of paper Sec. V-A —
    plus the batched ``predict_batch``/``predict_at`` pair."""

    cloud_targets: list
    edge_target: object | None
    cil: ContainerInfoList = field(default_factory=ContainerInfoList)
    quantile: float | None = None  # None = paper-faithful mean prediction

    def __post_init__(self):
        self._by_name = {t.name: t for t in self.cloud_targets}

    def predict(self, task, now: float, edge_queue_wait_ms: float = 0.0) -> dict[str, Prediction]:
        """Predicted end-to-end latency and cost for every target."""
        self.cil.reap(now)
        out: dict[str, Prediction] = {}
        for tgt in self.cloud_targets:
            cold = not self.cil.will_warm_start(tgt.name, now)
            comps = tgt.predict_components(task, cold, self.quantile)
            latency = sum(comps.values())
            out[tgt.name] = Prediction(
                target=tgt.name,
                latency_ms=latency,
                cost=tgt.cost(comps["comp"]),
                cold=cold,
                components=comps,
            )
        if self.edge_target is not None:
            comps = self.edge_target.predict_components(task, False, self.quantile)
            latency = edge_queue_wait_ms + sum(comps.values())
            comps = dict(comps, queue=edge_queue_wait_ms)
            out[self.edge_target.name] = Prediction(
                target=self.edge_target.name,
                latency_ms=latency,
                cost=self.edge_target.cost(comps["comp"]),
                cold=False,
                components=comps,
            )
        return out

    # ----------------------------------------------------------- batched API
    def predict_batch(self, tasks: list) -> PredictionBatch:
        """Evaluate every component model over all tasks × targets at once.

        One numpy pass per (target, start-mode) instead of a Python loop per
        task — the GBRT compute model alone turns N×M tree walks into M.
        """
        if not tasks:
            return PredictionBatch(n=0, cloud={}, edge=None, edge_name=None)
        sizes = np.array([t.size for t in tasks], dtype=np.float64)
        nbytes = np.array([t.bytes for t in tasks], dtype=np.float64)

        cloud: dict[str, TargetBatch] = {}
        for tgt in self.cloud_targets:
            cloud[tgt.name] = self._target_batch(tgt, sizes, nbytes)
        edge = (self._target_batch(self.edge_target, sizes, nbytes)
                if self.edge_target is not None else None)
        return PredictionBatch(
            n=len(tasks), cloud=cloud, edge=edge,
            edge_name=self.edge_target.name if self.edge_target is not None else None,
        )

    def _target_batch(self, tgt, sizes: np.ndarray, nbytes: np.ndarray) -> TargetBatch:
        if hasattr(tgt, "predict_components_batch"):
            warm, cold = tgt.predict_components_batch(sizes, nbytes, self.quantile)
        else:
            warm, cold = _stack_components(tgt, sizes, nbytes, self.quantile)
        if hasattr(tgt, "cost_batch"):
            cost = np.asarray(tgt.cost_batch(warm["comp"]), dtype=np.float64)
        else:
            cost = np.array([tgt.cost(float(c)) for c in warm["comp"]])
        return TargetBatch(
            warm=warm, cold=cold,
            warm_latency=sum(warm.values()),
            cold_latency=sum(cold.values()) if cold is not None else None,
            cost=cost,
        )

    def predict_at(self, batch: PredictionBatch, idx: int, now: float,
                   edge_queue_wait_ms: float = 0.0) -> dict[str, Prediction]:
        """Assemble the per-task view of a ``PredictionBatch``: consult the CIL
        for warm/cold per cloud target, add the predicted edge queue wait.

        Equivalent to ``predict(tasks[idx], now, edge_queue_wait_ms)``."""
        self.cil.reap(now)
        out: dict[str, Prediction] = {}
        for name, tb in batch.cloud.items():
            cold = not self.cil.will_warm_start(name, now)
            src = tb.cold if cold else tb.warm
            lat = tb.cold_latency if cold else tb.warm_latency
            out[name] = Prediction(
                target=name,
                latency_ms=float(lat[idx]),
                cost=float(tb.cost[idx]),
                cold=cold,
                components={k: float(v[idx]) for k, v in src.items()},
            )
        if batch.edge is not None:
            tb = batch.edge
            comps = {k: float(v[idx]) for k, v in tb.warm.items()}
            comps["queue"] = edge_queue_wait_ms
            out[batch.edge_name] = Prediction(
                target=batch.edge_name,
                latency_ms=edge_queue_wait_ms + float(tb.warm_latency[idx]),
                cost=float(tb.cost[idx]),
                cold=False,
                components=comps,
            )
        return out

    # ------------------------------------------------------------ CIL update
    def update_cil(self, chosen: str, now: float, prediction: Prediction) -> None:
        """Record the chosen placement (paper: Predictor.updateCIL)."""
        if self.edge_target is not None and chosen == self.edge_target.name:
            return  # edge executor state is tracked by its FIFO queue, not the CIL
        tgt = self._target(chosen)
        completion = now + tgt.occupancy_ms(dict(prediction.components))
        self.cil.record_dispatch(chosen, now, completion)

    def _target(self, name: str):
        try:
            return self._by_name[name]
        except KeyError:
            raise KeyError(f"unknown target {name!r}") from None


@dataclass
class LambdaTarget:
    """Cloud pipeline target: T_c(k) = upld(k) + start(m) + comp(k,m) + store(k)."""

    name: str
    memory_mb: float
    upld_model: RidgeModel
    start_warm: NormalModel
    start_cold: NormalModel
    comp_model: object  # GBRT over features (size, memory_mb)
    store_model: NormalModel
    pricing: LambdaPricing = field(default_factory=LambdaPricing)
    comp_std_frac: float = 0.0  # relative comp std for quantile prediction
    is_edge: bool = False

    def predict_components(self, task, cold: bool, quantile: float | None = None) -> dict[str, float]:
        start = self.start_cold if cold else self.start_warm
        comp = float(self.comp_model.predict(np.array([[task.size, self.memory_mb]]))[0])
        if quantile is not None:
            z = _norm_ppf(quantile)
            comp = comp * (1.0 + z * self.comp_std_frac)
            start_ms = start.predict_quantile(quantile)
            store_ms = self.store_model.predict_quantile(quantile)
        else:
            start_ms = start.predict()
            store_ms = self.store_model.predict()
        return {
            "upld": max(float(self.upld_model.predict(task.bytes)), 0.0),
            "start": max(start_ms, 0.0),
            "comp": max(comp, 0.0),
            "store": max(store_ms, 0.0),
        }

    def predict_components_batch(self, sizes: np.ndarray, nbytes: np.ndarray,
                                 quantile: float | None = None) -> tuple[dict, dict]:
        return cloud_components_batch(
            sizes, nbytes, comp_feature=self.memory_mb,
            comp_model=self.comp_model, upld_model=self.upld_model,
            start_warm=self.start_warm, start_cold=self.start_cold,
            store_model=self.store_model, comp_std_frac=self.comp_std_frac,
            quantile=quantile)

    def cost(self, comp_ms: float) -> float:
        return self.pricing.cost(comp_ms, self.memory_mb)

    def cost_batch(self, comp_ms: np.ndarray) -> np.ndarray:
        return self.pricing.cost_batch(comp_ms, self.memory_mb)

    def occupancy_ms(self, components: dict[str, float]) -> float:
        # The container is held from dispatch until the function returns:
        # upload + start + compute (storage happens after release).
        return components["upld"] + components["start"] + components["comp"]


@dataclass
class EdgeTarget:
    """Edge pipeline target: T_e(k) = comp(k) + iotup(k) + store(k) (+ queue wait)."""

    comp_model: RidgeModel
    iotup_model: NormalModel
    store_model: NormalModel
    pricing: EdgePricing = field(default_factory=EdgePricing)
    comp_std_frac: float = 0.0
    name: str = EDGE
    is_edge: bool = True

    def predict_components(self, task, cold: bool = False, quantile: float | None = None) -> dict[str, float]:
        comp = float(self.comp_model.predict(task.size))
        if quantile is not None:
            z = _norm_ppf(quantile)
            comp = comp * (1.0 + z * self.comp_std_frac)
            iot = self.iotup_model.predict_quantile(quantile)
            store = self.store_model.predict_quantile(quantile)
        else:
            iot = self.iotup_model.predict()
            store = self.store_model.predict()
        return {"comp": max(comp, 0.0), "iotup": max(iot, 0.0), "store": max(store, 0.0)}

    def predict_components_batch(self, sizes: np.ndarray, nbytes: np.ndarray,
                                 quantile: float | None = None) -> tuple[dict, None]:
        return edge_components_batch(
            sizes, comp_model=self.comp_model, store_model=self.store_model,
            comp_std_frac=self.comp_std_frac, quantile=quantile,
            iotup_model=self.iotup_model)

    def cost(self, comp_ms: float) -> float:
        return self.pricing.cost(comp_ms)

    def cost_batch(self, comp_ms: np.ndarray) -> np.ndarray:
        return self.pricing.cost_batch(comp_ms)

    def occupancy_ms(self, components: dict[str, float]) -> float:
        return components["comp"]
