"""The Predictor (paper Sec. V-A).

Given an input, the Predictor returns predicted end-to-end latency and cost for
every execution target: the N cloud configurations Φ = {λ_m} and the edge
executor λ_edge. Cold-vs-warm start is decided by consulting the CIL. The
Decision Engine then calls ``update_cil`` with the chosen configuration.

Targets are pluggable so the same Predictor drives both the AWS reproduction
(LambdaTarget/EdgeTarget, models from Sec. IV) and the TPU-fleet adaptation
(``repro.serving.placement.SliceTarget``).

The ``quantile`` option is a beyond-paper extension (the paper's stated future
work): predict a latency quantile instead of the mean, so placement can hedge
against the high variance the paper observed in cloud pipelines.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Protocol

from repro.core.cil import ContainerInfoList
from repro.core.perf_models import NormalModel, RidgeModel, _norm_ppf
from repro.core.pricing import EdgePricing, LambdaPricing

EDGE = "edge"


@dataclass(frozen=True)
class Prediction:
    target: str
    latency_ms: float
    cost: float
    cold: bool
    components: Mapping[str, float]

    @property
    def comp_ms(self) -> float:
        return self.components.get("comp", 0.0)


class ExecutionTarget(Protocol):
    """A place a task can run: a cloud config λ_m, the edge device, a TPU slice."""

    name: str
    is_edge: bool

    def predict_components(self, task, cold: bool, quantile: float | None) -> dict[str, float]:
        """Latency components in ms. Must include a 'comp' entry."""
        ...

    def cost(self, comp_ms: float) -> float:
        ...

    def occupancy_ms(self, components: dict[str, float]) -> float:
        """How long the executor/container is held busy (for CIL bookkeeping)."""
        ...


@dataclass
class LambdaTarget:
    """Cloud pipeline target: T_c(k) = upld(k) + start(m) + comp(k,m) + store(k)."""

    name: str
    memory_mb: float
    upld_model: RidgeModel
    start_warm: NormalModel
    start_cold: NormalModel
    comp_model: object  # GBRT over features (size, memory_mb)
    store_model: NormalModel
    pricing: LambdaPricing = field(default_factory=LambdaPricing)
    comp_std_frac: float = 0.0  # relative comp std for quantile prediction
    is_edge: bool = False

    def predict_components(self, task, cold: bool, quantile: float | None = None) -> dict[str, float]:
        import numpy as np

        start = self.start_cold if cold else self.start_warm
        comp = float(self.comp_model.predict(np.array([[task.size, self.memory_mb]]))[0])
        if quantile is not None:
            z = _norm_ppf(quantile)
            comp = comp * (1.0 + z * self.comp_std_frac)
            start_ms = start.predict_quantile(quantile)
            store_ms = self.store_model.predict_quantile(quantile)
        else:
            start_ms = start.predict()
            store_ms = self.store_model.predict()
        return {
            "upld": max(float(self.upld_model.predict(task.bytes)), 0.0),
            "start": max(start_ms, 0.0),
            "comp": max(comp, 0.0),
            "store": max(store_ms, 0.0),
        }

    def cost(self, comp_ms: float) -> float:
        return self.pricing.cost(comp_ms, self.memory_mb)

    def occupancy_ms(self, components: dict[str, float]) -> float:
        # The container is held from dispatch until the function returns:
        # upload + start + compute (storage happens after release).
        return components["upld"] + components["start"] + components["comp"]


@dataclass
class EdgeTarget:
    """Edge pipeline target: T_e(k) = comp(k) + iotup(k) + store(k) (+ queue wait)."""

    comp_model: RidgeModel
    iotup_model: NormalModel
    store_model: NormalModel
    pricing: EdgePricing = field(default_factory=EdgePricing)
    comp_std_frac: float = 0.0
    name: str = EDGE
    is_edge: bool = True

    def predict_components(self, task, cold: bool = False, quantile: float | None = None) -> dict[str, float]:
        comp = float(self.comp_model.predict(task.size))
        if quantile is not None:
            z = _norm_ppf(quantile)
            comp = comp * (1.0 + z * self.comp_std_frac)
            iot = self.iotup_model.predict_quantile(quantile)
            store = self.store_model.predict_quantile(quantile)
        else:
            iot = self.iotup_model.predict()
            store = self.store_model.predict()
        return {"comp": max(comp, 0.0), "iotup": max(iot, 0.0), "store": max(store, 0.0)}

    def cost(self, comp_ms: float) -> float:
        return self.pricing.cost(comp_ms)

    def occupancy_ms(self, components: dict[str, float]) -> float:
        return components["comp"]


@dataclass
class Predictor:
    """predict() + update_cil(), exactly the two methods of paper Sec. V-A."""

    cloud_targets: list
    edge_target: object | None
    cil: ContainerInfoList = field(default_factory=ContainerInfoList)
    quantile: float | None = None  # None = paper-faithful mean prediction

    def predict(self, task, now: float, edge_queue_wait_ms: float = 0.0) -> dict[str, Prediction]:
        """Predicted end-to-end latency and cost for every target."""
        self.cil.reap(now)
        out: dict[str, Prediction] = {}
        for tgt in self.cloud_targets:
            cold = not self.cil.will_warm_start(tgt.name, now)
            comps = tgt.predict_components(task, cold, self.quantile)
            latency = sum(comps.values())
            out[tgt.name] = Prediction(
                target=tgt.name,
                latency_ms=latency,
                cost=tgt.cost(comps["comp"]),
                cold=cold,
                components=comps,
            )
        if self.edge_target is not None:
            comps = self.edge_target.predict_components(task, False, self.quantile)
            latency = edge_queue_wait_ms + sum(comps.values())
            comps = dict(comps, queue=edge_queue_wait_ms)
            out[self.edge_target.name] = Prediction(
                target=self.edge_target.name,
                latency_ms=latency,
                cost=self.edge_target.cost(comps["comp"]),
                cold=False,
                components=comps,
            )
        return out

    def update_cil(self, chosen: str, now: float, prediction: Prediction) -> None:
        """Record the chosen placement (paper: Predictor.updateCIL)."""
        if self.edge_target is not None and chosen == self.edge_target.name:
            return  # edge executor state is tracked by its FIFO queue, not the CIL
        tgt = self._target(chosen)
        completion = now + tgt.occupancy_ms(dict(prediction.components))
        self.cil.record_dispatch(chosen, now, completion)

    def _target(self, name: str):
        for t in self.cloud_targets:
            if t.name == name:
                return t
        raise KeyError(f"unknown target {name!r}")
