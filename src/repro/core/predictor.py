"""The Predictor (paper Sec. V-A), generalized to multi-device edge fleets.

Given an input, the Predictor returns predicted end-to-end latency and cost for
every execution target: the N cloud configurations Φ = {λ_m} and every device
of the edge fleet. Cold-vs-warm start is decided by consulting the CIL. The
Decision Engine then calls ``update_cil`` with the chosen configuration.

Targets are pluggable so the same Predictor drives both the AWS reproduction
(LambdaTarget/EdgeTarget, models from Sec. IV) and the TPU-fleet adaptation
(``repro.serving.placement.SliceTarget``).

The paper assumes ONE smart edge device per application; ``EdgeFleet`` lifts
that to N named devices, each with its own compute model (heterogeneous fleets
via ``repro.core.perf_models.ScaledModel``) and its own predicted FIFO queue.
``Predictor(edge_target=...)`` survives as the single-device convenience and
builds a one-device fleet.

Two prediction paths:

- ``predict(task, now)`` — the paper's per-task call: consult the CIL, return
  one ``Prediction`` per target;
- ``predict_batch(tasks)`` + ``predict_at(batch, i, now)`` — the batched API:
  every component model (ridge/normal/GBRT — all accept arrays) is evaluated
  ONCE over all tasks × targets, for both the warm and the cold start variant;
  ``predict_at`` then assembles the per-task view by consulting the CIL, which
  is the only genuinely sequential part. ``DecisionEngine.place_many`` builds
  on this; results are identical to per-task ``predict`` (same models, same
  arithmetic, vectorized).

On the batched path the GBRT compute model can additionally be routed through
the ``repro.kernels.gbrt_predict`` Pallas kernel (see ``GBRT_KERNEL_MODE``):
on a TPU backend, batches of ≥ ``GBRT_KERNEL_MIN_BATCH`` rows run the one-hot
matmul ensemble kernel; everywhere else the vectorized numpy tree walk is the
fallback (it is both exact and faster than interpret-mode Pallas on CPU).

The ``quantile`` option is a beyond-paper extension (the paper's stated future
work): predict a latency quantile instead of the mean, so placement can hedge
against the high variance the paper observed in cloud pipelines.
"""

from __future__ import annotations

import dataclasses
import threading
import weakref
from dataclasses import dataclass, field
from typing import Mapping, Protocol

import numpy as np

from repro.core.cil import ContainerInfoList
from repro.core.perf_models import NormalModel, RidgeModel, ScaledModel, _norm_ppf
from repro.core.pricing import EdgePricing, LambdaPricing
from repro.core.workload import task_arrays

EDGE = "edge"

# GBRT-on-Pallas routing for the batched path (ROADMAP item):
#   "auto"  — use the kernel when a real TPU backend is attached and the batch
#             has at least GBRT_KERNEL_MIN_BATCH rows; numpy tree walk
#             otherwise (CPU interpret-mode Pallas is slower than numpy, and
#             the f32 kernel would break exact scalar/batch decision parity);
#   "force" — always use the kernel (tests / TPU microbenchmarks);
#   "off"   — always use the numpy tree walk.
GBRT_KERNEL_MODE = "auto"
GBRT_KERNEL_MIN_BATCH = 4096


_TPU_BACKEND: bool | None = None


def _tpu_backend() -> bool:
    """Cached TPU-backend probe — importing jax costs ~0.7 s, so the serving
    path must only ever pay it once per process."""
    global _TPU_BACKEND
    if _TPU_BACKEND is None:
        try:
            import jax

            _TPU_BACKEND = jax.default_backend() == "tpu"
        except Exception:
            _TPU_BACKEND = False
    return _TPU_BACKEND


# Serving-side GBRT step-table cache, keyed ``(id(model), comp_feature)``.
# The chunked/streaming serve path calls ``predict_batch`` once per chunk; the
# table must be derived once per (model, memory config) for a whole stream,
# not once per call. Keying on the model's *identity* (with a weakref guard
# against id reuse) makes online-refit invalidation automatic: a refit swaps
# in a fresh model object (never mutates a fitted one — see ROADMAP), so the
# fresh model simply misses the cache and builds its own table, and the stale
# entry is evicted the moment its id is recycled or the sweep finds it dead.
# The lock covers the sharded thread mode: shards predict concurrently, and
# an unlocked sweep could iterate while another thread inserts.
_CONST1_TABLES: dict[tuple[int, float], tuple] = {}
_CONST1_LOCK = threading.Lock()


def model_keyed_cache(cache: dict, lock: threading.Lock, key, models, build):
    """The ``_CONST1_TABLES`` idiom as a reusable helper: a module-level cache
    keyed on model *identities* (with weakref guards against id recycling),
    so refit-by-swap invalidation is automatic — a refit swaps in fresh model
    objects (never mutates fitted ones, see ROADMAP), the fresh ids miss the
    cache, and stale entries are evicted on id recycle or the size-capped
    dead-ref sweep. ``models`` are the guarded objects (kept alive by the
    caller for the entry to stay valid); ``build`` is the zero-arg derivation.
    Shared by the serving step tables below and the device-resident core's
    operand/table hosting (``repro.core.jax_core``) — per-chunk paths must
    never re-derive per-model artifacts.
    """
    with lock:
        hit = cache.get(key)
        if hit is not None:
            refs, val = hit
            if all(r() is m for r, m in zip(refs, models)):
                return val
            cache.pop(key, None)  # id recycled by a swap: stale
    val = build()
    try:
        refs = tuple(weakref.ref(m) for m in models)
    except TypeError:
        return val  # non-weakrefable model: serve uncached
    with lock:
        if len(cache) > 256:  # drop entries whose model is gone
            for k in [k for k, (rs, _) in cache.items()
                      if any(r() is None for r in rs)]:
                cache.pop(k, None)
        cache[key] = (refs, val)
    return val


def _const1_table(model, c: float) -> tuple[np.ndarray, np.ndarray]:
    return model_keyed_cache(
        _CONST1_TABLES, _CONST1_LOCK, (id(model), float(c)), (model,),
        lambda: model.const1_table(float(c)))


def const1_serving_table(model, c: float) -> tuple[np.ndarray, np.ndarray]:
    """Public handle on the cached serving step table ``(breaks, vals)`` for
    one ``(model, comp_feature)`` pair — the same weakref-guarded entries the
    numpy hot path reads, so a consumer that re-hosts the table (e.g. the
    device-resident jax core's gather operands) sees bit-identical values and
    inherits refit-by-swap invalidation for free (fresh model ⇒ fresh id ⇒
    cache miss)."""
    return _const1_table(model, float(c))


def _const1_eval(model, x0: np.ndarray, c: float) -> np.ndarray:
    """One cached-table lookup — the single implementation both batched
    entry points share (bit-identical to ``GBRT.predict_const1``)."""
    breaks, vals = _const1_table(model, c)
    return vals[np.searchsorted(breaks, x0, side="left")]


def gbrt_predict_const(model, x0: np.ndarray, c: float) -> np.ndarray:
    """Batched GBRT predict with feature 1 fixed at ``c`` — no feature stack.

    The serving pipeline's compute models are always evaluated at one
    ``comp_feature`` per cloud target (memory_mb / chips), so the hot path
    never needs the ``(n, 2)`` stack, the per-call constant-column scan, or a
    re-derived step table: the cached ``(breaks, vals)`` pair turns the call
    into one ``searchsorted``. Bit-identical to the tree walk (see
    ``GBRT.predict_const1``); the Pallas kernel route and arbitrary models
    fall back to the stacked ``gbrt_batch_predict``.
    """
    x0 = np.asarray(x0, dtype=np.float64)
    mode = GBRT_KERNEL_MODE
    kernel = (mode != "off" and hasattr(model, "thresholds")
              and (mode == "force"
                   or (x0.shape[0] >= GBRT_KERNEL_MIN_BATCH and _tpu_backend())))
    if not kernel and hasattr(model, "const1_table"):
        return _const1_eval(model, x0, c)
    feats = np.stack([x0, np.full(x0.shape[0], float(c))], axis=1)
    return gbrt_batch_predict(model, feats)


def gbrt_batch_predict(model, feats: np.ndarray) -> np.ndarray:
    """Batched GBRT evaluation: Pallas ensemble kernel when it pays off, the
    constant-feature step-function table for the serving pipeline's
    (size, memory_mb)-with-fixed-memory calls, vectorized numpy tree walk as
    the always-available fallback. All three are decision-equivalent; the
    table path is bit-identical to the tree walk (see ``GBRT.predict_const1``)
    and its table is cached per ``(id(model), comp_feature)`` across calls —
    any chunk size, down to single-task chunks, reuses it.
    """
    mode = GBRT_KERNEL_MODE
    if (mode != "off" and hasattr(model, "thresholds")
            and (mode == "force"
                 or (feats.shape[0] >= GBRT_KERNEL_MIN_BATCH and _tpu_backend()))):
        try:
            from repro.kernels.gbrt_predict.ops import gbrt_predict

            return np.asarray(gbrt_predict(model, feats), dtype=np.float64)
        except Exception:
            if mode == "force":
                raise
    if (hasattr(model, "const1_table") and feats.ndim == 2
            and feats.shape[1] == 2 and feats.shape[0] > 0
            and np.all(feats[:, 1] == feats[0, 1])):
        return _const1_eval(model, np.asarray(feats[:, 0], np.float64),
                            float(feats[0, 1]))
    return np.asarray(model.predict(feats), dtype=np.float64)


@dataclass(frozen=True)
class Prediction:
    target: str
    latency_ms: float
    cost: float
    cold: bool
    components: Mapping[str, float]

    @property
    def comp_ms(self) -> float:
        return self.components.get("comp", 0.0)


class ExecutionTarget(Protocol):
    """A place a task can run: a cloud config λ_m, the edge device, a TPU slice."""

    name: str
    is_edge: bool

    def predict_components(self, task, cold: bool, quantile: float | None) -> dict[str, float]:
        """Latency components in ms. Must include a 'comp' entry."""
        ...

    def predict_components_batch(self, sizes: np.ndarray, nbytes: np.ndarray,
                                 quantile: float | None) -> tuple[dict, dict | None]:
        """Vectorized components for n tasks: (warm, cold) dicts of (n,) arrays.

        ``cold`` is ``None`` for always-warm targets (the edge). Optional —
        ``Predictor.predict_batch`` falls back to per-task calls when absent.
        """
        ...

    def cost(self, comp_ms: float) -> float:
        ...

    def cost_batch(self, comp_ms: np.ndarray) -> np.ndarray:
        """Vectorized ``cost`` over an array of compute times. Optional."""
        ...

    def occupancy_ms(self, components: dict[str, float]) -> float:
        """How long the executor/container is held busy (for CIL bookkeeping)."""
        ...


@dataclass
class EdgeFleet:
    """Named edge devices — the multi-device generalization of λ_edge.

    Every device is an edge execution target (``EdgeTarget``,
    ``EdgeSliceTarget``, any ``is_edge`` target) with a unique name. Devices
    may carry distinct compute models, so heterogeneous fleets (a fast hub
    plus slow sensor nodes) are first-class: see ``replicate(speeds=...)``.
    """

    devices: list

    def __post_init__(self):
        names = [d.name for d in self.devices]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate edge device names: {names}")
        for d in self.devices:
            if not getattr(d, "is_edge", False):
                raise ValueError(f"edge device {d.name!r} must have is_edge=True")
        self._by_name = {d.name: d for d in self.devices}

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(d.name for d in self.devices)

    def __len__(self) -> int:
        return len(self.devices)

    def __iter__(self):
        return iter(self.devices)

    def __bool__(self) -> bool:
        return bool(self.devices)

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def __getitem__(self, name: str):
        return self._by_name[name]

    @classmethod
    def single(cls, target) -> "EdgeFleet":
        """The paper's one-device special case."""
        return cls([target])

    @classmethod
    def replicate(cls, target, n: int, prefix: str = "edge",
                  speeds: Mapping[str, float] | None = None) -> "EdgeFleet":
        """N copies of ``target`` named ``{prefix}0..{prefix}{n-1}``.

        ``speeds`` maps device name → relative compute speed (1.0 = the base
        device); a device at speed ``s`` gets ``comp_model`` wrapped in
        ``ScaledModel(base, 1/s)``.
        """
        speeds = speeds or {}
        return cls.from_speeds(
            target, {f"{prefix}{i}": float(speeds.get(f"{prefix}{i}", 1.0))
                     for i in range(n)})

    @classmethod
    def from_speeds(cls, target, speeds: Mapping[str, float]) -> "EdgeFleet":
        """One device per ``speeds`` entry (arbitrary names, fleet order =
        mapping order); a device at speed ``s`` predicts ``comp / s``."""
        devices = []
        for name, speed in speeds.items():
            dev = dataclasses.replace(target, name=name)
            if float(speed) != 1.0:
                dev = dataclasses.replace(
                    dev, comp_model=ScaledModel(dev.comp_model, 1.0 / float(speed)))
            devices.append(dev)
        return cls(devices)


@dataclass(frozen=True)
class TargetBatch:
    """Vectorized predictions for one target across a batch of tasks."""

    warm: dict[str, np.ndarray]          # component -> (n,) ms
    cold: dict[str, np.ndarray] | None   # None for always-warm targets
    warm_latency: np.ndarray             # (n,) — sum of warm components
    cold_latency: np.ndarray | None
    cost: np.ndarray                     # (n,) — cost depends on comp only


@dataclass(frozen=True)
class PredictionBatch:
    """All component-model evaluations for a batch of tasks, both start modes.

    Warm/cold selection and edge queueing are *not* baked in — they depend on
    sequential CIL / edge-queue state and are resolved per task by
    ``Predictor.predict_at``.
    """

    n: int
    cloud: dict[str, TargetBatch]
    edges: dict[str, TargetBatch]        # device name -> batch (fleet order)

    # ------------------------- deprecated single-edge convenience accessors
    @property
    def edge(self) -> TargetBatch | None:
        return next(iter(self.edges.values()), None)

    @property
    def edge_name(self) -> str | None:
        return next(iter(self.edges), None)


def cloud_components_batch(sizes: np.ndarray, nbytes: np.ndarray, *,
                           comp_feature: float, comp_model, upld_model,
                           start_warm: NormalModel, start_cold: NormalModel,
                           store_model: NormalModel, comp_std_frac: float,
                           quantile: float | None) -> tuple[dict, dict]:
    """Shared vectorized cloud pipeline: upld + start + comp + store.

    One source of truth for the batch variant of the cloud-target component
    math (``LambdaTarget`` with ``memory_mb``, ``SliceTarget`` with
    ``chips``), so the scalar/batch parity guarantee has a single place to
    break — and a parity test to catch it.
    """
    n = sizes.shape[0]
    comp = gbrt_predict_const(comp_model, sizes, comp_feature)
    if quantile is not None:
        z = _norm_ppf(quantile)
        comp = comp * (1.0 + z * comp_std_frac)
        warm_start = start_warm.predict_quantile(quantile)
        cold_start = start_cold.predict_quantile(quantile)
        store_ms = store_model.predict_quantile(quantile)
    else:
        warm_start = start_warm.predict()
        cold_start = start_cold.predict()
        store_ms = store_model.predict()
    warm = {
        "upld": np.maximum(np.asarray(upld_model.predict(nbytes)), 0.0),
        "start": np.full(n, max(warm_start, 0.0)),
        "comp": np.maximum(comp, 0.0),
        "store": np.full(n, max(store_ms, 0.0)),
    }
    cold = dict(warm, start=np.full(n, max(cold_start, 0.0)))
    return warm, cold


def edge_components_batch(sizes: np.ndarray, *, comp_model,
                          store_model: NormalModel, comp_std_frac: float,
                          quantile: float | None,
                          iotup_model: NormalModel | None = None) -> tuple[dict, None]:
    """Shared vectorized edge pipeline: comp + iotup + store (always warm).

    ``iotup_model=None`` means the pipeline has no IoT upload leg (the
    TPU-slice edge); the component is emitted as zeros for shape parity.
    """
    n = sizes.shape[0]
    comp = np.asarray(comp_model.predict(sizes), dtype=np.float64)
    if quantile is not None:
        z = _norm_ppf(quantile)
        comp = comp * (1.0 + z * comp_std_frac)
        iot = iotup_model.predict_quantile(quantile) if iotup_model else 0.0
        store = store_model.predict_quantile(quantile)
    else:
        iot = iotup_model.predict() if iotup_model else 0.0
        store = store_model.predict()
    warm = {"comp": np.maximum(comp, 0.0),
            "iotup": np.full(n, max(iot, 0.0)),
            "store": np.full(n, max(store, 0.0))}
    return warm, None


def _stack_components(tgt, sizes: np.ndarray, nbytes: np.ndarray,
                      quantile: float | None) -> tuple[dict, dict | None]:
    """Per-task fallback for targets without ``predict_components_batch``."""

    @dataclass
    class _Row:
        size: float
        bytes: float

    def rows(cold: bool) -> dict[str, np.ndarray]:
        per = [tgt.predict_components(_Row(float(s), float(b)), cold, quantile)
               for s, b in zip(sizes, nbytes)]
        return {k: np.array([p[k] for p in per]) for k in per[0]}

    warm = rows(False)
    cold = None if tgt.is_edge else rows(True)
    return warm, cold


@dataclass
class Predictor:
    """predict() + update_cil(), exactly the two methods of paper Sec. V-A —
    plus the batched ``predict_batch``/``predict_at`` pair.

    ``edge_fleet`` is the first-class multi-device form; ``edge_target`` is
    the deprecated single-device convenience (it becomes a one-device fleet).
    """

    cloud_targets: list
    edge_target: object | None = None
    cil: ContainerInfoList = field(default_factory=ContainerInfoList)
    quantile: float | None = None  # None = paper-faithful mean prediction
    edge_fleet: EdgeFleet | None = None

    def __post_init__(self):
        self._by_name = {t.name: t for t in self.cloud_targets}
        if self.edge_fleet is not None and self.edge_target is not None:
            raise ValueError("pass either edge_fleet or edge_target, not both")
        if self.edge_fleet is None and self.edge_target is not None:
            self.edge_fleet = EdgeFleet.single(self.edge_target)
        elif self.edge_fleet is not None and self.edge_target is None:
            # deprecated convenience alias: "the edge" = the fleet's first device
            self.edge_target = self.edge_fleet.devices[0] if self.edge_fleet else None

    @property
    def edge_names(self) -> tuple[str, ...]:
        return self.edge_fleet.names if self.edge_fleet is not None else ()

    def _edge_waits(self, edge_queue_wait_ms: float,
                    edge_waits: Mapping[str, float] | None) -> Mapping[str, float]:
        if edge_waits is not None:
            return edge_waits
        return {name: edge_queue_wait_ms for name in self.edge_names}

    def predict(self, task, now: float, edge_queue_wait_ms: float = 0.0,
                edge_waits: Mapping[str, float] | None = None) -> dict[str, Prediction]:
        """Predicted end-to-end latency and cost for every target.

        ``edge_waits`` maps device name → predicted FIFO queue wait; the
        scalar ``edge_queue_wait_ms`` is the deprecated single-edge spelling
        (applied to every device when ``edge_waits`` is not given).
        """
        self.cil.reap(now)
        waits = self._edge_waits(edge_queue_wait_ms, edge_waits)
        out: dict[str, Prediction] = {}
        for tgt in self.cloud_targets:
            cold = not self.cil.will_warm_start(tgt.name, now)
            comps = tgt.predict_components(task, cold, self.quantile)
            latency = sum(comps.values())
            out[tgt.name] = Prediction(
                target=tgt.name,
                latency_ms=latency,
                cost=tgt.cost(comps["comp"]),
                cold=cold,
                components=comps,
            )
        for dev in (self.edge_fleet or ()):
            wait = float(waits.get(dev.name, 0.0))
            comps = dev.predict_components(task, False, self.quantile)
            latency = wait + sum(comps.values())
            comps = dict(comps, queue=wait)
            out[dev.name] = Prediction(
                target=dev.name,
                latency_ms=latency,
                cost=dev.cost(comps["comp"]),
                cold=False,
                components=comps,
            )
        return out

    # ----------------------------------------------------------- batched API
    def predict_batch(self, tasks: list) -> PredictionBatch:
        """Evaluate every component model over all (tasks × targets) at once —
        cloud configs AND every edge device of the fleet.

        One numpy pass per (target, start-mode) instead of a Python loop per
        task — the GBRT compute model alone turns N×M tree walks into M (and
        can run on the Pallas ensemble kernel, see ``gbrt_batch_predict``).
        """
        if not tasks:
            return PredictionBatch(n=0, cloud={}, edges={})
        _, _, sizes, nbytes = task_arrays(tasks, "sb")

        cloud: dict[str, TargetBatch] = {}
        for tgt in self.cloud_targets:
            cloud[tgt.name] = self._target_batch(tgt, sizes, nbytes)
        edges: dict[str, TargetBatch] = {}
        for dev in (self.edge_fleet or ()):
            edges[dev.name] = self._target_batch(dev, sizes, nbytes)
        return PredictionBatch(n=len(tasks), cloud=cloud, edges=edges)

    def _target_batch(self, tgt, sizes: np.ndarray, nbytes: np.ndarray) -> TargetBatch:
        if hasattr(tgt, "predict_components_batch"):
            warm, cold = tgt.predict_components_batch(sizes, nbytes, self.quantile)
            if cold is not None and getattr(tgt, "is_edge", False):
                # always-warm targets never cold-start: drop any cold = warm
                # stack a custom target hands back instead of carrying (and
                # re-summing) a duplicate component set per chunk
                cold = None
        else:
            warm, cold = _stack_components(tgt, sizes, nbytes, self.quantile)
        if hasattr(tgt, "cost_batch"):
            cost = np.asarray(tgt.cost_batch(warm["comp"]), dtype=np.float64)
        else:
            cost = np.array([tgt.cost(float(c)) for c in warm["comp"]])
        return TargetBatch(
            warm=warm, cold=cold,
            warm_latency=sum(warm.values()),
            cold_latency=sum(cold.values()) if cold is not None else None,
            cost=cost,
        )

    def predict_at(self, batch: PredictionBatch, idx: int, now: float,
                   edge_queue_wait_ms: float = 0.0,
                   edge_waits: Mapping[str, float] | None = None) -> dict[str, Prediction]:
        """Assemble the per-task view of a ``PredictionBatch``: consult the CIL
        for warm/cold per cloud target, add each device's predicted queue wait.

        Equivalent to ``predict(tasks[idx], now, ...)``."""
        self.cil.reap(now)
        waits = self._edge_waits(edge_queue_wait_ms, edge_waits)
        out: dict[str, Prediction] = {}
        for name, tb in batch.cloud.items():
            cold = not self.cil.will_warm_start(name, now)
            src = tb.cold if cold else tb.warm
            lat = tb.cold_latency if cold else tb.warm_latency
            out[name] = Prediction(
                target=name,
                latency_ms=float(lat[idx]),
                cost=float(tb.cost[idx]),
                cold=cold,
                components={k: float(v[idx]) for k, v in src.items()},
            )
        for name, tb in batch.edges.items():
            wait = float(waits.get(name, 0.0))
            comps = {k: float(v[idx]) for k, v in tb.warm.items()}
            comps["queue"] = wait
            out[name] = Prediction(
                target=name,
                latency_ms=wait + float(tb.warm_latency[idx]),
                cost=float(tb.cost[idx]),
                cold=False,
                components=comps,
            )
        return out

    def prewarm(self, target: str, ready_ms: float,
                keepalive_until_ms: float):
        """Register a speculatively spawned container for a cloud target.

        The returned ``ContainerRecord`` is warm over exactly
        ``[ready_ms, keepalive_until_ms]`` (see ``ContainerInfoList.prewarm``
        for the encoding), so every warm/cold consult — ``predict``,
        ``predict_at``, and the columnar decision core — sees the prewarmed
        pool with no further plumbing. Edge devices have no containers.
        """
        self._target(target)  # raises KeyError for unknown/edge names
        return self.cil.prewarm(target, ready_ms, keepalive_until_ms)

    # ------------------------------------------------------------ CIL update
    def update_cil(self, chosen: str, now: float, prediction: Prediction) -> None:
        """Record the chosen placement (paper: Predictor.updateCIL)."""
        if self.edge_fleet is not None and chosen in self.edge_fleet:
            return  # edge executor state is tracked by its FIFO queue, not the CIL
        tgt = self._target(chosen)
        completion = now + tgt.occupancy_ms(dict(prediction.components))
        self.cil.record_dispatch(chosen, now, completion)

    def _target(self, name: str):
        try:
            return self._by_name[name]
        except KeyError:
            raise KeyError(f"unknown target {name!r}") from None


@dataclass
class LambdaTarget:
    """Cloud pipeline target: T_c(k) = upld(k) + start(m) + comp(k,m) + store(k)."""

    name: str
    memory_mb: float
    upld_model: RidgeModel
    start_warm: NormalModel
    start_cold: NormalModel
    comp_model: object  # GBRT over features (size, memory_mb)
    store_model: NormalModel
    pricing: LambdaPricing = field(default_factory=LambdaPricing)
    comp_std_frac: float = 0.0  # relative comp std for quantile prediction
    is_edge: bool = False

    def predict_components(self, task, cold: bool, quantile: float | None = None) -> dict[str, float]:
        start = self.start_cold if cold else self.start_warm
        comp = float(self.comp_model.predict(np.array([[task.size, self.memory_mb]]))[0])
        if quantile is not None:
            z = _norm_ppf(quantile)
            comp = comp * (1.0 + z * self.comp_std_frac)
            start_ms = start.predict_quantile(quantile)
            store_ms = self.store_model.predict_quantile(quantile)
        else:
            start_ms = start.predict()
            store_ms = self.store_model.predict()
        return {
            "upld": max(float(self.upld_model.predict(task.bytes)), 0.0),
            "start": max(start_ms, 0.0),
            "comp": max(comp, 0.0),
            "store": max(store_ms, 0.0),
        }

    def predict_components_batch(self, sizes: np.ndarray, nbytes: np.ndarray,
                                 quantile: float | None = None) -> tuple[dict, dict]:
        return cloud_components_batch(
            sizes, nbytes, comp_feature=self.memory_mb,
            comp_model=self.comp_model, upld_model=self.upld_model,
            start_warm=self.start_warm, start_cold=self.start_cold,
            store_model=self.store_model, comp_std_frac=self.comp_std_frac,
            quantile=quantile)

    def cost(self, comp_ms: float) -> float:
        return self.pricing.cost(comp_ms, self.memory_mb)

    def cost_batch(self, comp_ms: np.ndarray) -> np.ndarray:
        return self.pricing.cost_batch(comp_ms, self.memory_mb)

    def occupancy_ms(self, components: dict[str, float]) -> float:
        # The container is held from dispatch until the function returns:
        # upload + start + compute (storage happens after release).
        return components["upld"] + components["start"] + components["comp"]


@dataclass
class EdgeTarget:
    """Edge pipeline target: T_e(k) = comp(k) + iotup(k) + store(k) (+ queue wait)."""

    comp_model: RidgeModel
    iotup_model: NormalModel
    store_model: NormalModel
    pricing: EdgePricing = field(default_factory=EdgePricing)
    comp_std_frac: float = 0.0
    name: str = EDGE
    is_edge: bool = True

    def predict_components(self, task, cold: bool = False, quantile: float | None = None) -> dict[str, float]:
        comp = float(self.comp_model.predict(task.size))
        if quantile is not None:
            z = _norm_ppf(quantile)
            comp = comp * (1.0 + z * self.comp_std_frac)
            iot = self.iotup_model.predict_quantile(quantile)
            store = self.store_model.predict_quantile(quantile)
        else:
            iot = self.iotup_model.predict()
            store = self.store_model.predict()
        return {"comp": max(comp, 0.0), "iotup": max(iot, 0.0), "store": max(store, 0.0)}

    def predict_components_batch(self, sizes: np.ndarray, nbytes: np.ndarray,
                                 quantile: float | None = None) -> tuple[dict, None]:
        return edge_components_batch(
            sizes, comp_model=self.comp_model, store_model=self.store_model,
            comp_std_frac=self.comp_std_frac, quantile=quantile,
            iotup_model=self.iotup_model)

    def cost(self, comp_ms: float) -> float:
        return self.pricing.cost(comp_ms)

    def cost_batch(self, comp_ms: np.ndarray) -> np.ndarray:
        return self.pricing.cost_batch(comp_ms)

    def occupancy_ms(self, components: dict[str, float]) -> float:
        return components["comp"]
