"""Per-task records and aggregate results of a placement run.

``TaskRecord`` pairs the Decision Engine's *predicted* view of one task
(latency, cost, warm/cold) with the execution substrate's *actual* outcome;
``SimulationResult`` aggregates a run's records into the paper's reported
metrics (Tables III-V). Both are substrate-agnostic: the same types describe
an event-driven simulation against the AWS twin and a live prototype run over
real executors (see ``repro.core.runtime``).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.workload import TaskInput


@dataclass
class TaskRecord:
    task: TaskInput
    target: str
    predicted_latency_ms: float
    predicted_cost: float
    actual_latency_ms: float
    actual_cost: float
    predicted_cold: bool
    actual_cold: bool
    allowed_cost: float
    feasible: bool
    completion_ms: float
    hedged: bool = False

    @property
    def warm_cold_mismatch(self) -> bool:
        return self.target != "edge" and self.predicted_cold != self.actual_cold


@dataclass
class SimulationResult:
    records: list[TaskRecord]
    deadline_ms: float | None = None
    c_max: float | None = None
    edge_name: str = "edge"

    # ------------------------------------------------------------- totals
    @property
    def n(self) -> int:
        return len(self.records)

    @property
    def total_actual_cost(self) -> float:
        return sum(r.actual_cost for r in self.records)

    @property
    def total_predicted_cost(self) -> float:
        return sum(r.predicted_cost for r in self.records)

    @property
    def cost_error_pct(self) -> float:
        a = self.total_actual_cost
        return abs(self.total_predicted_cost - a) / max(a, 1e-12) * 100.0

    @property
    def avg_actual_latency_ms(self) -> float:
        return float(np.mean([r.actual_latency_ms for r in self.records]))

    @property
    def avg_predicted_latency_ms(self) -> float:
        return float(np.mean([r.predicted_latency_ms for r in self.records]))

    @property
    def latency_error_pct(self) -> float:
        a = self.avg_actual_latency_ms
        return abs(self.avg_predicted_latency_ms - a) / max(a, 1e-9) * 100.0

    @property
    def p95_actual_latency_ms(self) -> float:
        return float(np.percentile([r.actual_latency_ms for r in self.records], 95))

    @property
    def p99_actual_latency_ms(self) -> float:
        return float(np.percentile([r.actual_latency_ms for r in self.records], 99))

    # ------------------------------------------------- deadline (min-cost)
    @property
    def pct_deadline_violated(self) -> float:
        if self.deadline_ms is None:
            return 0.0
        v = [r for r in self.records if r.actual_latency_ms > self.deadline_ms]
        return len(v) / max(self.n, 1) * 100.0

    @property
    def avg_violation_ms(self) -> float:
        if self.deadline_ms is None:
            return 0.0
        v = [r.actual_latency_ms - self.deadline_ms for r in self.records
             if r.actual_latency_ms > self.deadline_ms]
        return float(np.mean(v)) if v else 0.0

    # ---------------------------------------------------- budget (min-lat)
    @property
    def pct_cost_violated(self) -> float:
        v = [r for r in self.records
             if np.isfinite(r.allowed_cost) and r.actual_cost > r.allowed_cost + 1e-15]
        return len(v) / max(self.n, 1) * 100.0

    @property
    def pct_budget_used(self) -> float:
        if self.c_max is None:
            return 0.0
        return self.total_actual_cost / max(self.c_max * self.n, 1e-12) * 100.0

    @property
    def n_warm_cold_mismatches(self) -> int:
        return sum(1 for r in self.records if r.warm_cold_mismatch)

    @property
    def n_edge(self) -> int:
        return sum(1 for r in self.records if r.target == self.edge_name)

    def configs_used(self) -> set[str]:
        return {r.target for r in self.records}
