"""Per-task records and aggregate results of a placement run.

``TaskRecord`` pairs the Decision Engine's *predicted* view of one task
(latency, cost, warm/cold) with the execution substrate's *actual* outcome;
``SimulationResult`` aggregates a run's records into the paper's reported
metrics (Tables III-V). Both are substrate-agnostic: the same types describe
an event-driven simulation against the AWS twin and a live prototype run over
real executors (see ``repro.core.runtime``).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.workload import TaskInput


@dataclass
class TaskRecord:
    task: TaskInput
    target: str
    predicted_latency_ms: float
    predicted_cost: float
    actual_latency_ms: float
    actual_cost: float
    predicted_cold: bool
    actual_cold: bool
    allowed_cost: float
    feasible: bool
    completion_ms: float
    hedged: bool = False
    queue_wait_ms: float = 0.0  # actual FIFO wait on the executor (edge)
    exec_ms: float = 0.0        # executor busy occupancy (utilization)
    hedge_target: str | None = None  # where the duplicate dispatch ran
    hedge_exec_ms: float = 0.0       # its busy occupancy (for device load)

    @property
    def warm_cold_mismatch(self) -> bool:
        return self.target != "edge" and self.predicted_cold != self.actual_cold


@dataclass(frozen=True)
class DeviceSummary:
    """Per-device load view of a fleet run (imbalance, not just aggregates)."""

    device: str
    n_tasks: int
    utilization: float        # busy occupancy / workload makespan
    queue_wait_mean_ms: float
    queue_wait_p50_ms: float
    queue_wait_p99_ms: float


@dataclass
class SimulationResult:
    records: list[TaskRecord]
    deadline_ms: float | None = None
    c_max: float | None = None
    edge_name: str = "edge"
    edge_names: tuple[str, ...] | None = None  # fleet devices (None = single)

    # ------------------------------------------------------------- totals
    @property
    def n(self) -> int:
        return len(self.records)

    @property
    def total_actual_cost(self) -> float:
        return sum(r.actual_cost for r in self.records)

    @property
    def total_predicted_cost(self) -> float:
        return sum(r.predicted_cost for r in self.records)

    @property
    def cost_error_pct(self) -> float:
        a = self.total_actual_cost
        return abs(self.total_predicted_cost - a) / max(a, 1e-12) * 100.0

    @property
    def avg_actual_latency_ms(self) -> float:
        return float(np.mean([r.actual_latency_ms for r in self.records]))

    @property
    def avg_predicted_latency_ms(self) -> float:
        return float(np.mean([r.predicted_latency_ms for r in self.records]))

    @property
    def latency_error_pct(self) -> float:
        a = self.avg_actual_latency_ms
        return abs(self.avg_predicted_latency_ms - a) / max(a, 1e-9) * 100.0

    @property
    def p95_actual_latency_ms(self) -> float:
        return float(np.percentile([r.actual_latency_ms for r in self.records], 95))

    @property
    def p99_actual_latency_ms(self) -> float:
        return float(np.percentile([r.actual_latency_ms for r in self.records], 99))

    # ------------------------------------------------- deadline (min-cost)
    @property
    def pct_deadline_violated(self) -> float:
        if self.deadline_ms is None:
            return 0.0
        v = [r for r in self.records if r.actual_latency_ms > self.deadline_ms]
        return len(v) / max(self.n, 1) * 100.0

    @property
    def avg_violation_ms(self) -> float:
        if self.deadline_ms is None:
            return 0.0
        v = [r.actual_latency_ms - self.deadline_ms for r in self.records
             if r.actual_latency_ms > self.deadline_ms]
        return float(np.mean(v)) if v else 0.0

    # ---------------------------------------------------- budget (min-lat)
    @property
    def pct_cost_violated(self) -> float:
        v = [r for r in self.records
             if np.isfinite(r.allowed_cost) and r.actual_cost > r.allowed_cost + 1e-15]
        return len(v) / max(self.n, 1) * 100.0

    @property
    def pct_budget_used(self) -> float:
        if self.c_max is None:
            return 0.0
        return self.total_actual_cost / max(self.c_max * self.n, 1e-12) * 100.0

    @property
    def n_warm_cold_mismatches(self) -> int:
        return sum(1 for r in self.records if r.warm_cold_mismatch)

    @property
    def n_edge(self) -> int:
        edge = set(self.edge_names) if self.edge_names else {self.edge_name}
        return sum(1 for r in self.records if r.target in edge)

    def configs_used(self) -> set[str]:
        return {r.target for r in self.records}

    # ------------------------------------------------- per-device (fleet) view
    @property
    def makespan_ms(self) -> float:
        """First arrival to last completion — the run's wall-clock horizon."""
        if not self.records:
            return 0.0
        t0 = min(r.task.arrival_ms for r in self.records)
        t1 = max(r.completion_ms for r in self.records)
        return max(t1 - t0, 0.0)

    def device_summaries(self) -> dict[str, DeviceSummary]:
        """Utilization and queue-wait distribution per edge device, so fleet
        benchmarks can report imbalance instead of just aggregate latency.

        Hedged duplicate dispatches count toward the device they ran on —
        both in ``n_tasks`` and in the busy time behind ``utilization`` —
        since they occupy its executor exactly like a primary dispatch.
        Queue-wait percentiles are over primary dispatches only.
        """
        devices = self.edge_names if self.edge_names else (self.edge_name,)
        span = self.makespan_ms
        out: dict[str, DeviceSummary] = {}
        for dev in devices:
            recs = [r for r in self.records if r.target == dev]
            hedges = [r for r in self.records if r.hedge_target == dev]
            waits = np.array([r.queue_wait_ms for r in recs]) if recs else np.zeros(1)
            busy = sum(r.exec_ms for r in recs) + sum(r.hedge_exec_ms for r in hedges)
            out[dev] = DeviceSummary(
                device=dev,
                n_tasks=len(recs) + len(hedges),
                utilization=busy / span if span > 0 else 0.0,
                queue_wait_mean_ms=float(np.mean(waits)),
                queue_wait_p50_ms=float(np.percentile(waits, 50)),
                queue_wait_p99_ms=float(np.percentile(waits, 99)),
            )
        return out

    def device_table(self) -> str:
        """Human-readable per-device summary (benchmarks and examples)."""
        rows = [f"{'device':<10} {'tasks':>6} {'util':>6} "
                f"{'wait_mean':>10} {'wait_p50':>9} {'wait_p99':>9}"]
        for s in self.device_summaries().values():
            rows.append(
                f"{s.device:<10} {s.n_tasks:>6d} {s.utilization:>6.1%} "
                f"{s.queue_wait_mean_ms:>10.0f} {s.queue_wait_p50_ms:>9.0f} "
                f"{s.queue_wait_p99_ms:>9.0f}")
        return "\n".join(rows)
