"""Per-task records and aggregate results of a placement run — columnar.

``RecordBatch`` is the struct-of-arrays home of a run's outcomes: one float64
column per field instead of N ``TaskRecord`` objects, which is what keeps
million-task serves practical (no per-task object churn, metrics computed as
array reductions). ``TaskRecord`` survives as the lazy per-task view —
``batch[i]`` materializes one on demand, so existing per-record consumers keep
working unchanged.

``SimulationResult`` aggregates a run's batch into the paper's reported
metrics (Tables III-V), all evaluated on the arrays. Both types are
substrate-agnostic: the same columns describe an event-driven simulation
against the AWS twin and a live prototype run over real executors (see
``repro.core.runtime``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import cached_property
from typing import Iterator, Sequence

import numpy as np

from repro.core.workload import TaskChunk, TaskInput


@dataclass
class TaskRecord:
    task: TaskInput
    target: str
    predicted_latency_ms: float
    predicted_cost: float
    actual_latency_ms: float
    actual_cost: float
    predicted_cold: bool
    actual_cold: bool
    allowed_cost: float
    feasible: bool
    completion_ms: float
    hedged: bool = False
    queue_wait_ms: float = 0.0  # actual FIFO wait on the executor (edge)
    exec_ms: float = 0.0        # executor busy occupancy (utilization)
    hedge_target: str | None = None  # where the duplicate dispatch ran
    hedge_exec_ms: float = 0.0       # its busy occupancy (for device load)
    # failure-aware serving (see ``repro.core.faults``): shed tasks never ran
    # (bill nothing); failed tasks exhausted retry/failover; ``attempts``
    # counts every dispatch billed to this task; ``tier`` is its SLO class
    shed: bool = False
    failed: bool = False
    attempts: int = 1
    tier: int = 0
    # fair-share reclamation demoted this task to a lower SLO class
    # (``tier`` holds the FINAL, post-demotion class)
    downgraded: bool = False

    @property
    def warm_cold_mismatch(self) -> bool:
        return self.target != "edge" and self.predicted_cold != self.actual_cold


@dataclass(eq=False)
class RecordBatch(Sequence):
    """Struct-of-arrays form of N ``TaskRecord``s (the columnar record path).

    ``target_codes`` indexes into ``target_names``; ``hedge_codes`` uses the
    same table with ``-1`` meaning "no hedge". Indexing or iterating yields
    lazy ``TaskRecord`` views; metrics should use the arrays directly.

    ``tasks`` may be a ``list[TaskInput]``, a columnar ``TaskChunk``, or —
    for streaming serves that drop per-task objects entirely
    (``serve_stream(keep_tasks=False)``) — empty, in which case the
    ``arrivals``/``task_idx`` columns back the metrics and ``__getitem__``
    synthesizes placeholder tasks (``meta={"streamed": True}``, NaN sizes).
    """

    tasks: "list[TaskInput] | TaskChunk"
    target_codes: np.ndarray        # (n,) int64 — index into target_names
    target_names: tuple[str, ...]
    predicted_latency_ms: np.ndarray
    predicted_cost: np.ndarray
    actual_latency_ms: np.ndarray
    actual_cost: np.ndarray
    predicted_cold: np.ndarray      # bool
    actual_cold: np.ndarray         # bool
    allowed_cost: np.ndarray
    feasible: np.ndarray            # bool
    completion_ms: np.ndarray
    hedged: np.ndarray              # bool
    queue_wait_ms: np.ndarray
    exec_ms: np.ndarray
    hedge_codes: np.ndarray         # (n,) int64, -1 = no hedge
    hedge_exec_ms: np.ndarray
    # streaming columns (set when per-task objects are dropped; see class doc)
    arrivals: np.ndarray | None = None
    task_idx: np.ndarray | None = None
    # input columns (set by ``RecordArena(keep_inputs=True)``): the task
    # size/bytes features, retained so a streamed run with no task objects is
    # still exportable as a replayable trace (``repro.trace.capture``)
    input_size: np.ndarray | None = None
    input_bytes: np.ndarray | None = None
    # failure-aware serving columns (``None`` at construction materializes
    # the no-failure defaults, so every existing producer stays valid):
    # shed = admission control dropped the task (it bills nothing), failed =
    # retries/failovers exhausted, attempts = dispatches billed, tier = SLO
    # class (0 = highest). See ``repro.core.faults``.
    shed: np.ndarray | None = None      # bool
    failed: np.ndarray | None = None    # bool
    attempts: np.ndarray | None = None  # int64, >= 1 (0 for shed rows)
    tier: np.ndarray | None = None      # int64
    # reclamation demoted the task's SLO class (``tier`` is the final class)
    downgraded: np.ndarray | None = None  # bool

    def __post_init__(self):
        n = self.target_codes.shape[0]
        if self.shed is None:
            self.shed = np.zeros(n, dtype=bool)
        if self.failed is None:
            self.failed = np.zeros(n, dtype=bool)
        if self.attempts is None:
            self.attempts = np.ones(n, dtype=np.int64)
        if self.tier is None:
            self.tier = np.zeros(n, dtype=np.int64)
        if self.downgraded is None:
            self.downgraded = np.zeros(n, dtype=bool)

    # ------------------------------------------------------------ construction
    @classmethod
    def empty(cls) -> "RecordBatch":
        z = np.zeros(0)
        zb = np.zeros(0, dtype=bool)
        zi = np.zeros(0, dtype=np.int64)
        return cls(tasks=[], target_codes=zi, target_names=(),
                   predicted_latency_ms=z, predicted_cost=z,
                   actual_latency_ms=z, actual_cost=z,
                   predicted_cold=zb, actual_cold=zb,
                   allowed_cost=z, feasible=zb, completion_ms=z,
                   hedged=zb, queue_wait_ms=z, exec_ms=z,
                   hedge_codes=zi, hedge_exec_ms=z)

    @classmethod
    def from_records(cls, records: Sequence[TaskRecord]) -> "RecordBatch":
        """Columnarize a list of per-task records (the object-path adapter)."""
        if isinstance(records, cls):
            return records
        records = list(records)
        if not records:
            return cls.empty()
        names = dict.fromkeys(r.target for r in records)
        names.update(dict.fromkeys(
            r.hedge_target for r in records if r.hedge_target is not None))
        table = tuple(names)
        code = {nm: i for i, nm in enumerate(table)}
        return cls(
            tasks=[r.task for r in records],
            target_codes=np.array([code[r.target] for r in records], np.int64),
            target_names=table,
            predicted_latency_ms=np.array([r.predicted_latency_ms for r in records]),
            predicted_cost=np.array([r.predicted_cost for r in records]),
            actual_latency_ms=np.array([r.actual_latency_ms for r in records]),
            actual_cost=np.array([r.actual_cost for r in records]),
            predicted_cold=np.array([r.predicted_cold for r in records], bool),
            actual_cold=np.array([r.actual_cold for r in records], bool),
            allowed_cost=np.array([r.allowed_cost for r in records]),
            feasible=np.array([r.feasible for r in records], bool),
            completion_ms=np.array([r.completion_ms for r in records]),
            hedged=np.array([r.hedged for r in records], bool),
            queue_wait_ms=np.array([r.queue_wait_ms for r in records]),
            exec_ms=np.array([r.exec_ms for r in records]),
            hedge_codes=np.array(
                [code[r.hedge_target] if r.hedge_target is not None else -1
                 for r in records], np.int64),
            hedge_exec_ms=np.array([r.hedge_exec_ms for r in records]),
            shed=np.array([r.shed for r in records], bool),
            failed=np.array([r.failed for r in records], bool),
            attempts=np.array([r.attempts for r in records], np.int64),
            tier=np.array([r.tier for r in records], np.int64),
            downgraded=np.array([r.downgraded for r in records], bool),
        )

    # ------------------------------------------------------------- sequence API
    def __len__(self) -> int:
        return self.target_codes.shape[0]

    def __bool__(self) -> bool:
        return len(self) > 0

    def _task_at(self, i: int) -> TaskInput:
        if len(self.tasks) > 0:
            return self.tasks[i]
        # streamed batch: the tasks were never retained — synthesize a
        # placeholder carrying what the record columns know
        return TaskInput(
            idx=int(self.task_idx[i]) if self.task_idx is not None else i,
            arrival_ms=float(self.arrivals[i]) if self.arrivals is not None else 0.0,
            size=float(self.input_size[i]) if self.input_size is not None
            else float("nan"),
            bytes=float(self.input_bytes[i]) if self.input_bytes is not None
            else float("nan"),
            meta={"streamed": True})

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        i = int(i)
        hc = int(self.hedge_codes[i])
        return TaskRecord(
            task=self._task_at(i),
            target=self.target_names[int(self.target_codes[i])],
            predicted_latency_ms=float(self.predicted_latency_ms[i]),
            predicted_cost=float(self.predicted_cost[i]),
            actual_latency_ms=float(self.actual_latency_ms[i]),
            actual_cost=float(self.actual_cost[i]),
            predicted_cold=bool(self.predicted_cold[i]),
            actual_cold=bool(self.actual_cold[i]),
            allowed_cost=float(self.allowed_cost[i]),
            feasible=bool(self.feasible[i]),
            completion_ms=float(self.completion_ms[i]),
            hedged=bool(self.hedged[i]),
            queue_wait_ms=float(self.queue_wait_ms[i]),
            exec_ms=float(self.exec_ms[i]),
            hedge_target=self.target_names[hc] if hc >= 0 else None,
            hedge_exec_ms=float(self.hedge_exec_ms[i]),
            shed=bool(self.shed[i]),
            failed=bool(self.failed[i]),
            attempts=int(self.attempts[i]),
            tier=int(self.tier[i]),
            downgraded=bool(self.downgraded[i]),
        )

    def __iter__(self) -> Iterator[TaskRecord]:
        for i in range(len(self)):
            yield self[i]

    # ------------------------------------------------------------- array views
    @cached_property
    def arrival_ms(self) -> np.ndarray:
        if self.arrivals is not None:
            return self.arrivals
        if isinstance(self.tasks, TaskChunk):
            return self.tasks.arrival_ms
        return np.array([t.arrival_ms for t in self.tasks])

    @property
    def targets(self) -> np.ndarray:
        """Per-row target names as an object array (diagnostics, benches)."""
        return np.array(self.target_names, dtype=object)[self.target_codes] \
            if self.target_names else np.empty(0, dtype=object)

    def code_of(self, name: str) -> int:
        """Code for ``name`` in this batch's table, -1 if never used."""
        try:
            return self.target_names.index(name)
        except ValueError:
            return -1

    def target_mask(self, names: set[str] | frozenset[str]) -> np.ndarray:
        """Boolean mask of rows whose target is in ``names`` (vectorized)."""
        table = np.array([nm in names for nm in self.target_names], bool)
        if table.shape[0] == 0:
            return np.zeros(len(self), bool)
        return table[self.target_codes]

    def completion_order(self) -> np.ndarray:
        """Row indices sorted by completion time (ties keep arrival order).

        Rows are stored in arrival order, but the event-driven runtime
        *finishes* them in completion order — this is the batch as the
        completion-event stream saw it, the natural replay order for
        consumers that react to outcomes (online refit of the component
        models, drift monitors) rather than to arrivals.
        """
        return np.argsort(self.completion_ms, kind="stable")

    def input_arrays(self) -> tuple[np.ndarray, np.ndarray]:
        """The ``(size, bytes)`` input-feature columns of this batch's tasks.

        Used by trace capture (``repro.trace.capture``) to make any serve run
        re-replayable. Prefers the dedicated input columns (streamed runs with
        ``keep_inputs=True``), then the retained task container. Raises an
        actionable ``ValueError`` when the inputs were dropped entirely.
        """
        if self.input_size is not None and self.input_bytes is not None:
            return self.input_size, self.input_bytes
        if isinstance(self.tasks, TaskChunk):
            return self.tasks.size, self.tasks.bytes
        if len(self.tasks) > 0:
            return (np.array([t.size for t in self.tasks], dtype=np.float64),
                    np.array([t.bytes for t in self.tasks], dtype=np.float64))
        if len(self) == 0:
            return np.zeros(0), np.zeros(0)
        raise ValueError(
            "task input sizes were not retained on this batch — re-run with "
            "serve_stream(..., keep_inputs=True) (constant-memory streams) or "
            "keep_tasks=True so the run can be captured as a replayable trace")

    def take(self, order) -> "RecordBatch":
        """Rows reordered/selected by an index array, as a new batch.

        Every column (including the optional streaming/input columns) is
        gathered through the same index, so ``take(completion_order())`` is
        the completion-event view and cross-shard merges can re-sort into
        global arrival order (``ShardedResult.merged_records``).
        """
        order = np.asarray(order, dtype=np.int64)
        if isinstance(self.tasks, TaskChunk):
            t = self.tasks
            tasks: "list[TaskInput] | TaskChunk" = TaskChunk(
                idx=t.idx[order], arrival_ms=t.arrival_ms[order],
                size=t.size[order], bytes=t.bytes[order])
        elif len(self.tasks) > 0:
            tasks = [self.tasks[int(i)] for i in order.tolist()]
        else:
            tasks = []
        opt = (lambda a: None if a is None else a[order])
        return RecordBatch(
            tasks=tasks,
            target_codes=self.target_codes[order],
            target_names=self.target_names,
            predicted_latency_ms=self.predicted_latency_ms[order],
            predicted_cost=self.predicted_cost[order],
            actual_latency_ms=self.actual_latency_ms[order],
            actual_cost=self.actual_cost[order],
            predicted_cold=self.predicted_cold[order],
            actual_cold=self.actual_cold[order],
            allowed_cost=self.allowed_cost[order],
            feasible=self.feasible[order],
            completion_ms=self.completion_ms[order],
            hedged=self.hedged[order],
            queue_wait_ms=self.queue_wait_ms[order],
            exec_ms=self.exec_ms[order],
            hedge_codes=self.hedge_codes[order],
            hedge_exec_ms=self.hedge_exec_ms[order],
            shed=self.shed[order],
            failed=self.failed[order],
            attempts=self.attempts[order],
            tier=self.tier[order],
            downgraded=self.downgraded[order],
            arrivals=opt(self.arrivals),
            task_idx=opt(self.task_idx),
            input_size=opt(self.input_size),
            input_bytes=opt(self.input_bytes),
        )


_ARENA_F64 = ("predicted_latency_ms", "predicted_cost", "actual_latency_ms",
              "actual_cost", "allowed_cost", "completion_ms", "queue_wait_ms",
              "exec_ms", "hedge_exec_ms")
_ARENA_BOOL = ("predicted_cold", "actual_cold", "feasible", "hedged",
               "shed", "failed", "downgraded")
_ARENA_I64 = ("target_codes", "hedge_codes", "attempts", "tier")


class RecordArena:
    """Growable struct-of-arrays accumulator for streaming serves.

    ``serve_stream`` appends one ``RecordBatch`` per chunk; the arena merges
    the columns in place into preallocated arrays that grow by geometric
    doubling — amortized O(1) per row, no per-chunk ``np.concatenate`` churn
    (which would copy the whole prefix on every chunk: O(n²/chunk) bytes).
    Target-name tables are unified incrementally: each chunk's codes are
    remapped through one vectorized table lookup, so batches from different
    sources (different shards, hedged fallback paths) merge cleanly.

    ``keep_tasks=False`` is the constant-memory mode: per-task objects are
    never retained — only the ``arrivals``/``task_idx`` columns — which is
    what holds a 10M-task streaming serve to O(result columns) instead of
    O(task objects). ``finish()`` returns the trimmed ``RecordBatch`` view;
    rows already appended are never rewritten, so the view stays valid if
    more rows are appended afterwards.

    ``keep_inputs=True`` additionally retains the task ``size``/``bytes``
    input-feature columns (two float64 columns — still constant-memory), so a
    streamed run that dropped its task objects can be exported back to a
    replayable trace (``repro.trace.capture``) round-trip exactly.
    """

    def __init__(self, keep_tasks: bool = True, capacity: int = 0,
                 keep_inputs: bool = False):
        self.n = 0
        self.keep_tasks = keep_tasks
        self.keep_inputs = keep_inputs
        self._cap0 = max(int(capacity), 0)  # optional preallocation hint
        self._cap = 0
        self._cols: dict[str, np.ndarray] = {}
        self._names: list[str] = []
        self._code: dict[str, int] = {}
        self.tasks: list[TaskInput] = []

    def __len__(self) -> int:
        return self.n

    @property
    def nbytes(self) -> int:
        """Currently allocated column bytes (capacity, not fill)."""
        return sum(c.nbytes for c in self._cols.values())

    def _reserve(self, need: int) -> None:
        if need <= self._cap:
            return
        new_cap = max(self._cap, self._cap0, 1024)
        while new_cap < need:
            new_cap *= 2
        f64 = _ARENA_F64 + ("arrivals",)
        if self.keep_inputs:
            f64 = f64 + ("input_size", "input_bytes")
        dtypes = ({k: np.float64 for k in f64}
                  | {k: np.bool_ for k in _ARENA_BOOL}
                  | {k: np.int64 for k in _ARENA_I64 + ("task_idx",)})
        for name, dt in dtypes.items():
            fresh = np.empty(new_cap, dtype=dt)
            old = self._cols.get(name)
            if old is not None:
                fresh[:self.n] = old[:self.n]
            self._cols[name] = fresh
        self._cap = new_cap

    def _remap_table(self, names: Sequence[str]) -> np.ndarray:
        """Chunk-local code → arena code, with a trailing -1 slot so hedge
        codes of -1 pass through (``table[-1] == -1``)."""
        for nm in names:
            if nm not in self._code:
                self._code[nm] = len(self._names)
                self._names.append(nm)
        return np.array([self._code[nm] for nm in names] + [-1], dtype=np.int64)

    def append(self, records: "RecordBatch | Sequence[TaskRecord]") -> None:
        rb = RecordBatch.from_records(records)
        m = len(rb)
        if m == 0:
            return
        self._reserve(self.n + m)
        sl = slice(self.n, self.n + m)
        table = self._remap_table(rb.target_names)
        cols = self._cols
        cols["target_codes"][sl] = table[rb.target_codes]
        cols["hedge_codes"][sl] = table[rb.hedge_codes]
        cols["attempts"][sl] = rb.attempts
        cols["tier"][sl] = rb.tier
        for name in _ARENA_F64 + _ARENA_BOOL:
            cols[name][sl] = getattr(rb, name)
        cols["arrivals"][sl] = rb.arrival_ms
        if self.keep_inputs:
            size, nbytes = rb.input_arrays()  # actionable error when dropped
            cols["input_size"][sl] = size
            cols["input_bytes"][sl] = nbytes
        if rb.task_idx is not None:
            cols["task_idx"][sl] = rb.task_idx
        elif isinstance(rb.tasks, TaskChunk):
            cols["task_idx"][sl] = rb.tasks.idx
        elif len(rb.tasks) > 0:
            cols["task_idx"][sl] = [getattr(t, "idx", -1) for t in rb.tasks]
        else:
            cols["task_idx"][sl] = -1
        if self.keep_tasks:
            self.tasks.extend(rb.tasks)
        self.n += m

    def finish(self) -> RecordBatch:
        """The accumulated rows as one ``RecordBatch`` (trimmed array views)."""
        if self.n == 0:
            return RecordBatch.empty()
        c = {k: v[:self.n] for k, v in self._cols.items()}
        return RecordBatch(
            tasks=self.tasks if self.keep_tasks else [],
            target_names=tuple(self._names),
            arrivals=c.pop("arrivals"),
            task_idx=c.pop("task_idx"),
            input_size=c.pop("input_size", None),
            input_bytes=c.pop("input_bytes", None),
            **c,
        )


@dataclass(frozen=True)
class DeviceSummary:
    """Per-device load view of a fleet run (imbalance, not just aggregates)."""

    device: str
    n_tasks: int
    utilization: float        # busy occupancy / workload makespan
    queue_wait_mean_ms: float
    queue_wait_p50_ms: float
    queue_wait_p99_ms: float


@dataclass
class SimulationResult:
    """Aggregate metrics of one serve/simulation run, computed on arrays.

    ``records`` accepts either a ``RecordBatch`` (the columnar serve path) or
    a plain ``list[TaskRecord]`` (live/per-task paths, hand-built tests); the
    list form is columnarized on construction.
    """

    records: RecordBatch | list[TaskRecord] = field(default_factory=list)
    deadline_ms: float | None = None
    c_max: float | None = None
    edge_name: str = "edge"
    edge_names: tuple[str, ...] | None = None  # fleet devices (None = single)

    def __post_init__(self):
        if not isinstance(self.records, RecordBatch):
            self.records = RecordBatch.from_records(self.records)

    # ------------------------------------------------------------- totals
    @property
    def n(self) -> int:
        return len(self.records)

    @property
    def total_actual_cost(self) -> float:
        return float(np.sum(self.records.actual_cost))

    @property
    def total_predicted_cost(self) -> float:
        return float(np.sum(self.records.predicted_cost))

    @property
    def cost_error_pct(self) -> float:
        a = self.total_actual_cost
        return abs(self.total_predicted_cost - a) / max(a, 1e-12) * 100.0

    @property
    def avg_actual_latency_ms(self) -> float:
        return float(np.mean(self.records.actual_latency_ms))

    @property
    def avg_predicted_latency_ms(self) -> float:
        return float(np.mean(self.records.predicted_latency_ms))

    @property
    def latency_error_pct(self) -> float:
        a = self.avg_actual_latency_ms
        return abs(self.avg_predicted_latency_ms - a) / max(a, 1e-9) * 100.0

    @property
    def p95_actual_latency_ms(self) -> float:
        return float(np.percentile(self.records.actual_latency_ms, 95))

    @property
    def p99_actual_latency_ms(self) -> float:
        return float(np.percentile(self.records.actual_latency_ms, 99))

    # ------------------------------------------------- deadline (min-cost)
    @property
    def pct_deadline_violated(self) -> float:
        if self.deadline_ms is None:
            return 0.0
        v = int(np.count_nonzero(self.records.actual_latency_ms > self.deadline_ms))
        return v / max(self.n, 1) * 100.0

    @property
    def avg_violation_ms(self) -> float:
        if self.deadline_ms is None:
            return 0.0
        lat = self.records.actual_latency_ms
        over = lat[lat > self.deadline_ms]
        return float(np.mean(over - self.deadline_ms)) if over.size else 0.0

    # ---------------------------------------------------- budget (min-lat)
    @property
    def pct_cost_violated(self) -> float:
        allowed = self.records.allowed_cost
        v = int(np.count_nonzero(
            np.isfinite(allowed) & (self.records.actual_cost > allowed + 1e-15)))
        return v / max(self.n, 1) * 100.0

    @property
    def pct_budget_used(self) -> float:
        if self.c_max is None:
            return 0.0
        return self.total_actual_cost / max(self.c_max * self.n, 1e-12) * 100.0

    # ------------------------------------------- failure-aware serving view
    @property
    def n_shed(self) -> int:
        return int(np.count_nonzero(self.records.shed))

    @property
    def n_failed(self) -> int:
        return int(np.count_nonzero(self.records.failed))

    @property
    def pct_shed(self) -> float:
        return self.n_shed / max(self.n, 1) * 100.0

    @property
    def n_retried(self) -> int:
        """Tasks that needed more than one dispatch (retry or failover)."""
        return int(np.count_nonzero(self.records.attempts > 1))

    @property
    def n_downgraded(self) -> int:
        """Tasks demoted to a lower SLO class by fair-share reclamation."""
        return int(np.count_nonzero(self.records.downgraded))

    @property
    def pct_downgraded(self) -> float:
        return self.n_downgraded / max(self.n, 1) * 100.0

    def slo_attainment(self, deadline_ms: float,
                       tier: int | None = None) -> float:
        """Fraction of tasks (optionally of one SLO tier) that completed
        within ``deadline_ms`` of arrival. Shed and permanently-failed tasks
        count as misses — degrading by dropping work is visible here, not
        hidden by it."""
        r = self.records
        sel = np.ones(len(r), dtype=bool) if tier is None else r.tier == tier
        n_sel = int(np.count_nonzero(sel))
        if n_sel == 0:
            return 1.0
        ok = sel & ~r.shed & ~r.failed & (r.actual_latency_ms <= deadline_ms)
        return int(np.count_nonzero(ok)) / n_sel

    @property
    def n_warm_cold_mismatches(self) -> int:
        r = self.records
        edge = set(self.edge_names) if self.edge_names else {self.edge_name}
        non_edge = ~r.target_mask(edge)
        return int(np.count_nonzero(
            non_edge & (r.predicted_cold != r.actual_cold)))

    @property
    def n_edge(self) -> int:
        edge = set(self.edge_names) if self.edge_names else {self.edge_name}
        return int(np.count_nonzero(self.records.target_mask(edge)))

    def configs_used(self) -> set[str]:
        r = self.records
        return {r.target_names[c] for c in np.unique(r.target_codes).tolist()}

    # ------------------------------------------------- per-device (fleet) view
    @property
    def makespan_ms(self) -> float:
        """First arrival to last completion — the run's wall-clock horizon."""
        if not self.records:
            return 0.0
        t0 = float(np.min(self.records.arrival_ms))
        t1 = float(np.max(self.records.completion_ms))
        return max(t1 - t0, 0.0)

    def device_summaries(self) -> dict[str, DeviceSummary]:
        """Utilization and queue-wait distribution per edge device, so fleet
        benchmarks can report imbalance instead of just aggregate latency.

        Hedged duplicate dispatches count toward the device they ran on —
        both in ``n_tasks`` and in the busy time behind ``utilization`` —
        since they occupy its executor exactly like a primary dispatch.
        Queue-wait percentiles are over primary dispatches only.
        """
        devices = self.edge_names if self.edge_names else (self.edge_name,)
        span = self.makespan_ms
        r = self.records
        out: dict[str, DeviceSummary] = {}
        for dev in devices:
            code = r.code_of(dev)
            mask = r.target_codes == code if code >= 0 else np.zeros(len(r), bool)
            hmask = r.hedge_codes == code if code >= 0 else np.zeros(len(r), bool)
            waits = r.queue_wait_ms[mask] if mask.any() else np.zeros(1)
            busy = float(np.sum(r.exec_ms[mask])) + float(np.sum(r.hedge_exec_ms[hmask]))
            out[dev] = DeviceSummary(
                device=dev,
                n_tasks=int(np.count_nonzero(mask)) + int(np.count_nonzero(hmask)),
                utilization=busy / span if span > 0 else 0.0,
                queue_wait_mean_ms=float(np.mean(waits)),
                queue_wait_p50_ms=float(np.percentile(waits, 50)),
                queue_wait_p99_ms=float(np.percentile(waits, 99)),
            )
        return out

    def device_table(self) -> str:
        """Human-readable per-device summary (benchmarks and examples)."""
        rows = [f"{'device':<10} {'tasks':>6} {'util':>6} "
                f"{'wait_mean':>10} {'wait_p50':>9} {'wait_p99':>9}"]
        for s in self.device_summaries().values():
            rows.append(
                f"{s.device:<10} {s.n_tasks:>6d} {s.utilization:>6.1%} "
                f"{s.queue_wait_mean_ms:>10.0f} {s.queue_wait_p50_ms:>9.0f} "
                f"{s.queue_wait_p99_ms:>9.0f}")
        return "\n".join(rows)
