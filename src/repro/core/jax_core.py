"""Device-resident placement core: the jit-compiled JAX predict→place pass.

The columnar decision core (``repro.core.decision``) is pure numpy: one
vectorized predict pass, then speculate-and-repair over the three sequential
recurrences. This module ports that hot per-chunk pipeline to JAX so a whole
chunk runs device-resident under ``jax.jit`` — selected per engine with
``DecisionEngine(array_backend="jax")`` or per stream with
``serve_stream(..., array_backend="jax")``. The numpy path stays the
correctness oracle.

Structure of one chunk (with stream residency — see below — even chunk
boundaries stop being host↔device sync points):

1. **Predict** — ridge upload / edge-compute models, normal-model scalars and
   Lambda pricing as jnp expressions; the GBRT compute model as a device-side
   gather over the serving step tables (``predictor.const1_serving_table``,
   padded to one ``(n_configs, B)`` matrix), or through the
   ``repro.kernels.gbrt_predict`` Pallas kernel on TPU / ``GBRT_KERNEL_MODE
   == "force"``.
2. **Place** — a chunk-level fixed-point driver replaces the host
   speculate-and-repair loop: a ``lax.while_loop`` carries the speculated
   policy-view codes (``-1`` = "no state effects yet", the frozen-state
   guess), and each iteration replays ALL THREE sequential recurrences from
   the chunk-start state under the current guess — the surplus bank and FIFO
   busy horizons as ``lax.scan`` left folds (or max-plus
   ``lax.associative_scan`` / ``repro.kernels.linear_scan`` forms in
   ``assoc`` mode, see ``recurrence.maxplus_combine``), the CIL warm/cold
   event walk as a ``lax.scan`` over fixed-capacity container pools. By the
   same induction the numpy repair loop relies on, the exact prefix grows by
   ≥ 1 row per iteration, so the fixed point (``pass(g) == g``) IS the true
   sequential trajectory and is reached in ≤ R+1 passes (2–3 in practice).
3. **Commit or stay resident** — decision outputs are sliced to the chunk on
   host either way. Without stream residency (standalone ``place_many``),
   CIL pools, edge horizons and the surplus bank are written back exactly
   like the numpy accept step (including the final ``reap`` at the last
   arrival). Under ``serve_stream`` the engine carries a
   ``_device_residency`` flag and the committed state instead STAYS ON
   DEVICE as a ``DeviceStreamState``: consecutive in-order chunks seed the
   next fixed point straight from the previous chunk's final state arrays
   (buffer-donated into the jitted step, so steady chunks reuse the same
   device buffers), and the host CIL/queues/policy are materialized only on
   demand — at stream end, on any fallback exit (hedged/custom policy swap,
   out-of-order arrivals, ``record_decisions``, a ``columnar=False`` chunk),
   or when an external consumer calls ``sync_engine``. Deferring the reap to
   materialization time is exact: the keep predicate is monotone in the reap
   time and dead containers are never warm-reusable, so the one deferred
   reap drops exactly the records the per-chunk reaps would have (order
   preserved — slot order is list order in both). ``stage_chunk`` +
   ``runtime._prefetched_chunks`` double-buffer the NEXT chunk's task arrays
   onto the device (``jax.device_put`` on a transfer thread) while the
   current fixed point runs, and the GBRT compute column launches ONE
   blocked multi-config Pallas kernel (``gbrt_predict_multi``) instead of a
   launch per cloud config.

Parity contract (mirrors the Pallas kernel tests):

- ``array_backend="jax_interpret"`` — float64 op-by-op execution
  (``jax.disable_jit``): BIT-IDENTICAL per record to the numpy path. XLA's
  compiled CPU pipeline contracts ``a + b*c`` into FMAs and reassociates
  constant chains, so the compiled path cannot promise last-ULP equality —
  interpret mode is the oracle, exactly like ``interpret=True`` Pallas.
- ``array_backend="jax"`` — jit-compiled: decision-equality (identical
  ``target_codes``) with tolerance-level float agreement.

Fallback rules (all BEFORE any balancer/RNG state is consumed, so a fallback
chunk is indistinguishable from a numpy chunk): hedged/custom policies,
non-columnar balancers, quantile prediction, ``record_decisions``, custom
target/model/pricing types, and out-of-order arrivals all take the existing
numpy path. Chunks are padded to power-of-two rows (pad rows carry code
``-1`` and no effects) so streaming tails never retrace the jit cache.
"""

from __future__ import annotations

import contextlib
import threading
import weakref
from dataclasses import dataclass, field

import numpy as np

from repro.core.cil import ContainerInfoList, ContainerRecord
from repro.core.perf_models import NormalModel, RidgeModel, ScaledModel
from repro.core.predictor import (
    EdgeTarget,
    LambdaTarget,
    Predictor,
    const1_serving_table,
    model_keyed_cache,
)
from repro.core.pricing import EdgePricing, LambdaPricing
from repro.core.workload import task_arrays

# "seq"   — sequential lax.scan left folds (bit-exact association vs numpy);
# "assoc" — max-plus associative_scan / cumsum forms (reassociated float sums:
#           decision-equality contract only);
# "auto"  — per-backend pick from the bench section 9 measurement (see
#           ``resolve_scan_mode``).
SCAN_MODE = "auto"
# Measured winners for SCAN_MODE="auto" (bench_runtime section 9's
# assoc-vs-seq timing; backends not listed default to "assoc"). XLA:CPU
# executes the short sequential scan faster than the log-depth max-plus
# associative form at serving chunk sizes — and seq is also the bit-exact
# association, so CPU keeps it. Accelerator backends win with assoc.
_AUTO_SCAN = {"cpu": "seq"}
# Route the assoc-mode surplus prefix through the repro.kernels.linear_scan
# Pallas kernel (f32 — decision-equality contract; exercised by tests/bench).
SURPLUS_LINEAR_SCAN = False

POOL_MIN_CAP = 8        # starting CIL container-pool capacity (doubles on demand)
PAD_MIN = 8             # minimum padded chunk rows
MAX_BACKENDS = ("numpy", "jax", "jax_interpret")


def resolve_scan_mode(backend: str) -> str:
    """Effective scan mode for a jax backend under the current ``SCAN_MODE``.

    ``"auto"`` resolves through the measured ``_AUTO_SCAN`` table (bench
    section 9 re-derives it and asserts agreement on accelerators)."""
    if SCAN_MODE != "auto":
        return SCAN_MODE
    return _AUTO_SCAN.get(backend, "assoc")


class CoreIneligible(Exception):
    """This engine's policy/targets/models are outside the jax core's replica."""


_JAX = None  # cached import probe: () = unavailable, (jax, jnp, lax) = ready


def _modules():
    global _JAX
    if _JAX is None:
        try:
            import jax
            import jax.numpy as jnp
            from jax import lax

            _JAX = (jax, jnp, lax)
        except Exception:  # pragma: no cover - jax is baked into the image
            _JAX = ()
    return _JAX if _JAX else None


def available() -> bool:
    return _modules() is not None


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


# Device-resident table/operand hosting, keyed on model identity + scope
# (x64 flag) with the _CONST1_TABLES weakref idiom — rebuilding a core (e.g.
# a hedged-policy swap and back) re-hosts NOTHING, and the per-chunk path
# does zero host-side operand prep (see ``model_keyed_cache``).
_DEVICE_TABLES: dict[tuple, dict] = {}
_DEVICE_TABLES_LOCK = threading.Lock()


@dataclass
class DeviceStreamState:
    """Cross-chunk device residency for one ``serve_stream`` run.

    Holds the sequential placement state ON DEVICE between consecutive
    in-order chunks: fixed-capacity CIL container pools (``busy``/``last``
    at ``cap`` slots per cloud config plus per-config ``cnt``), per-device
    edge FIFO horizons ``h``, and the Alg. 1 surplus bank ``s``. Host-side
    bookkeeping rides along: ``t_last`` (last committed arrival — validates
    in-order re-entry), ``cnt_max`` (pool-growth bound without materializing
    pools), ``chunks`` (resident chunks absorbed), and ``rng_draws`` (RNG
    stream offset — balancer draws consumed while resident; the host
    Generators advance identically, this records the offset). Strong refs
    to the CIL / policy / queues objects pin the state to the exact host
    structures it shadows — any object swap invalidates residency.
    """

    busy: object = None          # (n_cloud, cap) device array
    last: object = None          # (n_cloud, cap) device array
    cnt: object = None           # (n_cloud,) device array
    h: object = None             # (n_dev,) device array (edge fleets only)
    s: object = None             # scalar device array (MinLatency only)
    cap: int = 0
    t_last: float = -np.inf
    cnt_max: int = 0
    chunks: int = 0
    rng_draws: int = 0
    cil: object = None
    policy: object = None
    queues: object = field(default=None)


# --------------------------------------------------------------------- spec
@dataclass
class _CloudSpec:
    name: str
    memory_mb: float
    up_theta: tuple[float, float]
    start_warm: float          # max(mean, 0) — precomputed like the batch path
    start_cold: float
    store: float
    quantum: float
    gb: float
    rate: float
    breaks: np.ndarray
    vals: np.ndarray


@dataclass
class _EdgeSpec:
    name: str
    theta: tuple[float, float]
    scale: float
    iot: float
    store: float


def _ridge2(model) -> tuple[float, float]:
    if type(model) is not RidgeModel or model.theta.shape != (2,):
        raise CoreIneligible("non-affine upload/edge model")
    return float(model.theta[0]), float(model.theta[1])


def _normal_mean(model) -> float:
    if type(model) is not NormalModel:
        raise CoreIneligible("non-normal component model")
    return max(model.predict(), 0.0)


def _extract_cloud(tgt) -> _CloudSpec:
    if type(tgt) is not LambdaTarget:
        raise CoreIneligible(f"cloud target {tgt!r} is not a LambdaTarget")
    if type(tgt.pricing) is not LambdaPricing \
            or tgt.pricing.include_request_charge:
        raise CoreIneligible("non-Lambda or request-charge pricing")
    model = tgt.comp_model
    if not (hasattr(model, "const1_table") and hasattr(model, "thresholds")):
        raise CoreIneligible("cloud comp model is not a GBRT")
    breaks, vals = const1_serving_table(model, float(tgt.memory_mb))
    return _CloudSpec(
        name=tgt.name, memory_mb=float(tgt.memory_mb),
        up_theta=_ridge2(tgt.upld_model),
        start_warm=_normal_mean(tgt.start_warm),
        start_cold=_normal_mean(tgt.start_cold),
        store=_normal_mean(tgt.store_model),
        quantum=float(tgt.pricing.quantum_ms),
        gb=tgt.memory_mb / 1024.0,
        rate=float(tgt.pricing.gb_second_rate),
        breaks=np.asarray(breaks, np.float64),
        vals=np.asarray(vals, np.float64))


def _extract_edge(dev) -> _EdgeSpec:
    if type(dev) is not EdgeTarget:
        raise CoreIneligible(f"edge device {dev!r} is not an EdgeTarget")
    if type(dev.pricing) is not EdgePricing:
        raise CoreIneligible("edge pricing is not EdgePricing")
    model = dev.comp_model
    scale = 1.0
    if type(model) is ScaledModel:
        scale = float(model.scale)
        model = model.base
    t0, t1 = _ridge2(model)
    return _EdgeSpec(name=dev.name, theta=(t0, t1), scale=scale,
                     iot=_normal_mean(dev.iotup_model),
                     store=_normal_mean(dev.store_model))


def _engine_key(engine) -> tuple:
    """Cheap identity key for the per-engine core cache. Model swaps (online
    refit) change ids; ``valid_for`` weakref-guards against id recycling."""
    from repro.core import predictor as predictor_mod

    pred = engine.predictor
    ids = [id(pred), id(engine.policy), type(engine.policy),
           type(engine.balancer), pred.quantile,
           predictor_mod.GBRT_KERNEL_MODE, SCAN_MODE, SURPLUS_LINEAR_SCAN]
    for tgt in pred.cloud_targets:
        ids.append((id(tgt), id(tgt.comp_model), id(tgt.upld_model),
                    id(tgt.start_warm), id(tgt.start_cold),
                    id(tgt.store_model)))
    for dev in (pred.edge_fleet or ()):
        ids.append((id(dev), id(dev.comp_model), id(dev.iotup_model),
                    id(dev.store_model)))
    return tuple(ids)


# --------------------------------------------------------------------- core
class JaxPlacementCore:
    """One engine's compiled predict→place pipeline.

    Built lazily per engine (``core_for``), revalidated per chunk against the
    captured model identities — a refit-by-swap misses the cache and triggers
    a rebuild, exactly like the serving step-table cache.
    """

    def __init__(self, engine):
        mods = _modules()
        if mods is None:
            raise CoreIneligible("jax unavailable")
        self.jax, self.jnp, self.lax = mods
        if not engine._columnar_eligible():
            raise CoreIneligible("engine is not columnar-eligible")
        pred: Predictor = engine.predictor
        if pred.quantile is not None:
            raise CoreIneligible("quantile prediction is host-side only")
        self.cloud = [_extract_cloud(t) for t in pred.cloud_targets]
        self._kernel_models = [t.comp_model for t in pred.cloud_targets]
        self.edges = [_extract_edge(d) for d in (pred.edge_fleet or ())]
        self.n_cloud = len(self.cloud)
        self.n_dev = len(self.edges)
        self.has_edge = self.n_dev > 0
        self.T = self.n_cloud + (1 if self.has_edge else 0)
        self.edge_col = self.T - 1 if self.has_edge else -1
        self.t_idl = float(pred.cil.t_idl_ms)

        from repro.core import predictor as predictor_mod
        from repro.core.decision import (
            LeastPredictedWaitBalancer,
            MinLatencyPolicy,
        )

        self.is_minlat = type(engine.policy) is MinLatencyPolicy
        self.lpw = (self.n_dev > 1
                    and type(engine.balancer) is LeastPredictedWaitBalancer)
        mode = predictor_mod.GBRT_KERNEL_MODE
        tpu = self.jax.default_backend() == "tpu"
        self.use_gbrt_kernel = mode == "force" or (tpu and mode == "auto")
        self.dtype = self.jnp.float32 if tpu else self.jnp.float64
        self._x64 = not tpu
        self.seq = resolve_scan_mode(self.jax.default_backend()) == "seq"
        self.key = _engine_key(engine)
        self._targets = list(pred.cloud_targets) + list(pred.edge_fleet or ())
        self._refs = [weakref.ref(o) for o in (
            [pred, engine.policy]
            + [t for t in pred.cloud_targets]
            + [t.comp_model for t in pred.cloud_targets]
            + [d for d in (pred.edge_fleet or ())])]
        self._cap_hint = POOL_MIN_CAP
        with self._scope():
            self._tables = self._device_tables()
            self._state_fn = self._build_state()
            self._choose_fn = self._build_choose()
            self._finalize_fn = self._build_finalize()
            self._predict = self.jax.jit(self._build_predict())
            # S (the sequential-state seed) is donated: resident streams
            # feed chunk k's final state arrays straight back in as chunk
            # k+1's seed, reusing the same device buffers — steady chunks
            # allocate nothing for state.
            self._place = self.jax.jit(self._build_place(),
                                       donate_argnums=(1,))
            # interpret-mode hosts the fixed point itself on these pieces
            self._state = self.jax.jit(self._state_fn)
            self._choose = self.jax.jit(self._choose_fn)
            self._finalize = self.jax.jit(self._finalize_fn)
            self._compact = (self.jax.jit(self._build_compact())
                             if self.n_cloud else None)
        self.last_stats: dict | None = None
        # ---- stream residency (serve_stream only; see module docstring) ----
        self._resident: DeviceStreamState | None = None
        self.state_syncs = 0      # host materializations of resident state
        self.fallback_syncs = 0   # ... of which were forced by a fallback
        self.resident_chunks = 0  # chunks absorbed without a host sync
        self.chunk_commits = 0    # legacy per-chunk host commits
        self.resident_regrows = 0  # donated-seed restore+retry events

    # ------------------------------------------------------------ lifecycle
    def _scope(self):
        if not self._x64:
            return contextlib.nullcontext()
        from jax.experimental import enable_x64

        return enable_x64()

    def valid_for(self, engine) -> bool:
        return (self.key == _engine_key(engine)
                and all(r() is not None for r in self._refs))

    def compile_stats(self) -> dict:
        """jit-cache sizes — the bench's no-retrace probe."""
        return {"predict": self._predict._cache_size(),
                "place": self._place._cache_size(),
                "state": self._state._cache_size(),
                "choose": self._choose._cache_size()}

    # ------------------------------------------------------- device operands
    def _device_tables(self) -> dict:
        key = (tuple(id(t) for t in self._targets), self._x64)
        return model_keyed_cache(
            _DEVICE_TABLES, _DEVICE_TABLES_LOCK, key, self._targets,
            self._build_device_tables)

    def _build_device_tables(self) -> dict:
        jnp = self.jnp
        t: dict = {}
        if self.n_cloud:
            bmax = max(1, max(c.breaks.shape[0] for c in self.cloud))
            BR = np.full((self.n_cloud, bmax), np.inf)
            VL = np.zeros((self.n_cloud, bmax + 1))
            for i, c in enumerate(self.cloud):
                nb = c.breaks.shape[0]
                BR[i, :nb] = c.breaks
                VL[i, :nb + 1] = c.vals
                VL[i, nb + 1:] = c.vals[-1]
            t["BR"] = jnp.asarray(BR)
            t["VL"] = jnp.asarray(VL)
            t["UP0"] = jnp.asarray(np.array([c.up_theta[0] for c in self.cloud]))
            t["UP1"] = jnp.asarray(np.array([c.up_theta[1] for c in self.cloud]))
            t["SW"] = jnp.asarray(np.array([c.start_warm for c in self.cloud]))
            t["SC"] = jnp.asarray(np.array([c.start_cold for c in self.cloud]))
            t["ST"] = jnp.asarray(np.array([c.store for c in self.cloud]))
            t["QNT"] = jnp.asarray(np.array([c.quantum for c in self.cloud]))
            t["GB"] = jnp.asarray(np.array([c.gb for c in self.cloud]))
            t["RATE"] = jnp.asarray(np.array([c.rate for c in self.cloud]))
        if self.has_edge:
            t["ET0"] = jnp.asarray(np.array([e.theta[0] for e in self.edges]))
            t["ET1"] = jnp.asarray(np.array([e.theta[1] for e in self.edges]))
            t["ESC"] = jnp.asarray(np.array([e.scale for e in self.edges]))
            t["EIO"] = jnp.asarray(np.array([e.iot for e in self.edges]))
            t["EST"] = jnp.asarray(np.array([e.store for e in self.edges]))
        return t

    def _gbrt_kernel_operands(self):
        """Stacked multi-config Pallas operands for the ONE blocked
        ``gbrt_predict_multi`` launch (cached per model-identity tuple in
        ``ops.multi_kernel_operands`` — zero per-chunk / per-core-build host
        prep)."""
        from repro.kernels.gbrt_predict.ops import multi_kernel_operands

        F, TH, LV, LR, BASE, depth = multi_kernel_operands(
            self._kernel_models)
        MEM = self.jnp.asarray(np.array(
            [[c.memory_mb] for c in self.cloud], np.float32))
        return F, TH, LV, LR, BASE, MEM, depth

    # ----------------------------------------------------------- predict jit
    def _build_predict(self):
        jax, jnp = self.jax, self.jnp
        t = self._tables
        nc, nd = self.n_cloud, self.n_dev
        use_kernel = self.use_gbrt_kernel
        kernel_ops = None
        if use_kernel and nc:
            from repro.kernels.gbrt_predict.kernel import gbrt_predict_multi

            interpret = jax.default_backend() != "tpu"
            kernel_ops = self._gbrt_kernel_operands()

        def predict(sizes, nbytes):
            out = {}
            if nc:
                if use_kernel:
                    # ONE blocked launch over the padded (n_configs, trees,
                    # …) operand stack — grid (C, row-blocks) — instead of a
                    # pallas_call per cloud config. Bit-identical per column
                    # to the per-config launches (see multi_kernel_operands).
                    F, TH, LV, LR, BASE, MEM, depth = kernel_ops
                    x32 = sizes[:, None].astype(jnp.float32)
                    bn = min(256, x32.shape[0])
                    comp = gbrt_predict_multi(
                        x32, MEM, LR, BASE, F, TH, LV, depth=depth,
                        block_n=bn, interpret=interpret).astype(sizes.dtype)
                else:
                    comp = jax.vmap(
                        lambda b, v: v[jnp.searchsorted(b, sizes, side="left")]
                    )(t["BR"], t["VL"]).T
                compc = jnp.maximum(comp, 0.0)
                upld = jnp.maximum(
                    t["UP0"][None, :] + nbytes[:, None] * t["UP1"][None, :],
                    0.0)
                # associate exactly like sum(warm.values()) / occupancy_ms:
                # ((upld + start) + comp) (+ store)
                occ_w = (upld + t["SW"][None, :]) + compc
                occ_c = (upld + t["SC"][None, :]) + compc
                out["LATW"] = occ_w + t["ST"][None, :]
                out["LATC"] = occ_c + t["ST"][None, :]
                out["OCCW"] = occ_w
                out["OCCC"] = occ_c
                out["COMPC"] = compc
                billed = jnp.ceil(
                    jnp.maximum(jnp.round(compc), 1.0) / t["QNT"][None, :]
                ) * t["QNT"][None, :]
                out["COSTC"] = ((billed / 1000.0) * t["GB"][None, :]) \
                    * t["RATE"][None, :]
            if nd:
                ec = jnp.maximum(
                    (t["ET0"][None, :] + sizes[:, None] * t["ET1"][None, :])
                    * t["ESC"][None, :], 0.0)
                out["ECOMP"] = ec
                out["ELAT"] = (ec + t["EIO"][None, :]) + t["EST"][None, :]
            return out

        return predict

    # ----------------------------------------------------------- place parts
    # The per-chunk pass is split in three so interpret mode can keep the one
    # FMA-prone operation out of XLA: ``state`` (the three recurrences, the
    # CIL event walk and the policy-view matrices — additions, compares and
    # gathers only, which compiled XLA executes bit-exactly in sequential
    # order) → ``allowed = c_max + α·s_before`` (the ONLY multiply on the
    # place side; XLA CPU contracts mul+add chains into FMAs regardless of
    # optimization barriers, so interpret mode computes it op-by-op under
    # ``jax.disable_jit``) → ``choose`` (masked lexicographic argmins: exact
    # compares and min-reductions). Compiled mode composes all three inside
    # one jitted ``lax.while_loop`` fixed-point driver under the
    # decision-equality contract; interpret mode hosts the same fixed point
    # in Python over the jitted pieces and stays bit-exact.
    def _build_state(self):
        jax, jnp, lax = self.jax, self.jnp, self.lax
        nc, nd, T = self.n_cloud, self.n_dev, self.T
        edge_col, has_edge = self.edge_col, self.has_edge
        is_minlat, lpw, seq = self.is_minlat, self.lpw, self.seq
        t_idl = self.t_idl
        surplus_kernel = SURPLUS_LINEAR_SCAN and not seq
        from repro.core.recurrence import maxplus_combine

        def state_fn(guess, P):
            """One full state replay of the chunk under speculated codes
            ``guess`` (policy-view; -1 = no state effects yet — the
            frozen-state guess)."""
            nows, valid = P["nows"], P["valid"]
            R = nows.shape[0]
            rr = jnp.arange(R)
            is_edge_g = (guess == edge_col) if has_edge \
                else jnp.zeros(R, dtype=bool)
            is_cloud_g = (guess >= 0) & ~is_edge_g

            # --- edge busy horizons / nominations / induced waits ----------
            nom = ew = HB = h_fin = None
            if has_edge:
                ECOMP = P["ECOMP"]
                if lpw:
                    # winner feeds back into the next argmin: sequential only
                    def estep(h, xs):
                        now, ec, ie = xs
                        w = jnp.maximum(h - now, 0.0)
                        d = jnp.argmin(w)           # first-min == fleet order
                        upd = jnp.maximum(h[d], now) + ec[d]
                        h2 = h.at[d].set(jnp.where(ie, upd, h[d]))
                        return h2, (h, d)

                    h_fin, (HB, nom) = lax.scan(
                        estep, P["h0"], (nows, ECOMP, is_edge_g))
                else:
                    nom = P["nom_fixed"]
                    pushm = is_edge_g[:, None] \
                        & (nom[:, None] == jnp.arange(nd)[None, :])
                    if seq:
                        def estep(h, xs):
                            now, ec, pm = xs
                            return jnp.where(
                                pm, jnp.maximum(h, now) + ec, h), h

                        h_fin, HB = lax.scan(
                            estep, P["h0"], (nows, ECOMP, pushm))
                    else:
                        # exclusive max-plus scan: h_i = max(h0 + A_i, B_i)
                        a = jnp.where(pushm, ECOMP, 0.0)
                        b = jnp.where(pushm, nows[:, None] + ECOMP, -jnp.inf)
                        A, B = lax.associative_scan(
                            lambda x, y: maxplus_combine(x, y, jnp.maximum),
                            (a, b), axis=0)
                        z = jnp.zeros((1, nd), a.dtype)
                        ninf = jnp.full((1, nd), -jnp.inf, b.dtype)
                        Ax = jnp.concatenate([z, A[:-1]], axis=0)
                        Bx = jnp.concatenate([ninf, B[:-1]], axis=0)
                        HB = jnp.maximum(P["h0"][None, :] + Ax, Bx)
                        h_fin = jnp.maximum(P["h0"] + A[-1], B[-1])
                waits = jnp.maximum(HB - nows[:, None], 0.0)
                if nom is None:
                    nom = P["nom_fixed"]
                ew = waits[rr, nom]

            # --- CIL pools: one scan, per-config cold flags + dispatches ---
            overflow = jnp.asarray(False)
            if nc:
                cap = P["busy0"].shape[1]
                cidx = jnp.clip(guess, 0, nc - 1)

                def cstep(carry, xs):
                    busy, last, cnt = carry
                    now, ci, isc, occw, occc = xs
                    idle = (busy <= now) & (now <= last + t_idl)
                    cold_row = ~idle.any(axis=1)        # per-config, pre-row
                    idle_c = idle[ci]
                    # MRU reuse: first-max == the walk's strict > update
                    j_warm = jnp.argmax(
                        jnp.where(idle_c, last[ci], -jnp.inf))
                    is_cold = ~idle_c.any()
                    j = jnp.where(is_cold, cnt[ci], j_warm)
                    ovf = isc & is_cold & (j >= cap)
                    jc = jnp.minimum(j, cap - 1)
                    occ = jnp.where(is_cold, occc[ci], occw[ci])
                    completion = now + occ
                    do = isc & ~ovf
                    busy = busy.at[ci, jc].set(
                        jnp.where(do, completion, busy[ci, jc]))
                    last = last.at[ci, jc].set(
                        jnp.where(do, completion, last[ci, jc]))
                    cnt = cnt.at[ci].add(
                        jnp.where(do & is_cold, 1, 0))
                    return (busy, last, cnt), (cold_row, ovf)

                (busyF, lastF, cntF), (COLD, OVF) = lax.scan(
                    cstep, (P["busy0"], P["last0"], P["cnt0"]),
                    (nows, cidx, is_cloud_g, P["OCCW"], P["OCCC"]))
                overflow = OVF.any()
            else:
                busyF = lastF = cntF = None
                COLD = jnp.zeros((R, 0), dtype=bool)

            # --- (R, T) policy-view matrices -------------------------------
            cols_lat, cols_cost, cols_comp = [], [], []
            if nc:
                cols_lat.append(jnp.where(COLD, P["LATC"], P["LATW"]))
                cols_cost.append(P["COSTC"])
                cols_comp.append(P["COMPC"])
            if has_edge:
                cols_lat.append((ew + P["ELAT"][rr, nom])[:, None])
                cols_cost.append(P["ECOST"][rr, nom][:, None])
                cols_comp.append(P["ECOMP"][rr, nom][:, None])
            LAT = jnp.concatenate(cols_lat, axis=1)
            COST = jnp.concatenate(cols_cost, axis=1)
            COMP = jnp.concatenate(cols_comp, axis=1)

            # --- surplus bank (the third recurrence; MinLatency only) ------
            s_before = s_fin = None
            if is_minlat:
                safe_g = jnp.clip(guess, 0, T - 1)
                delta = jnp.where(guess >= 0,
                                  P["c_max"] - COST[rr, safe_g], 0.0)
                if seq:
                    def sstep(s, d):
                        return s + d, s

                    s_fin, s_before = lax.scan(sstep, P["s0"], delta)
                elif surplus_kernel:
                    from repro.kernels.linear_scan.ops import prefix_sum

                    incl = prefix_sum(delta).astype(delta.dtype)
                    s_before = P["s0"] + jnp.concatenate(
                        [jnp.zeros(1, delta.dtype), incl[:-1]])
                    s_fin = P["s0"] + incl[-1]
                else:
                    incl = jnp.cumsum(delta)
                    s_before = P["s0"] + jnp.concatenate(
                        [jnp.zeros(1, delta.dtype), incl[:-1]])
                    s_fin = P["s0"] + incl[-1]
            return {"nom": nom, "ew": ew, "LAT": LAT, "COST": COST,
                    "COMP": COMP, "COLD": COLD, "s_before": s_before,
                    "s_fin": s_fin, "h_fin": h_fin, "busyF": busyF,
                    "lastF": lastF, "cntF": cntF, "overflow": overflow}

        return state_fn

    def _build_choose(self):
        jnp = self.jnp
        T, edge_col, has_edge = self.T, self.edge_col, self.has_edge
        is_minlat = self.is_minlat

        def choose_fn(LAT, COST, allowed, deadline, valid):
            R = LAT.shape[0]
            if is_minlat:
                feas = COST <= allowed[:, None]
                none_f = ~feas.any(axis=1)
                if has_edge:
                    onehot = (jnp.arange(T) == edge_col)[None, :]
                    feas = jnp.where(none_f[:, None], onehot, feas)
                else:
                    feas = feas | none_f[:, None]
                l1 = jnp.where(feas, LAT, jnp.inf)
                lmin = l1.min(axis=1)
                tie = feas & (LAT == lmin[:, None])
                c2 = jnp.where(tie, COST, jnp.inf)
                cmin = c2.min(axis=1)
                final = tie & (COST == cmin[:, None])
                code = final.argmax(axis=1).astype(jnp.int32)
                feas_out = jnp.ones(R, dtype=bool)
            else:  # MinCostPolicy (edge column guaranteed by eligibility)
                feas = LAT <= deadline
                any_f = feas.any(axis=1)
                c1 = jnp.where(feas, COST, jnp.inf)
                cmin = c1.min(axis=1)
                tie = feas & (COST == cmin[:, None])
                l2 = jnp.where(tie, LAT, jnp.inf)
                lmin = l2.min(axis=1)
                final = tie & (LAT == lmin[:, None])
                code = final.argmax(axis=1).astype(jnp.int32)
                code = jnp.where(any_f, code, edge_col)
                feas_out = any_f
            return jnp.where(valid, code, -1), feas_out

        return choose_fn

    def _build_finalize(self):
        jnp = self.jnp
        nc, T = self.n_cloud, self.T
        edge_col, has_edge = self.edge_col, self.has_edge
        is_minlat = self.is_minlat

        def finalize(st, code, feas, allowed, P):
            """Chosen-row gathers + committed-state bundle for one chunk."""
            R = code.shape[0]
            rr = jnp.arange(R)
            safe = jnp.clip(code, 0, T - 1)
            res = {"code": code, "overflow": st["overflow"],
                   "lat": st["LAT"][rr, safe], "cost": st["COST"][rr, safe],
                   "comp": st["COMP"][rr, safe], "allowed": allowed,
                   "feas": feas}
            if is_minlat:
                res["s_fin"] = st["s_fin"]
            cold = (st["COLD"][rr, jnp.clip(code, 0, nc - 1)] if nc
                    else jnp.zeros(R, dtype=bool))
            if has_edge:
                is_edge_ch = code == edge_col
                res["cold"] = jnp.where(is_edge_ch, False, cold)
                res["wait"] = jnp.where(is_edge_ch, st["ew"], 0.0)
                res["nom"] = st["nom"]
                res["gcode"] = jnp.where(is_edge_ch, nc + st["nom"], code)
                res["h_fin"] = st["h_fin"]
            else:
                res["cold"] = cold
                res["wait"] = jnp.zeros(R)
                res["gcode"] = code
            if nc:
                res["busyF"], res["lastF"], res["cntF"] = \
                    st["busyF"], st["lastF"], st["cntF"]
                # scalar pool-growth bound for the NEXT resident chunk —
                # fetched with the decision outputs, so residency never
                # materializes the pools just to size them
                res["cnt_max"] = st["cntF"].max()
            return res

        return finalize

    def _build_compact(self):
        """Device-side stable pool compaction == the deferred reap, run ON
        DEVICE so long resident streams never sync to host just to shrink
        pools. Exact by the same two properties the deferred host reap rests
        on: the keep predicate is monotone in the reap time (a record the
        per-arrival walk dropped earlier is still dropped at ``t_last``) and
        dead records are never warm-reusable (the idle check can never pass
        again), so compaction keeps exactly the records the host list would
        hold — in the same relative (list) order, preserving MRU first-max
        tie-breaks."""
        jnp = self.jnp
        nc, t_idl = self.n_cloud, self.t_idl

        def compact(busy, last, cnt, t_last):
            cap = busy.shape[1]
            slots = jnp.arange(cap)
            in_use = slots[None, :] < cnt[:, None]
            keep = in_use & ((t_last < busy) | (t_last <= last + t_idl))
            # stable scatter: kept slot -> its rank; dropped -> the spill
            # column (sliced off below)
            d = jnp.where(keep, jnp.cumsum(keep, axis=1) - 1, cap)
            rows = jnp.arange(nc)[:, None]
            nb = jnp.full((nc, cap + 1), jnp.inf,
                          busy.dtype).at[rows, d].set(busy)[:, :cap]
            nl = jnp.full((nc, cap + 1), -jnp.inf,
                          last.dtype).at[rows, d].set(last)[:, :cap]
            return nb, nl, keep.sum(axis=1).astype(cnt.dtype)

        return compact

    def _build_place(self):
        jnp, lax = self.jnp, self.lax
        is_minlat = self.is_minlat
        state_fn = self._state_fn
        choose_fn = self._choose_fn
        finalize = self._finalize_fn

        def step(guess, P):
            st = state_fn(guess, P)
            if is_minlat:
                allowed = P["c_max"] + P["alpha"] * st["s_before"]
            else:
                allowed = jnp.full(guess.shape[0], jnp.inf)
            code, feas = choose_fn(st["LAT"], st["COST"], allowed,
                                   P["deadline"], P["valid"])
            return st, code, feas, allowed

        def place(P, S):
            # S carries the sequential-state seed (CIL pools, edge horizons,
            # surplus) split out so the jit can DONATE its buffers — resident
            # streams thread chunk k's final arrays in as chunk k+1's seed
            # with zero steady-state allocation. Callers must treat S as
            # consumed (place_chunk keeps a tiny device-side backup for the
            # overflow retry).
            P = {**P, **S}
            R = P["nows"].shape[0]
            g0 = jnp.full(R, -1, dtype=jnp.int32)
            g1 = step(g0, P)[1]

            def cond(c):
                gp, g, i = c
                return jnp.any(gp != g) & (i < R + 2)

            def body(c):
                _, g, i = c
                return g, step(g, P)[1], i + 1

            _, gF, iters = lax.while_loop(cond, body, (g0, g1, jnp.int32(1)))
            st, code, feas, allowed = step(gF, P)  # fixed point: code == gF
            res = finalize(st, code, feas, allowed, P)
            res["iters"] = iters
            res["converged"] = ~jnp.any(code != gF)
            return res

        return place

    def _run_interpret(self, P, R: int) -> dict:
        """Host-driven fixed point over the jitted FMA-free pieces: bit-exact
        (the α·s_before multiply runs op-by-op) at compiled-scan speed."""
        jax, jnp = self.jax, self.jnp
        g = jnp.asarray(np.full(R, -1, np.int32))
        g_np = np.asarray(g)
        st = code = feas = allowed = None
        iters = 0
        converged = False
        for _ in range(R + 2):
            st = self._state(g, P)
            if self.is_minlat:
                with jax.disable_jit():
                    allowed = P["c_max"] + P["alpha"] * st["s_before"]
            else:
                allowed = jnp.full(R, jnp.inf)
            code, feas = self._choose(st["LAT"], st["COST"], allowed,
                                      P["deadline"], P["valid"])
            iters += 1
            c_np = np.asarray(code)
            if np.array_equal(c_np, g_np):
                converged = True
                break
            g, g_np = code, c_np
        res = dict(self._finalize(st, code, feas, allowed, P))
        # the converging (verification) pass isn't an iteration, matching the
        # compiled driver's count
        res["iters"] = max(iters - 1, 1)
        res["converged"] = converged
        return res

    # ------------------------------------------------------------ residency
    def stage_chunk(self, tasks) -> dict:
        """Host prep + device upload for one chunk — engine-state-free, so
        ``runtime._prefetched_chunks`` can run it on the transfer thread
        while the previous chunk's fixed point occupies the device (the x64
        scope is thread-local and re-entered here). The bundle reaches
        ``place_chunk`` via ``engine._jax_staged``."""
        jax = self.jax
        n = len(tasks)
        host = task_arrays(tasks)
        _, nows_np, sizes_np, nbytes_np = host
        R = max(PAD_MIN, _next_pow2(n))
        pad = R - n
        with self._scope():
            dev = (jax.device_put(np.pad(sizes_np, (0, pad), mode="edge")),
                   jax.device_put(np.pad(nbytes_np, (0, pad), mode="edge")),
                   jax.device_put(np.pad(nows_np, (0, pad), mode="edge")),
                   jax.device_put(np.arange(R) < n))
        return {"host": host, "dev": dev, "n": n}

    def sync_host(self, reason: str = "external") -> bool:
        """Materialize resident device state into the host CIL / queues /
        policy and drop residency. Idempotent — ``False`` when nothing is
        resident. These calls (stream end, fallback exits, ``sync_engine``)
        are the ONLY host↔device state sync points of a resident stream."""
        rs = self._resident
        if rs is None:
            return False
        self._resident = None
        if self.is_minlat and rs.s is not None:
            rs.policy.surplus = float(rs.s)
        if self.has_edge and rs.h is not None:
            h = np.asarray(rs.h)
            for d, e in enumerate(self.edges):
                rs.queues[e.name].horizon_ms = float(h[d])
        if self.n_cloud and rs.busy is not None:
            self._commit_pools(rs.cil, np.asarray(rs.busy),
                               np.asarray(rs.last), np.asarray(rs.cnt),
                               rs.t_last)
        self.state_syncs += 1
        if reason == "fallback":
            self.fallback_syncs += 1
        return True

    def _commit_pools(self, cil, busyF, lastF, cntF, t_last):
        """The numpy accept step's pool writeback, with the reap at
        ``t_last`` == the per-arrival walk's end state (monotone keep
        predicate + dead records never warm-reused, see module docstring)."""
        for ci, c in enumerate(self.cloud):
            k = int(cntF[ci])
            b, l = busyF[ci, :k], lastF[ci, :k]
            keep = (t_last < b) | (t_last <= l + self.t_idl)
            recs = [ContainerRecord(c.name, float(bb), float(ll))
                    for bb, ll, kp in zip(b, l, keep) if kp]
            if recs:
                cil.containers[c.name] = recs
            else:
                cil.containers.pop(c.name, None)

    def _seed_state(self, rs, pools, cap, edge_queues, dev_names, policy):
        """The (donated) sequential-state seed ``S`` — from resident device
        arrays when a valid ``DeviceStreamState`` is held (growing pool
        width device-side when ``cap`` outgrew it), else from host state."""
        jnp = self.jnp
        S: dict = {}
        if rs is not None and self.n_cloud:
            busy, last = rs.busy, rs.last
            have = int(busy.shape[1])
            if cap > have:
                grow = ((0, 0), (0, cap - have))
                busy = jnp.pad(busy, grow, constant_values=np.inf)
                last = jnp.pad(last, grow, constant_values=-np.inf)
            S["busy0"], S["last0"], S["cnt0"] = busy, last, rs.cnt
        elif self.n_cloud:
            busy0 = np.full((self.n_cloud, cap), np.inf)
            last0 = np.full((self.n_cloud, cap), -np.inf)
            cnt0 = np.zeros(self.n_cloud, dtype=np.int32)
            for ci, recs in enumerate(pools):
                for j, rec in enumerate(recs):
                    busy0[ci, j] = rec.busy_until
                    last0[ci, j] = rec.last_completion
                cnt0[ci] = len(recs)
            S["busy0"] = jnp.asarray(busy0)
            S["last0"] = jnp.asarray(last0)
            S["cnt0"] = jnp.asarray(cnt0)
        else:
            S["busy0"] = jnp.zeros((0, cap))
            S["last0"] = jnp.zeros((0, cap))
            S["cnt0"] = jnp.zeros(0, dtype=jnp.int32)
        if self.has_edge:
            S["h0"] = rs.h if rs is not None else jnp.asarray(np.array(
                [edge_queues[nm].horizon_ms for nm in dev_names]))
        if self.is_minlat:
            # np scalar, not python float: a strongly-typed aval, so host-
            # and resident-seeded calls share one jit trace per pool shape
            S["s0"] = rs.s if rs is not None \
                else jnp.asarray(np.float64(policy.surplus))
        return S

    # ----------------------------------------------------------- chunk entry
    def place_chunk(self, engine, tasks, edge_queues, interpret: bool):
        """Run one chunk device-resident; returns a ``DecisionBatch`` with
        committed host state (or, under ``serve_stream`` residency, state
        left ON DEVICE), or ``None`` to fall back — in which case any
        resident state is synced first so the host walk sees canonical
        state and no balancer/RNG state is consumed."""
        from repro.core.decision import (
            DecisionBatch,
            RandomBalancer,
            RoundRobinBalancer,
        )

        jnp = self.jnp
        n = len(tasks)
        staged = engine.__dict__.pop("_jax_staged", None)
        if staged is not None and staged[0] is not tasks:
            staged = None       # stale prefetch for some other chunk
        if staged is not None:
            task_idx, nows_np, sizes_np, nbytes_np = staged[1]["host"]
        else:
            task_idx, nows_np, sizes_np, nbytes_np = task_arrays(tasks)
        if not self.has_edge and self.is_minlat and not self.cloud:
            self.sync_host("fallback")
            return None  # nothing to choose from — let the walk raise
        if n > 1 and not bool(np.all(np.diff(nows_np) >= 0.0)):
            self.sync_host("fallback")
            return None  # out-of-order arrivals: host walk replays reaps

        residency = bool(engine.__dict__.get("_device_residency", False))
        if not residency:
            # an out-of-stream place_many while state is resident: the
            # legacy per-chunk path needs canonical host state first
            self.sync_host("external")
        cil: ContainerInfoList = engine.predictor.cil
        policy = engine.policy
        rs = self._resident
        if rs is not None and (
                rs.cil is not cil or rs.policy is not policy
                or rs.queues is not edge_queues
                or (n and float(nows_np[0]) < rs.t_last)):
            # host-structure swap or a cross-chunk out-of-order arrival:
            # the resident state no longer shadows this stream — sync, then
            # re-enter residency from host state below
            self.sync_host("fallback")
            rs = None

        # Everything below may consume balancer state — no fallback past here.
        nom_fixed = None
        draws = 0
        if self.has_edge and not self.lpw:
            if self.n_dev == 1:
                nom_fixed = np.zeros(n, dtype=np.int64)
            else:
                bal = engine.balancer
                if type(bal) is RoundRobinBalancer:
                    nom_fixed = (bal._i + np.arange(n, dtype=np.int64)) \
                        % self.n_dev
                    bal._i += n
                elif type(bal) is RandomBalancer:
                    nom_fixed = bal.rng.integers(
                        self.n_dev, size=n).astype(np.int64)
                    draws = n

        R = max(PAD_MIN, _next_pow2(n))
        pad = R - n
        cloud_names = [c.name for c in self.cloud]
        dev_names = [e.name for e in self.edges]
        pools = [cil.containers.get(nm, []) for nm in cloud_names]
        if rs is not None:
            max_existing = int(rs.cnt_max)
            cap = rs.cap
        else:
            max_existing = max((len(p) for p in pools), default=0)
            cap = _next_pow2(max(self._cap_hint, POOL_MIN_CAP))

        with self._scope():
            if staged is not None:
                sizes, nbytes, nows_d, valid_d = staged[1]["dev"]
            else:
                sizes = jnp.asarray(np.pad(sizes_np, (0, pad), mode="edge"))
                nbytes = jnp.asarray(np.pad(nbytes_np, (0, pad), mode="edge"))
                nows_d = jnp.asarray(np.pad(nows_np, (0, pad), mode="edge"))
                valid_d = jnp.asarray(np.arange(R) < n)
            if interpret:
                # op-by-op: the predict pass is where the FMA-prone
                # multiplies live (ridge, pricing); eager execution keeps
                # every op individually rounded, bit-identical to numpy
                with self.jax.disable_jit():
                    P = dict(self._predict(sizes, nbytes))
            else:
                P = dict(self._predict(sizes, nbytes))
            P["nows"] = nows_d
            P["valid"] = valid_d
            if self.has_edge:
                P["ECOST"] = jnp.zeros((R, self.n_dev))
                if nom_fixed is not None:
                    P["nom_fixed"] = jnp.asarray(np.pad(
                        nom_fixed, (0, pad)).astype(np.int32))
                else:
                    P["nom_fixed"] = jnp.zeros(R, dtype=jnp.int32)
            if self.is_minlat:
                P["c_max"] = float(policy.c_max)
                P["alpha"] = float(policy.alpha)
                P["deadline"] = 0.0
            else:
                P["c_max"] = 0.0
                P["alpha"] = 0.0
                P["deadline"] = float(policy.deadline_ms)
            res = None
            compacted = rs is None   # host seeds arrive freshly reaped
            while True:
                if cap < max_existing + 1:
                    cap = _next_pow2(max_existing + 1)
                S = self._seed_state(rs, pools, cap, edge_queues, dev_names,
                                     policy)
                if interpret:
                    res = self._run_interpret({**P, **S}, R)
                else:
                    # the jit DONATES S; a resident seed must survive an
                    # overflow retry, so keep a (tiny) device-side copy
                    backup = ({k: jnp.copy(v) for k, v in S.items()}
                              if rs is not None else None)
                    res = self._place(P, S)
                if not bool(res["overflow"]) and bool(res["converged"]):
                    break
                # pool too small for this chunk's cold starts (clamped
                # writes may also stall convergence): results are discarded
                # (no state was committed) and the chunk re-runs
                if rs is not None:
                    self.resident_regrows += 1
                    if not interpret:
                        # donated seed was consumed — restore from backup
                        rs.busy, rs.last, rs.cnt = (
                            backup["busy0"], backup["last0"], backup["cnt0"])
                        rs.cap = int(backup["busy0"].shape[1])
                        cap = rs.cap
                        if "h0" in backup:
                            rs.h = backup["h0"]
                        if "s0" in backup:
                            rs.s = backup["s0"]
                    if not compacted and self.n_cloud:
                        # reap ON DEVICE first — a long resident stream
                        # accumulates dead records (the deferred reap), so
                        # compaction usually beats growing the pool and
                        # keeps steady-state pool width bounded by the LIVE
                        # container count, all without a host sync
                        rs.busy, rs.last, rs.cnt = self._compact(
                            rs.busy, rs.last, rs.cnt, rs.t_last)
                        rs.cnt_max = int(np.asarray(rs.cnt).max())
                        max_existing = rs.cnt_max
                        compacted = True
                        continue
                # ... against a doubled pool, capped at existing+R where
                # overflow is impossible and convergence is guaranteed
                new_cap = min(cap * 2, _next_pow2(max_existing + R))
                if new_cap <= cap:
                    raise RuntimeError(
                        "jax placement did not converge with an "
                        "overflow-proof container pool")
                cap = new_cap
            self._cap_hint = cap

            out = {k: np.asarray(res[k])[:n] for k in
                   ("gcode", "lat", "cost", "cold", "comp", "wait",
                    "feas", "allowed")}
            iters = int(res["iters"])
            t_last = float(nows_np[-1])
            if residency:
                # ---- stay resident: committed state LIVES on device -------
                if rs is None:
                    rs = DeviceStreamState()
                if self.n_cloud:
                    rs.busy, rs.last, rs.cnt = \
                        res["busyF"], res["lastF"], res["cntF"]
                    rs.cnt_max = int(res["cnt_max"])
                if self.has_edge:
                    rs.h = res["h_fin"]
                if self.is_minlat:
                    rs.s = res["s_fin"]
                rs.cap = cap
                rs.t_last = t_last
                rs.chunks += 1
                rs.rng_draws += draws
                rs.cil, rs.policy, rs.queues = cil, policy, edge_queues
                self._resident = rs
                self.resident_chunks += 1
            else:
                # ---- commit host state (the numpy accept step, once) ------
                if self.is_minlat:
                    policy.surplus = float(res["s_fin"])
                if self.has_edge:
                    h_fin = np.asarray(res["h_fin"])
                    for d, nm in enumerate(dev_names):
                        edge_queues[nm].horizon_ms = float(h_fin[d])
                if self.n_cloud:
                    self._commit_pools(cil, np.asarray(res["busyF"]),
                                       np.asarray(res["lastF"]),
                                       np.asarray(res["cntF"]), t_last)
                self.chunk_commits += 1

        nom_out = None
        if self.has_edge:
            nom_out = np.asarray(res["nom"])[:n].astype(np.int64)
        engine.columnar_stats = {"chunks": 1, "repairs": max(iters - 1, 0),
                                 "walked": 0, "n": n}
        self.last_stats = {"n": n, "passes": iters + 1, "rows": R,
                           "pool_cap": cap, "interpret": interpret,
                           "resident": residency,
                           "staged": staged is not None}
        engine.jax_stats = dict(self.last_stats)
        return DecisionBatch(
            batch=None,
            names=tuple(cloud_names) + tuple(dev_names),
            n_cloud=self.n_cloud,
            task_idx=task_idx,
            target_codes=out["gcode"].astype(np.int64),
            latency_ms=out["lat"].astype(np.float64),
            cost=out["cost"].astype(np.float64),
            cold=out["cold"].astype(bool),
            comp_ms=out["comp"].astype(np.float64),
            queue_wait_ms=out["wait"].astype(np.float64),
            feasible=out["feas"].astype(bool),
            allowed_cost=out["allowed"].astype(np.float64),
            edge_device_codes=nom_out,
            batch_factory=lambda pred=engine.predictor, ts=tasks:
                pred.predict_batch(ts),
        )


# ------------------------------------------------------------------ caching
def core_for(engine) -> JaxPlacementCore | None:
    """The engine's cached core, rebuilt when model identities / policy /
    kernel mode change; ``None`` when jax or the engine shape is ineligible."""
    if not available():
        return None
    key = _engine_key(engine)
    hit = engine.__dict__.get("_jax_core_cache")
    if hit is not None and hit[0] == key:
        core = hit[1]
        if core is None or core.valid_for(engine):
            return core
    if hit is not None and hit[1] is not None:
        # the outgoing core may hold resident stream state (a hedged-policy
        # swap mid-stream changes the key): materialize before replacing,
        # or the unsynced device state would be orphaned
        hit[1].sync_host("fallback")
    try:
        core = JaxPlacementCore(engine)
    except CoreIneligible:
        core = None
    engine.__dict__["_jax_core_cache"] = (key, core)
    return core


def sync_engine(engine, reason: str = "external") -> bool:
    """Materialize any device-resident stream state this engine's core
    holds back into the host CIL / queues / policy — the hook for external
    consumers (twin executors, admission snapshots, direct state reads).
    Safe no-op (``False``) when nothing is resident."""
    hit = engine.__dict__.get("_jax_core_cache")
    if hit is not None and hit[1] is not None:
        return hit[1].sync_host(reason)
    return False
