"""Device-resident placement core: the jit-compiled JAX predict→place pass.

The columnar decision core (``repro.core.decision``) is pure numpy: one
vectorized predict pass, then speculate-and-repair over the three sequential
recurrences. This module ports that hot per-chunk pipeline to JAX so a whole
chunk runs device-resident under ``jax.jit`` — selected per engine with
``DecisionEngine(array_backend="jax")`` or per stream with
``serve_stream(..., array_backend="jax")``. The numpy path stays the
correctness oracle.

Structure of one chunk (chunk boundaries are the only host↔device syncs):

1. **Predict** — ridge upload / edge-compute models, normal-model scalars and
   Lambda pricing as jnp expressions; the GBRT compute model as a device-side
   gather over the serving step tables (``predictor.const1_serving_table``,
   padded to one ``(n_configs, B)`` matrix), or through the
   ``repro.kernels.gbrt_predict`` Pallas kernel on TPU / ``GBRT_KERNEL_MODE
   == "force"``.
2. **Place** — a chunk-level fixed-point driver replaces the host
   speculate-and-repair loop: a ``lax.while_loop`` carries the speculated
   policy-view codes (``-1`` = "no state effects yet", the frozen-state
   guess), and each iteration replays ALL THREE sequential recurrences from
   the chunk-start state under the current guess — the surplus bank and FIFO
   busy horizons as ``lax.scan`` left folds (or max-plus
   ``lax.associative_scan`` / ``repro.kernels.linear_scan`` forms in
   ``assoc`` mode, see ``recurrence.maxplus_combine``), the CIL warm/cold
   event walk as a ``lax.scan`` over fixed-capacity container pools. By the
   same induction the numpy repair loop relies on, the exact prefix grows by
   ≥ 1 row per iteration, so the fixed point (``pass(g) == g``) IS the true
   sequential trajectory and is reached in ≤ R+1 passes (2–3 in practice).
3. **Commit** — outputs are sliced to the chunk on host; CIL pools, edge
   horizons and the surplus bank are written back exactly like the numpy
   accept step (including the final ``reap`` at the last arrival).

Parity contract (mirrors the Pallas kernel tests):

- ``array_backend="jax_interpret"`` — float64 op-by-op execution
  (``jax.disable_jit``): BIT-IDENTICAL per record to the numpy path. XLA's
  compiled CPU pipeline contracts ``a + b*c`` into FMAs and reassociates
  constant chains, so the compiled path cannot promise last-ULP equality —
  interpret mode is the oracle, exactly like ``interpret=True`` Pallas.
- ``array_backend="jax"`` — jit-compiled: decision-equality (identical
  ``target_codes``) with tolerance-level float agreement.

Fallback rules (all BEFORE any balancer/RNG state is consumed, so a fallback
chunk is indistinguishable from a numpy chunk): hedged/custom policies,
non-columnar balancers, quantile prediction, ``record_decisions``, custom
target/model/pricing types, and out-of-order arrivals all take the existing
numpy path. Chunks are padded to power-of-two rows (pad rows carry code
``-1`` and no effects) so streaming tails never retrace the jit cache.
"""

from __future__ import annotations

import contextlib
import weakref
from dataclasses import dataclass

import numpy as np

from repro.core.cil import ContainerInfoList, ContainerRecord
from repro.core.perf_models import NormalModel, RidgeModel, ScaledModel
from repro.core.predictor import (
    EdgeTarget,
    LambdaTarget,
    Predictor,
    const1_serving_table,
)
from repro.core.pricing import EdgePricing, LambdaPricing
from repro.core.workload import task_arrays

# "seq"   — sequential lax.scan left folds (bit-exact association vs numpy);
# "assoc" — max-plus associative_scan / cumsum forms (reassociated float sums:
#           decision-equality contract only);
# "auto"  — seq on CPU (where bit-parity matters), assoc elsewhere.
SCAN_MODE = "auto"
# Route the assoc-mode surplus prefix through the repro.kernels.linear_scan
# Pallas kernel (f32 — decision-equality contract; exercised by tests/bench).
SURPLUS_LINEAR_SCAN = False

POOL_MIN_CAP = 8        # starting CIL container-pool capacity (doubles on demand)
PAD_MIN = 8             # minimum padded chunk rows
MAX_BACKENDS = ("numpy", "jax", "jax_interpret")


class CoreIneligible(Exception):
    """This engine's policy/targets/models are outside the jax core's replica."""


_JAX = None  # cached import probe: () = unavailable, (jax, jnp, lax) = ready


def _modules():
    global _JAX
    if _JAX is None:
        try:
            import jax
            import jax.numpy as jnp
            from jax import lax

            _JAX = (jax, jnp, lax)
        except Exception:  # pragma: no cover - jax is baked into the image
            _JAX = ()
    return _JAX if _JAX else None


def available() -> bool:
    return _modules() is not None


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


# --------------------------------------------------------------------- spec
@dataclass
class _CloudSpec:
    name: str
    memory_mb: float
    up_theta: tuple[float, float]
    start_warm: float          # max(mean, 0) — precomputed like the batch path
    start_cold: float
    store: float
    quantum: float
    gb: float
    rate: float
    breaks: np.ndarray
    vals: np.ndarray


@dataclass
class _EdgeSpec:
    name: str
    theta: tuple[float, float]
    scale: float
    iot: float
    store: float


def _ridge2(model) -> tuple[float, float]:
    if type(model) is not RidgeModel or model.theta.shape != (2,):
        raise CoreIneligible("non-affine upload/edge model")
    return float(model.theta[0]), float(model.theta[1])


def _normal_mean(model) -> float:
    if type(model) is not NormalModel:
        raise CoreIneligible("non-normal component model")
    return max(model.predict(), 0.0)


def _extract_cloud(tgt) -> _CloudSpec:
    if type(tgt) is not LambdaTarget:
        raise CoreIneligible(f"cloud target {tgt!r} is not a LambdaTarget")
    if type(tgt.pricing) is not LambdaPricing \
            or tgt.pricing.include_request_charge:
        raise CoreIneligible("non-Lambda or request-charge pricing")
    model = tgt.comp_model
    if not (hasattr(model, "const1_table") and hasattr(model, "thresholds")):
        raise CoreIneligible("cloud comp model is not a GBRT")
    breaks, vals = const1_serving_table(model, float(tgt.memory_mb))
    return _CloudSpec(
        name=tgt.name, memory_mb=float(tgt.memory_mb),
        up_theta=_ridge2(tgt.upld_model),
        start_warm=_normal_mean(tgt.start_warm),
        start_cold=_normal_mean(tgt.start_cold),
        store=_normal_mean(tgt.store_model),
        quantum=float(tgt.pricing.quantum_ms),
        gb=tgt.memory_mb / 1024.0,
        rate=float(tgt.pricing.gb_second_rate),
        breaks=np.asarray(breaks, np.float64),
        vals=np.asarray(vals, np.float64))


def _extract_edge(dev) -> _EdgeSpec:
    if type(dev) is not EdgeTarget:
        raise CoreIneligible(f"edge device {dev!r} is not an EdgeTarget")
    if type(dev.pricing) is not EdgePricing:
        raise CoreIneligible("edge pricing is not EdgePricing")
    model = dev.comp_model
    scale = 1.0
    if type(model) is ScaledModel:
        scale = float(model.scale)
        model = model.base
    t0, t1 = _ridge2(model)
    return _EdgeSpec(name=dev.name, theta=(t0, t1), scale=scale,
                     iot=_normal_mean(dev.iotup_model),
                     store=_normal_mean(dev.store_model))


def _engine_key(engine) -> tuple:
    """Cheap identity key for the per-engine core cache. Model swaps (online
    refit) change ids; ``valid_for`` weakref-guards against id recycling."""
    from repro.core import predictor as predictor_mod

    pred = engine.predictor
    ids = [id(pred), id(engine.policy), type(engine.policy),
           type(engine.balancer), pred.quantile,
           predictor_mod.GBRT_KERNEL_MODE, SCAN_MODE, SURPLUS_LINEAR_SCAN]
    for tgt in pred.cloud_targets:
        ids.append((id(tgt), id(tgt.comp_model), id(tgt.upld_model),
                    id(tgt.start_warm), id(tgt.start_cold),
                    id(tgt.store_model)))
    for dev in (pred.edge_fleet or ()):
        ids.append((id(dev), id(dev.comp_model), id(dev.iotup_model),
                    id(dev.store_model)))
    return tuple(ids)


# --------------------------------------------------------------------- core
class JaxPlacementCore:
    """One engine's compiled predict→place pipeline.

    Built lazily per engine (``core_for``), revalidated per chunk against the
    captured model identities — a refit-by-swap misses the cache and triggers
    a rebuild, exactly like the serving step-table cache.
    """

    def __init__(self, engine):
        mods = _modules()
        if mods is None:
            raise CoreIneligible("jax unavailable")
        self.jax, self.jnp, self.lax = mods
        if not engine._columnar_eligible():
            raise CoreIneligible("engine is not columnar-eligible")
        pred: Predictor = engine.predictor
        if pred.quantile is not None:
            raise CoreIneligible("quantile prediction is host-side only")
        self.cloud = [_extract_cloud(t) for t in pred.cloud_targets]
        self._kernel_models = [t.comp_model for t in pred.cloud_targets]
        self.edges = [_extract_edge(d) for d in (pred.edge_fleet or ())]
        self.n_cloud = len(self.cloud)
        self.n_dev = len(self.edges)
        self.has_edge = self.n_dev > 0
        self.T = self.n_cloud + (1 if self.has_edge else 0)
        self.edge_col = self.T - 1 if self.has_edge else -1
        self.t_idl = float(pred.cil.t_idl_ms)

        from repro.core import predictor as predictor_mod
        from repro.core.decision import (
            LeastPredictedWaitBalancer,
            MinLatencyPolicy,
        )

        self.is_minlat = type(engine.policy) is MinLatencyPolicy
        self.lpw = (self.n_dev > 1
                    and type(engine.balancer) is LeastPredictedWaitBalancer)
        mode = predictor_mod.GBRT_KERNEL_MODE
        tpu = self.jax.default_backend() == "tpu"
        self.use_gbrt_kernel = mode == "force" or (tpu and mode == "auto")
        self.dtype = self.jnp.float32 if tpu else self.jnp.float64
        self._x64 = not tpu
        self.seq = SCAN_MODE == "seq" or (SCAN_MODE == "auto"
                                          and self.jax.default_backend() == "cpu")
        self.key = _engine_key(engine)
        self._refs = [weakref.ref(o) for o in (
            [pred, engine.policy]
            + [t for t in pred.cloud_targets]
            + [t.comp_model for t in pred.cloud_targets]
            + [d for d in (pred.edge_fleet or ())])]
        self._cap_hint = POOL_MIN_CAP
        with self._scope():
            self._tables = self._device_tables()
            self._state_fn = self._build_state()
            self._choose_fn = self._build_choose()
            self._finalize_fn = self._build_finalize()
            self._predict = self.jax.jit(self._build_predict())
            self._place = self.jax.jit(self._build_place())
            # interpret-mode hosts the fixed point itself on these pieces
            self._state = self.jax.jit(self._state_fn)
            self._choose = self.jax.jit(self._choose_fn)
            self._finalize = self.jax.jit(self._finalize_fn)
        self.last_stats: dict | None = None

    # ------------------------------------------------------------ lifecycle
    def _scope(self):
        if not self._x64:
            return contextlib.nullcontext()
        from jax.experimental import enable_x64

        return enable_x64()

    def valid_for(self, engine) -> bool:
        return (self.key == _engine_key(engine)
                and all(r() is not None for r in self._refs))

    def compile_stats(self) -> dict:
        """jit-cache sizes — the bench's no-retrace probe."""
        return {"predict": self._predict._cache_size(),
                "place": self._place._cache_size(),
                "state": self._state._cache_size(),
                "choose": self._choose._cache_size()}

    # ------------------------------------------------------- device operands
    def _device_tables(self) -> dict:
        jnp = self.jnp
        t: dict = {}
        if self.n_cloud:
            bmax = max(1, max(c.breaks.shape[0] for c in self.cloud))
            BR = np.full((self.n_cloud, bmax), np.inf)
            VL = np.zeros((self.n_cloud, bmax + 1))
            for i, c in enumerate(self.cloud):
                nb = c.breaks.shape[0]
                BR[i, :nb] = c.breaks
                VL[i, :nb + 1] = c.vals
                VL[i, nb + 1:] = c.vals[-1]
            t["BR"] = jnp.asarray(BR)
            t["VL"] = jnp.asarray(VL)
            t["UP0"] = jnp.asarray(np.array([c.up_theta[0] for c in self.cloud]))
            t["UP1"] = jnp.asarray(np.array([c.up_theta[1] for c in self.cloud]))
            t["SW"] = jnp.asarray(np.array([c.start_warm for c in self.cloud]))
            t["SC"] = jnp.asarray(np.array([c.start_cold for c in self.cloud]))
            t["ST"] = jnp.asarray(np.array([c.store for c in self.cloud]))
            t["QNT"] = jnp.asarray(np.array([c.quantum for c in self.cloud]))
            t["GB"] = jnp.asarray(np.array([c.gb for c in self.cloud]))
            t["RATE"] = jnp.asarray(np.array([c.rate for c in self.cloud]))
        if self.has_edge:
            t["ET0"] = jnp.asarray(np.array([e.theta[0] for e in self.edges]))
            t["ET1"] = jnp.asarray(np.array([e.theta[1] for e in self.edges]))
            t["ESC"] = jnp.asarray(np.array([e.scale for e in self.edges]))
            t["EIO"] = jnp.asarray(np.array([e.iot for e in self.edges]))
            t["EST"] = jnp.asarray(np.array([e.store for e in self.edges]))
        return t

    def _gbrt_kernel_operands(self):
        """Per-config Pallas-kernel operands (host-prepared, f32 like the
        ``gbrt_predict`` wrapper)."""
        from repro.kernels.gbrt_predict.ops import kernel_operands

        ops = []
        for c, tgt in zip(self.cloud, self._kernel_models):
            feats, thr, lvs = kernel_operands(tgt)
            ops.append((feats, thr, lvs, int(tgt.config.max_depth),
                        float(tgt.config.learning_rate), float(tgt.base),
                        c.memory_mb))
        return ops

    # ----------------------------------------------------------- predict jit
    def _build_predict(self):
        jax, jnp = self.jax, self.jnp
        t = self._tables
        nc, nd = self.n_cloud, self.n_dev
        use_kernel = self.use_gbrt_kernel
        kernel_ops = None
        if use_kernel and nc:
            from repro.kernels.gbrt_predict.kernel import gbrt_predict_blocked

            interpret = jax.default_backend() != "tpu"
            kernel_ops = self._gbrt_kernel_operands()

        def predict(sizes, nbytes):
            out = {}
            if nc:
                if use_kernel:
                    cols = []
                    for feats, thr, lvs, depth, lr, base, mem in kernel_ops:
                        x32 = jnp.stack(
                            [sizes, jnp.full(sizes.shape[0], mem)],
                            axis=1).astype(jnp.float32)
                        bn = min(256, x32.shape[0])
                        cols.append(gbrt_predict_blocked(
                            x32, feats, thr, lvs, depth=depth, lr=lr,
                            base=base, block_n=bn,
                            interpret=interpret).astype(sizes.dtype))
                    comp = jnp.stack(cols, axis=1)
                else:
                    comp = jax.vmap(
                        lambda b, v: v[jnp.searchsorted(b, sizes, side="left")]
                    )(t["BR"], t["VL"]).T
                compc = jnp.maximum(comp, 0.0)
                upld = jnp.maximum(
                    t["UP0"][None, :] + nbytes[:, None] * t["UP1"][None, :],
                    0.0)
                # associate exactly like sum(warm.values()) / occupancy_ms:
                # ((upld + start) + comp) (+ store)
                occ_w = (upld + t["SW"][None, :]) + compc
                occ_c = (upld + t["SC"][None, :]) + compc
                out["LATW"] = occ_w + t["ST"][None, :]
                out["LATC"] = occ_c + t["ST"][None, :]
                out["OCCW"] = occ_w
                out["OCCC"] = occ_c
                out["COMPC"] = compc
                billed = jnp.ceil(
                    jnp.maximum(jnp.round(compc), 1.0) / t["QNT"][None, :]
                ) * t["QNT"][None, :]
                out["COSTC"] = ((billed / 1000.0) * t["GB"][None, :]) \
                    * t["RATE"][None, :]
            if nd:
                ec = jnp.maximum(
                    (t["ET0"][None, :] + sizes[:, None] * t["ET1"][None, :])
                    * t["ESC"][None, :], 0.0)
                out["ECOMP"] = ec
                out["ELAT"] = (ec + t["EIO"][None, :]) + t["EST"][None, :]
            return out

        return predict

    # ----------------------------------------------------------- place parts
    # The per-chunk pass is split in three so interpret mode can keep the one
    # FMA-prone operation out of XLA: ``state`` (the three recurrences, the
    # CIL event walk and the policy-view matrices — additions, compares and
    # gathers only, which compiled XLA executes bit-exactly in sequential
    # order) → ``allowed = c_max + α·s_before`` (the ONLY multiply on the
    # place side; XLA CPU contracts mul+add chains into FMAs regardless of
    # optimization barriers, so interpret mode computes it op-by-op under
    # ``jax.disable_jit``) → ``choose`` (masked lexicographic argmins: exact
    # compares and min-reductions). Compiled mode composes all three inside
    # one jitted ``lax.while_loop`` fixed-point driver under the
    # decision-equality contract; interpret mode hosts the same fixed point
    # in Python over the jitted pieces and stays bit-exact.
    def _build_state(self):
        jax, jnp, lax = self.jax, self.jnp, self.lax
        nc, nd, T = self.n_cloud, self.n_dev, self.T
        edge_col, has_edge = self.edge_col, self.has_edge
        is_minlat, lpw, seq = self.is_minlat, self.lpw, self.seq
        t_idl = self.t_idl
        surplus_kernel = SURPLUS_LINEAR_SCAN and not seq
        from repro.core.recurrence import maxplus_combine

        def state_fn(guess, P):
            """One full state replay of the chunk under speculated codes
            ``guess`` (policy-view; -1 = no state effects yet — the
            frozen-state guess)."""
            nows, valid = P["nows"], P["valid"]
            R = nows.shape[0]
            rr = jnp.arange(R)
            is_edge_g = (guess == edge_col) if has_edge \
                else jnp.zeros(R, dtype=bool)
            is_cloud_g = (guess >= 0) & ~is_edge_g

            # --- edge busy horizons / nominations / induced waits ----------
            nom = ew = HB = h_fin = None
            if has_edge:
                ECOMP = P["ECOMP"]
                if lpw:
                    # winner feeds back into the next argmin: sequential only
                    def estep(h, xs):
                        now, ec, ie = xs
                        w = jnp.maximum(h - now, 0.0)
                        d = jnp.argmin(w)           # first-min == fleet order
                        upd = jnp.maximum(h[d], now) + ec[d]
                        h2 = h.at[d].set(jnp.where(ie, upd, h[d]))
                        return h2, (h, d)

                    h_fin, (HB, nom) = lax.scan(
                        estep, P["h0"], (nows, ECOMP, is_edge_g))
                else:
                    nom = P["nom_fixed"]
                    pushm = is_edge_g[:, None] \
                        & (nom[:, None] == jnp.arange(nd)[None, :])
                    if seq:
                        def estep(h, xs):
                            now, ec, pm = xs
                            return jnp.where(
                                pm, jnp.maximum(h, now) + ec, h), h

                        h_fin, HB = lax.scan(
                            estep, P["h0"], (nows, ECOMP, pushm))
                    else:
                        # exclusive max-plus scan: h_i = max(h0 + A_i, B_i)
                        a = jnp.where(pushm, ECOMP, 0.0)
                        b = jnp.where(pushm, nows[:, None] + ECOMP, -jnp.inf)
                        A, B = lax.associative_scan(
                            lambda x, y: maxplus_combine(x, y, jnp.maximum),
                            (a, b), axis=0)
                        z = jnp.zeros((1, nd), a.dtype)
                        ninf = jnp.full((1, nd), -jnp.inf, b.dtype)
                        Ax = jnp.concatenate([z, A[:-1]], axis=0)
                        Bx = jnp.concatenate([ninf, B[:-1]], axis=0)
                        HB = jnp.maximum(P["h0"][None, :] + Ax, Bx)
                        h_fin = jnp.maximum(P["h0"] + A[-1], B[-1])
                waits = jnp.maximum(HB - nows[:, None], 0.0)
                if nom is None:
                    nom = P["nom_fixed"]
                ew = waits[rr, nom]

            # --- CIL pools: one scan, per-config cold flags + dispatches ---
            overflow = jnp.asarray(False)
            if nc:
                cap = P["busy0"].shape[1]
                cidx = jnp.clip(guess, 0, nc - 1)

                def cstep(carry, xs):
                    busy, last, cnt = carry
                    now, ci, isc, occw, occc = xs
                    idle = (busy <= now) & (now <= last + t_idl)
                    cold_row = ~idle.any(axis=1)        # per-config, pre-row
                    idle_c = idle[ci]
                    # MRU reuse: first-max == the walk's strict > update
                    j_warm = jnp.argmax(
                        jnp.where(idle_c, last[ci], -jnp.inf))
                    is_cold = ~idle_c.any()
                    j = jnp.where(is_cold, cnt[ci], j_warm)
                    ovf = isc & is_cold & (j >= cap)
                    jc = jnp.minimum(j, cap - 1)
                    occ = jnp.where(is_cold, occc[ci], occw[ci])
                    completion = now + occ
                    do = isc & ~ovf
                    busy = busy.at[ci, jc].set(
                        jnp.where(do, completion, busy[ci, jc]))
                    last = last.at[ci, jc].set(
                        jnp.where(do, completion, last[ci, jc]))
                    cnt = cnt.at[ci].add(
                        jnp.where(do & is_cold, 1, 0))
                    return (busy, last, cnt), (cold_row, ovf)

                (busyF, lastF, cntF), (COLD, OVF) = lax.scan(
                    cstep, (P["busy0"], P["last0"], P["cnt0"]),
                    (nows, cidx, is_cloud_g, P["OCCW"], P["OCCC"]))
                overflow = OVF.any()
            else:
                busyF = lastF = cntF = None
                COLD = jnp.zeros((R, 0), dtype=bool)

            # --- (R, T) policy-view matrices -------------------------------
            cols_lat, cols_cost, cols_comp = [], [], []
            if nc:
                cols_lat.append(jnp.where(COLD, P["LATC"], P["LATW"]))
                cols_cost.append(P["COSTC"])
                cols_comp.append(P["COMPC"])
            if has_edge:
                cols_lat.append((ew + P["ELAT"][rr, nom])[:, None])
                cols_cost.append(P["ECOST"][rr, nom][:, None])
                cols_comp.append(P["ECOMP"][rr, nom][:, None])
            LAT = jnp.concatenate(cols_lat, axis=1)
            COST = jnp.concatenate(cols_cost, axis=1)
            COMP = jnp.concatenate(cols_comp, axis=1)

            # --- surplus bank (the third recurrence; MinLatency only) ------
            s_before = s_fin = None
            if is_minlat:
                safe_g = jnp.clip(guess, 0, T - 1)
                delta = jnp.where(guess >= 0,
                                  P["c_max"] - COST[rr, safe_g], 0.0)
                if seq:
                    def sstep(s, d):
                        return s + d, s

                    s_fin, s_before = lax.scan(sstep, P["s0"], delta)
                elif surplus_kernel:
                    from repro.kernels.linear_scan.ops import prefix_sum

                    incl = prefix_sum(delta).astype(delta.dtype)
                    s_before = P["s0"] + jnp.concatenate(
                        [jnp.zeros(1, delta.dtype), incl[:-1]])
                    s_fin = P["s0"] + incl[-1]
                else:
                    incl = jnp.cumsum(delta)
                    s_before = P["s0"] + jnp.concatenate(
                        [jnp.zeros(1, delta.dtype), incl[:-1]])
                    s_fin = P["s0"] + incl[-1]
            return {"nom": nom, "ew": ew, "LAT": LAT, "COST": COST,
                    "COMP": COMP, "COLD": COLD, "s_before": s_before,
                    "s_fin": s_fin, "h_fin": h_fin, "busyF": busyF,
                    "lastF": lastF, "cntF": cntF, "overflow": overflow}

        return state_fn

    def _build_choose(self):
        jnp = self.jnp
        T, edge_col, has_edge = self.T, self.edge_col, self.has_edge
        is_minlat = self.is_minlat

        def choose_fn(LAT, COST, allowed, deadline, valid):
            R = LAT.shape[0]
            if is_minlat:
                feas = COST <= allowed[:, None]
                none_f = ~feas.any(axis=1)
                if has_edge:
                    onehot = (jnp.arange(T) == edge_col)[None, :]
                    feas = jnp.where(none_f[:, None], onehot, feas)
                else:
                    feas = feas | none_f[:, None]
                l1 = jnp.where(feas, LAT, jnp.inf)
                lmin = l1.min(axis=1)
                tie = feas & (LAT == lmin[:, None])
                c2 = jnp.where(tie, COST, jnp.inf)
                cmin = c2.min(axis=1)
                final = tie & (COST == cmin[:, None])
                code = final.argmax(axis=1).astype(jnp.int32)
                feas_out = jnp.ones(R, dtype=bool)
            else:  # MinCostPolicy (edge column guaranteed by eligibility)
                feas = LAT <= deadline
                any_f = feas.any(axis=1)
                c1 = jnp.where(feas, COST, jnp.inf)
                cmin = c1.min(axis=1)
                tie = feas & (COST == cmin[:, None])
                l2 = jnp.where(tie, LAT, jnp.inf)
                lmin = l2.min(axis=1)
                final = tie & (LAT == lmin[:, None])
                code = final.argmax(axis=1).astype(jnp.int32)
                code = jnp.where(any_f, code, edge_col)
                feas_out = any_f
            return jnp.where(valid, code, -1), feas_out

        return choose_fn

    def _build_finalize(self):
        jnp = self.jnp
        nc, T = self.n_cloud, self.T
        edge_col, has_edge = self.edge_col, self.has_edge
        is_minlat = self.is_minlat

        def finalize(st, code, feas, allowed, P):
            """Chosen-row gathers + committed-state bundle for one chunk."""
            R = code.shape[0]
            rr = jnp.arange(R)
            safe = jnp.clip(code, 0, T - 1)
            res = {"code": code, "overflow": st["overflow"],
                   "lat": st["LAT"][rr, safe], "cost": st["COST"][rr, safe],
                   "comp": st["COMP"][rr, safe], "allowed": allowed,
                   "feas": feas}
            if is_minlat:
                res["s_fin"] = st["s_fin"]
            cold = (st["COLD"][rr, jnp.clip(code, 0, nc - 1)] if nc
                    else jnp.zeros(R, dtype=bool))
            if has_edge:
                is_edge_ch = code == edge_col
                res["cold"] = jnp.where(is_edge_ch, False, cold)
                res["wait"] = jnp.where(is_edge_ch, st["ew"], 0.0)
                res["nom"] = st["nom"]
                res["gcode"] = jnp.where(is_edge_ch, nc + st["nom"], code)
                res["h_fin"] = st["h_fin"]
            else:
                res["cold"] = cold
                res["wait"] = jnp.zeros(R)
                res["gcode"] = code
            if nc:
                res["busyF"], res["lastF"], res["cntF"] = \
                    st["busyF"], st["lastF"], st["cntF"]
            return res

        return finalize

    def _build_place(self):
        jnp, lax = self.jnp, self.lax
        is_minlat = self.is_minlat
        state_fn = self._state_fn
        choose_fn = self._choose_fn
        finalize = self._finalize_fn

        def step(guess, P):
            st = state_fn(guess, P)
            if is_minlat:
                allowed = P["c_max"] + P["alpha"] * st["s_before"]
            else:
                allowed = jnp.full(guess.shape[0], jnp.inf)
            code, feas = choose_fn(st["LAT"], st["COST"], allowed,
                                   P["deadline"], P["valid"])
            return st, code, feas, allowed

        def place(P):
            R = P["nows"].shape[0]
            g0 = jnp.full(R, -1, dtype=jnp.int32)
            g1 = step(g0, P)[1]

            def cond(c):
                gp, g, i = c
                return jnp.any(gp != g) & (i < R + 2)

            def body(c):
                _, g, i = c
                return g, step(g, P)[1], i + 1

            _, gF, iters = lax.while_loop(cond, body, (g0, g1, jnp.int32(1)))
            st, code, feas, allowed = step(gF, P)  # fixed point: code == gF
            res = finalize(st, code, feas, allowed, P)
            res["iters"] = iters
            res["converged"] = ~jnp.any(code != gF)
            return res

        return place

    def _run_interpret(self, P, R: int) -> dict:
        """Host-driven fixed point over the jitted FMA-free pieces: bit-exact
        (the α·s_before multiply runs op-by-op) at compiled-scan speed."""
        jax, jnp = self.jax, self.jnp
        g = jnp.asarray(np.full(R, -1, np.int32))
        g_np = np.asarray(g)
        st = code = feas = allowed = None
        iters = 0
        converged = False
        for _ in range(R + 2):
            st = self._state(g, P)
            if self.is_minlat:
                with jax.disable_jit():
                    allowed = P["c_max"] + P["alpha"] * st["s_before"]
            else:
                allowed = jnp.full(R, jnp.inf)
            code, feas = self._choose(st["LAT"], st["COST"], allowed,
                                      P["deadline"], P["valid"])
            iters += 1
            c_np = np.asarray(code)
            if np.array_equal(c_np, g_np):
                converged = True
                break
            g, g_np = code, c_np
        res = dict(self._finalize(st, code, feas, allowed, P))
        # the converging (verification) pass isn't an iteration, matching the
        # compiled driver's count
        res["iters"] = max(iters - 1, 1)
        res["converged"] = converged
        return res

    # ----------------------------------------------------------- chunk entry
    def place_chunk(self, engine, tasks, edge_queues, interpret: bool):
        """Run one chunk device-resident; returns a ``DecisionBatch`` with
        committed host state, or ``None`` to fall back (no state consumed)."""
        from repro.core.decision import (
            DecisionBatch,
            RandomBalancer,
            RoundRobinBalancer,
        )

        jnp = self.jnp
        n = len(tasks)
        task_idx, nows_np, sizes_np, nbytes_np = task_arrays(tasks)
        if not self.has_edge and self.is_minlat and not self.cloud:
            return None  # nothing to choose from — let the walk raise
        if n > 1 and not bool(np.all(np.diff(nows_np) >= 0.0)):
            return None  # out-of-order arrivals: host walk replays reaps

        # Everything below may consume balancer state — no fallback past here.
        nom_fixed = None
        if self.has_edge and not self.lpw:
            if self.n_dev == 1:
                nom_fixed = np.zeros(n, dtype=np.int64)
            else:
                bal = engine.balancer
                if type(bal) is RoundRobinBalancer:
                    nom_fixed = (bal._i + np.arange(n, dtype=np.int64)) \
                        % self.n_dev
                    bal._i += n
                elif type(bal) is RandomBalancer:
                    nom_fixed = bal.rng.integers(
                        self.n_dev, size=n).astype(np.int64)

        R = max(PAD_MIN, _next_pow2(n))
        pad = R - n
        cil: ContainerInfoList = engine.predictor.cil
        cloud_names = [c.name for c in self.cloud]
        dev_names = [e.name for e in self.edges]
        pools = [cil.containers.get(nm, []) for nm in cloud_names]
        max_existing = max((len(p) for p in pools), default=0)
        cap = _next_pow2(max(self._cap_hint, POOL_MIN_CAP))

        with self._scope():
            sizes = jnp.asarray(np.pad(sizes_np, (0, pad), mode="edge"))
            nbytes = jnp.asarray(np.pad(nbytes_np, (0, pad), mode="edge"))
            if interpret:
                # op-by-op: the predict pass is where the FMA-prone
                # multiplies live (ridge, pricing); eager execution keeps
                # every op individually rounded, bit-identical to numpy
                with self.jax.disable_jit():
                    P = dict(self._predict(sizes, nbytes))
            else:
                P = dict(self._predict(sizes, nbytes))
            P["nows"] = jnp.asarray(np.pad(nows_np, (0, pad), mode="edge"))
            P["valid"] = jnp.asarray(np.arange(R) < n)
            if self.has_edge:
                P["h0"] = jnp.asarray(np.array(
                    [edge_queues[nm].horizon_ms for nm in dev_names]))
                P["ECOST"] = jnp.zeros((R, self.n_dev))
                if nom_fixed is not None:
                    P["nom_fixed"] = jnp.asarray(np.pad(
                        nom_fixed, (0, pad)).astype(np.int32))
                else:
                    P["nom_fixed"] = jnp.zeros(R, dtype=jnp.int32)
            policy = engine.policy
            if self.is_minlat:
                P["s0"] = float(policy.surplus)
                P["c_max"] = float(policy.c_max)
                P["alpha"] = float(policy.alpha)
                P["deadline"] = 0.0
            else:
                P["s0"] = 0.0
                P["c_max"] = 0.0
                P["alpha"] = 0.0
                P["deadline"] = float(policy.deadline_ms)
            res = None
            while True:
                if cap < max_existing + 1:
                    cap = _next_pow2(max_existing + 1)
                if self.n_cloud:
                    busy0 = np.full((self.n_cloud, cap), np.inf)
                    last0 = np.full((self.n_cloud, cap), -np.inf)
                    cnt0 = np.zeros(self.n_cloud, dtype=np.int32)
                    for ci, recs in enumerate(pools):
                        for j, rec in enumerate(recs):
                            busy0[ci, j] = rec.busy_until
                            last0[ci, j] = rec.last_completion
                        cnt0[ci] = len(recs)
                    P["busy0"] = jnp.asarray(busy0)
                    P["last0"] = jnp.asarray(last0)
                    P["cnt0"] = jnp.asarray(cnt0)
                else:
                    P["busy0"] = jnp.zeros((0, cap))
                    P["last0"] = jnp.zeros((0, cap))
                    P["cnt0"] = jnp.zeros(0, dtype=jnp.int32)
                res = self._run_interpret(P, R) if interpret \
                    else self._place(P)
                if not bool(res["overflow"]) and bool(res["converged"]):
                    break
                # pool too small for this chunk's cold starts (clamped
                # writes may also stall convergence): results are discarded
                # (no state was committed) and the chunk re-runs against a
                # doubled pool, capped at existing+R where overflow is
                # impossible and convergence is guaranteed
                new_cap = min(cap * 2, _next_pow2(max_existing + R))
                if new_cap <= cap:
                    raise RuntimeError(
                        "jax placement did not converge with an "
                        "overflow-proof container pool")
                cap = new_cap
            self._cap_hint = cap

            out = {k: np.asarray(res[k])[:n] for k in
                   ("gcode", "lat", "cost", "cold", "comp", "wait",
                    "feas", "allowed")}
            iters = int(res["iters"])
            # ---- commit host state (the numpy accept step, once) ----------
            if self.is_minlat:
                policy.surplus = float(res["s_fin"])
            if self.has_edge:
                h_fin = np.asarray(res["h_fin"])
                for d, nm in enumerate(dev_names):
                    edge_queues[nm].horizon_ms = float(h_fin[d])
            if self.n_cloud:
                t_last = float(nows_np[-1])
                busyF = np.asarray(res["busyF"])
                lastF = np.asarray(res["lastF"])
                cntF = np.asarray(res["cntF"])
                for ci, nm in enumerate(cloud_names):
                    k = int(cntF[ci])
                    b, l = busyF[ci, :k], lastF[ci, :k]
                    # reap at the last arrival == the walk's end state
                    keep = (t_last < b) | (t_last <= l + self.t_idl)
                    recs = [ContainerRecord(nm, float(bb), float(ll))
                            for bb, ll, kp in zip(b, l, keep) if kp]
                    if recs:
                        cil.containers[nm] = recs
                    else:
                        cil.containers.pop(nm, None)

        nom_out = None
        if self.has_edge:
            nom_out = np.asarray(res["nom"])[:n].astype(np.int64)
        engine.columnar_stats = {"chunks": 1, "repairs": max(iters - 1, 0),
                                 "walked": 0, "n": n}
        self.last_stats = {"n": n, "passes": iters + 1, "rows": R,
                           "pool_cap": cap, "interpret": interpret}
        engine.jax_stats = dict(self.last_stats)
        return DecisionBatch(
            batch=None,
            names=tuple(cloud_names) + tuple(dev_names),
            n_cloud=self.n_cloud,
            task_idx=task_idx,
            target_codes=out["gcode"].astype(np.int64),
            latency_ms=out["lat"].astype(np.float64),
            cost=out["cost"].astype(np.float64),
            cold=out["cold"].astype(bool),
            comp_ms=out["comp"].astype(np.float64),
            queue_wait_ms=out["wait"].astype(np.float64),
            feasible=out["feas"].astype(bool),
            allowed_cost=out["allowed"].astype(np.float64),
            edge_device_codes=nom_out,
            batch_factory=lambda pred=engine.predictor, ts=tasks:
                pred.predict_batch(ts),
        )


# ------------------------------------------------------------------ caching
def core_for(engine) -> JaxPlacementCore | None:
    """The engine's cached core, rebuilt when model identities / policy /
    kernel mode change; ``None`` when jax or the engine shape is ineligible."""
    if not available():
        return None
    key = _engine_key(engine)
    hit = engine.__dict__.get("_jax_core_cache")
    if hit is not None and hit[0] == key:
        core = hit[1]
        if core is None or core.valid_for(engine):
            return core
    try:
        core = JaxPlacementCore(engine)
    except CoreIneligible:
        core = None
    engine.__dict__["_jax_core_cache"] = (key, core)
    return core
