"""Container Information List (paper Sec. V-A).

The CIL is the Predictor's *client-side shadow* of which containers are warm in
the provider's infrastructure. AWS exposes no API for this, so the framework
maintains its own estimate, updated after every placement decision:

- per configuration λ_m, a list of containers with (busy|idle) status, the
  completion time of the latest function executed in the container, and the
  estimated destruction time (completion + T_idl);
- a dispatch to a configuration with an idle container is predicted WARM (the
  idle container with the most recent completion time is assumed to be reused,
  matching the paper's empirical observation of AWS Lambda);
- otherwise the dispatch is predicted COLD and a new container record is added;
- dead containers (idle past their estimated lifetime) are reaped on every
  update.

All times are in milliseconds. In the TPU-fleet adaptation the same structure
tracks which slice executors hold a resident compiled executable.
"""

from __future__ import annotations

from dataclasses import dataclass, field

# The paper measures T_idl ≈ 27 minutes via binary search (corroborating [32]).
DEFAULT_T_IDL_MS = 27.0 * 60.0 * 1000.0


@dataclass
class ContainerRecord:
    config: str
    busy_until: float  # completion time of the latest function (ms)
    last_completion: float  # == busy_until after completion

    def is_busy(self, now: float) -> bool:
        return now < self.busy_until

    def expires_at(self, t_idl_ms: float) -> float:
        return self.last_completion + t_idl_ms


@dataclass
class ContainerInfoList:
    t_idl_ms: float = DEFAULT_T_IDL_MS
    containers: dict[str, list[ContainerRecord]] = field(default_factory=dict)

    # ------------------------------------------------------------------ query
    def reap(self, now: float) -> int:
        """Remove containers idle past their estimated lifetime. Returns #reaped."""
        reaped = 0
        for cfg, lst in self.containers.items():
            keep = [
                c for c in lst
                if c.is_busy(now) or now <= c.expires_at(self.t_idl_ms)
            ]
            reaped += len(lst) - len(keep)
            self.containers[cfg] = keep
        return reaped

    def idle_containers(self, config: str, now: float) -> list[ContainerRecord]:
        """Idle, unexpired containers, most-recent-completion first (reuse order)."""
        lst = [
            c for c in self.containers.get(config, [])
            if not c.is_busy(now) and now <= c.expires_at(self.t_idl_ms)
        ]
        return sorted(lst, key=lambda c: -c.last_completion)

    def will_warm_start(self, config: str, now: float) -> bool:
        return len(self.idle_containers(config, now)) > 0

    def count(self, config: str) -> int:
        return len(self.containers.get(config, []))

    # ----------------------------------------------------------------- update
    def record_dispatch(self, config: str, now: float, completion_time: float) -> bool:
        """Record a dispatch decided at ``now`` whose function is estimated to
        complete (container released) at ``completion_time``.

        Returns True if this dispatch is a (predicted) cold start.
        """
        self.reap(now)
        idle = self.idle_containers(config, now)
        if idle:
            c = idle[0]  # most recent completion — the paper's reuse assumption
            c.busy_until = completion_time
            c.last_completion = completion_time
            return False
        rec = ContainerRecord(config=config, busy_until=completion_time,
                              last_completion=completion_time)
        self.containers.setdefault(config, []).append(rec)
        return True

    def prewarm(self, config: str, ready_ms: float,
                keepalive_until_ms: float) -> ContainerRecord:
        """Add a speculatively spawned container, warm for exactly
        ``[ready_ms, keepalive_until_ms]``.

        Both the walk path (``idle_containers`` → ``expires_at``) and the
        columnar decision core hardcode the warm window as
        ``busy_until <= now <= last_completion + t_idl``, so the record
        encodes the keep-alive horizon through ``last_completion =
        keepalive_until_ms - t_idl_ms`` rather than a new field — a
        prewarmed container needs zero changes in either consumer. The
        shifted ``last_completion`` also makes prewarmed records the
        *least*-recently-completed idle containers, so genuinely warm
        containers win the MRU reuse race and the prewarmed pool absorbs
        overflow only. Reuse via ``record_dispatch`` converts the record to
        the normal completion-driven lifecycle.
        """
        if not keepalive_until_ms > ready_ms:
            raise ValueError(
                f"prewarm keep-alive window must end after it starts: "
                f"keepalive_until_ms={keepalive_until_ms!r} <= "
                f"ready_ms={ready_ms!r}")
        rec = ContainerRecord(
            config=config, busy_until=float(ready_ms),
            last_completion=float(keepalive_until_ms) - self.t_idl_ms)
        self.containers.setdefault(config, []).append(rec)
        return rec
