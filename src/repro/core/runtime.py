"""The unified placement runtime: one serve loop, pluggable execution backends.

The paper's framework is a single Decision Engine driving many execution
substrates (Greengrass edge devices, Lambda configurations). This module makes
that architecture literal:

- ``ExecutionBackend`` is the substrate contract — ``execute(task, target,
  now) -> ExecutionOutcome`` plus a non-mutating ``probe_cold`` — implemented
  by ``TwinBackend`` (the AWS digital twin: event-driven simulation, paper
  Sec. VI-A) here and by ``repro.serving.placement.LiveBackend`` (the real
  executor pool, Sec. VI-B) on the serving side;
- ``PlacementRuntime`` is the ONE serve loop shared by simulation and the live
  prototype. It owns the *predicted* edge-queue horizon
  (``PredictedEdgeQueue``), asks the Decision Engine for placements (batched
  ``place_many`` by default, per-task ``step`` otherwise), executes them
  through the backend, and merges hedged duplicates
  (first-completion-wins, both billed);
- policies are consumed only through the formal ``Policy`` protocol —
  constraints for result reporting come from ``policy.constraints()``, hedges
  from the ``hedge`` hook carried on the ``PlacementDecision``.

Placement is non-blocking (paper Sec. III-A): decisions happen at ingestion
time from *predicted* state only, so the decision loop factors cleanly out of
execution — which is what lets ``serve`` run the vectorized batched path
without changing any observable behavior.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.apps import AWSTwin
from repro.core.decision import DecisionEngine, PlacementDecision, PredictedEdgeQueue
from repro.core.predictor import Prediction
from repro.core.pricing import LambdaPricing
from repro.core.records import SimulationResult, TaskRecord
from repro.core.workload import TaskInput


@dataclass(frozen=True)
class ExecutionOutcome:
    """What actually happened when a backend ran one task on one target."""

    latency_ms: float    # end-to-end, including any actual queueing
    cost: float          # billed $ for this execution
    cold: bool           # did the substrate actually cold-start?
    completion_ms: float  # absolute completion time on the arrival clock


@runtime_checkable
class ExecutionBackend(Protocol):
    """An execution substrate: the AWS twin, a live executor pool, ..."""

    def probe_cold(self, target: str, now: float) -> bool:
        """Would a function *triggered* at ``now`` cold-start? (No mutation.)

        ``now`` is the trigger time, not the task arrival time: on the twin,
        the actual cold/warm outcome of a dispatch is judged after the upload
        leg (``arrival + upld``), so pass that time to anticipate it. Not
        consumed by the serve loop itself — exposed for external warm-state
        introspection (dashboards, calibration probes).
        """
        ...

    def execute(self, task: TaskInput, target: str, now: float) -> ExecutionOutcome:
        """Run ``task`` on ``target``, mutating substrate state (queues, pools)."""
        ...


# ----------------------------------------------------------------- twin side
@dataclass
class GTContainer:
    busy_until: float
    last_completion: float
    expires_at: float  # actual reclamation time, sampled per idle period


class GroundTruthCloud:
    """The provider's actual container state (what AWS really does)."""

    def __init__(self, twin: AWSTwin, seed: int = 0):
        self.twin = twin
        self.rng = np.random.default_rng(seed)
        self.pools: dict[str, list[GTContainer]] = {}

    def probe(self, config: str, trigger_time: float) -> bool:
        """Would a function triggered now cold-start? (No mutation.)"""
        pool = self.pools.get(config, [])
        idle = [c for c in pool if c.busy_until <= trigger_time and trigger_time <= c.expires_at]
        return len(idle) == 0

    def commit(self, config: str, trigger_time: float, busy_ms: float) -> bool:
        """Trigger a function occupying a container for ``busy_ms``.
        Returns True if this was an actual cold start."""
        pool = self.pools.setdefault(config, [])
        # reap actually-expired idle containers
        pool[:] = [c for c in pool if c.busy_until > trigger_time or trigger_time <= c.expires_at]
        idle = [c for c in pool if c.busy_until <= trigger_time and trigger_time <= c.expires_at]
        completion = trigger_time + busy_ms
        expiry = completion + self.twin.t_idl_ms(self.rng)
        if idle:
            c = max(idle, key=lambda c: c.last_completion)
            c.busy_until = completion
            c.last_completion = completion
            c.expires_at = expiry
            return False
        pool.append(GTContainer(busy_until=completion, last_completion=completion,
                                expires_at=expiry))
        return True


class TwinBackend:
    """ExecutionBackend over the AWS digital twin (paper Sec. VI-A).

    Actual latencies, billed costs, and warm/cold outcomes come from the
    twin's generative ground truth: a stochastic-lifetime container pool per
    configuration and a single-slot FIFO edge executor whose *actual* queueing
    emerges from actual compute times.
    """

    def __init__(self, twin: AWSTwin, seed: int = 0,
                 pricing: LambdaPricing | None = None, edge_name: str = "edge"):
        self.twin = twin
        self.pricing = pricing or LambdaPricing()
        self.gt_cloud = GroundTruthCloud(twin, seed=seed)
        self.rng = np.random.default_rng(seed + 7)
        self.edge_name = edge_name
        # edge executor state (single-slot FIFO)
        self.edge_free_at_actual = 0.0

    def probe_cold(self, target: str, now: float) -> bool:
        return self.gt_cloud.probe(target, now)

    def execute(self, task: TaskInput, target: str, now: float) -> ExecutionOutcome:
        if target == self.edge_name:
            return self._execute_edge(task, now)
        return self._execute_cloud(task, target, now)

    def _execute_cloud(self, task: TaskInput, config: str, now: float) -> ExecutionOutcome:
        twin, rng = self.twin, self.rng
        upld = twin.upld_ms(task.bytes, rng)
        trigger = now + upld
        cold = self.gt_cloud.probe(config, trigger)
        start = twin.start_ms(cold, rng)
        comp = twin.comp_cloud_ms(task.size, float(config), rng)
        self.gt_cloud.commit(config, trigger, start + comp)
        store = twin.store_cloud_ms(rng)
        latency = upld + start + comp + store
        return ExecutionOutcome(
            latency_ms=latency,
            cost=self.pricing.cost(comp, float(config)),
            cold=cold,
            completion_ms=now + latency,
        )

    def _execute_edge(self, task: TaskInput, now: float) -> ExecutionOutcome:
        twin, rng = self.twin, self.rng
        comp = twin.comp_edge_ms(task.size, rng)
        start_exec = max(self.edge_free_at_actual, now)
        self.edge_free_at_actual = start_exec + comp
        iot = twin.iotup_ms(rng)
        store = twin.store_edge_ms(rng)
        latency = (start_exec - now) + comp + iot + store
        return ExecutionOutcome(
            latency_ms=latency, cost=0.0, cold=False, completion_ms=now + latency,
        )


# -------------------------------------------------------------- the runtime
class PlacementRuntime:
    """ONE serve loop over any (DecisionEngine, ExecutionBackend) pair.

    ``Simulation`` (twin backend) and ``LivePlacementServer`` (live executor
    pool) are thin wrappers over this class.
    """

    def __init__(self, engine: DecisionEngine, backend: ExecutionBackend):
        self.engine = engine
        self.backend = backend
        self.edge_queue = PredictedEdgeQueue()

    @property
    def edge_name(self) -> str:
        return self.engine.edge_name

    def serve(self, tasks: list[TaskInput], batched: bool = True) -> SimulationResult:
        """Place and execute a workload; aggregate the per-task records.

        ``batched=True`` (default) runs all component-model predictions in one
        vectorized pass (``DecisionEngine.place_many``); ``batched=False``
        interleaves per-task placement and execution. The two paths make
        identical decisions — placement is non-blocking, so execution never
        feeds back into decision state.
        """
        if batched:
            decisions = self.engine.place_many(tasks, edge_queue=self.edge_queue)
            records = [self._run_decision(t, d) for t, d in zip(tasks, decisions)]
        else:
            records = [self.step(t) for t in tasks]
        return self.result(records)

    def step(self, task: TaskInput) -> TaskRecord:
        """Place and execute one task (the per-task serve path)."""
        now = task.arrival_ms
        d = self.engine.place(task, now,
                              edge_queue_wait_ms=self.edge_queue.wait_ms(now))
        if d.target == self.edge_name:
            self.edge_queue.push(now, d.prediction.comp_ms)
        if d.hedge_target == self.edge_name and d.hedge_prediction is not None:
            self.edge_queue.push(now, d.hedge_prediction.comp_ms)
        return self._run_decision(task, d)

    def result(self, records: list[TaskRecord]) -> SimulationResult:
        cons = self.engine.policy.constraints()
        return SimulationResult(records=records, deadline_ms=cons.deadline_ms,
                                c_max=cons.c_max, edge_name=self.edge_name)

    # ------------------------------------------------------------------
    def _run_decision(self, task: TaskInput, d: PlacementDecision) -> TaskRecord:
        now = task.arrival_ms
        rec = self._record(task, d, d.target, d.prediction,
                           self.backend.execute(task, d.target, now))
        # Hedged duplicate (beyond-paper): first completion wins, both billed.
        if d.hedge_target is not None and d.hedge_target != d.target:
            backup = d.hedge_prediction
            dup = self.backend.execute(task, d.hedge_target, now)
            rec = TaskRecord(
                task=task, target=rec.target,
                predicted_latency_ms=min(rec.predicted_latency_ms, backup.latency_ms),
                predicted_cost=rec.predicted_cost + backup.cost,
                actual_latency_ms=min(rec.actual_latency_ms, dup.latency_ms),
                actual_cost=rec.actual_cost + dup.cost,
                predicted_cold=rec.predicted_cold, actual_cold=rec.actual_cold,
                allowed_cost=rec.allowed_cost, feasible=rec.feasible,
                completion_ms=min(rec.completion_ms, dup.completion_ms), hedged=True,
            )
        return rec

    def _record(self, task: TaskInput, d: PlacementDecision, target: str,
                pred: Prediction, out: ExecutionOutcome) -> TaskRecord:
        return TaskRecord(
            task=task, target=target,
            predicted_latency_ms=pred.latency_ms, predicted_cost=pred.cost,
            actual_latency_ms=out.latency_ms, actual_cost=out.cost,
            predicted_cold=pred.cold, actual_cold=out.cold,
            allowed_cost=d.allowed_cost, feasible=d.feasible,
            completion_ms=out.completion_ms,
        )
