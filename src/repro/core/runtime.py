"""The unified placement runtime: one serve loop, pluggable execution backends.

The paper's framework is a single Decision Engine driving many execution
substrates (Greengrass edge devices, Lambda configurations). This module makes
that architecture literal:

- ``ExecutionBackend`` is the substrate contract — ``execute(task, target,
  now) -> ExecutionOutcome`` plus a non-mutating ``probe_cold`` — implemented
  by ``TwinBackend`` (the AWS digital twin: event-driven simulation, paper
  Sec. VI-A) here and by ``repro.serving.placement.LiveBackend`` (the real
  executor pool, Sec. VI-B) on the serving side;
- ``PlacementRuntime`` is the ONE serve loop shared by simulation and the live
  prototype. It owns the *predicted* edge-queue horizons — one
  ``PredictedEdgeQueue`` per fleet device — asks the Decision Engine for
  placements (batched ``place_many`` by default, per-task ``step`` otherwise),
  executes them through the backend, and merges hedged duplicates
  (first-completion-wins, both billed);
- policies are consumed only through the formal ``Policy`` protocol —
  constraints for result reporting come from ``policy.constraints()``, hedges
  from the ``hedge`` hook carried on the ``PlacementDecision``.

Placement is non-blocking (paper Sec. III-A): decisions happen at ingestion
time from *predicted* state only, so the decision loop factors cleanly out of
execution — which is what lets ``serve`` run the vectorized batched path
without changing any observable behavior.

``TwinBackend`` additionally implements ``execute_many``: the whole ground
truth is sampled in batched numpy (upload / start / compute / store legs as
one ``standard_normal`` block per substrate stream) instead of per-task scalar
draws, BIT-IDENTICAL to the sequential ``execute`` loop — numpy Generators
produce the same stream whether normals are drawn one at a time or in a block,
and every leg is an affine/exp transform of a standard normal. Only the
container-pool and per-device FIFO recurrences stay sequential (cheap Python,
no model math). This is what makes 100k-task fleet workloads fast — see
``benchmarks/bench_runtime.py``.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.apps import (
    AWSTwin,
    FULL_VCPU_MB,
    T_IDL_ACTUAL_MEAN_MS,
    T_IDL_ACTUAL_STD_MS,
)
from repro.core.decision import (
    DecisionBatch,
    DecisionEngine,
    PlacementDecision,
    PredictedEdgeQueue,
)
from repro.core.predictor import Prediction
from repro.core.pricing import LambdaPricing
from repro.core.records import RecordBatch, SimulationResult, TaskRecord
from repro.core.recurrence import fifo_starts
from repro.core.workload import TaskInput


@dataclass(frozen=True)
class ExecutionOutcome:
    """What actually happened when a backend ran one task on one target."""

    latency_ms: float    # end-to-end, including any actual queueing
    cost: float          # billed $ for this execution
    cold: bool           # did the substrate actually cold-start?
    completion_ms: float  # absolute completion time on the arrival clock
    queue_wait_ms: float = 0.0  # actual FIFO wait (edge executors)
    exec_ms: float = 0.0        # executor busy occupancy (utilization metric)


@dataclass
class ExecutionBatch:
    """Struct-of-arrays form of N ``ExecutionOutcome``s — what a vectorized
    backend naturally produces (``TwinBackend.execute_many``). ``outcomes()``
    or indexing recovers the per-dispatch view."""

    latency_ms: np.ndarray
    cost: np.ndarray
    cold: np.ndarray          # bool
    completion_ms: np.ndarray
    queue_wait_ms: np.ndarray
    exec_ms: np.ndarray

    def __len__(self) -> int:
        return self.latency_ms.shape[0]

    def __getitem__(self, i: int) -> ExecutionOutcome:
        return ExecutionOutcome(
            latency_ms=float(self.latency_ms[i]), cost=float(self.cost[i]),
            cold=bool(self.cold[i]), completion_ms=float(self.completion_ms[i]),
            queue_wait_ms=float(self.queue_wait_ms[i]),
            exec_ms=float(self.exec_ms[i]))

    def outcomes(self) -> list[ExecutionOutcome]:
        return [ExecutionOutcome(lat, c, k, m, q, e)
                for lat, c, k, m, q, e in zip(
                    self.latency_ms.tolist(), self.cost.tolist(),
                    self.cold.tolist(), self.completion_ms.tolist(),
                    self.queue_wait_ms.tolist(), self.exec_ms.tolist())]


@runtime_checkable
class ExecutionBackend(Protocol):
    """An execution substrate: the AWS twin, a live executor pool, ..."""

    def probe_cold(self, target: str, now: float) -> bool:
        """Would a function *triggered* at ``now`` cold-start? (No mutation.)

        ``now`` is the trigger time, not the task arrival time: on the twin,
        the actual cold/warm outcome of a dispatch is judged after the upload
        leg (``arrival + upld``), so pass that time to anticipate it. Not
        consumed by the serve loop itself — exposed for external warm-state
        introspection (dashboards, calibration probes).
        """
        ...

    def execute(self, task: TaskInput, target: str, now: float) -> ExecutionOutcome:
        """Run ``task`` on ``target``, mutating substrate state (queues, pools)."""
        ...


def edge_stream_key(name: str) -> int:
    """Stable per-device RNG stream offset: adding or removing a device can
    never perturb another device's draws (crc32 is process-independent)."""
    return zlib.crc32(name.encode("utf-8"))


CLOUD_LEGS = ("upld", "start", "comp", "store")
EDGE_LEGS = ("comp", "iot", "store")


# The FIFO-start recurrence moved to ``repro.core.recurrence`` so the columnar
# decision core can share it; the old private name stays importable.
_fifo_starts = fifo_starts


# ----------------------------------------------------------------- twin side
@dataclass(slots=True)
class GTContainer:
    busy_until: float
    last_completion: float
    expires_at: float  # actual reclamation time, sampled per idle period


class GroundTruthCloud:
    """The provider's actual container state (what AWS really does)."""

    def __init__(self, twin: AWSTwin, seed: int = 0):
        self.twin = twin
        self.rng = np.random.default_rng(seed)
        self.pools: dict[str, list[GTContainer]] = {}

    def probe(self, config: str, trigger_time: float) -> bool:
        """Would a function triggered now cold-start? (No mutation.)"""
        pool = self.pools.get(config, [])
        idle = [c for c in pool if c.busy_until <= trigger_time and trigger_time <= c.expires_at]
        return len(idle) == 0

    def commit(self, config: str, trigger_time: float, busy_ms: float) -> bool:
        """Trigger a function occupying a container for ``busy_ms``.
        Returns True if this was an actual cold start.

        NOTE: ``TwinBackend.execute_many`` runs this reap / MRU-idle-select /
        occupy-or-append walk inline over parallel float lists (with the
        lifetime draws pre-batched from this object's ``rng``) — any change
        to the pool semantics here must be mirrored there; the bit-parity
        tests in ``tests/test_fleet.py`` catch divergence.
        """
        pool = self.pools.setdefault(config, [])
        # reap actually-expired idle containers
        pool[:] = [c for c in pool if c.busy_until > trigger_time or trigger_time <= c.expires_at]
        idle = [c for c in pool if c.busy_until <= trigger_time and trigger_time <= c.expires_at]
        completion = trigger_time + busy_ms
        expiry = completion + self.twin.t_idl_ms(self.rng)
        if idle:
            c = max(idle, key=lambda c: c.last_completion)
            c.busy_until = completion
            c.last_completion = completion
            c.expires_at = expiry
            return False
        pool.append(GTContainer(busy_until=completion, last_completion=completion,
                                expires_at=expiry))
        return True


class TwinBackend:
    """ExecutionBackend over the AWS digital twin (paper Sec. VI-A).

    Actual latencies, billed costs, and warm/cold outcomes come from the
    twin's generative ground truth: a stochastic-lifetime container pool per
    configuration and N single-slot FIFO edge executors (one per fleet
    device) whose *actual* queueing emerges from actual compute times.

    One RNG stream per (substrate, latency leg): the cloud pipeline draws
    upld/start/comp/store each from its own stream, and each edge device
    draws comp/iot/store from streams seeded ``(seed, edge_stream_key(name),
    leg)`` — deterministic and independent of fleet composition, so adding a
    device never perturbs another device's ground truth, and the batched
    sampler can draw each leg as one contiguous block that is bit-identical
    to the per-task scalar draws. ``edge_speed`` maps device → relative
    compute speed (heterogeneous fleets; actual compute is divided by it).
    """

    def __init__(self, twin: AWSTwin, seed: int = 0,
                 pricing: LambdaPricing | None = None, edge_name: str = "edge",
                 edge_names: Sequence[str] | None = None,
                 edge_speed: dict[str, float] | None = None):
        self.twin = twin
        self.pricing = pricing or LambdaPricing()
        self.gt_cloud = GroundTruthCloud(twin, seed=seed)
        self.cloud_rngs = {leg: np.random.default_rng([seed, 7, i])
                           for i, leg in enumerate(CLOUD_LEGS)}
        names = tuple(edge_names) if edge_names is not None else (edge_name,)
        self.edge_names = names
        self.edge_name = names[0] if names else edge_name
        self.edge_speed = {n: float((edge_speed or {}).get(n, 1.0)) for n in names}
        self.edge_rngs = {
            n: {leg: np.random.default_rng([seed, edge_stream_key(n), i])
                for i, leg in enumerate(EDGE_LEGS)}
            for n in names}
        # per-device edge executor state (single-slot FIFO)
        self.edge_free_at = {n: 0.0 for n in names}

    @property
    def edge_free_at_actual(self) -> float:
        """Deprecated single-edge alias for ``edge_free_at[edge_name]``."""
        return self.edge_free_at[self.edge_name]

    @edge_free_at_actual.setter
    def edge_free_at_actual(self, value: float) -> None:
        self.edge_free_at[self.edge_name] = value

    def probe_cold(self, target: str, now: float) -> bool:
        return self.gt_cloud.probe(target, now)

    def execute(self, task: TaskInput, target: str, now: float) -> ExecutionOutcome:
        if target in self.edge_free_at:
            return self._execute_edge(task, now, target)
        return self._execute_cloud(task, target, now)

    def _execute_cloud(self, task: TaskInput, config: str, now: float) -> ExecutionOutcome:
        twin, rngs = self.twin, self.cloud_rngs
        upld = twin.upld_ms(task.bytes, rngs["upld"])
        trigger = now + upld
        cold = self.gt_cloud.probe(config, trigger)
        start = twin.start_ms(cold, rngs["start"])
        comp = twin.comp_cloud_ms(task.size, float(config), rngs["comp"])
        self.gt_cloud.commit(config, trigger, start + comp)
        store = twin.store_cloud_ms(rngs["store"])
        latency = upld + start + comp + store
        return ExecutionOutcome(
            latency_ms=latency,
            cost=self.pricing.cost(comp, float(config)),
            cold=cold,
            completion_ms=now + latency,
            exec_ms=start + comp,
        )

    def _execute_edge(self, task: TaskInput, now: float,
                      device: str | None = None) -> ExecutionOutcome:
        device = device if device is not None else self.edge_name
        twin, rngs = self.twin, self.edge_rngs[device]
        comp = twin.comp_edge_ms(task.size, rngs["comp"]) / self.edge_speed[device]
        start_exec = max(self.edge_free_at[device], now)
        self.edge_free_at[device] = start_exec + comp
        iot = twin.iotup_ms(rngs["iot"])
        store = twin.store_edge_ms(rngs["store"])
        latency = (start_exec - now) + comp + iot + store
        return ExecutionOutcome(
            latency_ms=latency, cost=0.0, cold=False, completion_ms=now + latency,
            queue_wait_ms=start_exec - now, exec_ms=comp,
        )

    # ------------------------------------------------- vectorized ground truth
    def execute_many(self, tasks: Sequence[TaskInput],
                     targets: Sequence[str]) -> ExecutionBatch:
        """Run one dispatch per (task, target) pair, sampling all ground-truth
        randomness in batched numpy; returns the struct-of-arrays view.

        Bit-identical to calling ``execute`` once per pair in order: every
        latency leg has its own RNG stream, and numpy Generators produce the
        same values whether ``normal``/``lognormal`` are drawn one at a time
        or as one ``size=n`` block; the arithmetic around each draw keeps the
        scalar path's operation order. Only the container pool and the
        per-device FIFO recurrences run sequentially — pure bookkeeping, no
        model math.
        """
        n = len(tasks)
        spec = self.twin.spec
        sizes = np.array([t.size for t in tasks])
        nows = np.array([t.arrival_ms for t in tasks])
        if spec.size_kind == "pixels":
            scaled = sizes / 1e6
        else:
            scaled = sizes / 32.0 / 1000.0

        # integer-encode targets in one pass: device i -> i, cloud -> -1
        devmap = {dev: i for i, dev in enumerate(self.edge_names)}
        dm_get = devmap.get
        codes = np.array([dm_get(tg, -1) for tg in targets], dtype=np.int64)
        edge_masks = {dev: codes == i for dev, i in devmap.items()}
        ci = np.nonzero(codes == -1)[0]

        out = ExecutionBatch(
            latency_ms=np.empty(n), cost=np.zeros(n),
            cold=np.zeros(n, dtype=bool), completion_ms=np.empty(n),
            queue_wait_ms=np.zeros(n), exec_ms=np.empty(n))
        placed = 0

        # ---- cloud: batch the 4 normals per dispatch (upld, start, comp, store)
        nc = ci.shape[0]
        if nc:
            rngs = self.cloud_rngs
            cfgs = [targets[i] for i in ci.tolist()]
            uniq = {c: float(c) for c in set(cfgs)}
            mem = np.array([uniq[c] for c in cfgs])
            share = np.minimum(mem, FULL_VCPU_MB) / FULL_VCPU_MB  # cpu_share, vectorized
            nbytes = np.array([tasks[i].bytes for i in ci.tolist()])
            upld = (spec.upld_base_ms + nbytes * spec.upld_ms_per_byte) \
                * rngs["upld"].lognormal(0.0, spec.upld_sigma, nc)
            zs = rngs["start"].standard_normal(nc)  # scaled per warm/cold below
            warm_start = np.maximum(spec.warm_mean + spec.warm_std * zs, 1.0)
            cold_start = np.maximum(spec.cold_mean + spec.cold_std * zs, 1.0)
            comp = (spec.c0_ms + spec.c1_ms * scaled[ci]) / share \
                * rngs["comp"].lognormal(0.0, spec.comp_sigma, nc)
            store = np.maximum(
                rngs["store"].normal(spec.store_cloud_mean, spec.store_cloud_std, nc), 1.0)
            zl = self.gt_cloud.rng.standard_normal(nc)
            t_idl = np.maximum(T_IDL_ACTUAL_MEAN_MS + T_IDL_ACTUAL_STD_MS * zl,
                               5 * 60e3)
            # sequential container-pool walk (state only; all draws done
            # above). Probe+commit fused into one scan per dispatch — reap,
            # find the most-recently-used idle container, occupy or append —
            # run per config over parallel float lists (pools are independent
            # across configs, so grouping preserves each pool's dispatch
            # order; the lifetime draws stay in global dispatch order).
            trigger = nows[ci] + upld
            trig_l = trigger.tolist()
            comp_l = comp.tolist()
            warm_l = warm_start.tolist()
            cold_l = cold_start.tolist()
            tidl_l = t_idl.tolist()
            start_l = [0.0] * nc
            was_cold = [False] * nc
            pools = self.gt_cloud.pools
            by_cfg: dict[str, list[int]] = {}
            for j, cfg in enumerate(cfgs):
                lst = by_cfg.get(cfg)
                if lst is None:
                    lst = by_cfg[cfg] = []
                lst.append(j)
            for cfg, js in by_cfg.items():
                pool = pools.setdefault(cfg, [])
                busy_l = [c.busy_until for c in pool]
                last_l = [c.last_completion for c in pool]
                exp_l = [c.expires_at for c in pool]
                for j in js:
                    t = trig_l[j]
                    best = -1
                    best_last = -1e308
                    reap = False
                    for i in range(len(busy_l)):
                        if busy_l[i] <= t:
                            if t <= exp_l[i]:
                                li = last_l[i]
                                if li > best_last:
                                    best_last = li
                                    best = i
                            else:
                                reap = True  # expired idle container
                    if reap:  # rare (27-min lifetimes): rebuild only when needed
                        nb: list[float] = []
                        nl: list[float] = []
                        ne: list[float] = []
                        best = -1
                        best_last = -1e308
                        for i in range(len(busy_l)):
                            b, li, e = busy_l[i], last_l[i], exp_l[i]
                            if b > t or t <= e:
                                if b <= t and li > best_last:
                                    best_last = li
                                    best = len(nb)
                                nb.append(b)
                                nl.append(li)
                                ne.append(e)
                        busy_l, last_l, exp_l = nb, nl, ne
                    st = warm_l[j] if best >= 0 else cold_l[j]
                    busy = st + comp_l[j]
                    completion_t = t + busy
                    expiry = completion_t + tidl_l[j]
                    if best >= 0:
                        busy_l[best] = completion_t
                        last_l[best] = completion_t
                        exp_l[best] = expiry
                    else:
                        busy_l.append(completion_t)
                        last_l.append(completion_t)
                        exp_l.append(expiry)
                        was_cold[j] = True
                    start_l[j] = st
                pools[cfg] = [GTContainer(b, li, e)
                              for b, li, e in zip(busy_l, last_l, exp_l)]
            start = np.asarray(start_l)
            cost = np.empty(nc)
            for cfg, fmem in uniq.items():
                m = mem == fmem
                cost[m] = self.pricing.cost_batch(comp[m], fmem)
            latency = upld + start + comp + store
            out.latency_ms[ci] = latency
            out.cost[ci] = cost
            out.cold[ci] = was_cold
            out.completion_ms[ci] = nows[ci] + latency
            out.exec_ms[ci] = start + comp
            placed += nc

        # ---- edge: per-device batched draws + exact FIFO recurrence
        for dev in self.edge_names:
            di = np.nonzero(edge_masks[dev])[0]
            nd = di.shape[0]
            if nd == 0:
                continue
            rngs = self.edge_rngs[dev]
            comp = (spec.e0_ms + spec.e1_ms * scaled[di]) \
                * rngs["comp"].lognormal(0.0, spec.edge_sigma, nd) \
                / self.edge_speed[dev]
            if spec.iotup_mean > 0:  # matches iotup_ms: no draw when unmodeled
                iot = np.maximum(
                    rngs["iot"].normal(spec.iotup_mean, spec.iotup_std, nd), 0.0)
            else:
                iot = np.zeros(nd)
            store = np.maximum(
                rngs["store"].normal(spec.store_edge_mean, spec.store_edge_std, nd), 1.0)
            dev_nows = nows[di]
            start_exec, free = _fifo_starts(self.edge_free_at[dev], dev_nows, comp)
            self.edge_free_at[dev] = free
            wait = start_exec - dev_nows
            latency = wait + comp + iot + store
            out.latency_ms[di] = latency
            out.completion_ms[di] = dev_nows + latency
            out.queue_wait_ms[di] = wait
            out.exec_ms[di] = comp
            placed += nd

        assert placed == n  # every dispatch is either a fleet device or cloud
        return out


# -------------------------------------------------------------- the runtime
class PlacementRuntime:
    """ONE serve loop over any (DecisionEngine, ExecutionBackend) pair.

    Owns one predicted edge-queue horizon per fleet device. ``Simulation``
    (twin backend) and ``LivePlacementServer`` (live executor pool) are thin
    wrappers over this class.
    """

    def __init__(self, engine: DecisionEngine, backend: ExecutionBackend):
        self.engine = engine
        self.backend = backend
        self.edge_queues = {n: PredictedEdgeQueue() for n in engine.edge_names}
        # cloud-only runtimes keep a zeroed queue behind the deprecated
        # ``edge_queue`` alias, matching the attribute's pre-fleet existence
        self._no_edge_queue = PredictedEdgeQueue()

    @property
    def edge_name(self) -> str:
        return self.engine.edge_name

    @property
    def edge_names(self) -> tuple[str, ...]:
        return self.engine.edge_names

    @property
    def edge_queue(self) -> PredictedEdgeQueue:
        """Deprecated single-edge alias for the first device's queue."""
        names = self.edge_names
        return self.edge_queues[names[0]] if names else self._no_edge_queue

    def serve(self, tasks: list[TaskInput], batched: bool = True) -> SimulationResult:
        """Place and execute a workload; aggregate the per-task records.

        ``batched=True`` (default) runs the columnar serve path: one
        vectorized prediction pass, the columnar decision core
        (``DecisionEngine.place_many`` → ``DecisionBatch``) and, when the
        backend implements ``execute_many``, one batched ground-truth pass
        whose outcome arrays land directly in a ``RecordBatch`` — array-native
        from prediction to result. ``batched=False`` interleaves per-task
        placement and execution. The two paths produce identical results —
        placement is non-blocking, so execution never feeds back into decision
        state; the columnar decision core is bit-identical to the per-task
        walk (speculate-and-repair, see ``repro.core.decision``); and the
        twin's batched sampler is bit-identical to its sequential one.
        """
        if batched:
            decisions = self.engine.place_many(tasks, edge_queues=self.edge_queues)
            records = self._execute_decisions(tasks, decisions)
        else:
            records = [self.step(t) for t in tasks]
        return self.result(records)

    def step(self, task: TaskInput) -> TaskRecord:
        """Place and execute one task (the per-task serve path)."""
        now = task.arrival_ms
        waits = {n: q.wait_ms(now) for n, q in self.edge_queues.items()}
        d = self.engine.place(task, now, edge_waits=waits)
        if d.target in self.edge_queues:
            self.edge_queues[d.target].push(now, d.prediction.comp_ms)
        if d.hedge_target is not None and d.hedge_target in self.edge_queues \
                and d.hedge_prediction is not None:
            self.edge_queues[d.hedge_target].push(now, d.hedge_prediction.comp_ms)
        return self._run_decision(task, d)

    def result(self, records: "RecordBatch | list[TaskRecord]") -> SimulationResult:
        cons = self.engine.policy.constraints()
        names = self.edge_names
        return SimulationResult(records=records, deadline_ms=cons.deadline_ms,
                                c_max=cons.c_max,
                                edge_name=names[0] if names else self.engine.edge_name,
                                edge_names=names or None)

    # ------------------------------------------------------------------
    def _execute_decisions(self, tasks: list[TaskInput], decisions,
                           ) -> "RecordBatch | list[TaskRecord]":
        """Execute a placed workload; vectorized when the backend supports it.

        A columnar ``DecisionBatch`` against a vectorized backend never leaves
        array land: decisions flow into ``execute_many`` and the outcome
        arrays zip straight into a ``RecordBatch`` — no ``PlacementDecision``,
        ``ExecutionOutcome`` or ``TaskRecord`` objects anywhere on the path.
        List decisions (hedged/custom policies, per-task backends) take the
        per-record path unchanged.
        """
        if isinstance(decisions, DecisionBatch):
            if hasattr(self.backend, "execute_many"):
                eb = self.backend.execute_many(tasks, decisions.target_list())
                if isinstance(eb, ExecutionBatch):
                    return self._record_batch(tasks, decisions, eb)
                return [self._record(t, d, d.target, d.prediction, o)
                        for t, d, o in zip(tasks, decisions, eb)]
            # per-task backend: iterate the lazy decision views
            return [self._run_decision(t, d) for t, d in zip(tasks, decisions)]
        if not hasattr(self.backend, "execute_many"):
            return [self._run_decision(t, d) for t, d in zip(tasks, decisions)]
        # one dispatch per execution leg, hedge duplicates right after their
        # primary — the same order the sequential loop executes them in
        d_tasks: list[TaskInput] = []
        d_targets: list[str] = []
        for t, d in zip(tasks, decisions):
            d_tasks.append(t)
            d_targets.append(d.target)
            if d.hedge_target is not None and d.hedge_target != d.target:
                d_tasks.append(t)
                d_targets.append(d.hedge_target)
        outcomes = self.backend.execute_many(d_tasks, d_targets)
        if isinstance(outcomes, ExecutionBatch):
            outcomes = outcomes.outcomes()
        records, j = [], 0
        for t, d in zip(tasks, decisions):
            out = outcomes[j]
            j += 1
            rec = self._record(t, d, d.target, d.prediction, out)
            if d.hedge_target is not None and d.hedge_target != d.target:
                rec = self._merge_hedge(rec, t, d, outcomes[j])
                j += 1
            records.append(rec)
        return records

    def _record_batch(self, tasks: list[TaskInput], d: DecisionBatch,
                      eb: ExecutionBatch) -> RecordBatch:
        """Zip decision and outcome arrays into the columnar record store."""
        n = len(d)
        return RecordBatch(
            tasks=tasks,
            target_codes=d.target_codes,
            target_names=d.names,
            predicted_latency_ms=d.latency_ms,
            predicted_cost=d.cost,
            actual_latency_ms=eb.latency_ms,
            actual_cost=eb.cost,
            predicted_cold=d.cold,
            actual_cold=eb.cold,
            allowed_cost=d.allowed_cost,
            feasible=d.feasible,
            completion_ms=eb.completion_ms,
            hedged=np.zeros(n, dtype=bool),  # columnar policies never hedge
            queue_wait_ms=eb.queue_wait_ms,
            exec_ms=eb.exec_ms,
            hedge_codes=np.full(n, -1, dtype=np.int64),
            hedge_exec_ms=np.zeros(n),
        )

    def _run_decision(self, task: TaskInput, d: PlacementDecision) -> TaskRecord:
        now = task.arrival_ms
        rec = self._record(task, d, d.target, d.prediction,
                           self.backend.execute(task, d.target, now))
        # Hedged duplicate (beyond-paper): first completion wins, both billed.
        if d.hedge_target is not None and d.hedge_target != d.target:
            dup = self.backend.execute(task, d.hedge_target, now)
            rec = self._merge_hedge(rec, task, d, dup)
        return rec

    def _merge_hedge(self, rec: TaskRecord, task: TaskInput,
                     d: PlacementDecision, dup: ExecutionOutcome) -> TaskRecord:
        backup = d.hedge_prediction
        return TaskRecord(
            task=task, target=rec.target,
            predicted_latency_ms=min(rec.predicted_latency_ms, backup.latency_ms),
            predicted_cost=rec.predicted_cost + backup.cost,
            actual_latency_ms=min(rec.actual_latency_ms, dup.latency_ms),
            actual_cost=rec.actual_cost + dup.cost,
            predicted_cold=rec.predicted_cold, actual_cold=rec.actual_cold,
            allowed_cost=rec.allowed_cost, feasible=rec.feasible,
            completion_ms=min(rec.completion_ms, dup.completion_ms), hedged=True,
            queue_wait_ms=rec.queue_wait_ms, exec_ms=rec.exec_ms,
            hedge_target=d.hedge_target, hedge_exec_ms=dup.exec_ms,
        )

    def _record(self, task: TaskInput, d: PlacementDecision, target: str,
                pred: Prediction, out: ExecutionOutcome) -> TaskRecord:
        return TaskRecord(
            task=task, target=target,
            predicted_latency_ms=pred.latency_ms, predicted_cost=pred.cost,
            actual_latency_ms=out.latency_ms, actual_cost=out.cost,
            predicted_cold=pred.cold, actual_cold=out.cold,
            allowed_cost=d.allowed_cost, feasible=d.feasible,
            completion_ms=out.completion_ms,
            queue_wait_ms=out.queue_wait_ms, exec_ms=out.exec_ms,
        )
