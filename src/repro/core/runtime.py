"""The unified placement runtime: one serve loop, pluggable execution backends.

The paper's framework is a single Decision Engine driving many execution
substrates (Greengrass edge devices, Lambda configurations). This module makes
that architecture literal:

- ``ExecutionBackend`` is the substrate contract — ``execute(task, target,
  now) -> ExecutionOutcome`` plus a non-mutating ``probe_cold`` — implemented
  by ``TwinBackend`` (the AWS digital twin: event-driven simulation, paper
  Sec. VI-A) here and by ``repro.serving.placement.LiveBackend`` (the real
  executor pool, Sec. VI-B) on the serving side;
- ``PlacementRuntime`` is the ONE serve loop shared by simulation and the live
  prototype. It owns the *predicted* edge-queue horizons — one
  ``PredictedEdgeQueue`` per fleet device — asks the Decision Engine for
  placements (batched ``place_many`` by default, per-task ``step`` otherwise),
  executes them through the backend, and merges hedged duplicates
  (first-completion-wins, both billed);
- policies are consumed only through the formal ``Policy`` protocol —
  constraints for result reporting come from ``policy.constraints()``, hedges
  from the ``hedge`` hook carried on the ``PlacementDecision``.

Placement is non-blocking (paper Sec. III-A): decisions happen at ingestion
time from *predicted* state only, so the decision loop factors cleanly out of
execution — which is what lets ``serve`` run the vectorized batched path
without changing any observable behavior.

``TwinBackend`` additionally implements ``execute_many``: the whole ground
truth is sampled in batched numpy (upload / start / compute / store legs as
one ``standard_normal`` block per substrate stream) instead of per-task scalar
draws, BIT-IDENTICAL to the sequential ``execute`` loop — numpy Generators
produce the same stream whether normals are drawn one at a time or in a block,
and every leg is an affine/exp transform of a standard normal. Only the
container-pool and per-device FIFO recurrences stay sequential (cheap Python,
no model math). This is what makes 100k-task fleet workloads fast — see
``benchmarks/bench_runtime.py``.

The STREAMING serve path (``PlacementRuntime.serve_stream``) runs the same
columnar pipeline over arrival chunks: every sequential state carrier — the
CIL, the Alg. 1 surplus bank, the predicted edge-queue horizons, the
per-(substrate, leg) RNG streams, and the twin's ground-truth container pool —
lives OUTSIDE the chunk, so the concatenated result is bit-identical to the
one-shot serve for every chunk size while the working set stays
O(chunk × targets). Outcome columns accumulate in a ``RecordArena``
(geometric doubling, in-place merge); ``repro.core.multiapp`` fans N
independent application streams out over this path in parallel shards.

The EVENT-DRIVEN serve path (``PlacementRuntime.serve_async``) reuses the same
non-blocking placement pass and fans execution out to per-target workers — one
per edge device, one per cloud config — that pull rows from the columnar
``DecisionBatch`` by ``target_codes``. On the twin the workers interleave on
the virtual-clock event heap (``repro.core.events``; ``execute_async``,
bit-identical to ``execute_many``); live backends run them as real threads
(``repro.serving.executors.ExecutorPool.serve_concurrent``) so fleet
executions genuinely overlap. Hedge duplicates become race events: first
completion wins, the loser is drained (twin) or cancelled when it never
started (live).
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Protocol, Sequence, runtime_checkable

import numpy as np

from repro.core.events import (
    ARRIVAL,
    COMPLETION,
    DISPATCH,
    PREEMPT,
    EventHeap,
    SingleSlotWorker,
)

from repro.core.apps import (
    AWSTwin,
    FULL_VCPU_MB,
    T_IDL_ACTUAL_MEAN_MS,
    T_IDL_ACTUAL_STD_MS,
)
from repro.core.decision import (
    DecisionBatch,
    DecisionEngine,
    PlacementDecision,
    PredictedEdgeQueue,
    failover_choice,
)
from repro.core.faults import (
    BLACKOUT,
    OUTAGE,
    TRANSIENT,
    AdmissionPolicy,
    CircuitBreaker,
    FaultSpec,
    RetryPolicy,
    TargetHealth,
)
from repro.core.overload import (
    OverloadManager,
    PrewarmPolicy,
    ReclamationPolicy,
    select_victims,
)
from repro.core.predictor import Prediction
from repro.core.pricing import LambdaPricing
from repro.core.records import RecordArena, RecordBatch, SimulationResult, TaskRecord
from repro.core.recurrence import fifo_starts
from repro.core.workload import TaskChunk, TaskInput, task_arrays, task_tiers


@dataclass(frozen=True)
class ExecutionOutcome:
    """What actually happened when a backend ran one task on one target."""

    latency_ms: float    # end-to-end, including any actual queueing
    cost: float          # billed $ for this execution
    cold: bool           # did the substrate actually cold-start?
    completion_ms: float  # absolute completion time on the arrival clock
    queue_wait_ms: float = 0.0  # actual FIFO wait (edge executors)
    exec_ms: float = 0.0        # executor busy occupancy (utilization metric)
    # fault injection (see ``repro.core.faults``): a failed dispatch bills
    # every leg that actually ran (``cost``/``exec_ms`` reflect them) but
    # produced no result; ``completion_ms`` is when the failure was detected
    failed: bool = False
    fail_kind: int = 0   # faults.OK / TRANSIENT / OUTAGE / BLACKOUT / BREAKER


@dataclass
class ExecutionBatch:
    """Struct-of-arrays form of N ``ExecutionOutcome``s — what a vectorized
    backend naturally produces (``TwinBackend.execute_many``). ``outcomes()``
    or indexing recovers the per-dispatch view."""

    latency_ms: np.ndarray
    cost: np.ndarray
    cold: np.ndarray          # bool
    completion_ms: np.ndarray
    queue_wait_ms: np.ndarray
    exec_ms: np.ndarray
    # set by concurrent drivers only: a hedge race leg that was cancelled
    # before it started (it ran nowhere, bills nothing). None = no races.
    cancelled: np.ndarray | None = None
    # set by fault-injecting backends only (None = nothing failed): which
    # dispatches failed and how (``repro.core.faults`` kind codes)
    failed: np.ndarray | None = None
    fail_kind: np.ndarray | None = None

    def __len__(self) -> int:
        return self.latency_ms.shape[0]

    def __getitem__(self, i: int) -> ExecutionOutcome:
        return ExecutionOutcome(
            latency_ms=float(self.latency_ms[i]), cost=float(self.cost[i]),
            cold=bool(self.cold[i]), completion_ms=float(self.completion_ms[i]),
            queue_wait_ms=float(self.queue_wait_ms[i]),
            exec_ms=float(self.exec_ms[i]),
            failed=bool(self.failed[i]) if self.failed is not None else False,
            fail_kind=int(self.fail_kind[i]) if self.fail_kind is not None else 0)

    def outcomes(self) -> list[ExecutionOutcome]:
        return [ExecutionOutcome(lat, c, k, m, q, e)
                for lat, c, k, m, q, e in zip(
                    self.latency_ms.tolist(), self.cost.tolist(),
                    self.cold.tolist(), self.completion_ms.tolist(),
                    self.queue_wait_ms.tolist(), self.exec_ms.tolist())]


@runtime_checkable
class ExecutionBackend(Protocol):
    """An execution substrate: the AWS twin, a live executor pool, ..."""

    def probe_cold(self, target: str, now: float) -> bool:
        """Would a function *triggered* at ``now`` cold-start? (No mutation.)

        ``now`` is the trigger time, not the task arrival time: on the twin,
        the actual cold/warm outcome of a dispatch is judged after the upload
        leg (``arrival + upld``), so pass that time to anticipate it. Not
        consumed by the serve loop itself — exposed for external warm-state
        introspection (dashboards, calibration probes).
        """
        ...

    def execute(self, task: TaskInput, target: str, now: float) -> ExecutionOutcome:
        """Run ``task`` on ``target``, mutating substrate state (queues, pools)."""
        ...


def edge_stream_key(name: str) -> int:
    """Stable per-device RNG stream offset: adding or removing a device can
    never perturb another device's draws (crc32 is process-independent)."""
    return zlib.crc32(name.encode("utf-8"))


CLOUD_LEGS = ("upld", "start", "comp", "store")
EDGE_LEGS = ("comp", "iot", "store")


# The FIFO-start recurrence moved to ``repro.core.recurrence`` so the columnar
# decision core can share it; the old private name stays importable.
_fifo_starts = fifo_starts


# ----------------------------------------------------------------- twin side
@dataclass(slots=True)
class GTContainer:
    busy_until: float
    last_completion: float
    expires_at: float  # actual reclamation time, sampled per idle period


class GroundTruthCloud:
    """The provider's actual container state (what AWS really does)."""

    def __init__(self, twin: AWSTwin, seed: int = 0):
        self.twin = twin
        self.rng = np.random.default_rng(seed)
        self.pools: dict[str, list[GTContainer]] = {}

    def probe(self, config: str, trigger_time: float) -> bool:
        """Would a function triggered now cold-start? (No mutation.)"""
        pool = self.pools.get(config, [])
        idle = [c for c in pool if c.busy_until <= trigger_time and trigger_time <= c.expires_at]
        return len(idle) == 0

    def commit(self, config: str, trigger_time: float, busy_ms: float) -> bool:
        """Trigger a function occupying a container for ``busy_ms``.
        Returns True if this was an actual cold start.

        NOTE: ``TwinBackend.execute_many`` runs this reap / MRU-idle-select /
        occupy-or-append walk inline over parallel float lists (with the
        lifetime draws pre-batched from this object's ``rng``) — any change
        to the pool semantics here must be mirrored there; the bit-parity
        tests in ``tests/test_fleet.py`` catch divergence.
        """
        cold, _ = self.commit_drawn(config, trigger_time, busy_ms, busy_ms,
                                    self.twin.t_idl_ms(self.rng))
        return cold

    def commit_drawn(self, config: str, trigger_time: float, warm_busy_ms: float,
                     cold_busy_ms: float, t_idl_ms: float) -> tuple[bool, float]:
        """``commit`` with pre-drawn randomness: the idle lifetime comes in as
        ``t_idl_ms`` (the batched samplers draw lifetimes as one block, so RNG
        stream order is the caller's job) and the busy occupancy is chosen
        warm/cold by the probe itself. Returns ``(cold, completion_ms)`` —
        what the event-driven driver needs to schedule the completion event.
        """
        pool = self.pools.setdefault(config, [])
        # reap actually-expired idle containers
        pool[:] = [c for c in pool if c.busy_until > trigger_time or trigger_time <= c.expires_at]
        idle = [c for c in pool if c.busy_until <= trigger_time and trigger_time <= c.expires_at]
        cold = not idle
        completion = trigger_time + (cold_busy_ms if cold else warm_busy_ms)
        expiry = completion + t_idl_ms
        if idle:
            c = max(idle, key=lambda c: c.last_completion)
            c.busy_until = completion
            c.last_completion = completion
            c.expires_at = expiry
        else:
            pool.append(GTContainer(busy_until=completion,
                                    last_completion=completion,
                                    expires_at=expiry))
        return cold, completion

    def spinup(self, config: str, ready_ms: float, expires_ms: float) -> None:
        """Speculatively spawn a container (predictive pre-warming): spinning
        up until ``ready_ms``, then idle-warm until its DETERMINISTIC
        keep-alive expiry ``expires_ms``. Never draws from ``self.rng`` — the
        container-lifetime draw block in the batched samplers must see the
        exact same stream with or without pre-warming (bit-parity). A reuse
        converts the container to the normal sampled-lifetime lifecycle."""
        self.pools.setdefault(config, []).append(GTContainer(
            busy_until=float(ready_ms), last_completion=float(ready_ms),
            expires_at=float(expires_ms)))

    def extend_keepalive(self, config: str, ready_ms: float,
                         old_expires_ms: float, new_expires_ms: float) -> bool:
        """Push out the keep-alive expiry of a STILL-UNUSED prewarmed
        container, matched by value — ``execute_many`` rebuilds its pool
        lists as fresh ``GTContainer`` objects, so object identity does not
        survive a dispatch round. A container that was reused no longer
        matches (its ``busy_until`` moved), which is exactly the
        "only extend idle retainers" rule. Returns True when extended."""
        for c in self.pools.get(config, []):
            if c.busy_until == ready_ms and c.expires_at == old_expires_ms:
                c.expires_at = float(new_expires_ms)
                return True
        return False


class TwinBackend:
    """ExecutionBackend over the AWS digital twin (paper Sec. VI-A).

    Actual latencies, billed costs, and warm/cold outcomes come from the
    twin's generative ground truth: a stochastic-lifetime container pool per
    configuration and N single-slot FIFO edge executors (one per fleet
    device) whose *actual* queueing emerges from actual compute times.

    One RNG stream per (substrate, latency leg): the cloud pipeline draws
    upld/start/comp/store each from its own stream, and each edge device
    draws comp/iot/store from streams seeded ``(seed, edge_stream_key(name),
    leg)`` — deterministic and independent of fleet composition, so adding a
    device never perturbs another device's ground truth, and the batched
    sampler can draw each leg as one contiguous block that is bit-identical
    to the per-task scalar draws. ``edge_speed`` maps device → relative
    compute speed (heterogeneous fleets; actual compute is divided by it).
    """

    # the vectorized drivers consume DecisionBatch targets without a name list
    accepts_decision_batch = True

    def __init__(self, twin: AWSTwin, seed: int = 0,
                 pricing: LambdaPricing | None = None, edge_name: str = "edge",
                 edge_names: Sequence[str] | None = None,
                 edge_speed: dict[str, float] | None = None,
                 faults: FaultSpec | None = None):
        self.twin = twin
        self.pricing = pricing or LambdaPricing()
        # an empty spec is indistinguishable from no spec: both take exactly
        # the pre-fault code path (zero extra draws, bit-identical output)
        self.faults = faults if faults else None
        self.gt_cloud = GroundTruthCloud(twin, seed=seed)
        self.cloud_rngs = {leg: np.random.default_rng([seed, 7, i])
                           for i, leg in enumerate(CLOUD_LEGS)}
        names = tuple(edge_names) if edge_names is not None else (edge_name,)
        self.edge_names = names
        self.edge_name = names[0] if names else edge_name
        self.edge_speed = {n: float((edge_speed or {}).get(n, 1.0)) for n in names}
        self.edge_rngs = {
            n: {leg: np.random.default_rng([seed, edge_stream_key(n), i])
                for i, leg in enumerate(EDGE_LEGS)}
            for n in names}
        # per-device edge executor state (single-slot FIFO)
        self.edge_free_at = {n: 0.0 for n in names}

    @property
    def edge_free_at_actual(self) -> float:
        """Deprecated single-edge alias for ``edge_free_at[edge_name]``."""
        return self.edge_free_at[self.edge_name]

    @edge_free_at_actual.setter
    def edge_free_at_actual(self, value: float) -> None:
        self.edge_free_at[self.edge_name] = value

    def probe_cold(self, target: str, now: float) -> bool:
        return self.gt_cloud.probe(target, now)

    def execute(self, task: TaskInput, target: str, now: float) -> ExecutionOutcome:
        if target in self.edge_free_at:
            return self._execute_edge(task, now, target)
        return self._execute_cloud(task, target, now)

    def _fault_fast(self, now: float, kind: int) -> ExecutionOutcome:
        """Fail-fast outcome: nothing ran, no draws consumed, no occupancy —
        only the spec's failure-detection latency elapses."""
        d = self.faults.detect_ms
        return ExecutionOutcome(
            latency_ms=d, cost=0.0, cold=False, completion_ms=now + d,
            failed=True, fail_kind=kind)

    def _execute_cloud(self, task: TaskInput, config: str, now: float) -> ExecutionOutcome:
        f = self.faults
        if f is not None:
            # fail-fast faults consume NO draws — mirrored by execute_many
            if bool(f.outage_mask(config, now)):
                return self._fault_fast(now, OUTAGE)
            if bool(f.blackout_mask("upld", config, now)):
                return self._fault_fast(now, BLACKOUT)
        twin, rngs = self.twin, self.cloud_rngs
        upld = twin.upld_ms(task.bytes, rngs["upld"])
        trigger = now + upld
        cold = self.gt_cloud.probe(config, trigger)
        start = twin.start_ms(cold, rngs["start"])
        if f is not None and cold:
            start *= float(f.cold_factor(config, trigger))
        comp = twin.comp_cloud_ms(task.size, float(config), rngs["comp"])
        self.gt_cloud.commit(config, trigger, start + comp)
        store = twin.store_cloud_ms(rngs["store"])
        if f is not None and bool(
                f.transient_mask(config, getattr(task, "idx", -1), now)):
            # the attempt ran its upload/start/compute legs (and bills them);
            # the result was lost — no store leg, failure detected at crash
            latency = upld + start + comp
            return ExecutionOutcome(
                latency_ms=latency, cost=self.pricing.cost(comp, float(config)),
                cold=cold, completion_ms=now + latency, exec_ms=start + comp,
                failed=True, fail_kind=TRANSIENT)
        latency = upld + start + comp + store
        return ExecutionOutcome(
            latency_ms=latency,
            cost=self.pricing.cost(comp, float(config)),
            cold=cold,
            completion_ms=now + latency,
            exec_ms=start + comp,
        )

    def _execute_edge(self, task: TaskInput, now: float,
                      device: str | None = None) -> ExecutionOutcome:
        device = device if device is not None else self.edge_name
        f = self.faults
        if f is not None and bool(f.outage_mask(device, now)):
            return self._fault_fast(now, OUTAGE)  # device down: nothing ran
        twin, rngs = self.twin, self.edge_rngs[device]
        comp = twin.comp_edge_ms(task.size, rngs["comp"]) / self.edge_speed[device]
        if f is not None:
            comp *= float(f.straggler_factor(device, now))
        start_exec = max(self.edge_free_at[device], now)
        self.edge_free_at[device] = start_exec + comp
        iot = twin.iotup_ms(rngs["iot"])
        store = twin.store_edge_ms(rngs["store"])
        wait = start_exec - now
        if f is not None:
            # the compute ran (the executor WAS occupied, draws consumed) but
            # the result never made it back: iot-leg blackout or a transient
            # crash — failure detected ``detect_ms`` after compute finished
            if bool(f.blackout_mask("iot", device, now)):
                kind = BLACKOUT
            elif bool(f.transient_mask(device, getattr(task, "idx", -1), now)):
                kind = TRANSIENT
            else:
                kind = 0
            if kind:
                latency = wait + comp + f.detect_ms
                return ExecutionOutcome(
                    latency_ms=latency, cost=0.0, cold=False,
                    completion_ms=now + latency, queue_wait_ms=wait,
                    exec_ms=comp, failed=True, fail_kind=kind)
        latency = wait + comp + iot + store
        return ExecutionOutcome(
            latency_ms=latency, cost=0.0, cold=False, completion_ms=now + latency,
            queue_wait_ms=wait, exec_ms=comp,
        )

    # --------------------------------------------------- batched leg sampling
    def _scaled_sizes(self, sizes: np.ndarray) -> np.ndarray:
        if self.twin.spec.size_kind == "pixels":
            return sizes / 1e6
        return sizes / 32.0 / 1000.0

    def _cloud_leg_draws(self, cfgs: list[str], scaled: np.ndarray,
                         nbytes: np.ndarray) -> dict[str, np.ndarray]:
        """One block draw per cloud (substrate, leg) stream for ``len(cfgs)``
        dispatches in dispatch order — bit-identical to the per-task scalar
        draws (numpy Generators produce the same stream either way). Also
        draws the container-lifetime block from the ground-truth RNG and
        prices the compute (no randomness), so every number that does NOT
        depend on pool/queue state comes from here; only warm/cold selection
        and FIFO waits are left to the caller's state walk.
        """
        spec = self.twin.spec
        rngs = self.cloud_rngs
        nc = len(cfgs)
        uniq = {c: float(c) for c in set(cfgs)}
        mem = np.array([uniq[c] for c in cfgs])
        share = np.minimum(mem, FULL_VCPU_MB) / FULL_VCPU_MB  # cpu_share, vectorized
        upld = (spec.upld_base_ms + nbytes * spec.upld_ms_per_byte) \
            * rngs["upld"].lognormal(0.0, spec.upld_sigma, nc)
        zs = rngs["start"].standard_normal(nc)  # scaled per warm/cold below
        warm_start = np.maximum(spec.warm_mean + spec.warm_std * zs, 1.0)
        cold_start = np.maximum(spec.cold_mean + spec.cold_std * zs, 1.0)
        comp = (spec.c0_ms + spec.c1_ms * scaled) / share \
            * rngs["comp"].lognormal(0.0, spec.comp_sigma, nc)
        store = np.maximum(
            rngs["store"].normal(spec.store_cloud_mean, spec.store_cloud_std, nc), 1.0)
        zl = self.gt_cloud.rng.standard_normal(nc)
        t_idl = np.maximum(T_IDL_ACTUAL_MEAN_MS + T_IDL_ACTUAL_STD_MS * zl,
                           5 * 60e3)
        cost = np.empty(nc)
        for cfg, fmem in uniq.items():
            m = mem == fmem
            cost[m] = self.pricing.cost_batch(comp[m], fmem)
        return {"upld": upld, "warm_start": warm_start, "cold_start": cold_start,
                "comp": comp, "store": store, "t_idl": t_idl, "cost": cost}

    def _edge_leg_draws(self, dev: str, scaled: np.ndarray) -> dict[str, np.ndarray]:
        """One block draw per leg stream of edge device ``dev`` for its
        dispatches in dispatch order (see ``_cloud_leg_draws``)."""
        spec = self.twin.spec
        rngs = self.edge_rngs[dev]
        nd = scaled.shape[0]
        comp = (spec.e0_ms + spec.e1_ms * scaled) \
            * rngs["comp"].lognormal(0.0, spec.edge_sigma, nd) \
            / self.edge_speed[dev]
        if spec.iotup_mean > 0:  # matches iotup_ms: no draw when unmodeled
            iot = np.maximum(
                rngs["iot"].normal(spec.iotup_mean, spec.iotup_std, nd), 0.0)
        else:
            iot = np.zeros(nd)
        store = np.maximum(
            rngs["store"].normal(spec.store_edge_mean, spec.store_edge_std, nd), 1.0)
        return {"comp": comp, "iot": iot, "store": store}

    def _encode_targets(self, targets) -> tuple[np.ndarray, Sequence[str]]:
        """Integer-encode dispatch targets (device i → i, cloud → -1) and
        return ``(codes, name_of)`` where ``name_of(i)`` is dispatch ``i``'s
        target name. A columnar ``DecisionBatch`` translates through one tiny
        per-table lookup — no per-dispatch Python at all — which is what
        keeps the streaming serve's execution stage GIL-light; a plain name
        sequence takes the per-dispatch encode it always did.
        """
        devmap = {dev: i for i, dev in enumerate(self.edge_names)}
        if isinstance(targets, DecisionBatch):
            trans = np.array([devmap.get(nm, -1) for nm in targets.names],
                             dtype=np.int64)
            table = targets.names
            tcodes = targets.target_codes
            return trans[tcodes], (lambda i: table[tcodes[i]])
        codes = np.array([devmap.get(tg, -1) for tg in targets],
                         dtype=np.int64)
        return codes, (lambda i: targets[i])

    # ------------------------------------------------- vectorized ground truth
    def execute_many(self, tasks: Sequence[TaskInput],
                     targets: "Sequence[str] | DecisionBatch") -> ExecutionBatch:
        """Run one dispatch per (task, target) pair, sampling all ground-truth
        randomness in batched numpy; returns the struct-of-arrays view.

        Bit-identical to calling ``execute`` once per pair in order: every
        latency leg has its own RNG stream, and numpy Generators produce the
        same values whether ``normal``/``lognormal`` are drawn one at a time
        or as one ``size=n`` block; the arithmetic around each draw keeps the
        scalar path's operation order. Only the container pool and the
        per-device FIFO recurrences run sequentially — pure bookkeeping, no
        model math. ``targets`` may be the columnar ``DecisionBatch`` itself
        (the runtime's batched path passes it straight through — no
        per-dispatch name list is ever materialized).
        """
        n = len(tasks)
        _, nows, sizes, nbytes_all = task_arrays(tasks, "as")
        scaled = self._scaled_sizes(sizes)

        codes, name_of = self._encode_targets(targets)
        devmap = {dev: i for i, dev in enumerate(self.edge_names)}
        edge_masks = {dev: codes == i for dev, i in devmap.items()}
        ci = np.nonzero(codes == -1)[0]

        out = ExecutionBatch(
            latency_ms=np.empty(n), cost=np.zeros(n),
            cold=np.zeros(n, dtype=bool), completion_ms=np.empty(n),
            queue_wait_ms=np.zeros(n), exec_ms=np.empty(n))
        placed = 0

        # fault bookkeeping (None = the exact pre-fault path, zero overhead).
        # Faults never touch the leg streams: fail-fast dispatches are carved
        # out BEFORE the block draws (they consume nothing, exactly like the
        # scalar path returning early), and every other fault is a pure
        # function of dispatch time / the dedicated counter-based stream.
        faults = self.faults
        kind_all = np.zeros(n, dtype=np.int8) if faults is not None else None
        idx_all = task_arrays(tasks, "i")[0] if faults is not None else None

        def _rows_of(cfgs_list, cfg):
            return np.array([j for j, c in enumerate(cfgs_list) if c == cfg],
                            dtype=np.int64)

        # ---- cloud: batch the 4 normals per dispatch (upld, start, comp, store)
        nc = ci.shape[0]
        cfgs: list[str] = [name_of(i) for i in ci.tolist()] if nc else []
        if nc and faults is not None:
            cnows = nows[ci]
            skip = np.zeros(nc, dtype=bool)
            for cfg in set(cfgs):
                rows = _rows_of(cfgs, cfg)
                om = faults.outage_mask(cfg, cnows[rows])
                bm = faults.blackout_mask("upld", cfg, cnows[rows]) & ~om
                kind_all[ci[rows[om]]] = OUTAGE
                kind_all[ci[rows[bm]]] = BLACKOUT
                skip[rows] = om | bm
            if skip.any():
                gi = ci[skip]
                dms = faults.detect_ms
                out.latency_ms[gi] = dms
                out.completion_ms[gi] = nows[gi] + dms
                out.exec_ms[gi] = 0.0
                placed += int(np.count_nonzero(skip))
                keep = ~skip
                ci = ci[keep]
                cfgs = [cfgs[j] for j in np.nonzero(keep)[0].tolist()]
                nc = ci.shape[0]
        if nc:
            nbytes = nbytes_all[ci] if nbytes_all is not None \
                else np.array([tasks[i].bytes for i in ci.tolist()])
            draws = self._cloud_leg_draws(cfgs, scaled[ci], nbytes)
            upld, comp, store = draws["upld"], draws["comp"], draws["store"]
            warm_start, cold_start = draws["warm_start"], draws["cold_start"]
            t_idl = draws["t_idl"]
            # sequential container-pool walk (state only; all draws done
            # above). Probe+commit fused into one scan per dispatch — reap,
            # find the most-recently-used idle container, occupy or append —
            # run per config over parallel float lists (pools are independent
            # across configs, so grouping preserves each pool's dispatch
            # order; the lifetime draws stay in global dispatch order).
            trigger = nows[ci] + upld
            if faults is not None and faults.cold_spikes:
                # cold-start storm: spike windows scale the cold candidate
                # (judged at the trigger time, like the warm/cold probe)
                cold_start = cold_start.copy()
                for cfg in set(cfgs):
                    rows = _rows_of(cfgs, cfg)
                    cold_start[rows] *= faults.cold_factor(cfg, trigger[rows])
            trig_l = trigger.tolist()
            comp_l = comp.tolist()
            warm_l = warm_start.tolist()
            cold_l = cold_start.tolist()
            tidl_l = t_idl.tolist()
            start_l = [0.0] * nc
            was_cold = [False] * nc
            pools = self.gt_cloud.pools
            by_cfg: dict[str, list[int]] = {}
            for j, cfg in enumerate(cfgs):
                lst = by_cfg.get(cfg)
                if lst is None:
                    lst = by_cfg[cfg] = []
                lst.append(j)
            for cfg, js in by_cfg.items():
                pool = pools.setdefault(cfg, [])
                busy_l = [c.busy_until for c in pool]
                last_l = [c.last_completion for c in pool]
                exp_l = [c.expires_at for c in pool]
                for j in js:
                    t = trig_l[j]
                    best = -1
                    best_last = -1e308
                    reap = False
                    for i in range(len(busy_l)):
                        if busy_l[i] <= t:
                            if t <= exp_l[i]:
                                li = last_l[i]
                                if li > best_last:
                                    best_last = li
                                    best = i
                            else:
                                reap = True  # expired idle container
                    if reap:  # rare (27-min lifetimes): rebuild only when needed
                        nb: list[float] = []
                        nl: list[float] = []
                        ne: list[float] = []
                        best = -1
                        best_last = -1e308
                        for i in range(len(busy_l)):
                            b, li, e = busy_l[i], last_l[i], exp_l[i]
                            if b > t or t <= e:
                                if b <= t and li > best_last:
                                    best_last = li
                                    best = len(nb)
                                nb.append(b)
                                nl.append(li)
                                ne.append(e)
                        busy_l, last_l, exp_l = nb, nl, ne
                    st = warm_l[j] if best >= 0 else cold_l[j]
                    busy = st + comp_l[j]
                    completion_t = t + busy
                    expiry = completion_t + tidl_l[j]
                    if best >= 0:
                        busy_l[best] = completion_t
                        last_l[best] = completion_t
                        exp_l[best] = expiry
                    else:
                        busy_l.append(completion_t)
                        last_l.append(completion_t)
                        exp_l.append(expiry)
                        was_cold[j] = True
                    start_l[j] = st
                pools[cfg] = [GTContainer(b, li, e)
                              for b, li, e in zip(busy_l, last_l, exp_l)]
            start = np.asarray(start_l)
            latency = upld + start + comp + store
            if faults is not None:
                tmask = np.zeros(nc, dtype=bool)
                cn = nows[ci]
                for cfg in set(cfgs):
                    if faults.transient_p(cfg) <= 0.0:
                        continue
                    rows = _rows_of(cfgs, cfg)
                    tmask[rows] = faults.transient_mask(
                        cfg, idx_all[ci[rows]], cn[rows])
                if tmask.any():
                    # crashed attempts ran upload/start/compute (billed, and
                    # the container WAS occupied) but never stored a result
                    latency = latency - store * tmask
                    kind_all[ci[tmask]] = TRANSIENT
            out.latency_ms[ci] = latency
            out.cost[ci] = draws["cost"]
            out.cold[ci] = was_cold
            out.completion_ms[ci] = nows[ci] + latency
            out.exec_ms[ci] = start + comp
            placed += nc

        # ---- edge: per-device batched draws + exact FIFO recurrence
        for dev in self.edge_names:
            di = np.nonzero(edge_masks[dev])[0]
            nd = di.shape[0]
            if nd == 0:
                continue
            if faults is not None:
                om = faults.outage_mask(dev, nows[di])
                if om.any():
                    # device down: fail fast, no draws, no FIFO occupancy
                    gi = di[om]
                    dms = faults.detect_ms
                    out.latency_ms[gi] = dms
                    out.completion_ms[gi] = nows[gi] + dms
                    out.exec_ms[gi] = 0.0
                    kind_all[gi] = OUTAGE
                    placed += int(np.count_nonzero(om))
                    di = di[~om]
                    nd = di.shape[0]
                    if nd == 0:
                        continue
            edraws = self._edge_leg_draws(dev, scaled[di])
            comp, iot, store = edraws["comp"], edraws["iot"], edraws["store"]
            dev_nows = nows[di]
            if faults is not None:
                comp = comp * faults.straggler_factor(dev, dev_nows)
            start_exec, free = _fifo_starts(self.edge_free_at[dev], dev_nows, comp)
            self.edge_free_at[dev] = free
            wait = start_exec - dev_nows
            latency = wait + comp + iot + store
            if faults is not None:
                bm = faults.blackout_mask("iot", dev, dev_nows)
                tm = faults.transient_mask(dev, idx_all[di], dev_nows) & ~bm
                lost = bm | tm
                if lost.any():
                    # compute ran (FIFO occupied) but the result never made
                    # it back — detected ``detect_ms`` after compute finished
                    latency = np.where(lost, wait + comp + faults.detect_ms,
                                       latency)
                    kind_all[di[bm]] = BLACKOUT
                    kind_all[di[tm]] = TRANSIENT
            out.latency_ms[di] = latency
            out.completion_ms[di] = dev_nows + latency
            out.queue_wait_ms[di] = wait
            out.exec_ms[di] = comp
            placed += nd

        assert placed == n  # every dispatch is either a fleet device or cloud
        if faults is not None:
            out.fail_kind = kind_all
            out.failed = kind_all != 0
        return out

    # --------------------------------------------- event-driven virtual clock
    def execute_async(self, tasks: Sequence[TaskInput],
                      targets: Sequence[str],
                      races: Sequence[tuple[int, int]] | None = None,
                      ) -> ExecutionBatch:
        """The event-driven virtual-clock driver (``serve_async``'s substrate).

        Per-target workers — one ``SingleSlotWorker`` per edge device, one
        dispatcher per cloud config — interleave on one ``EventHeap``:
        arrivals route each dispatch to its worker, dispatch events occupy
        executors, completion events free them and start the next queued task.
        BIT-IDENTICAL to ``execute_many`` (and therefore to the sequential
        ``execute`` loop): every leg draw comes from the same per-(substrate,
        leg) block sampling, cloud container commits apply in dispatch order
        per config (the provider's ingest order — the heap schedules *when*
        work happens, never reorders *whose* state it touches), and the edge
        workers run the exact ``start = max(free, now)`` FIFO recurrence that
        ``fifo_starts`` evaluates as cumsums. The parity is regression-tested.

        ``races`` (hedge duplicate pairs of dispatch indices) is accepted for
        protocol compatibility: on the twin both legs always run to completion
        on the virtual clock ("drained"), and the runtime merges the race by
        earliest completion — identical to the batched hedge merge. Live
        backends may instead cancel a not-yet-started loser.
        """
        del races  # virtual legs are always drained; the runtime merges
        if self.faults is not None:
            # Faults are pure functions of dispatch time and the dedicated
            # counter-based stream, so the event interleaving cannot change
            # them — route through execute_many, which is bit-identical by
            # the same contract that covers unsorted arrivals below. This is
            # what makes the fault schedule provably path-independent.
            return self.execute_many(tasks, targets)
        n = len(tasks)
        out = ExecutionBatch(
            latency_ms=np.empty(n), cost=np.zeros(n),
            cold=np.zeros(n, dtype=bool), completion_ms=np.empty(n),
            queue_wait_ms=np.zeros(n), exec_ms=np.empty(n))
        if n == 0:
            return out
        _, nows, sizes, nbytes_all = task_arrays(tasks, "as")
        if n > 1 and not bool(np.all(np.diff(nows) >= 0.0)):
            # Out-of-order dispatch lists: the heap would replay state in
            # time order while the batched/sequential paths replay dispatch
            # order. execute_many is bit-identical to the execute loop, so
            # falling back preserves the driver's identical-results contract
            # (all shipped workloads emit sorted arrivals; hedge duplicates
            # share their primary's arrival and tie-break by dispatch order).
            return self.execute_many(tasks, targets)
        scaled = self._scaled_sizes(sizes)
        codes, name_of = self._encode_targets(targets)
        devmap = {dev: i for i, dev in enumerate(self.edge_names)}
        ci = np.nonzero(codes == -1)[0]

        # every leg draw up front, one block per stream (== execute_many)
        cloud_slot = {}
        cdraws = None
        cfgs: list[str] = []
        if ci.shape[0]:
            cfgs = [name_of(i) for i in ci.tolist()]
            nbytes = nbytes_all[ci] if nbytes_all is not None \
                else np.array([tasks[i].bytes for i in ci.tolist()])
            cdraws = self._cloud_leg_draws(cfgs, scaled[ci], nbytes)
            cloud_slot = {int(g): j for j, g in enumerate(ci.tolist())}
        edraws: dict[str, dict[str, np.ndarray]] = {}
        edge_slot: dict[int, int] = {}
        for dev in self.edge_names:
            di = np.nonzero(codes == devmap[dev])[0]
            if di.shape[0]:
                edraws[dev] = self._edge_leg_draws(dev, scaled[di])
                edge_slot.update(
                    {int(g): j for j, g in enumerate(di.tolist())})

        workers = {dev: SingleSlotWorker(free_at=self.edge_free_at[dev])
                   for dev in self.edge_names}

        def start_edge(dev: str, start: float, row: int) -> None:
            """Row occupies ``dev``'s slot at ``start``: write its outcome,
            schedule the slot-free completion."""
            j = edge_slot[row]
            d = edraws[dev]
            comp = float(d["comp"][j])
            arrival = float(nows[row])
            wait = start - arrival
            latency = wait + comp + float(d["iot"][j]) + float(d["store"][j])
            out.latency_ms[row] = latency
            out.completion_ms[row] = arrival + latency
            out.queue_wait_ms[row] = wait
            out.exec_ms[row] = comp
            heap.push(start + comp, COMPLETION, (dev, row))

        heap = EventHeap()
        for i in range(n):
            heap.push(float(nows[i]), ARRIVAL, i)
        for ev in heap.drain():
            if ev.kind == ARRIVAL:
                row = ev.payload
                code = int(codes[row])
                if code >= 0:  # edge: enter the device's FIFO
                    dev = self.edge_names[code]
                    started = workers[dev].arrive(ev.time_ms, row)
                    if started is not None:
                        heap.push(started[0], DISPATCH, (dev, row))
                else:  # cloud: containers scale out — commit at ingest
                    j = cloud_slot[row]
                    trigger = ev.time_ms + float(cdraws["upld"][j])
                    warm, cold_s = (float(cdraws["warm_start"][j]),
                                    float(cdraws["cold_start"][j]))
                    comp = float(cdraws["comp"][j])
                    cold, _ = self.gt_cloud.commit_drawn(
                        cfgs[j], trigger, warm + comp, cold_s + comp,
                        float(cdraws["t_idl"][j]))
                    start = cold_s if cold else warm
                    latency = (float(cdraws["upld"][j]) + start + comp
                               + float(cdraws["store"][j]))
                    out.latency_ms[row] = latency
                    out.cost[row] = float(cdraws["cost"][j])
                    out.cold[row] = cold
                    out.completion_ms[row] = ev.time_ms + latency
                    out.exec_ms[row] = start + comp
                    # no COMPLETION event: cloud containers scale out, so a
                    # finishing dispatch frees no worker slot and nothing
                    # downstream consumes the pop. The completion-ordered
                    # view of a run lives in RecordBatch.completion_order().
            elif ev.kind == DISPATCH:
                dev, row = ev.payload
                start_edge(dev, ev.time_ms, row)
            else:  # COMPLETION: the edge slot frees, the next queued task starts
                dev, _row = ev.payload
                nxt = workers[dev].complete(ev.time_ms)
                if nxt is not None:
                    heap.push(nxt[0], DISPATCH, (dev, nxt[1]))
        for dev, w in workers.items():
            self.edge_free_at[dev] = w.free_at
        return out


def _iter_chunks(workload, chunk_size: int):
    """Normalize any workload spelling into an iterator of task chunks.

    Sequences (``list[TaskInput]`` / ``TaskChunk``) are sliced into
    ``chunk_size`` spans; iterators of ``TaskInput`` are buffered into lists
    of ``chunk_size``; iterators of ready chunks (what ``Workload.chunks``
    yields) pass through at their producer's sizing.
    """
    if isinstance(workload, (list, tuple, TaskChunk)):
        for lo in range(0, len(workload), chunk_size):
            yield workload[lo:lo + chunk_size]
        return
    it = iter(workload)
    first = next(it, None)
    if first is None:
        return
    if isinstance(first, TaskInput):
        buf = [first]
        for t in it:
            buf.append(t)
            if len(buf) >= chunk_size:
                yield buf
                buf = []
        if buf:
            yield buf
        return
    yield first
    yield from it


def _engine_core(eng):
    """The engine's cached jax placement core, or None (never builds one)."""
    hit = eng.__dict__.get("_jax_core_cache")
    return hit[1] if hit is not None else None


def _prefetched_chunks(it, eng, counters: dict):
    """Double-buffered chunk staging for a device-backed ``serve_stream``.

    A single transfer thread pulls chunk k+1 from the workload iterator AND
    uploads its padded task arrays (``jax_core.stage_chunk`` →
    ``jax.device_put``) while the consumer runs chunk k's fixed point on
    device — overlapping workload generation and the H2D transfer with
    compute. The staged bundle is handed to ``place_chunk`` through
    ``eng._jax_staged`` (set here on the CONSUMER thread at yield time, so
    the dict is never raced) and validated by chunk identity; a chunk that
    ends up on a fallback path simply leaves its bundle to be discarded.
    ``stage_chunk`` is engine-state-free, so staging never observes a
    half-updated stream.
    """
    from concurrent.futures import ThreadPoolExecutor

    def pull():
        chunk = next(it, None)
        if chunk is None:
            return None
        staged = None
        if len(chunk):
            core = _engine_core(eng)  # appears once the first chunk compiled
            if core is not None:
                try:
                    staged = core.stage_chunk(chunk)
                except Exception:  # staging is an optimization, never fatal
                    staged = None
        return chunk, staged

    with ThreadPoolExecutor(max_workers=1) as ex:
        fut = ex.submit(pull)
        while True:
            item = fut.result()
            if item is None:
                return
            fut = ex.submit(pull)
            chunk, staged = item
            if staged is not None:
                eng.__dict__["_jax_staged"] = (chunk, staged)
                counters["prefetched"] += 1
            yield chunk


# -------------------------------------------------------------- the runtime
class PlacementRuntime:
    """ONE serve loop over any (DecisionEngine, ExecutionBackend) pair.

    Owns one predicted edge-queue horizon per fleet device. ``Simulation``
    (twin backend) and ``LivePlacementServer`` (live executor pool) are thin
    wrappers over this class.
    """

    def __init__(self, engine: DecisionEngine, backend: ExecutionBackend,
                 retry: RetryPolicy | None = None,
                 admission: AdmissionPolicy | None = None,
                 breaker: CircuitBreaker | None = None,
                 prewarm: PrewarmPolicy | None = None,
                 reclamation: ReclamationPolicy | None = None):
        self.engine = engine
        self.backend = backend
        self.stream_stats: dict | None = None  # last serve_stream aggregate
        self.edge_queues = {n: PredictedEdgeQueue() for n in engine.edge_names}
        # cloud-only runtimes keep a zeroed queue behind the deprecated
        # ``edge_queue`` alias, matching the attribute's pre-fleet existence
        self._no_edge_queue = PredictedEdgeQueue()
        # failure-aware serving (see ``repro.core.faults``). All three knobs
        # default to off, which takes EXACTLY the pre-fault serve paths; with
        # them set but nothing failing/shedding, the round-0 dispatch is the
        # identical backend call, so an empty FaultSpec stays bit-identical.
        self.retry = retry
        self.admission = admission
        self.health = TargetHealth(breaker) if breaker is not None else None
        self._failure_aware = (retry is not None or admission is not None
                               or breaker is not None)
        self._pre_horizons: dict[str, float] | None = None
        # overload survival (see ``repro.core.overload``): predictive
        # container pre-warming and/or fair-share tier reclamation. Both off
        # (the default) takes EXACTLY the pre-overload serve paths —
        # ``self.overload is None`` gates every hook.
        self.overload = (OverloadManager(prewarm, reclamation)
                         if prewarm is not None or reclamation is not None
                         else None)

    @property
    def edge_name(self) -> str:
        return self.engine.edge_name

    @property
    def edge_names(self) -> tuple[str, ...]:
        return self.engine.edge_names

    @property
    def edge_queue(self) -> PredictedEdgeQueue:
        """Deprecated single-edge alias for the first device's queue."""
        names = self.edge_names
        return self.edge_queues[names[0]] if names else self._no_edge_queue

    def serve(self, tasks: list[TaskInput], batched: bool = True) -> SimulationResult:
        """Place and execute a workload; aggregate the per-task records.

        ``batched=True`` (default) runs the columnar serve path: one
        vectorized prediction pass, the columnar decision core
        (``DecisionEngine.place_many`` → ``DecisionBatch``) and, when the
        backend implements ``execute_many``, one batched ground-truth pass
        whose outcome arrays land directly in a ``RecordBatch`` — array-native
        from prediction to result. ``batched=False`` interleaves per-task
        placement and execution. The two paths produce identical results —
        placement is non-blocking, so execution never feeds back into decision
        state; the columnar decision core is bit-identical to the per-task
        walk (speculate-and-repair, see ``repro.core.decision``); and the
        twin's batched sampler is bit-identical to its sequential one.
        """
        if batched:
            self._pre_place(tasks)
            self._snapshot_horizons()
            decisions = self.engine.place_many(tasks, edge_queues=self.edge_queues)
            records = self._execute_decisions(tasks, decisions)
            self._post_execute(records)
        else:
            # the per-task step path skips the overload hooks, exactly like
            # the failure machinery (both are columnar-batch features)
            records = [self.step(t) for t in tasks]
        return self.result(records)

    def serve_stream(self, workload, chunk_size: int = 65536,
                     keep_tasks: bool | None = None,
                     expected_tasks: int | None = None,
                     keep_inputs: bool = False,
                     array_backend: str | None = None,
                     device_residency: bool | None = None,
                     prefetch: bool | None = None) -> SimulationResult:
        """Streaming chunked serve: the columnar pipeline over arrival chunks,
        carrying every piece of sequential state across chunk boundaries.

        ``workload`` may be a task sequence (``list[TaskInput]`` or a columnar
        ``TaskChunk``, sliced into ``chunk_size`` spans), an iterator of
        tasks, or an iterator of ready chunks (``PoissonWorkload.chunks`` /
        ``BurstyWorkload.chunks`` — the constant-memory spelling). Each chunk
        runs the exact batched path of ``serve(batched=True)``:
        ``predict_batch`` → the columnar decision core → ``execute_many``,
        with outcome columns merged into a ``RecordArena``.

        BIT-IDENTICAL to one-shot ``serve(batched=True)`` for EVERY chunk
        size (including ``chunk_size=1`` and boundaries landing inside a
        speculate-and-repair segment), because all five sequential state
        carriers live outside the chunk: the CIL (on the Predictor), the
        Alg. 1 surplus bank (on the policy), the predicted edge-queue
        horizons (on this runtime), the per-(substrate, leg) RNG streams and
        the ground-truth container pool / edge FIFO horizons (on the
        backend). Numpy Generators produce the same stream drawn in one block
        or per chunk, and every recurrence is a left fold restarting from a
        scalar — so chunking changes where passes pause, never what they
        compute. The parity is hypothesis-tested per record.

        Peak memory is O(chunk_size × targets) working set plus the O(n)
        result columns — never the O(n × targets) prediction matrices of the
        one-shot path. ``keep_tasks`` controls whether per-task objects are
        retained on the result (default: only when ``workload`` is already a
        materialized list; streamed sources drop them and the result backs
        its metrics with the arena's arrival/index columns).
        ``keep_inputs=True`` retains the task size/bytes feature columns on
        the result even in constant-memory mode, so the run can be exported
        as a replayable trace (``repro.trace.capture``) without task objects.

        ``stream_stats`` afterwards reports ``{"chunks", "n", "spec_segments",
        "repairs", "walked"}`` aggregated over the stream. ``expected_tasks``
        is an optional arena-capacity hint (a known stream length skips the
        geometric-doubling overshoot — exact-size result columns).

        ``array_backend`` overrides the engine's chunk-pipeline backend for
        this stream only (``"numpy"`` / ``"jax"`` / ``"jax_interpret"`` — see
        ``DecisionEngine``): ``serve_stream(..., array_backend="jax")`` runs
        every eligible chunk device-resident through ``repro.core.jax_core``
        and falls back per chunk exactly like the engine-level setting.

        On a jax backend two stream-level optimizations engage (see the
        ``jax_core`` module docstring for the full residency model):

        - ``device_residency`` (default on when eligible) keeps the
          sequential placement state (CIL pools, surplus bank, edge
          horizons) ON DEVICE across consecutive in-order chunks — chunk
          boundaries stop being host↔device sync points; the host
          structures are materialized only at stream end, on fallback exits
          and for external readers (``jax_core.sync_engine``). Disabled
          automatically when admission control or failure-aware serving is
          configured (those read/mutate host placement state mid-stream).
        - ``prefetch`` (default on) double-buffers chunk staging: a
          transfer thread pulls chunk k+1 from the workload iterator and
          uploads its task arrays (``jax.device_put``) while chunk k's
          fixed point runs, overlapping workload generation and H2D
          transfer with device compute.

        ``stream_stats["residency"]`` afterwards reports the resident-chunk
        / sync / prefetch counters for this stream.
        """
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        if keep_tasks is None:
            keep_tasks = isinstance(workload, (list, tuple))
        eng = self.engine
        was_backend = eng.array_backend
        if array_backend is not None:
            if array_backend not in ("numpy", "jax", "jax_interpret"):
                raise ValueError(
                    f"array_backend must be 'numpy', 'jax' or "
                    f"'jax_interpret', got {array_backend!r}")
            eng.array_backend = array_backend
        arena = RecordArena(keep_tasks=keep_tasks,
                            capacity=expected_tasks or 0,
                            keep_inputs=keep_inputs)
        stats = {"chunks": 0, "n": 0, "spec_segments": 0, "repairs": 0,
                 "walked": 0}
        use_device = eng.array_backend in ("jax", "jax_interpret")
        residency = (use_device
                     and (device_residency is None or device_residency)
                     and self.admission is None and not self._failure_aware
                     and self.overload is None)
        do_prefetch = (use_device
                       and (prefetch is None or prefetch)
                       and not eng.record_decisions)
        pf = {"prefetched": 0}
        base: dict = {}
        if use_device:
            c0 = _engine_core(eng)
            if c0 is not None:
                base = {"state_syncs": c0.state_syncs,
                        "fallback_syncs": c0.fallback_syncs,
                        "resident_chunks": c0.resident_chunks,
                        "chunk_commits": c0.chunk_commits}
            if residency:
                eng.__dict__["_device_residency"] = True
        chunk_iter = _iter_chunks(workload, chunk_size)
        if do_prefetch:
            chunk_iter = _prefetched_chunks(chunk_iter, eng, pf)
        prev_last = -np.inf
        force_walk = False
        try:
            for chunk in chunk_iter:
                m = len(chunk)
                if m == 0:
                    continue
                first = float(chunk[0].arrival_ms)
                last = float(chunk[m - 1].arrival_ms)
                if first < prev_last:
                    # the stream as a whole is out of arrival order: a
                    # columnar chunk would snapshot CIL state the one-shot
                    # walk has already reaped differently — from here on,
                    # every chunk must take the per-task walk (exactly what
                    # the one-shot path does)
                    force_walk = True
                prev_last = max(prev_last, last)
                was_columnar = eng.columnar
                eng.columnar_stats = None
                try:
                    if force_walk:
                        eng.columnar = False
                    self._pre_place(chunk)
                    self._snapshot_horizons()
                    decisions = eng.place_many(
                        chunk, edge_queues=self.edge_queues)
                finally:
                    eng.columnar = was_columnar
                recs = self._execute_decisions(chunk, decisions)
                arena.append(recs)
                self._post_execute(recs)
                stats["chunks"] += 1
                stats["n"] += m
                cs = eng.columnar_stats
                if cs is not None:
                    stats["spec_segments"] += cs["chunks"]
                    stats["repairs"] += cs["repairs"]
                    stats["walked"] += cs["walked"]
                else:
                    stats["walked"] += m
        finally:
            eng.array_backend = was_backend
            if use_device:
                eng.__dict__.pop("_device_residency", None)
                eng.__dict__.pop("_jax_staged", None)
                core = _engine_core(eng)
                if core is not None:
                    core.sync_host("stream_end")
        if use_device:
            core = _engine_core(eng)
            if core is not None:
                stats["residency"] = {
                    "enabled": residency,
                    "resident_chunks": core.resident_chunks
                    - base.get("resident_chunks", 0),
                    "state_syncs": core.state_syncs
                    - base.get("state_syncs", 0),
                    "fallback_syncs": core.fallback_syncs
                    - base.get("fallback_syncs", 0),
                    "chunk_commits": core.chunk_commits
                    - base.get("chunk_commits", 0),
                    "prefetched": pf["prefetched"]}
        self.stream_stats = stats
        return self.result(arena.finish())

    def serve_async(self, tasks: list[TaskInput]) -> SimulationResult:
        """The event-driven serve: place like ``serve(batched=True)``, then
        execute through the backend's concurrent driver.

        Placement is non-blocking (decisions come from predicted state only),
        so the decision pass is exactly the batched columnar one; execution
        then fans out to per-target workers — ``TwinBackend`` interleaves
        them on the virtual-clock event heap (``repro.core.events``), a live
        backend runs them as real threads so fleet executions genuinely
        overlap. A columnar ``DecisionBatch`` stays object-free end-to-end:
        workers pull rows by ``target_codes`` and the outcome arrays merge
        straight into a ``RecordBatch``. Hedged (list) decisions become race
        events — primary and hedge legs dispatched together, first completion
        wins, the loser drained (twin) or cancelled when it never started
        (live). On ``TwinBackend`` the result is METRIC-IDENTICAL to
        ``serve(batched=True)`` — asserted in tests; backends without an
        ``execute_async`` driver serve the same plan synchronously.
        """
        self._pre_place(tasks)
        self._snapshot_horizons()
        decisions = self.engine.place_many(tasks, edge_queues=self.edge_queues)
        run = getattr(self.backend, "execute_async", None)
        reclaiming = (self.overload is not None
                      and self.overload.reclamation is not None)
        if run is None or ((self._failure_aware or reclaiming)
                           and isinstance(decisions, DecisionBatch)):
            # the failure-aware driver issues the identical dispatch rounds
            # from every serve path (the twin's async driver routes faulted
            # runs through execute_many anyway — see ``execute_async``)
            records = self._execute_decisions(tasks, decisions)
        elif isinstance(decisions, DecisionBatch):
            eb = run(tasks, decisions
                     if getattr(self.backend, "accepts_decision_batch", False)
                     else decisions.target_list())
            records = self._record_batch(tasks, decisions, eb) \
                if isinstance(eb, ExecutionBatch) \
                else [self._record(t, d, d.target, d.prediction, o)
                      for t, d, o in zip(tasks, decisions, eb)]
        else:
            records = self._race_decisions(tasks, decisions, run)
        self._post_execute(records)
        return self.result(records)

    def _race_decisions(self, tasks: list[TaskInput], decisions,
                        run) -> list[TaskRecord]:
        """Async-execute list decisions; hedge duplicates are race events."""
        d_tasks, d_targets, races = self._hedge_plan(tasks, decisions)
        eb = run(d_tasks, d_targets, races=races)
        return self._merge_hedged_outcomes(tasks, decisions, eb)

    @staticmethod
    def _hedge_plan(tasks: list[TaskInput], decisions,
                    ) -> tuple[list[TaskInput], list[str], list[tuple[int, int]]]:
        """One dispatch per execution leg, hedge duplicates right after their
        primary — the same order the sequential loop executes them in.
        ``races`` pairs each primary's dispatch index with its hedge's."""
        d_tasks: list[TaskInput] = []
        d_targets: list[str] = []
        races: list[tuple[int, int]] = []
        for t, d in zip(tasks, decisions):
            d_tasks.append(t)
            d_targets.append(d.target)
            if d.hedge_target is not None and d.hedge_target != d.target:
                races.append((len(d_tasks) - 1, len(d_tasks)))
                d_tasks.append(t)
                d_targets.append(d.hedge_target)
        return d_tasks, d_targets, races

    def _merge_hedged_outcomes(self, tasks: list[TaskInput], decisions,
                               outcomes) -> list[TaskRecord]:
        """Walk ``_hedge_plan``-ordered outcomes back into one record per
        task, resolving hedge races. ``outcomes`` is anything indexable to
        ``ExecutionOutcome``; a ``cancelled`` array (concurrent drivers)
        marks legs that never ran."""
        flags = getattr(outcomes, "cancelled", None)
        records, j = [], 0
        for t, d in zip(tasks, decisions):
            pj = j
            j += 1
            if d.hedge_target is None or d.hedge_target == d.target:
                records.append(
                    self._record(t, d, d.target, d.prediction, outcomes[pj]))
                continue
            hj = j
            j += 1
            if flags is not None and bool(flags[pj]):
                # the race resolved to the HEDGE: the primary never started —
                # the record reports the leg that actually ran (its target,
                # actuals, device occupancy), with the cancelled primary as
                # the zero-occupancy duplicate; predicted stays the
                # decision-time expectation of racing both legs
                rec = self._record(t, d, d.hedge_target, d.hedge_prediction,
                                   outcomes[hj])
                rec.predicted_latency_ms = min(d.prediction.latency_ms,
                                               d.hedge_prediction.latency_ms)
                rec.predicted_cost = d.prediction.cost + d.hedge_prediction.cost
                rec.hedged = True
                rec.hedge_target = d.target
                records.append(rec)
                continue
            rec = self._record(t, d, d.target, d.prediction, outcomes[pj])
            cancelled = flags is not None and bool(flags[hj])
            records.append(self._merge_hedge(rec, t, d, outcomes[hj],
                                             cancelled=cancelled))
        return records

    def step(self, task: TaskInput) -> TaskRecord:
        """Place and execute one task (the per-task serve path)."""
        now = task.arrival_ms
        waits = {n: q.wait_ms(now) for n, q in self.edge_queues.items()}
        d = self.engine.place(task, now, edge_waits=waits)
        if d.target in self.edge_queues:
            self.edge_queues[d.target].push(now, d.prediction.comp_ms)
        if d.hedge_target is not None and d.hedge_target in self.edge_queues \
                and d.hedge_prediction is not None:
            self.edge_queues[d.hedge_target].push(now, d.hedge_prediction.comp_ms)
        return self._run_decision(task, d)

    def result(self, records: "RecordBatch | list[TaskRecord]") -> SimulationResult:
        cons = self.engine.policy.constraints()
        names = self.edge_names
        return SimulationResult(records=records, deadline_ms=cons.deadline_ms,
                                c_max=cons.c_max,
                                edge_name=names[0] if names else self.engine.edge_name,
                                edge_names=names or None)

    # ------------------------------------------------------------------
    def _execute_decisions(self, tasks: list[TaskInput], decisions,
                           ) -> "RecordBatch | list[TaskRecord]":
        """Execute a placed workload; vectorized when the backend supports it.

        A columnar ``DecisionBatch`` against a vectorized backend never leaves
        array land: decisions flow into ``execute_many`` and the outcome
        arrays zip straight into a ``RecordBatch`` — no ``PlacementDecision``,
        ``ExecutionOutcome`` or ``TaskRecord`` objects anywhere on the path.
        List decisions (hedged/custom policies, per-task backends) take the
        per-record path unchanged.
        """
        if isinstance(decisions, DecisionBatch):
            if self._failure_aware or (self.overload is not None and
                                       self.overload.reclamation is not None):
                return self._execute_failure_aware(tasks, decisions)
            if hasattr(self.backend, "execute_many"):
                eb = self.backend.execute_many(
                    tasks, decisions
                    if getattr(self.backend, "accepts_decision_batch", False)
                    else decisions.target_list())
                if isinstance(eb, ExecutionBatch):
                    return self._record_batch(tasks, decisions, eb)
                return [self._record(t, d, d.target, d.prediction, o)
                        for t, d, o in zip(tasks, decisions, eb)]
            # per-task backend: iterate the lazy decision views
            return [self._run_decision(t, d) for t, d in zip(tasks, decisions)]
        if not hasattr(self.backend, "execute_many"):
            return [self._run_decision(t, d) for t, d in zip(tasks, decisions)]
        d_tasks, d_targets, _ = self._hedge_plan(tasks, decisions)
        outcomes = self.backend.execute_many(d_tasks, d_targets)
        return self._merge_hedged_outcomes(tasks, decisions, outcomes)

    # ------------------------------------------------- overload survival
    def _pre_place(self, tasks) -> None:
        """Predictive pre-warming hook, called right before each placement
        pass (per chunk on the streaming path): feed the chunk's arrival
        gaps to the burst forecaster and spawn warm containers for every
        trigger it fires. Runs BEFORE ``place_many`` so the prewarmed pool
        is visible to the Predictor's warm/cold split for every row whose
        arrival falls inside a keep-alive window (earlier rows see the
        container as still spinning up — ``busy_until`` in the future — and
        are unaffected, so spawn position inside the batch doesn't matter).
        No-op unless pre-warming is armed."""
        ov = self.overload
        if ov is None or ov.prewarm is None or len(tasks) == 0:
            return
        _, arrivals, _, _ = task_arrays(tasks, "a")
        ov.reap_prewarms(float(arrivals[0]))
        for t in ov.feed_arrivals(arrivals):
            self._spawn_prewarm(t)

    def _spawn_prewarm(self, trigger_ms: float) -> None:
        """Spawn ``count`` keep-alive containers per target for one burst
        trigger: CIL record (client-side shadow), ground-truth spinup (twin
        backends), and the idle-retainer debit from the Alg. 1 surplus bank
        — billed exactly once per container, at spawn. Keep-alive extensions
        (``_post_execute``) ride the same retainer and are not re-billed."""
        ov = self.overload
        pw = ov.prewarm
        eng = self.engine
        predictor = eng.predictor
        targets = pw.targets if pw.targets is not None \
            else tuple(t.name for t in predictor.cloud_targets)
        spin = pw.spinup_ms
        if spin is None:
            spec = getattr(getattr(self.backend, "twin", None), "spec", None)
            spin = float(spec.cold_mean) if spec is not None else 250.0
        ready = trigger_ms + spin
        expires = ready + pw.keepalive_ms
        pol = eng.policy
        gt = getattr(self.backend, "gt_cloud", None)
        pricing = getattr(self.backend, "pricing", None)
        for nm in targets:
            cost = 0.0
            if pricing is not None:
                try:
                    # the retainer: billed occupancy over spinup + keep-alive
                    cost = float(pricing.cost(spin + pw.keepalive_ms,
                                              float(nm)))
                except (TypeError, ValueError):
                    cost = 0.0  # non-numeric config names price as free
            for _ in range(pw.count):
                rec = predictor.prewarm(nm, ready, expires)
                if gt is not None:
                    gt.spinup(nm, ready, expires)
                if hasattr(pol, "surplus"):
                    pol.surplus -= cost
                ov.record_spawn(trigger_ms, nm, ready, expires, cost, rec)

    def _post_execute(self, records) -> None:
        """Completion-stream keep-alive hook, called after each execution
        round: while the forecaster still sees the burst regime, push the
        keep-alive expiry of every still-unused prewarmed container out to
        (latest completion + keepalive_ms). Unbilled — the spawn-time
        retainer covers extensions (documented pricing simplification)."""
        ov = self.overload
        if ov is None or ov.prewarm is None or not ov.active_prewarms:
            return
        fc = ov.forecaster
        if fc is None or not fc.in_burst:
            return
        comp = records.completion_ms if isinstance(records, RecordBatch) \
            else np.array([r.completion_ms for r in records])
        if comp.size == 0:
            return
        new_exp = float(np.max(comp)) + ov.prewarm.keepalive_ms
        gt = getattr(self.backend, "gt_cloud", None)
        t_idl = self.engine.predictor.cil.t_idl_ms
        for e in ov.active_prewarms:
            if new_exp <= e.expires_ms:
                continue
            if e.cil_rec.busy_until != e.ready_ms:
                continue  # reused: the normal lifecycle owns it now
            e.cil_rec.last_completion = new_exp - t_idl
            if gt is not None:
                gt.extend_keepalive(e.target, e.ready_ms, e.expires_ms,
                                    new_exp)
            e.expires_ms = new_exp
            ov.n_extensions += 1

    # ------------------------------------------------- failure-aware serving
    def _snapshot_horizons(self) -> None:
        """Snapshot the predicted edge horizons right before ``place_many``
        so an admission shed (or a reclamation preemption) can unwind the
        queue pushes its placements made (``_rollback_shed``). No-op unless
        admission control or reclamation is configured."""
        if self.admission is not None or (
                self.overload is not None
                and self.overload.reclamation is not None):
            self._pre_horizons = {
                n: q.horizon_ms for n, q in self.edge_queues.items()}

    def _rollback_shed(self, tasks, d: DecisionBatch, shed: np.ndarray) -> None:
        """Unwind the decision-state side effects of shed placements.

        Surplus bank: the policy's ``observe`` banked ``c_max - cost`` for
        every placement; shed rows never execute, so their contributions are
        removed. Predicted edge horizons: restored to the pre-placement
        snapshot, then the SURVIVING edge pushes are replayed in arrival
        order — exactly the horizons a placement pass over the surviving set
        would have left. CIL reservations of shed rows are left to expire
        (conservative: the predictor may see phantom warmth for one idle
        window; a reservation never makes a later prediction worse than the
        truth by more than a warm/cold misjudgement).
        """
        pol = self.engine.policy
        if hasattr(pol, "surplus") and hasattr(pol, "c_max"):
            pol.surplus -= float(np.sum(pol.c_max - d.cost[shed]))
        if self._pre_horizons is None:
            return
        _, nows, _, _ = task_arrays(tasks, "a")
        for name, q in self.edge_queues.items():
            if name in self._pre_horizons:
                q.horizon_ms = self._pre_horizons[name]
        codes = d.target_codes
        replay = np.nonzero(~shed & (codes >= d.n_cloud))[0]
        for i in replay.tolist():
            q = self.edge_queues.get(d.names[int(codes[i])])
            if q is not None:
                q.push(float(nows[i]), float(d.comp_ms[i]))

    def _failover_place(self, task: TaskInput, now: float,
                        tried: set) -> "tuple[str, Prediction] | None":
        """Re-place a failed task at failure-detection time ``now``: re-enter
        the prediction pass against live CIL/queue state, mask the targets
        already tried plus any open circuits, and let the policy choose among
        the survivors (``failover_choice``). Applies the same decision-state
        accounting a placement does — surplus billed for the extra leg (the
        hedge precedent: an extra execution leg debits the bank), CIL
        reservation, predicted edge-queue push. Returns ``None`` when no
        surviving target remains."""
        eng = self.engine
        waits = {n: q.wait_ms(now) for n, q in self.edge_queues.items()}
        preds = eng.predictor.predict(task, now, edge_waits=waits)
        exclude = set(tried)
        h = self.health
        if h is not None:
            for nm in preds:
                if nm not in exclude and h.would_fail_fast(nm, now):
                    exclude.add(nm)
        choice = failover_choice(eng.policy, preds, exclude,
                                 self.edge_names, waits)
        if choice is None:
            return None
        name, pred = choice
        pol = eng.policy
        if hasattr(pol, "surplus"):
            pol.surplus -= pred.cost
        eng.predictor.update_cil(name, now, pred)
        if name in self.edge_queues:
            self.edge_queues[name].push(now, pred.comp_ms)
        return name, pred

    def _dispatch_rows(self, sub_tasks, targets) -> ExecutionBatch:
        """One dispatch round against the backend, normalized to columns.
        ``targets`` is whatever the backend's batched driver eats (a target
        list, or the full ``DecisionBatch`` on the round-0 fast path);
        per-task backends run the same round as sequential ``execute`` calls
        — the retry/timeout contract is identical either way."""
        em = getattr(self.backend, "execute_many", None)
        if em is not None:
            eb = em(sub_tasks, targets)
            if isinstance(eb, ExecutionBatch):
                return eb
            outs = list(eb)
        else:
            tl = targets if isinstance(targets, list) else targets.target_list()
            outs = [self.backend.execute(t, tg, t.arrival_ms)
                    for t, tg in zip(sub_tasks, tl)]
        return ExecutionBatch(
            latency_ms=np.array([o.latency_ms for o in outs]),
            cost=np.array([o.cost for o in outs]),
            cold=np.array([o.cold for o in outs], dtype=bool),
            completion_ms=np.array([o.completion_ms for o in outs]),
            queue_wait_ms=np.array([o.queue_wait_ms for o in outs]),
            exec_ms=np.array([o.exec_ms for o in outs]),
            failed=np.array([getattr(o, "failed", False) for o in outs],
                            dtype=bool),
            fail_kind=np.array([getattr(o, "fail_kind", 0) for o in outs],
                               dtype=np.int64))

    @staticmethod
    def _after_failure(pending: list, i: int, task: TaskInput, nm: str,
                       tf: float, attempts: int, tried: set, arrival: float,
                       kind: int, rp: RetryPolicy,
                       f_fail, f_comp, f_lat) -> None:
        """Route one failed dispatch: transient failures retry the SAME
        target after exponential backoff; fail-fast kinds (outage, blackout,
        breaker) fail over immediately at detection time; attempts exhausted
        or the failure detected past the timeout → permanent failure (the
        record keeps every attempted leg's cost, latency = give-up time)."""
        if attempts < rp.max_attempts and tf - arrival < rp.timeout_ms:
            if kind == TRANSIENT:
                pending.append([i, task, nm, tf + rp.backoff_for(attempts),
                                attempts, tried, arrival])
                return
            if rp.failover:
                pending.append([i, task, None, tf, attempts, tried, arrival])
                return
        f_fail[i] = True
        f_comp[i] = tf
        f_lat[i] = tf - arrival

    def _execute_failure_aware(self, tasks, d: DecisionBatch) -> RecordBatch:
        """The failure-aware batched driver: admission shed → round-0
        dispatch → retry / failover rounds, all on the virtual clock.

        Round 0 with nothing shed and no open circuit is the IDENTICAL
        backend call the plain batched path makes (the whole task container
        and ``DecisionBatch`` go straight to ``execute_many``), so an empty
        ``FaultSpec`` stays bit-identical per record with retry / admission /
        breaker configured. Every serve path (one-shot, streaming chunks,
        event-driven) funnels through this one driver, so the fault
        schedule, retry times, failover placements and shed set are
        identical across paths at a fixed chunking.

        Breaker health is evaluated against state as of the start of the
        batch and advanced in dispatch order within it — at round
        granularity, deterministically. Pending retries sort by (dispatch
        time, row) each round; failover placements resolve in that order
        against live CIL / queue state.
        """
        n = len(d)
        rp = self.retry if self.retry is not None else RetryPolicy()
        tiers = task_tiers(tasks)
        _, arrivals, _, _ = task_arrays(tasks, "a")
        names = d.names
        code_of = {nm: c for c, nm in enumerate(names)}
        codes = d.target_codes

        # --- SLO-tiered admission: shed sheddable rows whose predicted
        # latency blows the tier budget, then unwind their placement state
        shed = np.zeros(n, dtype=bool)
        if self.admission is not None:
            shed = self.admission.shed_mask(tiers, d.latency_ms)

        # --- fair-share reclamation (see ``repro.core.overload``): when a
        # device's tier-0 predictions blow their deadline headroom, preempt
        # lower-tier rows already placed on it. Shed and victim placements
        # unwind in ONE combined rollback (victims are always edge rows, so
        # no CIL state is involved), then each victim re-places at its own
        # arrival time with its device masked (``_replace_victims``).
        recl = self.overload.reclamation if self.overload is not None else None
        downgraded = np.zeros(n, dtype=bool)
        pred_lat, pred_cost, pred_cold = d.latency_ms, d.cost, d.cold
        moved_any = False
        victims = np.zeros(0, dtype=np.int64)
        if recl is not None:
            tiers = np.asarray(tiers, dtype=np.int64).copy()
            victims = select_victims(
                recl, codes=codes, tier=tiers, latency_ms=d.latency_ms,
                comp_ms=d.comp_ms, active=~shed, n_cloud=d.n_cloud,
                n_targets=len(names))
        vict = np.zeros(n, dtype=bool)
        vict[victims] = True
        rollback = shed | vict
        if rollback.any():
            self._rollback_shed(tasks, d, rollback)
        if victims.size:
            codes = codes.copy()
            pred_lat = pred_lat.copy()
            pred_cost = pred_cost.copy()
            pred_cold = pred_cold.copy()
            comp = d.comp_ms.astype(np.float64, copy=True)
            moved_any = self._replace_victims(
                tasks, d, victims, recl, codes, tiers, downgraded,
                pred_lat, pred_cost, pred_cold, comp, arrivals)
            # exactness: a victim push appended after the survivor replay
            # escapes the max(horizon, t) drain-resets its in-order push
            # was subject to, so rebuild the horizons with one event-ordered
            # replay of the FINAL assignment — bit-identical to a fresh
            # placement pass over it.
            self._replay_final_pushes(d, shed, codes, comp, arrivals)

        # final per-row outcome columns; shed rows keep the zeroed defaults
        # (bill nothing, complete at arrival, zero attempts)
        f_lat = np.zeros(n)
        f_cost = np.zeros(n)
        f_cold = np.zeros(n, dtype=bool)
        f_comp = np.asarray(arrivals, dtype=np.float64).copy()
        f_qw = np.zeros(n)
        f_ex = np.zeros(n)
        f_code = codes.astype(np.int64, copy=True)
        f_att = np.zeros(n, dtype=np.int64)
        f_fail = np.zeros(n, dtype=bool)

        # --- circuit breaker: dispatches to open targets fail fast at
        # arrival (no draws, no occupancy) and go straight to failover
        health = self.health
        pending: list[list] = []  # [row, task, target|None, t, attempts, tried, arrival]
        blocked = np.zeros(n, dtype=bool)
        if health is not None and health.any_open():
            for i in range(n):
                if shed[i]:
                    continue
                nm = names[int(codes[i])]
                if health.is_open(nm, float(arrivals[i])):
                    blocked[i] = True
                    t0 = float(arrivals[i])
                    if rp.failover:
                        pending.append([i, tasks[i], None, t0, 0, {nm}, t0])
                    else:
                        f_fail[i] = True

        # --- round 0: the surviving placements, dispatched exactly like the
        # plain batched path (full batch = the identical backend call)
        skip = shed | blocked
        live = np.nonzero(~skip)[0]
        eb = None
        if live.size == n and not moved_any:
            eb = self._dispatch_rows(
                tasks, d
                if getattr(self.backend, "accepts_decision_batch", False)
                else d.target_list())
        elif live.size == n:
            # a victim moved off its device: same full-batch dispatch, but
            # through the revised target list (d's codes are stale)
            eb = self._dispatch_rows(
                tasks, [names[int(c)] for c in codes.tolist()])
        elif live.size:
            sub_tasks = [tasks[int(i)] for i in live]
            sub_targets = [names[int(codes[i])] for i in live]
            eb = self._dispatch_rows(sub_tasks, sub_targets)
        if eb is not None:
            f_lat[live] = eb.latency_ms
            f_cost[live] = eb.cost
            f_cold[live] = eb.cold
            f_comp[live] = eb.completion_ms
            f_qw[live] = eb.queue_wait_ms
            f_ex[live] = eb.exec_ms
            f_att[live] = 1

        fmask = eb.failed if eb is not None else None
        any_failed = fmask is not None and bool(fmask.any())
        if eb is not None and (any_failed
                               or (health is not None and health.dirty())):
            # walk round-0 outcomes in dispatch order: health bookkeeping +
            # retry/failover scheduling for the failed rows
            kinds = eb.fail_kind
            for j, i in enumerate(live.tolist()):
                nm = names[int(codes[i])]
                if fmask is not None and fmask[j]:
                    tf = float(eb.completion_ms[j])
                    if health is not None:
                        health.record_failure(nm, tf)
                    kind = int(kinds[j]) if kinds is not None else TRANSIENT
                    self._after_failure(pending, i, tasks[i], nm, tf, 1,
                                        {nm}, float(arrivals[i]), kind, rp,
                                        f_fail, f_comp, f_lat)
                elif health is not None:
                    health.record_success(nm)

        # --- retry / failover rounds (bounded by rp.max_attempts)
        while pending:
            pending.sort(key=lambda p: (p[3], p[0]))
            ready = []
            for p in pending:
                if p[2] is None:
                    choice = self._failover_place(p[1], p[3], p[5])
                    if choice is None:
                        f_fail[p[0]] = True
                        f_comp[p[0]] = p[3]
                        f_lat[p[0]] = p[3] - p[6]
                        continue
                    p[2] = choice[0]
                ready.append(p)
            if not ready:
                break
            sub_tasks = [TaskInput(idx=p[1].idx, arrival_ms=p[3],
                                   size=p[1].size, bytes=p[1].bytes,
                                   tier=getattr(p[1], "tier", 0))
                         for p in ready]
            reb = self._dispatch_rows(sub_tasks, [p[2] for p in ready])
            pending = []
            for j, p in enumerate(ready):
                i, nm = p[0], p[2]
                p[5].add(nm)
                p[4] += 1
                f_att[i] += 1
                f_cost[i] += float(reb.cost[j])
                f_ex[i] += float(reb.exec_ms[j])
                failed = bool(reb.failed[j]) if reb.failed is not None else False
                if not failed:
                    if health is not None:
                        health.record_success(nm)
                    f_fail[i] = False
                    f_code[i] = code_of.get(nm, f_code[i])
                    f_cold[i] = bool(reb.cold[j])
                    f_comp[i] = float(reb.completion_ms[j])
                    f_lat[i] = f_comp[i] - p[6]
                    f_qw[i] = float(reb.queue_wait_ms[j])
                    continue
                tf = float(reb.completion_ms[j])
                if health is not None:
                    health.record_failure(nm, tf)
                kind = int(reb.fail_kind[j]) if reb.fail_kind is not None \
                    else TRANSIENT
                self._after_failure(pending, i, p[1], nm, tf, p[4], p[5],
                                    p[6], kind, rp, f_fail, f_comp, f_lat)

        return RecordBatch(
            tasks=tasks,
            target_codes=f_code,
            target_names=names,
            predicted_latency_ms=pred_lat,
            predicted_cost=pred_cost,
            actual_latency_ms=f_lat,
            actual_cost=f_cost,
            predicted_cold=pred_cold,
            actual_cold=f_cold,
            allowed_cost=d.allowed_cost,
            feasible=d.feasible,
            completion_ms=f_comp,
            hedged=np.zeros(n, dtype=bool),
            queue_wait_ms=f_qw,
            exec_ms=f_ex,
            hedge_codes=np.full(n, -1, dtype=np.int64),
            hedge_exec_ms=np.zeros(n),
            task_idx=d.task_idx,
            shed=shed,
            failed=f_fail,
            attempts=f_att,
            tier=tiers,
            downgraded=downgraded,
        )

    def _replace_victims(self, tasks, d: DecisionBatch, victims: np.ndarray,
                         recl: ReclamationPolicy, codes: np.ndarray,
                         tiers: np.ndarray, downgraded: np.ndarray,
                         pred_lat: np.ndarray, pred_cost: np.ndarray,
                         pred_cold: np.ndarray, comp: np.ndarray,
                         arrivals) -> bool:
        """Re-place reclamation victims at their own arrival times, oldest
        first (PREEMPT events on the virtual-clock heap — ordered after any
        same-instant arrival), through the same masked ``failover_choice``
        path failovers use. Accounting is observe-style, NOT the failover
        debit: a victim executes exactly once, so its new placement banks
        ``c_max − cost`` exactly as a fresh placement would — the combined
        rollback already removed the old contribution, so surplus state ends
        exactly re-debited. A victim with every alternative excluded is kept
        in place (its original placement re-applied verbatim) and demoted
        one SLO class unconditionally — the platform owes it nothing at its
        old class; a moved victim is demoted only when the new placement
        blows its old tier's deadline headroom. Returns True when any
        victim actually moved (the round-0 fast path must then rebuild its
        target list). Mutates ``codes`` / ``tiers`` / ``downgraded`` /
        ``pred_*`` in place and appends to the manager's ``reclaim_log``."""
        eng = self.engine
        pol = eng.policy
        names = d.names
        code_of = {nm: c for c, nm in enumerate(names)}
        health = self.health
        ov = self.overload
        nt = len(recl.tiers)
        banks = hasattr(pol, "surplus") and hasattr(pol, "c_max")
        heap = EventHeap()
        for i in victims.tolist():
            heap.push(float(arrivals[i]), PREEMPT, i)
        moved_any = False
        for ev in heap.drain():
            i = ev.payload
            t0 = ev.time_ms
            src = names[int(codes[i])]
            old_tier = int(tiers[i])
            waits = {nm: q.wait_ms(t0) for nm, q in self.edge_queues.items()}
            preds = eng.predictor.predict(tasks[i], t0, edge_waits=waits)
            exclude = {src}
            if health is not None:
                for nm in preds:
                    if nm not in exclude and health.would_fail_fast(nm, t0):
                        exclude.add(nm)
            choice = failover_choice(pol, preds, exclude, self.edge_names,
                                     waits)
            if choice is not None:
                nm, pred = choice
                if banks:
                    pol.surplus += pol.c_max - pred.cost
                eng.predictor.update_cil(nm, t0, pred)
                if nm in self.edge_queues:
                    self.edge_queues[nm].push(t0, pred.comp_ms)
                codes[i] = code_of.get(nm, codes[i])
                pred_lat[i] = pred.latency_ms
                pred_cost[i] = pred.cost
                pred_cold[i] = pred.cold
                comp[i] = pred.comp_ms
                moved = True
                moved_any = True
                demote = pred.latency_ms \
                    > recl.deadline_of(old_tier) * recl.headroom
            else:
                if banks:
                    pol.surplus += pol.c_max - float(d.cost[i])
                if src in self.edge_queues:
                    self.edge_queues[src].push(t0, float(d.comp_ms[i]))
                nm = src
                moved = False
                demote = True
            if demote:
                tiers[i] = min(old_tier + 1, nt - 1)
            downgraded[i] = tiers[i] != old_tier
            ov.reclaim_log.append(
                (t0, int(d.task_idx[i]), src, nm, old_tier, int(tiers[i]),
                 moved, bool(downgraded[i])))
        return moved_any

    def _replay_final_pushes(self, d: DecisionBatch, shed: np.ndarray,
                             codes: np.ndarray, comp: np.ndarray,
                             arrivals) -> None:
        """Reset the predicted edge horizons to the pre-placement snapshot
        and replay the final assignment's edge pushes in arrival order —
        the horizons a single fresh placement pass over the post-reclamation
        assignment would have left. (The intermediate per-victim pushes in
        ``_replace_victims`` only shape the waits later victims predict
        against; this pass owns the state that crosses into the next chunk.)
        """
        if self._pre_horizons is None:
            return
        for name, q in self.edge_queues.items():
            if name in self._pre_horizons:
                q.horizon_ms = self._pre_horizons[name]
        replay = np.nonzero(~shed & (codes >= d.n_cloud))[0]
        for i in replay.tolist():
            q = self.edge_queues.get(d.names[int(codes[i])])
            if q is not None:
                q.push(float(arrivals[i]), float(comp[i]))

    def _record_batch(self, tasks: list[TaskInput], d: DecisionBatch,
                      eb: ExecutionBatch) -> RecordBatch:
        """Zip decision and outcome arrays into the columnar record store."""
        n = len(d)
        return RecordBatch(
            tasks=tasks,
            target_codes=d.target_codes,
            target_names=d.names,
            predicted_latency_ms=d.latency_ms,
            predicted_cost=d.cost,
            actual_latency_ms=eb.latency_ms,
            actual_cost=eb.cost,
            predicted_cold=d.cold,
            actual_cold=eb.cold,
            allowed_cost=d.allowed_cost,
            feasible=d.feasible,
            completion_ms=eb.completion_ms,
            hedged=np.zeros(n, dtype=bool),  # columnar policies never hedge
            queue_wait_ms=eb.queue_wait_ms,
            exec_ms=eb.exec_ms,
            hedge_codes=np.full(n, -1, dtype=np.int64),
            hedge_exec_ms=np.zeros(n),
            task_idx=d.task_idx,
            failed=eb.failed,
            tier=tasks.tier if isinstance(tasks, TaskChunk)
            else task_tiers(tasks),
        )

    def _run_decision(self, task: TaskInput, d: PlacementDecision) -> TaskRecord:
        now = task.arrival_ms
        rec = self._record(task, d, d.target, d.prediction,
                           self.backend.execute(task, d.target, now))
        # Hedged duplicate (beyond-paper): first completion wins, both billed.
        if d.hedge_target is not None and d.hedge_target != d.target:
            dup = self.backend.execute(task, d.hedge_target, now)
            rec = self._merge_hedge(rec, task, d, dup)
        return rec

    def _merge_hedge(self, rec: TaskRecord, task: TaskInput,
                     d: PlacementDecision, dup: ExecutionOutcome,
                     cancelled: bool = False) -> TaskRecord:
        """Resolve a hedge race: first completion wins, both legs billed.

        ``cancelled`` marks a duplicate a concurrent driver cancelled before
        it ever started (live only): it ran nowhere and bills nothing, so the
        primary's actuals stand alone — the *predicted* merge still reflects
        the decision-time expectation of racing both legs.

        Failed legs (fault injection) never win the race: a crashed primary
        falls to a surviving duplicate — the record reports the duplicate's
        target and actuals with the primary as the hedge leg — and a crashed
        duplicate leaves the primary standing; either way BOTH legs bill
        what they actually ran. Both crashed → a failed record on the
        primary, its failure-detection time as completion.
        """
        backup = d.hedge_prediction
        p_failed = rec.failed
        h_failed = (not cancelled) and bool(getattr(dup, "failed", False))
        p_lat = min(rec.predicted_latency_ms, backup.latency_ms)
        p_cost = rec.predicted_cost + backup.cost
        both_cost = rec.actual_cost + (0.0 if cancelled else dup.cost)
        if p_failed and not h_failed and not cancelled:
            # race resolved to the surviving duplicate
            return TaskRecord(
                task=task, target=d.hedge_target,
                predicted_latency_ms=p_lat, predicted_cost=p_cost,
                actual_latency_ms=dup.latency_ms, actual_cost=both_cost,
                predicted_cold=rec.predicted_cold, actual_cold=dup.cold,
                allowed_cost=rec.allowed_cost, feasible=rec.feasible,
                completion_ms=dup.completion_ms, hedged=True,
                queue_wait_ms=dup.queue_wait_ms, exec_ms=dup.exec_ms,
                hedge_target=rec.target, hedge_exec_ms=rec.exec_ms,
                tier=rec.tier,
            )
        alive = not p_failed and not h_failed and not cancelled
        return TaskRecord(
            task=task, target=rec.target,
            predicted_latency_ms=p_lat,
            predicted_cost=p_cost,
            actual_latency_ms=min(rec.actual_latency_ms, dup.latency_ms)
            if alive else rec.actual_latency_ms,
            actual_cost=both_cost,
            predicted_cold=rec.predicted_cold, actual_cold=rec.actual_cold,
            allowed_cost=rec.allowed_cost, feasible=rec.feasible,
            completion_ms=min(rec.completion_ms, dup.completion_ms)
            if alive else rec.completion_ms, hedged=True,
            queue_wait_ms=rec.queue_wait_ms, exec_ms=rec.exec_ms,
            hedge_target=d.hedge_target,
            hedge_exec_ms=0.0 if cancelled else dup.exec_ms,
            failed=p_failed and (cancelled or h_failed),
            tier=rec.tier,
        )

    def _record(self, task: TaskInput, d: PlacementDecision, target: str,
                pred: Prediction, out: ExecutionOutcome) -> TaskRecord:
        return TaskRecord(
            task=task, target=target,
            predicted_latency_ms=pred.latency_ms, predicted_cost=pred.cost,
            actual_latency_ms=out.latency_ms, actual_cost=out.cost,
            predicted_cold=pred.cold, actual_cold=out.cold,
            allowed_cost=d.allowed_cost, feasible=d.feasible,
            completion_ms=out.completion_ms,
            queue_wait_ms=out.queue_wait_ms, exec_ms=out.exec_ms,
            failed=bool(getattr(out, "failed", False)),
            tier=getattr(task, "tier", 0),
        )
