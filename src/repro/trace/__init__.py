"""Versioned traffic traces: ingestion, bit-exact replay, and capture.

The bridge between recorded traffic and the simulator: load a trace
(JSONL or NPZ, validated with the offending record named), replay it through
``PlacementRuntime.serve_stream`` bit-identically to an in-memory workload,
and capture any served run back out as a trace — round-trip exact. The
what-if capacity planner (``repro.planner``) replays these traces against
candidate fleet/policy configurations.
"""

from repro.trace.format import (
    TRACE_SCHEMA,
    TRACE_SCHEMA_VERSION,
    Trace,
    TraceError,
    load,
    load_jsonl,
    load_npz,
    merge,
)
from repro.trace.replay import (
    TraceChunkFactory,
    TraceWorkload,
    capture,
    capture_sharded,
    fault_spec_of,
    trace_shards,
)

__all__ = [
    "TRACE_SCHEMA",
    "TRACE_SCHEMA_VERSION",
    "Trace",
    "TraceError",
    "TraceChunkFactory",
    "TraceWorkload",
    "capture",
    "capture_sharded",
    "fault_spec_of",
    "load",
    "load_jsonl",
    "load_npz",
    "merge",
    "trace_shards",
]
