"""Replaying traces through the serve paths, and capturing runs back out.

``TraceWorkload`` is the replay side: it wears the same ``generate`` /
``chunks`` interface as ``PoissonWorkload``/``BurstyWorkload``, but instead of
drawing arrivals it slices the trace's own float64 columns into ``TaskChunk``
views. No value is recomputed, re-parsed, or re-sampled on the way in — the
chunks ARE the trace arrays — so replaying a trace through ``serve_stream`` is
bit-identical to serving the equivalent in-memory task list, at every chunk
size (the existing streaming-parity guarantee does the rest: all sequential
state lives outside the chunk).

``capture`` is the inverse: any served ``SimulationResult`` (or raw
``RecordBatch``) back out as a ``Trace``, observed latencies included. A
captured trace replays to the same records, and capture∘replay is exact —
the round trip the planner's what-if search rests on. ``capture_sharded`` /
``trace_shards`` extend both directions across multi-app runs: a multi-app
trace splits per app (deterministic, order-preserving) into ``AppShard``s for
``ShardedRuntime``, and a sharded run merges back into one multi-app trace.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterator, Mapping

import numpy as np

from repro.core.faults import FaultSpec
from repro.core.multiapp import AppShard, ShardedResult
from repro.core.records import RecordBatch, SimulationResult
from repro.core.runtime import PlacementRuntime
from repro.core.workload import TaskChunk, TaskInput
from repro.trace.format import Trace, TraceError, merge


@dataclass(eq=False)
class TraceWorkload:
    """A recorded trace wearing the workload interface (replay source).

    ``chunks()`` yields ``TaskChunk`` *views* over the trace's columns —
    zero-copy, and trivially bit-identical to ``generate()``'s task list, so
    every parity property the synthetic workloads enjoy transfers to replay.
    Multi-app traces replay fine through a single runtime (one app's models
    serve all records); use ``trace_shards`` to route each app to its own
    runtime instead.
    """

    trace: Trace

    @property
    def n(self) -> int:
        return self.trace.n

    def __len__(self) -> int:
        return self.trace.n

    def _clip(self, n: int | None) -> int:
        if n is None:
            return self.trace.n
        if n > self.trace.n:
            raise TraceError(
                f"replay of {n} tasks requested but the trace has only "
                f"{self.trace.n} records")
        return max(int(n), 0)

    def generate(self, n: int | None = None) -> list[TaskInput]:
        """The first ``n`` trace records as per-task objects (parity tests,
        per-task consumers); defaults to the whole trace."""
        n = self._clip(n)
        t = self.trace
        return [TaskInput(idx=i, arrival_ms=float(t.arrival_ms[i]),
                          size=float(t.size[i]), bytes=float(t.bytes[i]))
                for i in range(n)]

    def chunks(self, n: int | None = None,
               chunk_size: int = 65536) -> Iterator[TaskChunk]:
        """Stream the first ``n`` records (default: all) as ``TaskChunk``
        views of the trace columns — the constant-overhead replay path."""
        n = self._clip(n)
        t = self.trace
        for lo in range(0, n, chunk_size):
            hi = min(lo + chunk_size, n)
            yield TaskChunk(idx=np.arange(lo, hi, dtype=np.int64),
                            arrival_ms=t.arrival_ms[lo:hi],
                            size=t.size[lo:hi], bytes=t.bytes[lo:hi])

    def task_chunk(self) -> TaskChunk:
        """The whole trace as one columnar chunk (``serve_stream`` slices it)."""
        return self.trace.task_chunk()


def capture(result: "SimulationResult | RecordBatch", app: str = "app",
            observed: bool = True, meta: dict | None = None,
            faults: "FaultSpec | None" = None) -> Trace:
    """A served run back out as a single-app ``Trace``.

    Reads the record batch's arrival and input-feature columns — present when
    the run kept its tasks (``serve``, ``serve_stream(keep_tasks=True)``) or
    retained the input columns (``serve_stream(keep_inputs=True)``, the
    constant-memory spelling); otherwise ``input_arrays`` raises an actionable
    error naming both fixes. ``observed=True`` stores the run's actual
    latencies as ``observed_latency_ms``, so a replay can be compared against
    what the captured run saw.

    ``faults`` embeds the run's ``FaultSpec`` in the trace meta (under
    ``"fault_spec"``), so a chaos run is replayable with its exact fault
    schedule: ``fault_spec_of(trace)`` reconstructs the spec on the way back
    in, and the counter-based fault streams make the schedule a pure function
    of (spec, dispatch times) — identical on replay.
    """
    rb = result.records if isinstance(result, SimulationResult) else result
    size, nbytes = rb.input_arrays()
    if faults is not None:
        meta = dict(meta or {})
        meta["fault_spec"] = faults.to_json()
    return Trace.from_arrays(
        np.array(rb.arrival_ms, dtype=np.float64, copy=True),
        np.array(size, dtype=np.float64, copy=True),
        np.array(nbytes, dtype=np.float64, copy=True),
        app_names=(app,),
        observed_latency_ms=np.array(rb.actual_latency_ms, copy=True)
        if observed else None,
        meta=meta,
    )


def fault_spec_of(trace: Trace) -> "FaultSpec | None":
    """The ``FaultSpec`` a chaos capture embedded in ``trace.meta``, or
    ``None`` for traces captured without one. The inverse of
    ``capture(..., faults=spec)`` — survives the JSONL/NPZ round trip."""
    payload = (trace.meta or {}).get("fault_spec")
    if payload is None:
        return None
    return FaultSpec.from_json(payload)


def capture_sharded(sharded: ShardedResult, observed: bool = True) -> Trace:
    """A multi-app sharded run as ONE multi-app trace.

    Captures each shard's result as a single-app trace and interleaves them by
    arrival time (``format.merge`` — stable, shard order breaks ties), the
    same global order ``ShardedResult.merged_records`` reports.
    """
    return merge({name: capture(res, app=name, observed=observed)
                  for name, res in sharded.results.items()})


@dataclass(eq=False)
class TraceChunkFactory:
    """Picklable zero-arg workload factory over a (single-app) trace.

    ``ShardedRuntime(use_processes=True)`` requires shard workloads to be
    factories so children build their own copies; a ``Trace`` is plain
    ndarrays and pickles cheaply, so this is all a process-mode replay needs.
    """

    trace: Trace

    def __call__(self) -> TaskChunk:
        return self.trace.task_chunk()


def trace_shards(trace: Trace,
                 runtimes: Mapping[str, "PlacementRuntime | Callable[[], PlacementRuntime]"],
                 chunk_size: int = 65536, keep_tasks: bool = False,
                 as_factories: bool = False) -> list[AppShard]:
    """Split a multi-app trace into per-app ``AppShard``s for sharded replay.

    The split is ``Trace.split_by_app`` — deterministic and order-preserving,
    so each shard's stream is exactly the trace filtered to that app up front
    (the regression tests pin this equivalence). ``runtimes`` maps every app
    name in the trace to its runtime or runtime factory; ``as_factories=True``
    wraps each sub-trace in a picklable ``TraceChunkFactory`` (required for
    ``use_processes=True``, where runtimes must be factories too).
    """
    missing = [a for a in trace.app_names if a not in runtimes]
    if missing:
        raise TraceError(
            f"no runtime supplied for trace apps {missing}; this trace's "
            f"apps are {list(trace.app_names)}")
    shards = []
    for app, sub in trace.split_by_app().items():
        workload = TraceChunkFactory(sub) if as_factories else sub.task_chunk()
        shards.append(AppShard(name=app, runtime=runtimes[app],
                               workload=workload, chunk_size=chunk_size,
                               keep_tasks=keep_tasks))
    return shards
