"""The versioned trace format: recorded arrival traffic as columnar arrays.

A trace is the EdgeBench-style record of one stretch of real traffic: per
arrival a timestamp, an application name, the input size feature and payload
bytes, and optionally the latency that was observed when the arrival was
originally served. Two interchangeable encodings carry the same schema:

- **JSONL** (``.jsonl``): a header line ``{"schema": "repro.trace",
  "version": 1, "apps": [...], "n": ...}`` followed by one record per line —
  human-greppable, appendable, diff-able. Floats are written with Python's
  shortest round-tripping ``repr``, so a JSONL round trip is BIT-EXACT.
- **NPZ** (``.npz``): the columns saved directly — the fast path for large
  traces (no per-row JSON), trivially bit-exact.

Loading VALIDATES by default and rejects malformed traces with the offending
record named — unsorted timestamps, NaN/negative sizes, out-of-range app
codes — instead of letting bad data propagate into the serve path (where an
unsorted stream silently drops to the slow per-task walk and NaN sizes poison
every prediction downstream).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from repro.core.workload import TaskChunk, first_disorder, task_arrays

TRACE_SCHEMA = "repro.trace"
TRACE_SCHEMA_VERSION = 1


class TraceError(ValueError):
    """A malformed trace: wrong schema, unsorted, NaN/negative, unknown app."""


def _col(name: str, values, dtype) -> np.ndarray:
    a = np.asarray(values, dtype=dtype)
    if a.ndim != 1:
        raise TraceError(f"trace column {name!r} must be 1-D, got shape {a.shape}")
    return a


@dataclass(eq=False)
class Trace:
    """One recorded stretch of traffic, struct-of-arrays.

    ``app_codes[i]`` indexes ``app_names`` — a single-app trace has one name
    and an all-zero code column. ``observed_latency_ms`` is optional: set when
    the trace was captured from a served run (twin or live), so replays can be
    compared against what actually happened.
    """

    arrival_ms: np.ndarray              # (n,) float64, nondecreasing
    size: np.ndarray                    # (n,) float64 — model input feature
    bytes: np.ndarray                   # (n,) float64 — payload for transfer
    app_codes: np.ndarray               # (n,) int64 into app_names
    app_names: tuple[str, ...]
    observed_latency_ms: np.ndarray | None = None
    meta: dict = field(default_factory=dict)
    version: int = TRACE_SCHEMA_VERSION

    # ------------------------------------------------------------ construction
    @classmethod
    def from_arrays(cls, arrival_ms, size, bytes, app_codes=None,
                    app_names: Sequence[str] = ("app",),
                    observed_latency_ms=None, meta: dict | None = None,
                    validate: bool = True) -> "Trace":
        arrival_ms = _col("arrival_ms", arrival_ms, np.float64)
        n = arrival_ms.shape[0]
        if app_codes is None:
            app_codes = np.zeros(n, dtype=np.int64)
        t = cls(
            arrival_ms=arrival_ms,
            size=_col("size", size, np.float64),
            bytes=_col("bytes", bytes, np.float64),
            app_codes=_col("app_codes", app_codes, np.int64),
            app_names=tuple(app_names),
            observed_latency_ms=None if observed_latency_ms is None
            else _col("observed_latency_ms", observed_latency_ms, np.float64),
            meta=dict(meta or {}),
        )
        if validate:
            t.validate()
        return t

    @classmethod
    def from_tasks(cls, tasks, app: str = "app",
                   meta: dict | None = None) -> "Trace":
        """A single-app trace from any task container (list or ``TaskChunk``)."""
        _, arrivals, sizes, nbytes = task_arrays(tasks, "asb")
        return cls.from_arrays(arrivals, sizes, nbytes, app_names=(app,),
                               meta=meta)

    # --------------------------------------------------------------- basic API
    @property
    def n(self) -> int:
        return self.arrival_ms.shape[0]

    def __len__(self) -> int:
        return self.n

    @property
    def duration_ms(self) -> float:
        if self.n == 0:
            return 0.0
        return float(self.arrival_ms[-1] - self.arrival_ms[0])

    def equal(self, other: "Trace") -> bool:
        """Bit-exact equality of every column (ignores ``meta``)."""
        if self.n != other.n or self.app_names != other.app_names:
            return False
        if (self.observed_latency_ms is None) != (other.observed_latency_ms is None):
            return False
        cols = (np.array_equal(self.arrival_ms, other.arrival_ms)
                and np.array_equal(self.size, other.size)
                and np.array_equal(self.bytes, other.bytes)
                and np.array_equal(self.app_codes, other.app_codes))
        if not cols:
            return False
        if self.observed_latency_ms is not None:
            return np.array_equal(self.observed_latency_ms,
                                  other.observed_latency_ms)
        return True

    # ------------------------------------------------------------- validation
    def validate(self) -> "Trace":
        """Reject malformed traces with the offending record named.

        Returns ``self`` so construction sites can chain. The checks exist to
        fail *at ingestion* — an unsorted trace would otherwise silently drop
        ``serve_stream`` into the per-task-walk fallback, and NaN/negative
        sizes would poison every component-model prediction downstream.
        """
        if self.version > TRACE_SCHEMA_VERSION:
            raise TraceError(
                f"trace schema version {self.version} is newer than the "
                f"supported version {TRACE_SCHEMA_VERSION} — upgrade repro "
                "or re-export the trace at the older version")
        n = self.n
        for name in ("size", "bytes", "app_codes"):
            col = getattr(self, name)
            if col.shape[0] != n:
                raise TraceError(
                    f"trace column {name!r} has {col.shape[0]} records but "
                    f"arrival_ms has {n}")
        if self.observed_latency_ms is not None \
                and self.observed_latency_ms.shape[0] != n:
            raise TraceError(
                f"trace column 'observed_latency_ms' has "
                f"{self.observed_latency_ms.shape[0]} records but arrival_ms "
                f"has {n}")
        if not self.app_names:
            raise TraceError("trace has no app names")
        if len(set(self.app_names)) != len(self.app_names):
            raise TraceError(f"duplicate app names: {self.app_names}")

        bad = np.nonzero(~np.isfinite(self.arrival_ms))[0]
        if bad.size:
            i = int(bad[0])
            raise TraceError(
                f"trace record {i}: non-finite arrival_ms "
                f"{self.arrival_ms[i]!r}")
        i = first_disorder(self.arrival_ms)
        if i >= 0:
            raise TraceError(
                f"trace arrivals unsorted at record {i}: "
                f"arrival_ms[{i}]={float(self.arrival_ms[i])!r} < "
                f"arrival_ms[{i - 1}]={float(self.arrival_ms[i - 1])!r} — "
                "sort the trace by arrival time before replay (an unsorted "
                "stream would silently fall back to the slow per-task walk)")
        for name in ("size", "bytes"):
            col = getattr(self, name)
            bad = np.nonzero(np.isnan(col))[0]
            if bad.size:
                raise TraceError(f"trace record {int(bad[0])}: NaN {name}")
            bad = np.nonzero(col < 0.0)[0]
            if bad.size:
                i = int(bad[0])
                raise TraceError(
                    f"trace record {i}: negative {name} {float(col[i])!r}")
        bad = np.nonzero((self.app_codes < 0)
                         | (self.app_codes >= len(self.app_names)))[0]
        if bad.size:
            i = int(bad[0])
            raise TraceError(
                f"trace record {i}: app code {int(self.app_codes[i])} out of "
                f"range for apps {self.app_names}")
        if self.observed_latency_ms is not None:
            lat = self.observed_latency_ms
            bad = np.nonzero(np.isnan(lat) | (lat < 0.0))[0]
            if bad.size:
                i = int(bad[0])
                raise TraceError(
                    f"trace record {i}: invalid observed_latency_ms "
                    f"{float(lat[i])!r}")
        return self

    # ---------------------------------------------------------- app filtering
    def for_app(self, app: str) -> "Trace":
        """The single-app sub-trace of ``app``, original order preserved."""
        if app not in self.app_names:
            raise TraceError(
                f"unknown app {app!r}: this trace's apps are "
                f"{list(self.app_names)}")
        mask = self.app_codes == self.app_names.index(app)
        return Trace(
            arrival_ms=self.arrival_ms[mask],
            size=self.size[mask],
            bytes=self.bytes[mask],
            app_codes=np.zeros(int(np.count_nonzero(mask)), dtype=np.int64),
            app_names=(app,),
            observed_latency_ms=None if self.observed_latency_ms is None
            else self.observed_latency_ms[mask],
            meta=dict(self.meta),
            version=self.version,
        )

    def split_by_app(self) -> dict[str, "Trace"]:
        """One single-app trace per app — the deterministic, order-preserving
        split behind multi-app shard replay (``repro.trace.trace_shards``):
        within each app the records keep their original relative order, so a
        shard's stream is exactly the trace filtered to that app up front."""
        return {app: self.for_app(app) for app in self.app_names}

    def prefix(self, n: int) -> "Trace":
        """The first ``n`` records (what successive-halving rungs replay)."""
        n = max(0, min(int(n), self.n))
        return Trace(
            arrival_ms=self.arrival_ms[:n], size=self.size[:n],
            bytes=self.bytes[:n], app_codes=self.app_codes[:n],
            app_names=self.app_names,
            observed_latency_ms=None if self.observed_latency_ms is None
            else self.observed_latency_ms[:n],
            meta=dict(self.meta), version=self.version,
        )

    def task_chunk(self) -> TaskChunk:
        """The whole trace as one columnar ``TaskChunk`` (array views)."""
        return TaskChunk(idx=np.arange(self.n, dtype=np.int64),
                         arrival_ms=self.arrival_ms, size=self.size,
                         bytes=self.bytes)

    # ----------------------------------------------------------------- JSONL
    def save_jsonl(self, path) -> None:
        header = {"schema": TRACE_SCHEMA, "version": self.version,
                  "apps": list(self.app_names), "n": int(self.n)}
        if self.meta:
            header["meta"] = self.meta
        lat = self.observed_latency_ms
        with open(path, "w") as f:
            f.write(json.dumps(header) + "\n")
            for i in range(self.n):
                row = {"t": float(self.arrival_ms[i]),
                       "app": int(self.app_codes[i]),
                       "size": float(self.size[i]),
                       "bytes": float(self.bytes[i])}
                if lat is not None:
                    row["lat"] = float(lat[i])
                f.write(json.dumps(row) + "\n")

    # ------------------------------------------------------------------- NPZ
    def save_npz(self, path) -> None:
        data = {
            "schema_version": np.array(self.version, dtype=np.int64),
            "arrival_ms": self.arrival_ms,
            "size": self.size,
            "bytes": self.bytes,
            "app_codes": self.app_codes,
            "app_names": np.array(self.app_names, dtype=np.str_),
            "meta_json": np.array(json.dumps(self.meta), dtype=np.str_),
        }
        if self.observed_latency_ms is not None:
            data["observed_latency_ms"] = self.observed_latency_ms
        np.savez(path, **data)

    def save(self, path) -> None:
        """Dispatch on extension: ``.jsonl``/``.json`` or ``.npz``."""
        p = str(path)
        if p.endswith(".npz"):
            self.save_npz(path)
        elif p.endswith((".jsonl", ".json")):
            self.save_jsonl(path)
        else:
            raise TraceError(
                f"cannot infer trace format from {p!r} — use a .jsonl or "
                ".npz extension, or call save_jsonl/save_npz directly")


def load_jsonl(path, validate: bool = True) -> Trace:
    """Load a JSONL trace; validates by default (see ``Trace.validate``)."""
    with open(path) as f:
        first = f.readline()
        if not first.strip():
            raise TraceError(f"{path}: empty file, expected a trace header line")
        try:
            header = json.loads(first)
        except json.JSONDecodeError as e:
            raise TraceError(f"{path}: line 1 is not valid JSON ({e})") from e
        if not isinstance(header, dict) or header.get("schema") != TRACE_SCHEMA:
            raise TraceError(
                f"{path}: line 1 is not a {TRACE_SCHEMA!r} header "
                f"(got {header!r:.120}) — JSONL traces start with "
                '{"schema": "repro.trace", "version": 1, "apps": [...]}')
        version = int(header.get("version", 0))
        if version > TRACE_SCHEMA_VERSION:
            raise TraceError(
                f"{path}: schema version {version} is newer than the "
                f"supported version {TRACE_SCHEMA_VERSION}")
        apps = header.get("apps")
        if not isinstance(apps, list) or not apps:
            raise TraceError(f"{path}: header has no 'apps' list")
        arrivals: list[float] = []
        sizes: list[float] = []
        nbytes: list[float] = []
        codes: list[int] = []
        lats: list[float] = []
        for lineno, line in enumerate(f, start=2):
            if not line.strip():
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError as e:
                raise TraceError(
                    f"{path}: line {lineno} is not valid JSON ({e})") from e
            try:
                arrivals.append(float(row["t"]))
                codes.append(int(row["app"]))
                sizes.append(float(row["size"]))
                nbytes.append(float(row["bytes"]))
            except KeyError as e:
                raise TraceError(
                    f"{path}: line {lineno} is missing field {e.args[0]!r} "
                    "(records carry t/app/size/bytes[/lat])") from e
            if "lat" in row:
                if len(lats) != len(arrivals) - 1:
                    raise TraceError(
                        f"{path}: line {lineno} has 'lat' but an earlier "
                        "record does not — observed latency is all-or-none")
                lats.append(float(row["lat"]))
            elif lats:
                raise TraceError(
                    f"{path}: line {lineno} is missing 'lat' but earlier "
                    "records carry it — observed latency is all-or-none")
    t = Trace(
        arrival_ms=np.array(arrivals, dtype=np.float64),
        size=np.array(sizes, dtype=np.float64),
        bytes=np.array(nbytes, dtype=np.float64),
        app_codes=np.array(codes, dtype=np.int64),
        app_names=tuple(str(a) for a in apps),
        observed_latency_ms=np.array(lats, dtype=np.float64) if lats else None,
        meta=dict(header.get("meta") or {}),
        version=version,
    )
    return t.validate() if validate else t


def load_npz(path, validate: bool = True) -> Trace:
    """Load an NPZ trace; validates by default (see ``Trace.validate``)."""
    with np.load(path, allow_pickle=False) as z:
        missing = [k for k in ("schema_version", "arrival_ms", "size",
                               "bytes", "app_codes", "app_names")
                   if k not in z.files]
        if missing:
            raise TraceError(
                f"{path}: not a {TRACE_SCHEMA!r} NPZ archive — missing "
                f"arrays {missing}")
        version = int(z["schema_version"])
        if version > TRACE_SCHEMA_VERSION:
            raise TraceError(
                f"{path}: schema version {version} is newer than the "
                f"supported version {TRACE_SCHEMA_VERSION}")
        meta = {}
        if "meta_json" in z.files:
            meta = json.loads(str(z["meta_json"]))
        t = Trace(
            arrival_ms=z["arrival_ms"].astype(np.float64, copy=True),
            size=z["size"].astype(np.float64, copy=True),
            bytes=z["bytes"].astype(np.float64, copy=True),
            app_codes=z["app_codes"].astype(np.int64, copy=True),
            app_names=tuple(str(a) for a in z["app_names"].tolist()),
            observed_latency_ms=z["observed_latency_ms"].astype(
                np.float64, copy=True)
            if "observed_latency_ms" in z.files else None,
            meta=meta,
            version=version,
        )
    return t.validate() if validate else t


def load(path, validate: bool = True) -> Trace:
    """Load a trace, dispatching on extension (``.jsonl``/``.json``/``.npz``)."""
    p = str(path)
    if p.endswith(".npz"):
        return load_npz(path, validate=validate)
    if p.endswith((".jsonl", ".json")):
        return load_jsonl(path, validate=validate)
    raise TraceError(
        f"cannot infer trace format from {p!r} — use a .jsonl or .npz "
        "extension, or call load_jsonl/load_npz directly")


def merge(traces: Mapping[str, Trace]) -> Trace:
    """Interleave single-app traces into one multi-app trace by arrival time.

    The sort is stable with ties broken by mapping order, so
    ``merge(t.split_by_app()).equal(t)`` holds for any valid multi-app trace
    whose per-app streams came from that same split — the round-trip behind
    sharded replay and ``capture_sharded``.
    """
    if not traces:
        raise TraceError("merge needs at least one trace")
    names: list[str] = []
    arr, size, nbytes, codes, lats = [], [], [], [], []
    any_lat = any(t.observed_latency_ms is not None for t in traces.values())
    all_lat = all(t.observed_latency_ms is not None for t in traces.values())
    if any_lat and not all_lat:
        raise TraceError(
            "cannot merge traces where only some carry observed_latency_ms "
            "— observed latency is all-or-none")
    for app, t in traces.items():
        if len(t.app_names) != 1:
            raise TraceError(
                f"merge takes single-app traces; {app!r} has apps "
                f"{list(t.app_names)} (split_by_app() first)")
        names.append(app)
        arr.append(t.arrival_ms)
        size.append(t.size)
        nbytes.append(t.bytes)
        codes.append(np.full(t.n, len(names) - 1, dtype=np.int64))
        if all_lat:
            lats.append(t.observed_latency_ms)
    arrival = np.concatenate(arr)
    order = np.argsort(arrival, kind="stable")
    return Trace(
        arrival_ms=arrival[order],
        size=np.concatenate(size)[order],
        bytes=np.concatenate(nbytes)[order],
        app_codes=np.concatenate(codes)[order],
        app_names=tuple(names),
        observed_latency_ms=np.concatenate(lats)[order] if all_lat else None,
    ).validate()
