"""Checkpointing: atomic, keep-k, auto-resume (orbax is not available).

Layout::

    <dir>/step_000123/arrays.npz     # flat {escaped_path: np.ndarray}
    <dir>/step_000123/META.json      # step, keys, dtypes
    <dir>/LATEST                     # text pointer, written last (commit point)

Writes go to a temp directory then ``os.rename`` (atomic on POSIX) — a crash
mid-save can never corrupt the latest checkpoint, which is what checkpoint/
restart fault tolerance rests on. ``restore_latest`` also supports *elastic*
restarts: arrays are restored host-side and can be re-sharded onto a different
mesh by the caller (``repro.distributed.elastic``).
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

_SEP = "|"  # npz keys cannot contain '/' reliably across tools


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    tree: dict = {}
    for key, v in flat.items():
        parts = key.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save_checkpoint(directory: str, step: int, state: dict, keep: int = 3) -> str:
    """Atomically save ``state`` (pytree of arrays) as step ``step``."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:09d}"
    final = os.path.join(directory, name)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(state)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {"step": step, "n_arrays": len(arrays)}
    with open(os.path.join(tmp, "META.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    # commit point: LATEST names the new checkpoint
    latest_tmp = os.path.join(directory, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(name)
    os.rename(latest_tmp, os.path.join(directory, "LATEST"))

    _prune(directory, keep)
    return final


def _prune(directory: str, keep: int):
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    pointer = os.path.join(directory, "LATEST")
    if not os.path.exists(pointer):
        return None
    with open(pointer) as f:
        name = f.read().strip()
    path = os.path.join(directory, name)
    if not os.path.exists(path):
        return None
    return int(name.split("_")[1])


def restore_checkpoint(directory: str, step: int) -> dict:
    path = os.path.join(directory, f"step_{step:09d}", "arrays.npz")
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten(flat)


def restore_latest(directory: str) -> tuple[int, dict] | None:
    step = latest_step(directory)
    if step is None:
        return None
    return step, restore_checkpoint(directory, step)
