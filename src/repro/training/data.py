"""Data pipeline: deterministic synthetic corpora per architecture family.

Batches are generated from a counter-seeded PRNG, so the pipeline is
(a) infinite, (b) deterministically resumable from a step index after restart
(the same guarantee a production sharded-file loader provides via per-step
shard bookkeeping), and (c) identical across hosts — each host slices its
data-parallel shard from the global batch by process index.

The token stream is a Zipf-distributed "language" with document boundaries —
enough structure for loss curves to be meaningfully decreasing.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0
    zipf_a: float = 1.3
    doc_len_mean: int = 512
    bos_token: int = 1


class TokenPipeline:
    """Deterministic, restartable synthetic LM data."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # precompute a Zipf-ish categorical over the vocab
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / np.power(ranks, cfg.zipf_a)
        self.probs = p / p.sum()

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab, size=(B, S + 1), p=self.probs).astype(np.int32)
        # inject document boundaries: bos then a copied "topic" token run —
        # makes next-token prediction learnable
        n_docs = max((S + 1) // cfg.doc_len_mean, 1)
        for b in range(B):
            starts = rng.integers(0, S, size=n_docs)
            for s in starts:
                toks[b, s] = cfg.bos_token
                run = min(int(rng.integers(4, 16)), S - s)
                if run > 2:
                    topic = rng.integers(2, cfg.vocab)
                    toks[b, s + 1 : s + run : 2] = topic
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "loss_mask": np.ones((B, S), np.float32),
        }

    def host_batch(self, step: int, process_index: int = 0, process_count: int = 1):
        """The slice of the global batch this host feeds (multi-host feed)."""
        b = self.batch(step)
        B = self.cfg.global_batch
        per = B // process_count
        sl = slice(process_index * per, (process_index + 1) * per)
        return {k: v[sl] for k, v in b.items()}


class AudioPipeline:
    """Synthetic frame-feature batches for the encoder-only (HuBERT) family."""

    def __init__(self, seq_len: int, global_batch: int, vocab: int,
                 feat_dim: int, mask_prob: float = 0.08, seed: int = 0):
        self.seq_len, self.global_batch = seq_len, global_batch
        self.vocab, self.feat_dim = vocab, feat_dim
        self.mask_prob, self.seed = mask_prob, seed

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        B, S = self.global_batch, self.seq_len
        # cluster targets correlate with frame features (learnable)
        targets = rng.integers(0, self.vocab, size=(B, S)).astype(np.int32)
        centroids = np.random.default_rng(self.seed).normal(
            size=(self.vocab, self.feat_dim)).astype(np.float32)
        frames = centroids[targets] + 0.5 * rng.normal(size=(B, S, self.feat_dim)).astype(np.float32)
        mask = (rng.random((B, S)) < self.mask_prob).astype(np.float32)
        return {"frames": frames, "mask": mask, "targets": targets}


def make_pipeline(arch_cfg, seq_len: int, global_batch: int, seed: int = 0):
    if arch_cfg.family == "audio":
        return AudioPipeline(seq_len, global_batch, arch_cfg.vocab,
                             arch_cfg.frame_feat_dim, arch_cfg.mask_prob, seed)
    return TokenPipeline(DataConfig(seq_len=seq_len, global_batch=global_batch,
                                    vocab=arch_cfg.vocab, seed=seed))
