"""AdamW + LR schedules, from scratch (optax is not available).

Optimizer state (m, v) mirrors the parameter pytree — and therefore inherits
the parameter shardings (FSDP keeps optimizer state fully sharded). Gradient
clipping is by global norm. Optional gradient-compression transform hooks in
before the moment update (see ``repro.distributed.compression``).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_frac: float = 0.1
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_schedule(cfg: OptimizerConfig, step):
    """Linear warmup then cosine decay to min_lr_frac·peak."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = cfg.peak_lr * jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    return {
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros_like(p), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(params, grads, state, cfg: OptimizerConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
