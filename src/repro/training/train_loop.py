"""Fault-tolerant training loop.

Production behaviors implemented (and exercised by tests):

- **train step**: value_and_grad → (optional) gradient compression with error
  feedback → global-norm clip → AdamW; jitted with donated params/opt state;
  under a mesh, params/opt are sharded by the logical-axis rules and the batch
  by ("pod","data").
- **checkpoint/restart**: atomic keep-k checkpoints every N steps; on start
  the loop auto-resumes from LATEST (bit-exact: data pipeline is
  counter-seeded, optimizer state is saved).
- **failure injection**: ``FailureInjector`` raises at a given step;
  ``run_with_restarts`` restarts the loop from the last checkpoint — the test
  asserts the recovered run matches an uninterrupted one.
- **straggler watchdog**: per-step wall-clock EWMA; steps slower than
  ``straggler_factor``× the EWMA are counted and logged (on a fleet this
  signal feeds the re-dispatch hook; in the serving half of this framework the
  paper's own deadline-based re-placement plays that role).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (
    CompressionConfig,
    compress_decompress,
    init_error_state,
)
from repro.training import checkpoint as ckpt
from repro.training.optimizer import OptimizerConfig, adamw_update, init_opt_state


@dataclass
class LoopConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    keep: int = 3
    straggler_factor: float = 3.0
    compression: CompressionConfig = field(default_factory=CompressionConfig)


class SimulatedFailure(RuntimeError):
    pass


class FailureInjector:
    """Raises SimulatedFailure the first time ``step == fail_at``."""

    def __init__(self, fail_at: int | None):
        self.fail_at = fail_at
        self.fired = False

    def maybe_fail(self, step: int):
        if self.fail_at is not None and step == self.fail_at and not self.fired:
            self.fired = True
            raise SimulatedFailure(f"injected failure at step {step}")


def make_train_step(model, opt_cfg: OptimizerConfig,
                    comp_cfg: CompressionConfig | None = None):
    comp_cfg = comp_cfg or CompressionConfig()
    microbatch = getattr(model.cfg, "microbatch", 1)

    def grad_fn(params, batch):
        if microbatch <= 1:
            return jax.value_and_grad(model.loss, has_aux=True)(params, batch)

        # §Perf: gradient accumulation — k sequential microbatches cut live
        # activation memory ~k× at the same global batch (math unchanged).
        def split(x):
            return x.reshape(microbatch, x.shape[0] // microbatch, *x.shape[1:])

        mbatches = jax.tree.map(split, batch)

        def body(acc, mb):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                               acc, grads)
            return acc, (loss, metrics)

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        acc, (losses, metrics) = jax.lax.scan(body, zeros, mbatches)
        grads = jax.tree.map(lambda g: g / microbatch, acc)
        loss = jnp.mean(losses)
        metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        return (loss, metrics), grads

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        if comp_cfg.scheme != "none":
            grads, new_err = compress_decompress(
                grads, opt_state["err"], comp_cfg, step=opt_state["opt"]["step"])
            new_params, new_opt, opt_metrics = adamw_update(
                params, grads, opt_state["opt"], opt_cfg)
            new_state = {"opt": new_opt, "err": new_err}
        else:
            new_params, new_opt, opt_metrics = adamw_update(
                params, grads, opt_state["opt"], opt_cfg)
            new_state = {"opt": new_opt}
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return new_params, new_state, metrics

    return train_step


def init_train_state(model, key, comp_cfg: CompressionConfig | None = None):
    params = model.init(key)
    state = {"opt": init_opt_state(params)}
    if comp_cfg and comp_cfg.scheme != "none":
        state["err"] = init_error_state(params)
    return params, state


@dataclass
class TrainResult:
    losses: list
    final_step: int
    straggler_steps: int
    restarts: int = 0


def train(model, pipeline, loop_cfg: LoopConfig, opt_cfg: OptimizerConfig,
          key=None, injector: FailureInjector | None = None,
          to_device: Callable | None = None, log: Callable | None = None) -> TrainResult:
    """Run (or resume) a training loop. ``pipeline.batch(step)`` feeds data."""
    log = log or (lambda *a: None)
    key = key if key is not None else jax.random.key(0)
    step0 = 0
    comp = loop_cfg.compression

    resumed = None
    if loop_cfg.ckpt_dir:
        resumed = ckpt.restore_latest(loop_cfg.ckpt_dir)
    if resumed is not None:
        step0, tree = resumed
        params, state = tree["params"], tree["state"]
        params = jax.tree.map(jnp.asarray, params)
        state = jax.tree.map(jnp.asarray, state)
        # npz round-trips scalars as arrays; restore dtypes
        state["opt"]["step"] = jnp.asarray(state["opt"]["step"], jnp.int32)
        log(f"resumed from step {step0}")
    else:
        params, state = init_train_state(model, key, comp)

    step_fn = jax.jit(make_train_step(model, opt_cfg, comp), donate_argnums=(0, 1))

    losses, ewma, stragglers = [], None, 0
    step = step0
    while step < loop_cfg.steps:
        batch = pipeline.batch(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if to_device:
            batch = to_device(batch)
        t0 = time.monotonic()
        params, state, metrics = step_fn(params, state, batch)
        loss = float(metrics["loss"])
        dt = time.monotonic() - t0
        if ewma is None:
            ewma = dt
        else:
            if dt > loop_cfg.straggler_factor * ewma:
                stragglers += 1
                log(f"straggler: step {step} took {dt:.3f}s (ewma {ewma:.3f}s)")
            ewma = 0.9 * ewma + 0.1 * dt
        losses.append(loss)
        step += 1
        if step % loop_cfg.log_every == 0:
            log(f"step {step}: loss {loss:.4f} ({dt*1e3:.0f} ms)")
        if loop_cfg.ckpt_dir and step % loop_cfg.ckpt_every == 0:
            ckpt.save_checkpoint(loop_cfg.ckpt_dir, step,
                                 {"params": params, "state": state},
                                 keep=loop_cfg.keep)
        if injector:
            injector.maybe_fail(step)

    if loop_cfg.ckpt_dir:
        ckpt.save_checkpoint(loop_cfg.ckpt_dir, step,
                             {"params": params, "state": state}, keep=loop_cfg.keep)
    return TrainResult(losses=losses, final_step=step, straggler_steps=stragglers)


def run_with_restarts(model, pipeline, loop_cfg: LoopConfig, opt_cfg: OptimizerConfig,
                      key=None, injector: FailureInjector | None = None,
                      max_restarts: int = 3, log: Callable | None = None) -> TrainResult:
    """Supervisor: restart-from-checkpoint on (simulated) node failure."""
    restarts = 0
    while True:
        try:
            result = train(model, pipeline, loop_cfg, opt_cfg, key=key,
                           injector=injector, log=log)
            result.restarts = restarts
            return result
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            (log or (lambda *a: None))(f"restart #{restarts}")
