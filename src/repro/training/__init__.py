"""Training substrate: optimizer, data pipeline, checkpointing, fault-tolerant loop."""
