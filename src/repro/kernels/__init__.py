"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel is a subpackage with three modules:

- ``kernel.py`` — the ``pl.pallas_call`` body with explicit BlockSpec VMEM
  tiling, written for the TPU target (MXU-aligned block shapes, online
  accumulation in VMEM scratch that persists across the sequential grid);
- ``ops.py``    — the jit'd public wrapper (padding, layout, interpret-mode
  selection: interpret=True on non-TPU backends so CPU CI validates the
  exact kernel body the fleet runs);
- ``ref.py``    — the pure-jnp oracle every shape/dtype sweep asserts against.

Kernels:

- ``flash_attention``  — causal/local GQA attention, online softmax (prefill/train)
- ``decode_attention`` — flash-decode: one query token vs. a length-masked KV cache
- ``ssd_scan``         — Mamba-2 state-space-duality chunked scan
- ``linear_scan``      — RG-LRU gated linear recurrence (chunked, state carried in VMEM)
- ``gbrt_predict``     — GBRT ensemble inference via one-hot MXU contractions
                         (the paper's Predictor hot loop, batched per decision)
"""
