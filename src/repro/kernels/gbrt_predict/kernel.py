"""GBRT ensemble inference Pallas TPU kernel — the Predictor's hot loop.

The paper's Decision Engine calls the GBRT compute-time model once per
(input × configuration); a serving fleet with thousands of placement decisions
per second amortizes them by *batching* prediction rows, which is exactly what
this kernel serves.

TPU adaptation of tree traversal (a scattered-memory GPU/CPU workload): trees
are complete (heap layout, pass-through nodes use threshold=+inf), so the
traversal is a fixed ``depth``-step index walk with no divergence. Every
gather is re-expressed as a **one-hot matmul** — the MXU-native form of a
permutation — so the kernel never issues a data-dependent load:

- node→feature-id and node→threshold selection: one_hot(node, I) contraction;
- sample→feature-value selection: one_hot(feat_id, F) row-product;
- leaf lookup: one_hot(leaf, L) contraction.

Grid is (num_row_blocks,); the whole (small) ensemble sits in VMEM per step;
trees accumulate through a ``fori_loop`` into an fp32 running sum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _one_hot(idx, n):
    """(rows,) int32 -> (rows, n) f32 via broadcasted-iota compare (no gather)."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], n), 1)
    return (idx[:, None] == cols).astype(jnp.float32)


def _gbrt_kernel(x_ref, f_ref, th_ref, lv_ref, o_ref, *, depth: int,
                 n_trees: int, lr: float, base: float):
    x = x_ref[...].astype(jnp.float32)            # (bn, F)
    bn, F = x.shape
    I = f_ref.shape[1]                             # internal nodes per tree
    L = lv_ref.shape[1]                            # leaves per tree

    def tree_step(t, acc):
        feat = f_ref[pl.dslice(t, 1), :][0]        # (I,) int32
        thr = th_ref[pl.dslice(t, 1), :][0]        # (I,) f32
        leaves = lv_ref[pl.dslice(t, 1), :][0]     # (L,) f32
        node = jnp.zeros((bn,), jnp.int32)
        for _ in range(depth):                     # static unroll
            sel = _one_hot(node, I)                # (bn, I)
            f_id = jax.lax.dot_general(
                sel, feat.astype(jnp.float32)[:, None],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)[:, 0]
            t_val = jax.lax.dot_general(
                sel, thr[:, None], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)[:, 0]
            fsel = _one_hot(f_id.astype(jnp.int32), F)   # (bn, F)
            x_val = jnp.sum(x * fsel, axis=1)
            go_right = (x_val > t_val).astype(jnp.int32)
            node = 2 * node + 1 + go_right
        leaf = node - (2 ** depth - 1)
        lsel = _one_hot(leaf, L)
        contrib = jax.lax.dot_general(
            lsel, leaves[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]
        return acc + lr * contrib

    acc = jnp.full((bn,), base, jnp.float32)
    acc = jax.lax.fori_loop(0, n_trees, tree_step, acc)
    o_ref[...] = acc[:, None]


def _gbrt_multi_kernel(x_ref, mem_ref, lr_ref, base_ref, f_ref, th_ref,
                       lv_ref, o_ref, *, depth: int, n_trees: int):
    """One (config, row-block) grid cell of the blocked multi-config launch.

    ``x_ref`` carries the shared size column; the config's constant memory
    feature is broadcast in-kernel (so the host never materializes the
    per-config ``(N, 2)`` stacks). The learning-rate multiply stays INSIDE
    the accumulation (``acc + lr * contrib``) exactly like the per-config
    kernel — XLA contracts that pattern into an FMA, so hoisting the multiply
    host-side would break bit-identity with the per-config launches.
    """
    sizes = x_ref[...].astype(jnp.float32)        # (bn, 1)
    bn = sizes.shape[0]
    mem = jnp.full((bn, 1), mem_ref[0, 0], jnp.float32)
    x = jnp.concatenate([sizes, mem], axis=1)      # (bn, F=2)
    F = x.shape[1]
    I = f_ref.shape[2]
    L = lv_ref.shape[2]

    lr = lr_ref[0, 0]

    def tree_step(t, acc):
        feat = f_ref[0, pl.dslice(t, 1), :][0]     # (I,) int32
        thr = th_ref[0, pl.dslice(t, 1), :][0]     # (I,) f32
        leaves = lv_ref[0, pl.dslice(t, 1), :][0]  # (L,) f32
        node = jnp.zeros((bn,), jnp.int32)
        for _ in range(depth):                     # static unroll
            sel = _one_hot(node, I)
            f_id = jax.lax.dot_general(
                sel, feat.astype(jnp.float32)[:, None],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)[:, 0]
            t_val = jax.lax.dot_general(
                sel, thr[:, None], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)[:, 0]
            fsel = _one_hot(f_id.astype(jnp.int32), F)
            x_val = jnp.sum(x * fsel, axis=1)
            go_right = (x_val > t_val).astype(jnp.int32)
            node = 2 * node + 1 + go_right
        leaf = node - (2 ** depth - 1)
        lsel = _one_hot(leaf, L)
        contrib = jax.lax.dot_general(
            lsel, leaves[:, None], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, 0]
        return acc + lr * contrib

    acc = jnp.full((bn,), base_ref[0, 0], jnp.float32)
    acc = jax.lax.fori_loop(0, n_trees, tree_step, acc)
    o_ref[...] = acc[:, None]


@functools.partial(jax.jit, static_argnames=("depth", "block_n", "interpret"))
def gbrt_predict_multi(x, mem, lr, base, features, thresholds, leaves, *,
                       depth: int, block_n: int = 256,
                       interpret: bool = True):
    """ALL cloud configs in one blocked launch — grid (n_configs, row blocks).

    ``x``: (N, 1) f32 shared size column; ``mem``/``lr``/``base``: (C, 1) f32
    per-config memory feature, learning rate and ensemble base;
    ``features``/``thresholds``: (C, T, I) padded operand stacks (+big
    thresholds mark pass-through nodes/trees); ``leaves``: (C, T, L) f32 (see
    ``ops.multi_kernel_operands`` for the exact-equivalence padding scheme).
    Returns (N, C) f32 — column ``c`` matches a per-config
    ``gbrt_predict_blocked`` launch bit-for-bit. ``N % block_n == 0``.
    """
    N = x.shape[0]
    C, T, I = features.shape
    L = leaves.shape[2]
    bn = min(block_n, N)
    assert N % bn == 0, (N, bn)

    kernel = functools.partial(_gbrt_multi_kernel, depth=depth, n_trees=T)
    return pl.pallas_call(
        kernel,
        grid=(C, N // bn),
        in_specs=[
            pl.BlockSpec((bn, 1), lambda c, i: (i, 0)),
            pl.BlockSpec((1, 1), lambda c, i: (c, 0)),
            pl.BlockSpec((1, 1), lambda c, i: (c, 0)),
            pl.BlockSpec((1, 1), lambda c, i: (c, 0)),
            pl.BlockSpec((1, T, I), lambda c, i: (c, 0, 0)),
            pl.BlockSpec((1, T, I), lambda c, i: (c, 0, 0)),
            pl.BlockSpec((1, T, L), lambda c, i: (c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda c, i: (i, c)),
        out_shape=jax.ShapeDtypeStruct((N, C), jnp.float32),
        interpret=interpret,
    )(x, mem, lr, base, features, thresholds, leaves)


@functools.partial(jax.jit, static_argnames=("depth", "lr", "base", "block_n",
                                             "interpret"))
def gbrt_predict_blocked(x, features, thresholds, leaves, *, depth: int,
                         lr: float, base: float, block_n: int = 256,
                         interpret: bool = True):
    """x: (N, F) f32; features: (T, I) int32; thresholds: (T, I) f32;
    leaves: (T, L) f32. Returns (N,) f32 predictions. N % block_n == 0."""
    N, F = x.shape
    T, I = features.shape
    L = leaves.shape[1]
    bn = min(block_n, N)
    assert N % bn == 0, (N, bn)

    kernel = functools.partial(_gbrt_kernel, depth=depth, n_trees=T, lr=lr,
                               base=base)
    out = pl.pallas_call(
        kernel,
        grid=(N // bn,),
        in_specs=[
            pl.BlockSpec((bn, F), lambda i: (i, 0)),
            pl.BlockSpec((T, I), lambda i: (0, 0)),   # full ensemble in VMEM
            pl.BlockSpec((T, I), lambda i: (0, 0)),
            pl.BlockSpec((T, L), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.float32),
        interpret=interpret,
    )(x, features, thresholds, leaves)
    return out[:, 0]
