"""Oracle for gbrt_predict: the numpy GBRT.predict path (repro.core.gbrt)."""

from __future__ import annotations

import numpy as np


def gbrt_predict_ref(x, features, thresholds, leaves, *, depth: int, lr: float,
                     base: float) -> np.ndarray:
    """Same heap-walk semantics as repro.core.gbrt._predict_tree, summed."""
    x = np.asarray(x, np.float64)
    out = np.full(x.shape[0], base, np.float64)
    for t in range(features.shape[0]):
        node = np.zeros(x.shape[0], np.int64)
        for _ in range(depth):
            go_right = x[np.arange(x.shape[0]), features[t][node]] > thresholds[t][node]
            node = 2 * node + 1 + go_right.astype(np.int64)
        out += lr * leaves[t][node - (2 ** depth - 1)]
    return out
