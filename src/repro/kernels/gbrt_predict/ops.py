"""Public wrapper: predict a fitted ``repro.core.gbrt.GBRT`` with the kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gbrt_predict.kernel import gbrt_predict_blocked


def kernel_operands(model) -> tuple:
    """Device-ready ensemble operands for ``gbrt_predict_blocked``.

    Returns ``(features i32, thresholds f32, leaves f32)`` as jnp arrays.
    +inf thresholds mark pass-through nodes; the kernel compares in f32, so
    thresholds are clipped to the finite f32 range host-side. Shared by the
    wrapper below and the device-resident placement core
    (``repro.core.jax_core``), which hosts one tuple per cloud config.
    """
    big = np.float32(3.0e38)
    thr = np.clip(model.thresholds, -big, big).astype(np.float32)
    return (jnp.asarray(np.asarray(model.features, np.int32)),
            jnp.asarray(thr),
            jnp.asarray(np.asarray(model.leaves, np.float32)))


def gbrt_predict(model, x, *, block_n: int = 256,
                 interpret: bool | None = None) -> np.ndarray:
    """model: repro.core.gbrt.GBRT; x: (N, F). Returns np.ndarray (N,)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    x = np.asarray(x, np.float32)
    if x.ndim == 1:
        x = x[:, None]
    N = x.shape[0]
    feats, thr, lvs = kernel_operands(model)
    bn = min(block_n, max(N, 1))
    pad = (-N) % bn
    if pad:
        x = np.pad(x, ((0, pad), (0, 0)))
    out = gbrt_predict_blocked(
        jnp.asarray(x), feats, thr, lvs,
        depth=model.config.max_depth, lr=float(model.config.learning_rate),
        base=float(model.base), block_n=bn, interpret=interpret)
    return np.asarray(out)[:N]
