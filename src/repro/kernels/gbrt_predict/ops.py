"""Public wrapper: predict a fitted ``repro.core.gbrt.GBRT`` with the kernel."""

from __future__ import annotations

import threading
import weakref

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gbrt_predict.kernel import (
    gbrt_predict_blocked,
    gbrt_predict_multi,
)

# Device-operand caches, keyed on model identity with a weakref guard — the
# ``_CONST1_TABLES`` idiom (see ``repro.core.predictor``): an online refit
# swaps in a fresh model object, so the fresh id misses the cache and the
# stale entry is evicted on id recycle or the size-capped dead-ref sweep.
# Hosting the ensemble arrays once per model (not once per call/chunk) is
# what keeps the streaming serve path free of per-chunk host→device prep.
_OPERANDS: dict[int, tuple] = {}
_MULTI_OPERANDS: dict[tuple, tuple] = {}
_OPERAND_LOCK = threading.Lock()


def _cached(cache: dict, key, models, build):
    with _OPERAND_LOCK:
        hit = cache.get(key)
        if hit is not None:
            refs, val = hit
            if all(r() is m for r, m in zip(refs, models)):
                return val
            cache.pop(key, None)  # id recycled by a swap: stale
    val = build()
    try:
        refs = tuple(weakref.ref(m) for m in models)
    except TypeError:
        return val  # non-weakrefable model: serve uncached
    with _OPERAND_LOCK:
        if len(cache) > 128:  # drop entries whose model is gone
            for k in [k for k, (rs, _) in cache.items()
                      if any(r() is None for r in rs)]:
                cache.pop(k, None)
        cache[key] = (refs, val)
    return val


def kernel_operands(model) -> tuple:
    """Device-ready ensemble operands for ``gbrt_predict_blocked``.

    Returns ``(features i32, thresholds f32, leaves f32)`` as jnp arrays.
    +inf thresholds mark pass-through nodes; the kernel compares in f32, so
    thresholds are clipped to the finite f32 range host-side. Shared by the
    wrapper below and the device-resident placement core
    (``repro.core.jax_core``); hosted once per model identity (weakref-guarded
    — refit-by-swap invalidates automatically).
    """
    def build():
        big = np.float32(3.0e38)
        thr = np.clip(model.thresholds, -big, big).astype(np.float32)
        return (jnp.asarray(np.asarray(model.features, np.int32)),
                jnp.asarray(thr),
                jnp.asarray(np.asarray(model.leaves, np.float32)))

    return _cached(_OPERANDS, id(model), (model,), build)


def multi_kernel_operands(models) -> tuple:
    """Stacked, padded operands for the blocked ``gbrt_predict_multi`` launch.

    Pads every config's ensemble to the common ``(T, I, L)`` of the deepest /
    widest one so a single (n_configs, row-blocks) grid covers them all, while
    staying BIT-IDENTICAL per config to the per-config launches:

    - extra trees are all-pass-through (+big thresholds) with zero leaves —
      each contributes exactly ``+0.0f``;
    - a depth-``d`` tree padded to depth ``dmax`` extends every walk through
      pass-through levels (``x > +big`` is always false), landing on the
      leftmost descendant — leaf ``j`` maps to ``j << (dmax - d)``, so leaf
      values are scattered to those slots and the lookup is exact;
    - the learning-rate multiply stays in-kernel (per-config ``lr`` operand),
      preserving the FMA-contracted ``acc + lr * contrib`` accumulation of
      the per-config kernel bit-for-bit.

    Returns ``(features (C,T,I) i32, thresholds (C,T,I) f32, leaves (C,T,L)
    f32, lr (C,1) f32, base (C,1) f32, depth)`` with all but ``depth`` as jnp
    arrays. Cached per model-identity tuple (weakref-guarded, refit-by-swap
    safe).
    """
    models = tuple(models)

    def build():
        big = np.float32(3.0e38)
        depths = [int(m.config.max_depth) for m in models]
        dmax = max(depths)
        tmax = max(int(np.asarray(m.features).shape[0]) for m in models)
        n_int, n_leaf = 2 ** dmax - 1, 2 ** dmax
        C = len(models)
        F = np.zeros((C, tmax, n_int), np.int32)
        TH = np.full((C, tmax, n_int), big, np.float32)
        LV = np.zeros((C, tmax, n_leaf), np.float32)
        LR = np.zeros((C, 1), np.float32)
        BASE = np.zeros((C, 1), np.float32)
        for c, m in enumerate(models):
            f = np.asarray(m.features, np.int32)
            th = np.clip(m.thresholds, -big, big).astype(np.float32)
            lv = np.asarray(m.leaves, np.float32)
            t, i = f.shape
            F[c, :t, :i] = f
            TH[c, :t, :i] = th
            LV[c, :t, ::1 << (dmax - depths[c])] = lv
            LR[c, 0] = np.float32(m.config.learning_rate)
            BASE[c, 0] = np.float32(m.base)
        return (jnp.asarray(F), jnp.asarray(TH), jnp.asarray(LV),
                jnp.asarray(LR), jnp.asarray(BASE), dmax)

    key = tuple(id(m) for m in models)
    return _cached(_MULTI_OPERANDS, key, models, build)


def gbrt_predict(model, x, *, block_n: int = 256,
                 interpret: bool | None = None) -> np.ndarray:
    """model: repro.core.gbrt.GBRT; x: (N, F). Returns np.ndarray (N,)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    x = np.asarray(x, np.float32)
    if x.ndim == 1:
        x = x[:, None]
    N = x.shape[0]
    feats, thr, lvs = kernel_operands(model)
    bn = min(block_n, max(N, 1))
    pad = (-N) % bn
    if pad:
        x = np.pad(x, ((0, pad), (0, 0)))
    out = gbrt_predict_blocked(
        jnp.asarray(x), feats, thr, lvs,
        depth=model.config.max_depth, lr=float(model.config.learning_rate),
        base=float(model.base), block_n=bn, interpret=interpret)
    return np.asarray(out)[:N]
