from repro.kernels.gbrt_predict import ops, ref  # noqa: F401
