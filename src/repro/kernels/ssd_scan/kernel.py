"""Mamba-2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

The SSD insight (arXiv:2405.21060) is itself a hardware adaptation: a linear
recurrence re-expressed so that *within-chunk* work is a masked attention-like
matmul (MXU food) and only a tiny (head_dim × state) recurrence crosses chunk
boundaries. This kernel maps that structure onto the TPU grid directly:

- grid = (batch, heads, num_chunks); the chunk axis is innermost and
  **sequential**, so the running state h ∈ (head_dim, d_state) fp32 lives in
  VMEM scratch across chunk steps — the inter-chunk recurrence never touches
  HBM;
- per chunk, three MXU contractions: scores = C·Bᵀ (Q×Q), y_intra = scores·x,
  state update = xᵀ·B — all fp32-accumulated;
- decay factors come from a within-chunk cumulative sum of dt·A computed in
  log space (exact, no overflow: A < 0 so all exponents are ≤ 0);
- chunk size Q defaults to 128 (MXU-aligned); B/C blocks are shared across
  heads via index maps that drop the head coordinate.

Emits both y and the final state (prefill hands the state to decode).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, h_ref, *,
                Q: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)      # (Q, hd)
    dt = dt_ref[0, 0].astype(jnp.float32)    # (Q,)
    a = a_ref[0].astype(jnp.float32)         # scalar
    bm = b_ref[0].astype(jnp.float32)        # (Q, ds)
    cm = c_ref[0].astype(jnp.float32)        # (Q, ds)

    dA = dt * a                               # (Q,) all <= 0
    cum = jnp.cumsum(dA)                      # (Q,)
    total = cum[-1]

    # ---- intra-chunk: masked attention-like matmul -------------------------
    seg = cum[:, None] - cum[None, :]         # (Q, Q) log-decay q<-s
    row = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.exp(jnp.where(row >= col, seg, NEG_INF))
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # (Q, Q)
    scores = scores * L * dt[None, :]         # dt_s scales column s
    y = jax.lax.dot_general(
        scores, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # (Q, hd)

    # ---- inter-chunk: contribution of the carried state --------------------
    h_prev = h_ref[...]                       # (hd, ds)
    y_inter = jax.lax.dot_general(
        cm, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # (Q, hd)
    y = y + y_inter * jnp.exp(cum)[:, None]

    # ---- state update -------------------------------------------------------
    w = (dt * jnp.exp(total - cum))[:, None]      # (Q, 1)
    h_new = h_prev * jnp.exp(total) + jax.lax.dot_general(
        x * w, bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # (hd, ds)
    h_ref[...] = h_new

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        st_ref[0, 0] = h_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_bhsd(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = True):
    """x: (b, H, S, hd); dt: (b, H, S) fp32 (post-softplus); A: (H,) negative;
    B/C: (b, S, ds). S must be a multiple of ``chunk`` (ops.py pads).

    Returns (y (b, H, S, hd), final_state (b, H, hd, ds) fp32).
    """
    b, H, S, hd = x.shape
    ds = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    kernel = functools.partial(_ssd_kernel, Q=Q, nc=nc)

    y, state = pl.pallas_call(
        kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, hd), lambda bi, h, ci: (bi, h, ci, 0)),
            pl.BlockSpec((1, 1, Q), lambda bi, h, ci: (bi, h, ci)),
            pl.BlockSpec((1,), lambda bi, h, ci: (h,)),
            pl.BlockSpec((1, Q, ds), lambda bi, h, ci: (bi, ci, 0)),
            pl.BlockSpec((1, Q, ds), lambda bi, h, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, hd), lambda bi, h, ci: (bi, h, ci, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda bi, h, ci: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, H, S, hd), x.dtype),
            jax.ShapeDtypeStruct((b, H, hd, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, state
