"""Public wrapper for the SSD kernel: model layout, padding, interpret."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_bhsd


def ssd(x, dt, A, B, C, *, chunk: int = 128, interpret: bool | None = None):
    """Model layout in/out: x (b, S, nh, hd); dt (b, S, nh) fp32; A (nh,);
    B/C (b, S, ds). Returns (y (b, S, nh, hd), final_state (b, nh, hd, ds)).

    Zero-padding the tail chunk is inert: dt=0 ⇒ decay exp(0)=1 and zero input
    contribution, so the carried state passes through padded steps unchanged.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, S, nh, hd = x.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    xt = jnp.moveaxis(x, 2, 1)               # (b, nh, S, hd)
    dtt = jnp.moveaxis(dt, 2, 1)             # (b, nh, S)
    if pad:
        xt = jnp.pad(xt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dtt = jnp.pad(dtt, ((0, 0), (0, 0), (0, pad)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    y, state = ssd_scan_bhsd(
        xt, dtt.astype(jnp.float32), A.astype(jnp.float32), B, C,
        chunk=Q, interpret=interpret)
    y = jnp.moveaxis(y, 1, 2)[:, :S]          # (b, S, nh, hd)
    return y, state
