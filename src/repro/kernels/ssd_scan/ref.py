"""Pure-jnp oracle for ssd_scan: the literal SSD recurrence, step by step."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, A, B, C):
    """Literal recurrence (fp32). Layout matches the model:
    x: (b, S, nh, hd); dt: (b, S, nh); A: (nh,); B/C: (b, S, ds).
    Returns (y (b, S, nh, hd), final_state (b, nh, hd, ds))."""
    b, S, nh, hd = x.shape

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        decay = jnp.exp(dt_t * A)[:, :, None, None]
        upd = (dt_t[:, :, None] * x_t)[..., None] * B_t[:, None, None, :]
        h = h * decay + upd
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y

    h0 = jnp.zeros((b, nh, hd, B.shape[-1]), jnp.float32)
    xs = (
        x.astype(jnp.float32).transpose(1, 0, 2, 3),
        dt.astype(jnp.float32).transpose(1, 0, 2),
        B.astype(jnp.float32).transpose(1, 0, 2),
        C.astype(jnp.float32).transpose(1, 0, 2),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h
