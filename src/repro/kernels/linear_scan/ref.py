"""Pure-jnp oracle for linear_scan: associative scan of h_t = a_t h_{t-1} + x_t."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan_ref(x, a):
    """x, a: (B, S, D). Returns (h (B, S, D), final_state (B, D)). fp32."""

    def combine(l, r):
        a_l, b_l = l
        a_r, b_r = r
        return a_l * a_r, a_r * b_l + b_r

    x = x.astype(jnp.float32)
    a = a.astype(jnp.float32)
    _, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h, h[:, -1]
