"""Public wrapper for the RG-LRU linear scan kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.linear_scan.kernel import linear_scan_bsd


def linear_scan(x, a, *, chunk: int = 256, interpret: bool | None = None):
    """x, a: (B, S, D). Returns (h (B, S, D) fp32, final_state (B, D) fp32).

    Tail padding uses (a=1, x=0): the state passes through unchanged.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, D = x.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    y, state = linear_scan_bsd(x, a, chunk=Q, interpret=interpret)
    return y[:, :S], state


def prefix_sum(delta, *, chunk: int = 256, interpret: bool | None = None):
    """Inclusive prefix sum of a 1-D sequence via the scan kernel (a ≡ 1).

    ``delta``: (S,). Returns an (S,) fp32 array with ``out[i] = Σ_{j<=i}
    delta[j]``. A plain running sum is the degenerate RG-LRU recurrence with
    unit decay, so this routes the surplus-bank prefix of the device
    placement core (``repro.core.jax_core``, ``SURPLUS_LINEAR_SCAN``) through
    the same blocked kernel. fp32 accumulation: decision-equality use only.
    """
    x = jnp.asarray(delta, jnp.float32)[None, :, None]
    a = jnp.ones(x.shape, jnp.float32)
    h, _state = linear_scan(x, a, chunk=chunk, interpret=interpret)
    return h[0, :, 0]
