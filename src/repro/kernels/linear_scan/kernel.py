"""RG-LRU gated linear recurrence Pallas TPU kernel.

Computes h_t = a_t ⊙ h_{t-1} + x_t ⊙ u_t along the sequence, with per-channel
gates a_t ∈ (0, 1] (Griffin / RecurrentGemma's recurrent core).

Unlike SSD, the decay here is *per-channel* (a_t is (S, D)), so the
chunk-as-matmul trick would need a (Q, Q, D) decay tensor — not VMEM-viable.
The TPU-natural structure instead is the classic sequential-in-S, vector-in-D
scan (this is how the production RecurrentGemma Pallas kernel works too):

- grid = (batch, num_chunks) with the chunk axis sequential; the carried state
  h ∈ (1, D) fp32 persists in VMEM scratch across chunks;
- within a chunk, a ``fori_loop`` walks the Q rows; every step is a fused
  multiply-add over a (1, D) vector — VPU-lane parallel across the model
  dimension, which is the wide axis (d_rnn = 4096 for recurrentgemma-9b);
- chunking exists purely to bound the VMEM block: (Q, D) in/out tiles double-
  buffer HBM↔VMEM while the inner loop runs.

I/O is fp32: the model computes gates in fp32 and consumes h in fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, a_ref, y_ref, st_ref, h_ref, *, Q: int, nc: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def body(t, h):
        a_t = a_ref[0, pl.dslice(t, 1), :]      # (1, D)
        x_t = x_ref[0, pl.dslice(t, 1), :]
        h = a_t * h + x_t
        y_ref[0, pl.dslice(t, 1), :] = h
        return h

    h = jax.lax.fori_loop(0, Q, body, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == nc - 1)
    def _emit():
        st_ref[0] = h


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def linear_scan_bsd(x, a, *, chunk: int = 256, interpret: bool = True):
    """x, a: (B, S, D) fp32. Returns (h (B, S, D), final_state (B, D)).

    S must be a multiple of ``chunk`` (ops.py pads with a=1, x=0 — inert).
    """
    B, S, D = x.shape
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    kernel = functools.partial(_scan_kernel, Q=Q, nc=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, Q, D), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, Q, D), lambda b, ci: (b, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, D), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, 1, D), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B, 1, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32), a.astype(jnp.float32))
    return y, state[:, 0]
