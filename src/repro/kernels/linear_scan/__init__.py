from repro.kernels.linear_scan import ops, ref  # noqa: F401
