"""Flash attention Pallas TPU kernel (causal / local-window, GQA).

TPU adaptation of the flash-attention insight (the paper-of-record GPU
algorithm re-blocked for the TPU memory hierarchy):

- grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the kv axis is the
  innermost, **sequential** grid dimension, so the online-softmax state
  (m, l, acc) lives in VMEM scratch that persists across kv steps — the TPU
  equivalent of a CUDA thread-block's shared-memory accumulator;
- Q/K/V blocks are staged HBM→VMEM by BlockSpec index maps. GQA is expressed
  in the K/V index maps (``h // group``) so K/V blocks are fetched once per
  query-head group rather than materialized repeated;
- block shapes default to (128, head_dim): 128 is the MXU systolic dimension,
  and three (128, D) tiles + (128, 128) scores fit comfortably in the ~16 MB
  VMEM budget for every head_dim in the model zoo (64–256);
- fully-masked (q, kv) block pairs are *skipped* (``pl.when``): for causal
  attention this halves compute; for local windows it makes long-context
  prefill cost O(S·W) instead of O(S²) — this is the banded-attention
  optimization recorded in EXPERIMENTS.md §Perf.

Softmax statistics are fp32 regardless of input dtype (bf16-safe).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38  # fp32-representable; avoids -inf NaN hazards in exp


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: int, bq: int, bk: int,
               nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk

    # --- block-level skip: is any (q, k) pair in this tile unmasked? --------
    live = jnp.bool_(True)
    if causal:
        # need k_start <= q_end
        live &= k_start <= q_start + bq - 1
    if window and window > 0:
        # need k_end > q_start - window
        live &= k_start + bk - 1 > q_start - window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.bool_(True)
        if causal:
            mask &= k_pos <= q_pos
        if window and window > 0:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True):
    """q: (B, H, Sq, D); k/v: (B, Hkv, Skv, D). Returns (B, H, Sq, D).

    Sq must be a multiple of block_q and Skv of block_k (ops.py pads).
    """
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert H % Hkv == 0, (H, Hkv)
    group = H // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, bq, Skv, bk)
    nq, nk = Sq // bq, Skv // bk
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            # online-softmax state, persistent across the sequential kv axis
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
