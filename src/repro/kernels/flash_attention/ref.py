"""Pure-jnp oracle for flash_attention (naive full-matrix softmax attention)."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, H, D).

    Full (B, H, Sq, Skv) score matrix in fp32; the memory-unbounded reference.
    """
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) / (D ** 0.5)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window and window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return out.reshape(B, Sq, H, D).astype(q.dtype)
