"""Public wrapper: layout, padding, interpret-mode selection.

Model code calls ``flash_attention(q, k, v)`` with the (B, S, H, D) layout the
rest of the stack uses; this wrapper transposes to the kernel's (B, H, S, D),
pads sequences to block multiples (padded key blocks are masked out by the
causal/window mask plus an explicit length mask on the final block), and picks
``interpret=True`` automatically off-TPU so CPU tests execute the exact kernel
body the fleet runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D) -> (B, Sq, H, D)."""
    if interpret is None:
        interpret = _interpret_default()
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    bq = min(block_q, max(Sq, 1))
    bk = min(block_k, max(Skv, 1))
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk

    qt = jnp.moveaxis(q, 2, 1)  # (B, H, Sq, D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # Padded keys sit at positions >= Skv. Under a causal mask every real
        # query (pos < Sq <= padded-key pos) ignores them iff Sq <= Skv; for
        # the general case we mask them via a NEG_INF key: zero K would still
        # get weight, so instead shift padded K positions out of every window
        # by masking in the kernel through the causal test — guaranteed when
        # Sq == Skv (self-attention, the only case the model uses). Assert it.
        assert causal and Sq == Skv, "key padding requires causal self-attention"
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               block_q=bq, block_k=bk, interpret=interpret)
    out = jnp.moveaxis(out, 1, 2)
    return out[:, :Sq]
