"""Flash-decode Pallas TPU kernel: one query token vs. a length-masked KV cache.

Decode attention is memory-bound: per step it streams the whole KV cache from
HBM once and does O(S·D) FLOPs. The kernel therefore:

- iterates kv blocks as the innermost sequential grid axis, carrying the
  online-softmax state (m, l, acc) in VMEM scratch — one HBM pass, no
  (S,)-sized intermediates;
- masks cache slots ``>= length_b`` (per-batch valid lengths; ring-buffer
  caches pass length = capacity once full);
- skips kv blocks entirely past every valid slot (``pl.when``), so short
  sequences in a long cache don't pay for dead blocks;
- the query tile is (1, D) per (batch, head) — decode has no q parallelism to
  tile, so batch×heads is the parallel grid surface (matching TPU cores via
  the megacore grid split on real hardware).

lengths ride in SMEM (scalar memory): they gate control flow, not vectors.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                scale: float, bk: int, nk: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    length = len_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ki * bk

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (1, bk)
        slot = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(slot < length, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention_bhd(q, k, v, lengths, *, block_k: int = 256,
                         interpret: bool = True):
    """q: (B, H, 1, D); k/v: (B, Hkv, S, D); lengths: (B,) int32 -> (B, H, 1, D)."""
    B, H, _, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    group = H // Hkv
    bk = min(block_k, S)
    assert S % bk == 0, (S, bk)
    nk = S // bk
    scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(_dec_kernel, scale=scale, bk=bk, nk=nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nk),
        in_specs=[
            # index maps receive the scalar-prefetch ref as a trailing arg
            pl.BlockSpec((1, 1, 1, D), lambda b, h, ki, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, ki, lens: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, ki, lens: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, ki, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, 1, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), q, k, v)
