"""Pure-jnp oracle for decode_attention (naive length-masked attention)."""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -2.0e38


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: (B, 1, H, D); caches: (B, S, Hkv, D); lengths: (B,) -> (B, 1, H, D)."""
    B, _, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, 1, Hkv, G, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k_cache.astype(jnp.float32))
    s = s / (D ** 0.5)
    valid = jnp.arange(S)[None, :] < lengths[:, None]  # (B, S)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)
