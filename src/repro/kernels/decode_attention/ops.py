"""Public wrapper for the flash-decode kernel: layout + padding + interpret."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_bhd


def decode_attention(q, k_cache, v_cache, lengths, *, block_k: int = 256,
                     interpret: bool | None = None):
    """q: (B, 1, H, D); caches: (B, S, Hkv, D); lengths: (B,) -> (B, 1, H, D)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, _, H, D = q.shape
    S = k_cache.shape[1]
    bk = min(block_k, S)
    pad = (-S) % bk
    kt = jnp.moveaxis(k_cache, 2, 1)  # (B, Hkv, S, D)
    vt = jnp.moveaxis(v_cache, 2, 1)
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qt = jnp.moveaxis(q, 2, 1)  # (B, H, 1, D)
    out = decode_attention_bhd(qt, kt, vt, lengths, block_k=bk,
                               interpret=interpret)
    return jnp.moveaxis(out, 1, 2)  # (B, 1, H, D)
