"""Gradient compression for cross-pod data parallelism.

At multi-pod scale the DP all-reduce over the (slow) pod interconnect is the
marginal collective; compressing what crosses it is a standard lever. Two
composable schemes, both with error feedback so compression error accumulates
into the next step instead of being lost (Stich et al.; 1-bit Adam lineage):

- ``topk``: keep the top-k fraction of entries by magnitude per tensor;
- ``int8``: per-tensor scale, stochastic rounding.

``compress_decompress`` is the in-graph simulation used by the train step:
grad -> compress -> decompress + error-feedback state. On a real fleet the
compressed representation is what crosses the pod axis; the roofline benefit
is byte-count, which ``compressed_bytes`` reports for EXPERIMENTS.md.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "none"  # "none" | "topk" | "int8"
    topk_frac: float = 0.05
    seed: int = 0


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_cd(g, frac: float):
    """Top-|g| sparsification: returns the dense decompressed tensor."""
    flat = g.reshape(-1)
    k = max(int(np.ceil(flat.shape[0] * frac)), 1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros_like(flat).at[idx].set(1.0)
    return (flat * mask).reshape(g.shape)


def _int8_cd(g, key):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    scaled = g / scale
    noise = jax.random.uniform(key, g.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def compress_decompress(grads, error_state, cfg: CompressionConfig, step=0):
    """Error-feedback compression: returns (decompressed grads, new error state)."""
    if cfg.scheme == "none":
        return grads, error_state

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error_state)
    out_g, out_e = [], []
    for i, (g, e) in enumerate(zip(flat_g, flat_e)):
        corrected = g.astype(jnp.float32) + e
        if cfg.scheme == "topk":
            d = _topk_cd(corrected, cfg.topk_frac)
        elif cfg.scheme == "int8":
            key = jax.random.fold_in(jax.random.key(cfg.seed), step * 10_000 + i)
            d = _int8_cd(corrected, key)
        else:
            raise ValueError(f"unknown compression scheme {cfg.scheme!r}")
        out_g.append(d.astype(g.dtype))
        out_e.append(corrected - d)
    return treedef.unflatten(out_g), treedef.unflatten(out_e)


def compressed_bytes(params, cfg: CompressionConfig) -> int:
    """Bytes that cross the pod axis per step under this scheme (for §Roofline)."""
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    if cfg.scheme == "none":
        return n * 4
    if cfg.scheme == "topk":
        k = int(np.ceil(n * cfg.topk_frac))
        return k * (4 + 4)  # value + index
    if cfg.scheme == "int8":
        return n * 1 + 4
    raise ValueError(cfg.scheme)
