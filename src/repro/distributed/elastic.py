"""Elastic re-sharding: restore a checkpoint onto a *different* mesh.

Checkpoints are saved host-side as full (unsharded) arrays
(repro.training.checkpoint), so elasticity reduces to: load → build the new
mesh's shardings from the same logical axes → ``jax.device_put`` each array
with its new NamedSharding. Scale 256→512 chips (or degrade 512→256 after
losing a pod) without touching the checkpoint format.

``reshard_tree`` is also the restart path after a failed pod: the supervisor
re-invokes the launcher with the surviving mesh and resumes from LATEST.
"""

from __future__ import annotations

import jax

from repro.distributed.sharding import make_rules, param_shardings


def reshard_tree(tree: dict, specs: dict, cfg, mesh, fsdp: bool = False) -> dict:
    """Place a flat {path: host_array} tree onto ``mesh`` per logical axes."""
    rules = make_rules(cfg, mesh, fsdp=fsdp)
    shardings = param_shardings(specs, rules, mesh)
    out = {}
    for path, arr in tree.items():
        s = shardings.get(path)
        out[path] = jax.device_put(arr, s) if s is not None else jax.device_put(arr)
    return out


def elastic_restore(ckpt_dir: str, model, cfg, mesh, fsdp: bool = False):
    """restore_latest + reshard onto ``mesh``. Returns (step, params, state)."""
    from repro.training import checkpoint as ckpt

    resumed = ckpt.restore_latest(ckpt_dir)
    if resumed is None:
        return None
    step, tree = resumed
    specs = model.param_specs()
    params = reshard_tree(tree["params"], specs, cfg, mesh, fsdp=fsdp)
    # optimizer moments mirror the parameter shardings
    state = tree["state"]
    state["opt"]["m"] = reshard_tree(state["opt"]["m"], specs, cfg, mesh, fsdp=fsdp)
    state["opt"]["v"] = reshard_tree(state["opt"]["v"], specs, cfg, mesh, fsdp=fsdp)
    return step, params, state
