"""Distribution substrate: logical-axis sharding rules, mesh helpers, compression."""
