"""Logical-axis sharding: one rules table maps logical axis names to mesh axes.

MaxText-style: every parameter (via ``ParamSpec.axes``) and key activation (via
``shard(x, axes)`` calls inside model code) is annotated with *logical* names.
``make_rules(cfg, mesh)`` resolves those names to physical mesh axes, checking
divisibility per architecture — e.g. gemma-2b's 8 query heads cannot shard over
a 16-way model axis, so "heads" resolves to None (replicated) there and the
d_ff/vocab axes carry the model parallelism instead.

``shard()`` is a no-op outside an active sharding context, so single-device
smoke tests run the exact same model code.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


@dataclass(frozen=True)
class ShardingCtx:
    mesh: Mesh
    rules: dict[str, tuple[str, ...] | None]


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def make_rules(cfg, mesh: Mesh, fsdp: bool = False,
               serving: bool = False) -> dict[str, tuple[str, ...] | None]:
    """Resolve logical axis names to mesh axes for one architecture.

    ``serving=True`` + ``cfg.serve_2d_ffn`` (§Perf): FFN / expert-FFN weight
    dims shard over model×data so giant serving weights are fully distributed
    WITHOUT per-step FSDP all-gathers — the partial-sum all-reduce moves to
    the (tiny at decode) activations instead of the weights.
    """
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    model_ax = ("model",) if "model" in mesh.shape else None
    m = _axis_size(mesh, model_ax)

    def if_div(dim: int, axes):
        return axes if axes and dim % _axis_size(mesh, axes) == 0 else None

    kv_heads = if_div(getattr(cfg, "n_kv_heads", 0) or 0, model_ax)

    # "rnn" names several related recurrent widths; shard only if every tensor
    # dim carrying it divides the model axis. For SSM that is the in_proj
    # output (2·d_inner + 2·ds + nh), the conv channel (d_inner + 2·ds) and
    # d_inner itself; for Griffin it is d_rnn.
    rnn_dims: list[int] = []
    if getattr(cfg, "d_rnn", 0):
        rnn_dims = [cfg.d_rnn]
    elif getattr(cfg, "ssm_state", 0):
        d_inner = cfg.ssm_expand * cfg.d_model
        nh = d_inner // cfg.ssm_head_dim
        ds = cfg.ssm_state
        rnn_dims = [2 * d_inner + 2 * ds + nh, d_inner + 2 * ds, d_inner]
    rnn_ok = bool(rnn_dims) and all(
        d % _axis_size(mesh, model_ax) == 0 for d in rnn_dims)

    rules: dict[str, tuple[str, ...] | None] = {
        "batch": data_axes or None,
        "embed": None,
        "embed_fsdp": None,
        "heads": if_div(cfg.n_heads, model_ax),
        "kv_heads": kv_heads,
        "head_dim": None,
        "mlp": if_div(cfg.d_ff or 0, model_ax),
        "vocab": if_div(cfg.vocab, model_ax),
        "experts": if_div(getattr(cfg, "n_experts", 0) or 0, model_ax),
        # expert-internal FF: shard over model ONLY when experts cannot
        # (otherwise the same mesh axis would appear twice in one spec)
        "expert_mlp": (
            None if if_div(getattr(cfg, "n_experts", 0) or 0, model_ax)
            else if_div(getattr(cfg, "d_ff_expert", 0) or 0, model_ax)),
        "rnn_blocks": if_div(getattr(cfg, "rglru_block_gates", 0) or 0,
                             model_ax),
        # activation counterpart of "mlp": always model-only (activations are
        # already batch-sharded over the data axes)
        "mlp_act": if_div(cfg.d_ff or 0, model_ax),
        "rnn": model_ax if rnn_ok else None,
        "ssm_heads": if_div(
            (cfg.ssm_expand * cfg.d_model // cfg.ssm_head_dim)
            if getattr(cfg, "ssm_state", 0) else 0, model_ax),
        "layers": None,
        # activation sequence axis: used only by the cp_attn / sp_acts §Perf
        # knobs (gated in model code); sequence lengths are model-axis aligned
        "seq": model_ax,
        # GQA/MQA with few KV heads: shard the KV-cache *sequence* axis over
        # the model axis instead (flash-decode style); GSPMD inserts the
        # softmax-denominator all-reduce.
        "kv_seq": model_ax if (model_ax and kv_heads is None
                               and (getattr(cfg, "n_kv_heads", 0) or 0) > 0)
                  else None,
    }
    if serving and getattr(cfg, "serve_2d_ffn", False):
        mlp2d = (model_ax or ()) + data_axes
        if cfg.d_ff and cfg.d_ff % _axis_size(mesh, mlp2d) == 0:
            rules["mlp"] = mlp2d
        if rules["experts"] is not None:
            dfe = getattr(cfg, "d_ff_expert", 0) or 0
            rules["expert_mlp"] = if_div(dfe, data_axes)
    elif fsdp:
        # FSDP: shard the d_model axis of weights over the data axes too
        # (params are gathered just-in-time by GSPMD; optimizer state stays sharded).
        rules["embed"] = if_div(cfg.d_model, data_axes)
        rules["embed_fsdp"] = rules["embed"]
    return rules


def spec_for(axes, rules) -> P:
    parts = []
    for a in axes:
        r = rules.get(a) if a is not None else None
        if r is None:
            parts.append(None)
        elif len(r) == 1:
            parts.append(r[0])
        else:
            parts.append(tuple(r))
    return P(*parts)


def param_shardings(specs, rules, mesh) -> dict:
    """NamedShardings for a ``param_specs`` dict."""
    return {
        path: NamedSharding(mesh, spec_for(s.axes, rules))
        for path, s in specs.items()
    }


# ------------------------------------------------------------------ context
@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, rules: dict):
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = ShardingCtx(mesh=mesh, rules=rules)
    try:
        yield
    finally:
        _STATE.ctx = prev


def current_ctx() -> ShardingCtx | None:
    return getattr(_STATE, "ctx", None)


def shard(x, axes):
    """Annotate activation ``x`` with logical axes; no-op without a context."""
    ctx = current_ctx()
    if ctx is None:
        return x
    spec = spec_for(axes, ctx.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def axis_ways(logical: str) -> int:
    """Mesh size a logical axis resolves to (0 outside a sharding context)."""
    ctx = current_ctx()
    if ctx is None:
        return 0
    r = ctx.rules.get(logical)
    if not r:
        return 0
    size = 1
    for a in r:
        size *= ctx.mesh.shape[a]
    return size
