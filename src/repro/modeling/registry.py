"""Model registry: ArchConfig.family -> model class."""

from __future__ import annotations

from repro.modeling.encoder import AudioEncoder
from repro.modeling.griffin import GriffinLM
from repro.modeling.lm import LM
from repro.modeling.mamba import MambaLM

FAMILIES = {
    "dense": LM,
    "moe": LM,
    "vlm": LM,
    "hybrid": GriffinLM,
    "audio": AudioEncoder,
    "ssm": MambaLM,
}


def build_model(cfg):
    try:
        cls = FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r} for arch {cfg.name!r}")
    return cls(cfg)
