"""Attention: GQA/MQA/MHA with flash-style chunked online computation.

Two implementations, selected by ``cfg.attn_impl``:

- ``xla``: pure-JAX chunked attention (lax.scan over query blocks with a
  remat'd body) — memory-bounded like flash attention, shardable under pjit,
  compilable on any backend. This is the path the multi-pod dry-run exercises.
- ``pallas``: the TPU Pallas kernels in ``repro.kernels`` (flash_attention /
  decode_attention). Validated in interpret mode on CPU; the TARGET on real
  TPU fleets.

GQA is computed with grouped einsums on (B, S, Hkv, G, D) — K/V are never
materialized repeated across query heads.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def _block_mask(q_pos, k_pos, causal: bool, window: int):
    """(qc, S) boolean mask: True = attend."""
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), dtype=bool)
    if causal:
        m &= k_pos[None, :] <= q_pos[:, None]
    if window and window > 0:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def _attend_block(q_blk, k, v, q_pos, k_pos, causal, window, scale):
    """One query block vs. full K/V. q_blk: (B, qc, Hkv, G, D); k/v: (B, S, Hkv, D)."""
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", q_blk, k, preferred_element_type=jnp.float32
    ) * scale
    mask = _block_mask(q_pos, k_pos, causal, window)  # (qc, S)
    scores = jnp.where(mask[None, None, None, :, :], scores, NEG_INF)
    scores = scores - jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
    probs = jnp.exp(scores)
    denom = jnp.sum(probs, axis=-1, keepdims=True)
    probs = (probs / jnp.maximum(denom, 1e-30)).astype(v.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


def chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      q_chunk: int = 512, banded: bool = False):
    """Flash-style attention. q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D).

    Scans over query chunks with a remat'd body, so peak memory is
    O(B · H · q_chunk · Skv) instead of O(B · H · Sq · Skv), and the backward
    pass recomputes block scores instead of storing them.

    ``banded=True`` (§Perf, local windows only): each query chunk attends to a
    dynamic K/V slice of static length window+q_chunk instead of the full
    sequence — O(S·(W+qc)) compute instead of O(S²); at 32k context with a
    2048-window this is ~13× fewer attention FLOPs.
    """
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / (D ** 0.5)
    qc = min(q_chunk, Sq)
    pad = (-Sq) % qc
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sq_p = Sq + pad
    n_blocks = Sq_p // qc

    qg = q.reshape(B, n_blocks, qc, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5)
    use_band = (banded and causal and window and window > 0
                and window + qc < Skv and Sq == Skv)
    band = min(window + qc, Skv) if use_band else Skv

    @jax.checkpoint
    def body(carry, blk):
        q_blk, blk_idx = blk
        q_pos = blk_idx * qc + jnp.arange(qc)
        if use_band:
            # static-size K/V slice covering [q_end - band, q_end)
            start = jnp.clip(blk_idx * qc + qc - band, 0, Skv - band)
            k_blk = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            k_pos_blk = start + jnp.arange(band)
        else:
            k_blk, v_blk = k, v
            k_pos_blk = jnp.arange(Skv)
        out = _attend_block(q_blk, k_blk, v_blk, q_pos, k_pos_blk, causal,
                            window, scale)
        return carry, out

    if n_blocks == 1:
        _, out = body(None, (qg[0], jnp.asarray(0)))
        out = out[None]
    else:
        _, out = jax.lax.scan(body, None, (qg, jnp.arange(n_blocks)))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq_p, H, D)
    return out[:, :Sq]


def decode_attention(q, k_cache, v_cache, length, *, window: int = 0,
                     positions=None, impl: str = "xla"):
    """Single-token attention against a KV cache.

    q: (B, 1, H, D); caches: (B, S, Hkv, D); length: (B,) valid cache lengths
    (entries at index >= length are masked). ``positions`` optionally gives the
    absolute position of each cache slot (for ring-buffer local-window caches).

    ``impl="pallas"`` dispatches to the flash-decode kernel when the mask is a
    pure length mask (ring-buffer caches need no window filter: every resident
    slot is within the window by construction).
    """
    if impl == "pallas" and not (window and window > 0):
        from repro.kernels.decode_attention import ops as da_ops

        return da_ops.decode_attention(q, k_cache, v_cache, length)
    B, _, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, 1, Hkv, G, D)
    scores = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale  # (B, Hkv, G, 1, S)
    slot = jnp.arange(S)
    valid = slot[None, :] < length[:, None]  # (B, S)
    if window and window > 0 and positions is not None:
        cur = jnp.max(jnp.where(valid, positions, -1), axis=1, keepdims=True)
        valid &= positions > (cur - window)
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores)
    probs = probs / jnp.maximum(jnp.sum(probs, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, D)


def cp_chunked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                         q_chunk: int = 512, ways: int = 16, shard_fn=None):
    """Context-parallel flash-style attention (§Perf H1.2).

    A plain ``lax.scan`` over query chunks serializes exactly the dimension
    context parallelism needs to shard (scan trips cannot be partitioned —
    measured: a with_sharding_constraint on q changed nothing, EXPERIMENTS.md
    §Perf H1.1). Restructure: fold the sequence into (outer, ways, qc) where
    ``ways`` is a TENSOR dim sharded over the model axis; the scan runs over
    ``outer`` only. Per-device score traffic and attention FLOPs drop ~ways×
    for archs whose head count cannot shard (gemma: 8 q-heads, llama4: 40).
    """
    shard_fn = shard_fn or (lambda a, axes: a)
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / (D ** 0.5)
    qc = min(q_chunk, max(Sq // ways, 1))
    span = ways * qc
    pad = (-Sq) % span
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sq_p = Sq + pad
    outer = Sq_p // span

    qg = q.reshape(B, outer, ways, qc, Hkv, G, D).transpose(1, 0, 2, 3, 4, 5, 6)
    k_pos = jnp.arange(Skv)

    @jax.checkpoint
    def body(carry, blk):
        q_blk, o_idx = blk  # (B, ways, qc, Hkv, G, D)
        q_blk = shard_fn(q_blk, ("batch", "seq", None, None, None, None))
        q_pos = (o_idx * span
                 + jnp.arange(ways)[:, None] * qc
                 + jnp.arange(qc)[None, :])  # (ways, qc)
        s = jnp.einsum("bwqkgd,bskd->bwkgqs", q_blk, k,
                       preferred_element_type=jnp.float32) * scale
        s = shard_fn(s, ("batch", "seq", None, None, None, None))
        mask = jnp.ones((ways, qc, Skv), bool)
        if causal:
            mask &= k_pos[None, None, :] <= q_pos[:, :, None]
        if window and window > 0:
            mask &= k_pos[None, None, :] > (q_pos[:, :, None] - window)
        s = jnp.where(mask[None, :, None, None, :, :], s, NEG_INF)
        s = s - jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s)
        p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        out = jnp.einsum("bwkgqs,bskd->bwqkgd", p.astype(v.dtype), v)
        return carry, shard_fn(out, ("batch", "seq", None, None, None, None))

    if outer == 1:
        _, out = body(None, (qg[0], jnp.asarray(0)))
        out = out[None]
    else:
        _, out = jax.lax.scan(body, None, (qg, jnp.arange(outer)))
    out = out.transpose(1, 0, 2, 3, 4, 5, 6).reshape(B, Sq_p, H, D)
    return out[:, :Sq]


def attention(q, k, v, *, causal=True, window=0, q_chunk=512, impl="xla",
              banded=False, cp_ways=0, shard_fn=None):
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        return fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    if cp_ways and cp_ways > 1:
        return cp_chunked_attention(q, k, v, causal=causal, window=window,
                                    q_chunk=q_chunk, ways=cp_ways,
                                    shard_fn=shard_fn)
    return chunked_attention(q, k, v, causal=causal, window=window,
                             q_chunk=q_chunk, banded=banded)
