"""Shared layers: norms, rotary embeddings, activations, positional encodings."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------- norms
def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mean) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        out = out * scale.astype(jnp.float32)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    return out.astype(dt)


def np_layer_norm(x, eps: float = 1e-5):
    """Non-parametric LayerNorm (OLMo): no learned scale/bias."""
    return layer_norm(x, None, None, eps)


def apply_norm(kind: str, x, params: dict, prefix: str):
    if kind == "rmsnorm":
        return rms_norm(x, params[f"{prefix}/scale"])
    if kind == "layernorm":
        return layer_norm(x, params[f"{prefix}/scale"], params[f"{prefix}/bias"])
    if kind == "np_layernorm":
        return np_layer_norm(x)
    raise ValueError(f"unknown norm {kind!r}")


def norm_specs(kind: str, d: int):
    from repro.modeling.module import ParamSpec

    if kind == "rmsnorm":
        return {"scale": ParamSpec((d,), ("embed",), init="zeros")}
    if kind == "layernorm":
        return {
            "scale": ParamSpec((d,), ("embed",), init="ones"),
            "bias": ParamSpec((d,), ("embed",), init="zeros"),
        }
    if kind == "np_layernorm":
        return {}
    raise ValueError(f"unknown norm {kind!r}")


# ---------------------------------------------------------------- activations
def activation(kind: str, x, x_gate=None):
    """Gated activations take (gate_input, linear_input)."""
    if kind == "swiglu":
        return jax.nn.silu(x) * x_gate
    if kind == "geglu":
        return jax.nn.gelu(x, approximate=True) * x_gate
    if kind == "sqrelu":  # Nemotron-4: squared ReLU
        r = jax.nn.relu(x)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {kind!r}")


def is_gated(kind: str) -> bool:
    return kind in ("swiglu", "geglu")


# --------------------------------------------------------------------- rotary
def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D) with matching positions (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta))  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    sin = jnp.sin(angles)[..., None, :]  # broadcast over heads
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int, dtype=jnp.float32):
    pos = np.arange(seq_len)[:, None]
    i = np.arange(d_model // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / d_model)
    emb = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(emb, dtype)


def softcap(logits, cap: float):
    if cap and cap > 0:
        return jnp.tanh(logits / cap) * cap
    return logits
