"""Pure-JAX model zoo for the assigned architecture pool."""

from repro.modeling.registry import build_model, FAMILIES

__all__ = ["build_model", "FAMILIES"]
