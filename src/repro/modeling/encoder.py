"""Encoder-only audio model (HuBERT-XL backbone).

The CNN waveform frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame features (B, S, frame_feat_dim); the model applies
the learned feature projection, sinusoidal positions, and a bidirectional
transformer encoder. Training is masked prediction over a 504-entry codebook
(HuBERT-style): masked frames are replaced by a learned mask embedding and the
cross-entropy is computed at masked positions only.

Encoder-only ⇒ no decode step (decode shape cells are documented skips).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.modeling.attention import attention
from repro.modeling.layers import apply_norm, norm_specs, sinusoidal_positions
from repro.modeling.lm import (
    LM,
    _maybe_remat,
    attn_qkv,
    attn_specs,
    mlp_apply,
    mlp_specs,
    subtree_rel,
)
from repro.modeling.losses import chunked_softmax_xent
from repro.modeling.module import ParamSpec, prefix_specs, stacked, subtree


class AudioEncoder(LM):
    def layer_specs(self):
        cfg = self.cfg
        s = {}
        s.update(prefix_specs("ln_attn", norm_specs(cfg.norm, cfg.d_model)))
        s.update(prefix_specs("attn", attn_specs(cfg)))
        s.update(prefix_specs("ln_mlp", norm_specs(cfg.norm, cfg.d_model)))
        s.update(prefix_specs("mlp", mlp_specs(cfg, cfg.d_ff)))
        return s

    def param_specs(self):
        cfg = self.cfg
        specs = {
            "frontend/w": ParamSpec((cfg.frame_feat_dim, cfg.d_model),
                                    (None, "embed")),
            "frontend/b": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
            "mask_emb": ParamSpec((cfg.d_model,), ("embed",), init="embed",
                                  scale=0.02),
        }
        specs.update(prefix_specs(
            "layers", {k: stacked(v, cfg.n_layers) for k, v in self.layer_specs().items()}))
        specs.update(prefix_specs("ln_f", norm_specs(cfg.norm, cfg.d_model)))
        specs["head/w"] = ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                                    scale=cfg.d_model ** -0.5)
        return specs

    def _layer(self, p, x, positions, mode, **kw):
        cfg = self.cfg
        h = apply_norm(cfg.norm, x, p, "ln_attn")
        q, k, v = attn_qkv(cfg, subtree_rel(p, "attn"), h, positions)
        att = attention(q, k, v, causal=False, window=0,
                        q_chunk=cfg.q_chunk, impl=cfg.attn_impl)
        o = jnp.einsum("bshk,hkd->bsd", att, p["attn/o"].astype(x.dtype))
        x = x + shard(o, ("batch", None, None))
        h2 = apply_norm(cfg.norm, x, p, "ln_mlp")
        x = x + shard(mlp_apply(cfg, subtree_rel(p, "mlp"), h2),
                      ("batch", None, None))
        return x

    def forward(self, params, batch):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = (jnp.einsum("bsf,fd->bsd", batch["frames"].astype(dt),
                        params["frontend/w"].astype(dt))
             + params["frontend/b"].astype(dt))
        if "mask" in batch:
            m = batch["mask"].astype(dt)[..., None]
            x = x * (1.0 - m) + params["mask_emb"].astype(dt) * m
        S = x.shape[1]
        x = x + sinusoidal_positions(S, cfg.d_model, dt)[None]
        x = shard(x, ("batch", None, None))
        positions = jnp.arange(S)[None, :]
        stacked_p = subtree(params, "layers")

        def body(x, layer_p):
            return self._layer(layer_p, x, positions, "train"), None

        body = _maybe_remat(body, cfg.remat)
        x, _ = jax.lax.scan(body, x, stacked_p)
        x = apply_norm(cfg.norm, x, params, "ln_f")
        return x, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        cfg = self.cfg
        h, _ = self.forward(params, batch)
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones(batch["targets"].shape, jnp.float32)
        loss_sum, denom = chunked_softmax_xent(
            h, params["head/w"].astype(h.dtype), batch["targets"],
            mask.astype(jnp.float32), chunk=cfg.loss_chunk,
            impl=cfg.loss_impl)
        loss = loss_sum / jnp.maximum(denom, 1.0)
        return loss, {"xent": loss}

    def encode(self, params, batch):
        """Inference forward ("prefill" for the encoder family): frame logits."""
        h, _ = self.forward(params, batch)
        logits = jnp.einsum("bsd,dv->bsv", h, params["head/w"].astype(h.dtype),
                            preferred_element_type=jnp.float32)
        return logits

    # encoder-only: no KV cache / decode step
    def prefill(self, params, batch, cache_len=None):
        return self.encode(params, batch), None

    def decode_step(self, params, cache, batch):
        raise NotImplementedError("encoder-only architecture has no decode step")
