"""Minimal functional parameter substrate (no flax available — built from scratch).

Params live in a flat dict ``{path: jax.Array}``. Each model declares its
parameters once through ``param_specs(cfg) -> {path: ParamSpec}`` — a single
source of truth used for initialization, logical-axis sharding, checkpoint
layout, and abstract (ShapeDtypeStruct) instantiation for the dry-run.

Logical axis names used across the zoo (mapped to mesh axes by
``repro.distributed.sharding``):

- "batch"     — global batch (→ pod, data)
- "embed"     — d_model (FSDP-shardable → data for large dense archs)
- "heads"     — attention query heads (→ model)
- "kv_heads"  — KV heads (→ model iff divisible)
- "head_dim"  — per-head dim (replicated)
- "mlp"       — feed-forward hidden (→ model)
- "vocab"     — vocabulary (→ model)
- "experts"   — MoE experts (→ model)
- "layers"    — stacked scan-over-layers axis (replicated)
- "rnn"       — recurrent width (→ model)
- "ssm_state" / "ssm_heads" — SSD dims
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # "normal" | "zeros" | "ones" | "embed" | "output"
    scale: float | None = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _fan_in(shape: tuple[int, ...]) -> int:
    # For projection kernels (..., out) we treat all but the last dim as fan-in.
    if len(shape) <= 1:
        return max(shape[0] if shape else 1, 1)
    return int(np.prod(shape[:-1]))


def init_param(key, spec: ParamSpec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        scale = spec.scale if spec.scale is not None else 1.0
        return scale * jax.random.normal(key, spec.shape, dtype)
    # truncated-normal fan-in init for projections
    scale = spec.scale if spec.scale is not None else 1.0 / np.sqrt(_fan_in(spec.shape))
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, spec.shape, dtype)


def init_params(key, specs: dict[str, ParamSpec], dtype=jnp.float32) -> dict[str, jax.Array]:
    keys = jax.random.split(key, len(specs))
    return {
        path: init_param(k, spec, dtype)
        for k, (path, spec) in zip(keys, sorted(specs.items()))
    }


def abstract_params(specs: dict[str, ParamSpec], dtype=jnp.float32) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct pytree for .lower() without allocating anything."""
    return {p: jax.ShapeDtypeStruct(s.shape, dtype) for p, s in specs.items()}


def param_axes(specs: dict[str, ParamSpec]) -> dict[str, tuple[str | None, ...]]:
    return {p: s.axes for p, s in specs.items()}


def param_count(specs: dict[str, ParamSpec]) -> int:
    return int(sum(np.prod(s.shape) for s in specs.values()))


def stacked(spec: ParamSpec, n_layers: int) -> ParamSpec:
    """Stack a per-layer spec along a leading scan axis."""
    return ParamSpec(
        shape=(n_layers, *spec.shape),
        axes=("layers", *spec.axes),
        init=spec.init,
        scale=spec.scale,
    )


def prefix_specs(prefix: str, specs: dict[str, ParamSpec]) -> dict[str, ParamSpec]:
    return {f"{prefix}/{k}": v for k, v in specs.items()}


def subtree(params: dict[str, jax.Array], prefix: str) -> dict[str, jax.Array]:
    """View of a flat param dict under ``prefix`` (keys relativized)."""
    p = prefix + "/"
    return {k[len(p):]: v for k, v in params.items() if k.startswith(p)}


def layer_slice(stacked_params: dict[str, jax.Array], i) -> dict[str, jax.Array]:
    """Select layer ``i`` from a stacked (scan) param subtree."""
    return {k: v[i] for k, v in stacked_params.items()}
