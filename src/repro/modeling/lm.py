"""Decoder-only LM covering the dense / MoE / VLM families.

One composable implementation parameterized by ArchConfig:
- GQA/MQA/MHA attention with RoPE (optionally local-windowed),
- gated (SwiGLU/GeGLU) or plain (squared-ReLU/GeLU) MLPs, or Gshard MoE
  (with optional shared expert, llama4-style),
- ``moe_every = k``: MoE on every k-th layer (llama4-maverick interleaving),
  implemented as a grouped scan over (k−1 dense + 1 MoE) parameter stacks,
- optional vision-prefix input (InternVL-style stub frontend),
- scan-over-layers with stacked parameters (keeps HLO size O(1) in depth),
- chunked-vocab cross-entropy loss,
- prefill (cache build) and single-token decode steps for serving.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import axis_ways, shard
from repro.modeling.attention import attention, decode_attention
from repro.modeling.layers import (
    activation,
    apply_norm,
    apply_rope,
    is_gated,
    norm_specs,
)
from repro.modeling.losses import chunked_softmax_xent
from repro.modeling.moe import moe_apply, moe_specs
from repro.modeling.module import (
    ParamSpec,
    abstract_params,
    init_params,
    param_count,
    prefix_specs,
    stacked,
    subtree,
)


def mlp_specs(cfg, d_ff: int) -> dict[str, ParamSpec]:
    d = cfg.d_model
    s = {"wo": ParamSpec((d_ff, d), ("mlp", "embed"))}
    if is_gated(cfg.act):
        s["wi_0"] = ParamSpec((d, d_ff), ("embed", "mlp"))
        s["wi_1"] = ParamSpec((d, d_ff), ("embed", "mlp"))
    else:
        s["wi"] = ParamSpec((d, d_ff), ("embed", "mlp"))
    return s


def mlp_apply(cfg, p: dict, x):
    dt = x.dtype
    if is_gated(cfg.act):
        h = activation(cfg.act,
                       jnp.einsum("bsd,df->bsf", x, p["wi_0"].astype(dt)),
                       jnp.einsum("bsd,df->bsf", x, p["wi_1"].astype(dt)))
    else:
        h = activation(cfg.act, jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt)))
    h = shard(h, ("batch", None, "mlp_act"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))


def attn_specs(cfg) -> dict[str, ParamSpec]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "q": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "k": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "v": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "o": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }


def attn_qkv(cfg, p: dict, h, positions):
    dt = h.dtype
    q = jnp.einsum("bsd,dhk->bshk", h, p["q"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", h, p["k"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", h, p["v"].astype(dt))
    if cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, ("batch", None, "heads", None))
    k = shard(k, ("batch", None, "kv_heads", None))
    v = shard(v, ("batch", None, "kv_heads", None))
    return q, k, v


def subtree_rel(p: dict, prefix: str) -> dict:
    pre = prefix + "/"
    return {k[len(pre):]: v for k, v in p.items() if k.startswith(pre)}


def kv_quantize(x):
    """(…, hd) bf16 -> (int8 values, fp32 scales with trailing 1-dim)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def kv_dequantize(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _maybe_remat(fn, remat: str):
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


class LM:
    def __init__(self, cfg):
        self.cfg = cfg

    # ------------------------------------------------------------- layout
    @property
    def moe_every(self) -> int:
        return getattr(self.cfg, "moe_every", 1) if self.cfg.n_experts else 1

    def _layout(self):
        """Returns (n_groups, dense_per_group) for the grouped-scan layout."""
        e = self.moe_every
        if e <= 1:
            return self.cfg.n_layers, 0
        assert self.cfg.n_layers % e == 0, (self.cfg.n_layers, e)
        return self.cfg.n_layers // e, e - 1

    # ------------------------------------------------------------- params
    def layer_specs(self, moe: bool | None = None) -> dict[str, ParamSpec]:
        cfg = self.cfg
        if moe is None:
            moe = bool(cfg.n_experts)
        s: dict[str, ParamSpec] = {}
        s.update(prefix_specs("ln_attn", norm_specs(cfg.norm, cfg.d_model)))
        s.update(prefix_specs("attn", attn_specs(cfg)))
        s.update(prefix_specs("ln_mlp", norm_specs(cfg.norm, cfg.d_model)))
        if moe:
            s.update(prefix_specs("moe", moe_specs(cfg)))
            if cfg.shared_expert:
                s.update(prefix_specs("shared_mlp", mlp_specs(cfg, cfg.d_ff)))
        else:
            s.update(prefix_specs("mlp", mlp_specs(cfg, cfg.d_ff)))
        return s

    def param_specs(self) -> dict[str, ParamSpec]:
        cfg = self.cfg
        specs: dict[str, ParamSpec] = {
            "embed/w": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                                 init="embed"),
        }
        if cfg.vision_feat_dim:
            specs["vision_proj/w"] = ParamSpec(
                (cfg.vision_feat_dim, cfg.d_model), (None, "embed"))
        G, dpg = self._layout()
        if dpg == 0:
            specs.update(prefix_specs(
                "layers",
                {k: stacked(v, cfg.n_layers) for k, v in self.layer_specs().items()}))
        else:
            specs.update(prefix_specs(
                "layers_dense",
                {k: stacked(v, G * dpg) for k, v in self.layer_specs(moe=False).items()}))
            specs.update(prefix_specs(
                "layers_moe",
                {k: stacked(v, G) for k, v in self.layer_specs(moe=True).items()}))
        specs.update(prefix_specs("ln_f", norm_specs(cfg.norm, cfg.d_model)))
        if not cfg.tie_embeddings:
            specs["unembed/w"] = ParamSpec(
                (cfg.d_model, cfg.vocab), ("embed", "vocab"),
                scale=cfg.d_model ** -0.5)
        return specs

    def init(self, key, dtype=None):
        dtype = dtype or jnp.dtype(self.cfg.param_dtype)
        return init_params(key, self.param_specs(), dtype)

    def abstract_params(self, dtype=None):
        dtype = dtype or jnp.dtype(self.cfg.param_dtype)
        return abstract_params(self.param_specs(), dtype)

    def param_count(self) -> int:
        return param_count(self.param_specs())

    def active_param_count(self) -> int:
        """Active params per token (differs from total for MoE)."""
        cfg = self.cfg
        total = 0
        for path, s in self.param_specs().items():
            n = int(np.prod(s.shape))
            if "/moe/" in path and "router" not in path:
                n = n * max(cfg.top_k, 1) // max(cfg.n_experts, 1)
            total += n
        return total

    def _unembed(self, params):
        if self.cfg.tie_embeddings:
            return params["embed/w"].T
        return params["unembed/w"]

    # ------------------------------------------------------------ forward
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = params["embed/w"].astype(dt)[batch["tokens"]]
        if cfg.vision_feat_dim and "vision_embeds" in batch:
            ve = jnp.einsum("bvf,fd->bvd", batch["vision_embeds"].astype(dt),
                            params["vision_proj/w"].astype(dt))
            x = jnp.concatenate([ve, x], axis=1)
        x = shard(x, ("batch", None, None))
        return x

    def _layer(self, p, x, positions, mode, moe, kc=None, vc=None, pos=None,
               ksc=None, vsc=None):
        """One transformer layer. p holds this layer's (unstacked) params."""
        cfg = self.cfg
        h = apply_norm(cfg.norm, x, p, "ln_attn")
        q, k, v = attn_qkv(cfg, subtree_rel(p, "attn"), h, positions)
        if mode == "decode":
            if cfg.kv_quant:
                kq, ks = kv_quantize(k)
                vq, vs = kv_quantize(v)
                kc = jax.lax.dynamic_update_slice(kc, kq, (0, pos, 0, 0))
                vc = jax.lax.dynamic_update_slice(vc, vq, (0, pos, 0, 0))
                ksc = jax.lax.dynamic_update_slice(ksc, ks, (0, pos, 0, 0))
                vsc = jax.lax.dynamic_update_slice(vsc, vs, (0, pos, 0, 0))
                k_att = kv_dequantize(kc, ksc, x.dtype)
                v_att = kv_dequantize(vc, vsc, x.dtype)
            else:
                kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
                vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
                k_att, v_att = kc, vc
            B = x.shape[0]
            length = jnp.full((B,), pos + 1, jnp.int32)
            att = decode_attention(q, k_att, v_att, length,
                                   window=cfg.attn_window,
                                   positions=jnp.arange(kc.shape[1]),
                                   impl=cfg.attn_impl)
        else:
            # context parallelism (§Perf H1.2): shard query blocks over the
            # model axis (ways = what "seq" resolves to). K/V stay replicated
            # (they already are for MQA/GQA with few KV heads).
            ways = axis_ways("seq") if cfg.cp_attn else 0
            att = attention(q, k, v, causal=True, window=cfg.attn_window,
                            q_chunk=cfg.q_chunk, impl=cfg.attn_impl,
                            banded=cfg.banded_window, cp_ways=ways,
                            shard_fn=shard)
            if mode == "prefill":
                if cfg.kv_quant:
                    kc, ksc = kv_quantize(k)
                    vc, vsc = kv_quantize(v)
                else:
                    kc, vc = k, v
        o = jnp.einsum("bshk,hkd->bsd", att, p["attn/o"].astype(x.dtype))
        x = x + shard(o, ("batch", None, None))
        if cfg.sp_acts and mode == "train":
            # Megatron-style sequence parallelism: keep residuals sequence-
            # sharded between blocks; GSPMD turns the TP all-reduces into
            # reduce-scatter + all-gather pairs (half the link bytes).
            x = shard(x, ("batch", "seq", None))

        h2 = apply_norm(cfg.norm, x, p, "ln_mlp")
        if moe:
            y, aux = moe_apply(cfg, subtree_rel(p, "moe"), h2, shard_fn=shard)
            if cfg.shared_expert:
                y = y + mlp_apply(cfg, subtree_rel(p, "shared_mlp"), h2)
        else:
            y, aux = mlp_apply(cfg, subtree_rel(p, "mlp"), h2), jnp.zeros((), jnp.float32)
        x = x + shard(y, ("batch", None, None))
        if cfg.sp_acts and mode == "train":
            x = shard(x, ("batch", "seq", None))
        return x, aux, kc, vc, ksc, vsc

    def _trunk(self, params, x, positions, mode, cache=None):
        """Scan over layers. Returns (x, aux_sum, new_cache or None)."""
        cfg = self.cfg
        G, dpg = self._layout()
        dec = mode == "decode"
        emit_cache = mode in ("prefill", "decode")
        pos = cache["pos"] if dec else None
        kv_len = cache["k"].shape[2] if dec else None
        write_pos = (pos % kv_len if cfg.attn_window else pos) if dec else None

        quant = bool(cfg.kv_quant)
        if dpg == 0:
            stacked_p = subtree(params, "layers")
            moe = bool(cfg.n_experts)

            def body(x, xs):
                ksc = vsc = None
                if dec and quant:
                    layer_p, kc, vc, ksc, vsc = xs
                elif dec:
                    layer_p, kc, vc = xs
                else:
                    layer_p, kc, vc = xs, None, None
                x, aux, kc, vc, ksc, vsc = self._layer(
                    layer_p, x, positions, mode, moe,
                    kc=kc, vc=vc, pos=write_pos, ksc=ksc, vsc=vsc)
                ys = (aux,)
                if emit_cache:
                    ys = ys + ((kc, vc, ksc, vsc) if quant else (kc, vc))
                return x, ys

            body = _maybe_remat(body, cfg.remat if mode != "decode" else "none")
            if dec and quant:
                xs = (stacked_p, cache["k"], cache["v"],
                      cache["k_scale"], cache["v_scale"])
            elif dec:
                xs = (stacked_p, cache["k"], cache["v"])
            else:
                xs = stacked_p
            x, ys = jax.lax.scan(body, x, xs)
            aux = jnp.sum(ys[0])
            new_cache = None
            if emit_cache:
                new_cache = {"k": ys[1], "v": ys[2]}
                if quant:
                    new_cache["k_scale"], new_cache["v_scale"] = ys[3], ys[4]
            return x, aux, new_cache

        # ---- grouped layout: (dpg dense + 1 moe) per group -----------------
        dense_p = subtree(params, "layers_dense")
        moe_p = subtree(params, "layers_moe")
        g_dense = {k: v.reshape(G, dpg, *v.shape[1:]) for k, v in dense_p.items()}
        if dec:
            # cache layout: per group, dpg dense layers then the moe layer
            k_all = cache["k"].reshape(G, dpg + 1, *cache["k"].shape[1:])
            v_all = cache["v"].reshape(G, dpg + 1, *cache["v"].shape[1:])

        assert not quant, "kv_quant: grouped (moe_every) layout not supported"

        def body(x, xs):
            if dec:
                dense_g, moe_g, kg, vg = xs
            else:
                dense_g, moe_g = xs
                kg = vg = [None] * (dpg + 1)
            auxs = jnp.zeros((), jnp.float32)
            kcs, vcs = [], []
            for j in range(dpg):
                pj = {k: v[j] for k, v in dense_g.items()}
                x, a, kc, vc, _, _ = self._layer(pj, x, positions, mode, False,
                                                 kc=kg[j] if dec else None,
                                                 vc=vg[j] if dec else None,
                                                 pos=write_pos)
                auxs += a
                kcs.append(kc)
                vcs.append(vc)
            x, a, kc, vc, _, _ = self._layer(moe_g, x, positions, mode, True,
                                             kc=kg[dpg] if dec else None,
                                             vc=vg[dpg] if dec else None,
                                             pos=write_pos)
            auxs += a
            kcs.append(kc)
            vcs.append(vc)
            ys = (auxs,)
            if emit_cache:
                ys = ys + (jnp.stack(kcs), jnp.stack(vcs))
            return x, ys

        body = _maybe_remat(body, cfg.remat if mode != "decode" else "none")
        xs = (g_dense, moe_p) + ((k_all, v_all) if dec else ())
        x, ys = jax.lax.scan(body, x, xs)
        aux = jnp.sum(ys[0])
        new_cache = None
        if emit_cache:
            ks = ys[1].reshape(G * (dpg + 1), *ys[1].shape[2:])
            vs = ys[2].reshape(G * (dpg + 1), *ys[2].shape[2:])
            new_cache = {"k": ks, "v": vs}
        return x, aux, new_cache

    def forward(self, params, batch):
        """Training/scoring forward: returns (hidden (B,S,D), aux_loss)."""
        x = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])[None, :]
        x, aux, _ = self._trunk(params, x, positions, "train")
        x = apply_norm(self.cfg.norm, x, params, "ln_f")
        return x, aux

    # --------------------------------------------------------------- loss
    def loss(self, params, batch):
        cfg = self.cfg
        h, aux = self.forward(params, batch)
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones_like(batch["targets"], jnp.float32)
        loss_sum, denom = chunked_softmax_xent(
            h, self._unembed(params).astype(h.dtype), batch["targets"],
            mask.astype(jnp.float32), chunk=cfg.loss_chunk,
            cap=cfg.logits_softcap, impl=cfg.loss_impl)
        loss = loss_sum / jnp.maximum(denom, 1.0)
        xent = loss
        if cfg.n_experts:
            G, _ = self._layout()
            loss = loss + 0.01 * aux / max(G, 1)
        return loss, {"xent": xent, "aux": aux}

    # ------------------------------------------------------------ serving
    def cache_shape(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        L = cfg.n_layers
        kv_len = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
        shp = (L, batch_size, kv_len, cfg.n_kv_heads, cfg.head_dim)
        kv_dt = jnp.int8 if cfg.kv_quant else jnp.dtype(cfg.dtype)
        out = {
            "k": jax.ShapeDtypeStruct(shp, kv_dt),
            "v": jax.ShapeDtypeStruct(shp, kv_dt),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
        if cfg.kv_quant:
            sshp = (L, batch_size, kv_len, cfg.n_kv_heads, 1)
            out["k_scale"] = jax.ShapeDtypeStruct(sshp, jnp.float32)
            out["v_scale"] = jax.ShapeDtypeStruct(sshp, jnp.float32)
        return out

    def cache_axes(self):
        """Logical sharding axes matching cache_shape (for pjit in_shardings).

        The KV sequence axis carries model parallelism when KV heads cannot
        (GQA/MQA with n_kv_heads < model-axis size): flash-decode style
        sequence sharding, with GSPMD inserting the softmax all-reduce.
        """
        kv = ("layers", "batch", "kv_seq", "kv_heads", None)
        out = {"k": kv, "v": kv, "pos": ()}
        if self.cfg.kv_quant:
            out["k_scale"] = kv
            out["v_scale"] = kv
        return out

    def init_cache(self, batch_size: int, cache_len: int):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_shape(batch_size, cache_len))

    def prefill(self, params, batch, cache_len: int | None = None):
        """Process a full prompt; returns (last-token logits, cache)."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        S = x.shape[1]
        cache_len = cache_len or S
        positions = jnp.arange(S)[None, :]
        x, _, cache = self._trunk(params, x, positions, "prefill")
        x = apply_norm(cfg.norm, x, params, "ln_f")
        logits = jnp.einsum("bd,dv->bv", x[:, -1, :],
                            self._unembed(params).astype(x.dtype),
                            preferred_element_type=jnp.float32)

        kv_len = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len

        def fit(arr):
            if kv_len >= S:
                pad = [(0, 0), (0, 0), (0, kv_len - S), (0, 0), (0, 0)]
                return jnp.pad(arr, pad)
            shift = (S - kv_len) % kv_len
            return jnp.roll(arr[:, :, -kv_len:], shift, axis=2)

        out = {"k": fit(cache["k"]), "v": fit(cache["v"]),
               "pos": jnp.asarray(S, jnp.int32)}
        if cfg.kv_quant:
            out["k_scale"] = fit(cache["k_scale"])
            out["v_scale"] = fit(cache["v_scale"])
        return logits, out

    def decode_step(self, params, cache, batch):
        """One token for every sequence in the batch (uniform position)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = params["embed/w"].astype(dt)[batch["token"]][:, None, :]
        x = shard(x, ("batch", None, None))
        positions = jnp.broadcast_to(cache["pos"], (x.shape[0], 1))
        x, _, new_cache = self._trunk(params, x, positions, "decode", cache=cache)
        x = apply_norm(cfg.norm, x, params, "ln_f")
        logits = jnp.einsum("bd,dv->bv", x[:, 0, :],
                            self._unembed(params).astype(x.dtype),
                            preferred_element_type=jnp.float32)
        new_cache["pos"] = cache["pos"] + 1
        return logits, new_cache
