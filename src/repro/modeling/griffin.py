"""Griffin-style hybrid LM (RecurrentGemma): RG-LRU blocks + local attention.

Layer pattern (rec, rec, attn) repeats; for 38 layers that is 12 full groups
plus a (rec, rec) tail. Scan-over-groups keeps the HLO small: recurrent-layer
params are stacked (n_rec, ...) and attention-layer params (n_attn, ...);
full groups scan over (2 rec + 1 attn) slices, tail layers run unrolled.

The local-attention KV cache is a ring buffer of ``attn_window`` slots (keys
stored post-RoPE), which is what makes the 500k-token decode cell bounded.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.modeling.attention import attention, decode_attention
from repro.modeling.layers import apply_norm, norm_specs
from repro.modeling.lm import (
    LM,
    _maybe_remat,
    attn_qkv,
    attn_specs,
    mlp_apply,
    mlp_specs,
    subtree_rel,
)
from repro.modeling.losses import chunked_softmax_xent
from repro.modeling.module import (
    ParamSpec,
    abstract_params,
    init_params,
    param_count,
    prefix_specs,
    stacked,
    subtree,
)
from repro.modeling.rglru import rglru_block_apply, rglru_block_specs


def _pattern_layout(cfg):
    pat = cfg.block_pattern or ("rec", "rec", "attn")
    full = cfg.n_layers // len(pat)
    tail = tuple(pat[: cfg.n_layers % len(pat)])
    n_rec = full * pat.count("rec") + tail.count("rec")
    n_attn = full * pat.count("attn") + tail.count("attn")
    return pat, full, tail, n_rec, n_attn


class GriffinLM(LM):
    # ------------------------------------------------------------- params
    def rec_layer_specs(self):
        cfg = self.cfg
        s = {}
        s.update(prefix_specs("ln_mix", norm_specs(cfg.norm, cfg.d_model)))
        s.update(prefix_specs("mixer", rglru_block_specs(cfg)))
        s.update(prefix_specs("ln_mlp", norm_specs(cfg.norm, cfg.d_model)))
        s.update(prefix_specs("mlp", mlp_specs(cfg, cfg.d_ff)))
        return s

    def attn_layer_specs(self):
        cfg = self.cfg
        s = {}
        s.update(prefix_specs("ln_mix", norm_specs(cfg.norm, cfg.d_model)))
        s.update(prefix_specs("attn", attn_specs(cfg)))
        s.update(prefix_specs("ln_mlp", norm_specs(cfg.norm, cfg.d_model)))
        s.update(prefix_specs("mlp", mlp_specs(cfg, cfg.d_ff)))
        return s

    def param_specs(self):
        cfg = self.cfg
        _, _, _, n_rec, n_attn = _pattern_layout(cfg)
        specs = {
            "embed/w": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                                 init="embed"),
        }
        specs.update(prefix_specs(
            "rec_layers",
            {k: stacked(v, n_rec) for k, v in self.rec_layer_specs().items()}))
        specs.update(prefix_specs(
            "attn_layers",
            {k: stacked(v, n_attn) for k, v in self.attn_layer_specs().items()}))
        specs.update(prefix_specs("ln_f", norm_specs(cfg.norm, cfg.d_model)))
        if not cfg.tie_embeddings:
            specs["unembed/w"] = ParamSpec((cfg.d_model, cfg.vocab),
                                           ("embed", "vocab"),
                                           scale=cfg.d_model ** -0.5)
        return specs

    # ------------------------------------------------------------- layers
    def _rec_layer(self, p, x, state=None, conv=None):
        cfg = self.cfg
        h = apply_norm(cfg.norm, x, p, "ln_mix")
        mix, st, cv = rglru_block_apply(cfg, subtree_rel(p, "mixer"), h,
                                        state=state, conv_state=conv,
                                        impl=cfg.attn_impl)
        # NOTE: no sequence sharding here — the RG-LRU scan is sequential in
        # S, so sequence-sharded residuals would force an all-gather per rec
        # layer (measured: collective went UP 40%; EXPERIMENTS.md §Perf H2.2).
        x = x + shard(mix, ("batch", None, None))
        h2 = apply_norm(cfg.norm, x, p, "ln_mlp")
        x = x + shard(mlp_apply(cfg, subtree_rel(p, "mlp"), h2),
                      ("batch", None, None))
        return x, st, cv

    def _attn_layer(self, p, x, positions, mode, kc=None, vc=None, pos=None):
        cfg = self.cfg
        h = apply_norm(cfg.norm, x, p, "ln_mix")
        q, k, v = attn_qkv(cfg, subtree_rel(p, "attn"), h, positions)
        W = cfg.attn_window
        if mode == "decode":
            wp = pos % kc.shape[1]
            kc = jax.lax.dynamic_update_slice(kc, k, (0, wp, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v, (0, wp, 0, 0))
            B = x.shape[0]
            length = jnp.minimum(pos + 1, kc.shape[1])
            att = decode_attention(q, kc, vc, jnp.full((B,), length, jnp.int32),
                                   impl=cfg.attn_impl)
        else:
            if cfg.cp_attn:
                q = shard(q, ("batch", "seq", None, None))
            att = attention(q, k, v, causal=True, window=W,
                            q_chunk=cfg.q_chunk, impl=cfg.attn_impl,
                            banded=cfg.banded_window)
            if mode == "prefill":
                S = k.shape[1]
                kv_len = min(W, S) if W else S
                # ring-buffer convention: slot = position % kv_len
                shift = (S - kv_len) % kv_len
                kc = jnp.roll(k[:, -kv_len:], shift, axis=1)
                vc = jnp.roll(v[:, -kv_len:], shift, axis=1)
        o = jnp.einsum("bshk,hkd->bsd", att, p["attn/o"].astype(x.dtype))
        x = x + shard(o, ("batch", None, None))
        if cfg.sp_acts and mode == "train":
            # SP pays off only around attention+MLP (position-local ops);
            # the following rec layer re-gathers once instead of per-op.
            x = shard(x, ("batch", "seq", None))
        h2 = apply_norm(cfg.norm, x, p, "ln_mlp")
        x = x + shard(mlp_apply(cfg, subtree_rel(p, "mlp"), h2),
                      ("batch", None, None))
        return x, kc, vc

    # ------------------------------------------------------------ forward
    def _run(self, params, x, positions, mode, cache=None):
        """Shared trunk for train/prefill/decode; returns (x, new_cache)."""
        cfg = self.cfg
        pat, full, tail, n_rec, n_attn = _pattern_layout(cfg)
        rec_per_group = pat.count("rec")
        attn_per_group = pat.count("attn")
        rec_p = subtree(params, "rec_layers")
        attn_p = subtree(params, "attn_layers")
        grouped_rec = {k: v[: full * rec_per_group].reshape(
            full, rec_per_group, *v.shape[1:]) for k, v in rec_p.items()}
        grouped_attn = {k: v[: full * attn_per_group].reshape(
            full, attn_per_group, *v.shape[1:]) for k, v in attn_p.items()}

        dec = mode == "decode"
        if dec:
            st, cv = cache["state"], cache["conv"]
            kc, vc = cache["k"], cache["v"]
            pos = cache["pos"]
            g_st = st[: full * rec_per_group].reshape(full, rec_per_group, *st.shape[1:])
            g_cv = cv[: full * rec_per_group].reshape(full, rec_per_group, *cv.shape[1:])
            g_kc = kc[: full * attn_per_group].reshape(full, attn_per_group, *kc.shape[1:])
            g_vc = vc[: full * attn_per_group].reshape(full, attn_per_group, *vc.shape[1:])

        def group_body(x, xs):
            if dec:
                rec2, attn1, st2, cv2, kc1, vc1 = xs
            else:
                rec2, attn1 = xs
                st2 = cv2 = kc1 = vc1 = None
            sts, cvs, kcs, vcs = [], [], [], []
            ri = ai = 0
            for kind in pat:
                if kind == "rec":
                    pi = {k: v[ri] for k, v in rec2.items()}
                    x, s_new, c_new = self._rec_layer(
                        pi, x,
                        state=st2[ri] if dec else None,
                        conv=cv2[ri] if dec else None)
                    sts.append(s_new)
                    cvs.append(c_new)
                    ri += 1
                else:
                    pi = {k: v[ai] for k, v in attn1.items()}
                    x, kc_new, vc_new = self._attn_layer(
                        pi, x, positions, mode,
                        kc=kc1[ai] if dec else None,
                        vc=vc1[ai] if dec else None,
                        pos=pos if dec else None)
                    kcs.append(kc_new)
                    vcs.append(vc_new)
                    ai += 1
            ys = (jnp.stack(sts), jnp.stack(cvs))
            if mode != "train":
                ys = ys + (jnp.stack(kcs), jnp.stack(vcs))
            return x, ys

        body = _maybe_remat(group_body, cfg.remat if mode != "decode" else "none")
        xs = (grouped_rec, grouped_attn)
        if dec:
            xs = xs + (g_st, g_cv, g_kc, g_vc)
        x, ys = jax.lax.scan(body, x, xs)

        # tail layers (unrolled)
        tail_out = []
        for i, kind in enumerate(tail):
            idx = full * rec_per_group + i  # tails are "rec" for our pattern
            assert kind == "rec"
            pi = {k: v[idx] for k, v in rec_p.items()}
            x, s_new, c_new = self._rec_layer(
                pi, x,
                state=cache["state"][idx] if dec else None,
                conv=cache["conv"][idx] if dec else None)
            tail_out.append((s_new, c_new))

        new_cache = None
        if mode != "train":
            sts = ys[0].reshape(full * rec_per_group, *ys[0].shape[2:])
            cvs = ys[1].reshape(full * rec_per_group, *ys[1].shape[2:])
            if tail_out:
                sts = jnp.concatenate([sts, jnp.stack([t[0] for t in tail_out])])
                cvs = jnp.concatenate([cvs, jnp.stack([t[1] for t in tail_out])])
            kcs = ys[2].reshape(n_attn, *ys[2].shape[2:])
            vcs = ys[3].reshape(n_attn, *ys[3].shape[2:])
            new_cache = {"state": sts, "conv": cvs, "k": kcs, "v": vcs}
        return x, new_cache

    def forward(self, params, batch):
        x = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])[None, :]
        x, _ = self._run(params, x, positions, "train")
        x = apply_norm(self.cfg.norm, x, params, "ln_f")
        return x, jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        cfg = self.cfg
        h, _ = self.forward(params, batch)
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones_like(batch["targets"], jnp.float32)
        loss_sum, denom = chunked_softmax_xent(
            h, self._unembed(params).astype(h.dtype), batch["targets"],
            mask.astype(jnp.float32), chunk=cfg.loss_chunk,
            cap=cfg.logits_softcap, impl=cfg.loss_impl)
        loss = loss_sum / jnp.maximum(denom, 1.0)
        return loss, {"xent": loss}

    # ------------------------------------------------------------ serving
    def cache_shape(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        _, _, _, n_rec, n_attn = _pattern_layout(cfg)
        W = cfg.conv_width
        kv_len = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
        dt = jnp.dtype(cfg.dtype)
        return {
            "state": jax.ShapeDtypeStruct((n_rec, batch_size, cfg.d_rnn), jnp.float32),
            "conv": jax.ShapeDtypeStruct((n_rec, batch_size, W - 1, cfg.d_rnn), dt),
            "k": jax.ShapeDtypeStruct(
                (n_attn, batch_size, kv_len, cfg.n_kv_heads, cfg.head_dim), dt),
            "v": jax.ShapeDtypeStruct(
                (n_attn, batch_size, kv_len, cfg.n_kv_heads, cfg.head_dim), dt),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def cache_axes(self):
        kv = ("layers", "batch", "kv_seq", "kv_heads", None)
        return {
            "state": ("layers", "batch", "rnn"),
            "conv": ("layers", "batch", None, "rnn"),
            "k": kv, "v": kv, "pos": (),
        }

    def prefill(self, params, batch, cache_len: int | None = None):
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        B, S = x.shape[0], x.shape[1]
        cache_len = cache_len or S
        positions = jnp.arange(S)[None, :]
        x, cache = self._run(params, x, positions, "prefill")
        x = apply_norm(cfg.norm, x, params, "ln_f")
        logits = jnp.einsum("bd,dv->bv", x[:, -1, :],
                            self._unembed(params).astype(x.dtype),
                            preferred_element_type=jnp.float32)
        kv_len = min(cache_len, cfg.attn_window) if cfg.attn_window else cache_len
        cur = cache["k"].shape[2]
        if kv_len > cur:
            pad = [(0, 0), (0, 0), (0, kv_len - cur), (0, 0), (0, 0)]
            cache["k"] = jnp.pad(cache["k"], pad)
            cache["v"] = jnp.pad(cache["v"], pad)
        cache["conv"] = cache["conv"].astype(jnp.dtype(cfg.dtype))
        cache["pos"] = jnp.asarray(S, jnp.int32)
        return logits, cache

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        tok = batch["token"]
        pos = cache["pos"]
        x = params["embed/w"].astype(dt)[tok][:, None, :]
        positions = jnp.broadcast_to(pos, (x.shape[0], 1))
        x, new_cache = self._run(params, x, positions, "decode", cache=cache)
        x = apply_norm(cfg.norm, x, params, "ln_f")
        logits = jnp.einsum("bd,dv->bv", x[:, 0, :],
                            self._unembed(params).astype(x.dtype),
                            preferred_element_type=jnp.float32)
        new_cache["pos"] = pos + 1
        return logits, new_cache
