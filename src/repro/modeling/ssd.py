"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Selective state space with scalar-identity A per head:

    h_t = exp(dt_t A) h_{t-1} + dt_t x_t ⊗ B_t        (state: (heads, hd, ds))
    y_t = C_t · h_t + D x_t

Training/prefill uses the paper's chunked block decomposition — quadratic
attention-like compute within chunks (MXU-friendly) plus a tiny inter-chunk
state recurrence — O(S·Q) instead of O(S²). ``ssd_chunked`` is the XLA path;
``repro.kernels.ssd_scan`` is the Pallas TPU kernel with the same math and
``ssd_naive`` (the literal recurrence) is the correctness oracle for both.

Decode is a single O(1) state update — this is what makes mamba2 runnable at
the 500k-token long-context cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.modeling.layers import rms_norm
from repro.modeling.module import ParamSpec


def ssd_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def ssd_block_specs(cfg) -> dict[str, ParamSpec]:
    d = cfg.d_model
    d_inner, nh, hd, ds = ssd_dims(cfg)
    w = cfg.conv_width
    conv_dim = d_inner + 2 * ds
    return {
        # in_proj -> [z (d_inner), x (d_inner), B (ds), C (ds), dt (nh)]
        "in_proj": ParamSpec((d, 2 * d_inner + 2 * ds + nh), ("embed", "rnn")),
        "conv/w": ParamSpec((w, conv_dim), (None, "rnn")),
        "conv/b": ParamSpec((conv_dim,), ("rnn",), init="zeros"),
        "a_log": ParamSpec((nh,), ("ssm_heads",), init="ones"),
        "d_skip": ParamSpec((nh,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        "norm/scale": ParamSpec((d_inner,), ("rnn",), init="zeros"),
        "out_proj": ParamSpec((d_inner, d), ("rnn", "embed")),
    }


def _segsum(x):
    """x: (..., Q) -> (..., Q, Q) with out[i,j] = sum_{k=j+1..i} x_k, -inf above diag."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD. Shapes:
    x: (b, S, nh, hd); dt: (b, S, nh) (post-softplus, fp32); A: (nh,) negative;
    B, C: (b, S, ds)  (single group, shared across heads).
    Returns y: (b, S, nh, hd) and final state (b, nh, hd, ds) fp32.
    """
    b, S, nh, hd = x.shape
    ds = B.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        # zero-dt padding is inert: decay exp(0)=1, zero input contribution
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    S_p = S + pad
    nc = S_p // Q
    dtype = x.dtype

    xq = x.reshape(b, nc, Q, nh, hd)
    dtq = dt.reshape(b, nc, Q, nh)
    Bq = B.reshape(b, nc, Q, ds)
    Cq = C.reshape(b, nc, Q, ds)

    dA = dtq * A  # (b,nc,Q,nh) fp32, negative
    dA_cum = jnp.cumsum(dA, axis=2)                      # within-chunk cumulative
    dA_total = dA_cum[:, :, -1, :]                        # (b,nc,nh)

    # ---- intra-chunk (quadratic, attention-like) --------------------------
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))        # (b,nc,nh,Q,Q)
    scores = jnp.einsum("bcqn,bcsn->bcqs", Cq, Bq,
                        preferred_element_type=jnp.float32)
    att = scores[:, :, None, :, :] * L                    # (b,nc,nh,Q,Q)
    att = att * dtq.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bchqs,bcshp->bcqhp", att.astype(dtype), xq)

    # ---- chunk boundary states -------------------------------------------
    decay_to_end = jnp.exp(dA_total[:, :, None, :] - dA_cum)  # (b,nc,Q,nh)
    weighted_x = (xq.astype(jnp.float32)
                  * (dtq * decay_to_end)[..., None])            # (b,nc,Q,nh,hd)
    states = jnp.einsum("bcqhp,bcqn->bchpn", weighted_x,
                        Bq.astype(jnp.float32))                  # (b,nc,nh,hd,ds)

    # ---- inter-chunk recurrence (tiny scan over nc) ------------------------
    def step(h, inp):
        s_c, g_c = inp  # g_c: (b,nh) total decay of this chunk
        h_new = h * jnp.exp(g_c)[:, :, None, None] + s_c
        return h_new, h  # emit state *entering* the chunk

    h0 = jnp.zeros((b, nh, hd, ds), jnp.float32)
    final, h_prev = jax.lax.scan(
        step, h0,
        (states.transpose(1, 0, 2, 3, 4), dA_total.transpose(1, 0, 2)),
    )
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)  # (b,nc,nh,hd,ds), state entering chunk

    # ---- inter-chunk output contribution ----------------------------------
    decay_from_start = jnp.exp(dA_cum)  # (b,nc,Q,nh)
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", Cq.astype(jnp.float32), h_prev) \
        * decay_from_start[..., None]

    y = y_intra.astype(jnp.float32) + y_inter
    return y.reshape(b, S_p, nh, hd)[:, :S].astype(dtype), final


def ssd_naive(x, dt, A, B, C):
    """Literal recurrence oracle (fp32). Same shapes as ``ssd_chunked``."""
    b, S, nh, hd = x.shape

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp  # (b,nh,hd), (b,nh), (b,ds), (b,ds)
        decay = jnp.exp(dt_t * A)[:, :, None, None]
        upd = (dt_t[:, :, None] * x_t)[..., None] * B_t[:, None, None, :]
        h = h * decay + upd
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, y

    h0 = jnp.zeros((b, nh, hd, B.shape[-1]), jnp.float32)
    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3),
          dt.transpose(1, 0, 2), B.astype(jnp.float32).transpose(1, 0, 2),
          C.astype(jnp.float32).transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), h


def ssd_block_apply(cfg, p, x, state=None, conv_state=None, impl="xla"):
    """Full Mamba-2 block: in_proj → conv → SSD → gated norm → out_proj.

    Train/prefill: x (B,S,D), state=None.
    Decode: x (B,1,D), state (B,nh,hd,ds) fp32, conv_state (B,W-1,conv_dim).
    Returns (y (B,S,D), state, conv_state).
    """
    from repro.modeling.rglru import causal_conv1d

    d_inner, nh, hd, ds = ssd_dims(cfg)
    dtype = x.dtype
    W = p["conv/w"].shape[0]

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dtype))
    z, xin, Bc, Cc, dt_raw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + ds, 2 * d_inner + 2 * ds],
        axis=-1)

    xBC = jnp.concatenate([xin, Bc, Cc], axis=-1)
    if conv_state is None:
        xBC_conv = causal_conv1d(xBC, p["conv/w"].astype(dtype),
                                 p["conv/b"].astype(dtype))
        new_conv_state = xBC[:, -(W - 1):, :]
    else:
        hist = jnp.concatenate([conv_state, xBC], axis=1)
        xBC_conv = (jnp.einsum("bwr,wr->br", hist, p["conv/w"].astype(dtype))
                    + p["conv/b"].astype(dtype))[:, None, :]
        new_conv_state = hist[:, 1:, :]
    xBC_conv = jax.nn.silu(xBC_conv)

    xs = xBC_conv[..., :d_inner].reshape(*x.shape[:2], nh, hd)
    Bs = xBC_conv[..., d_inner : d_inner + ds]
    Cs = xBC_conv[..., d_inner + ds :]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    if state is None:
        if impl == "pallas":
            from repro.kernels.ssd_scan import ops as ssd_ops

            y, final = ssd_ops.ssd(xs, dt, A, Bs, Cs, chunk=cfg.ssm_chunk)
        else:
            y, final = ssd_chunked(xs, dt, A, Bs, Cs, cfg.ssm_chunk)
    else:
        decay = jnp.exp(dt[:, 0] * A)[:, :, None, None]          # (B,nh,1,1)
        upd = (dt[:, 0][:, :, None] * xs[:, 0].astype(jnp.float32))[..., None] \
            * Bs[:, 0].astype(jnp.float32)[:, None, None, :]
        final = state * decay + upd
        y = jnp.einsum("bhpn,bn->bhp", final, Cs[:, 0].astype(jnp.float32))
        y = y[:, None].astype(dtype)

    y = y + xs * p["d_skip"].astype(dtype)[None, None, :, None]
    y = y.reshape(*x.shape[:2], d_inner)
    y = rms_norm(y, p["norm/scale"]) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y.astype(dtype), p["out_proj"].astype(dtype))
    return out, final, new_conv_state
