"""Mamba-2 LM (attention-free, SSD blocks). Decode state is O(1) in context
length — the long_500k cell runs with a fixed (heads, head_dim, state) cache.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.modeling.layers import apply_norm, norm_specs
from repro.modeling.lm import LM, _maybe_remat, subtree_rel
from repro.modeling.module import (
    ParamSpec,
    prefix_specs,
    stacked,
    subtree,
)
from repro.modeling.ssd import ssd_block_apply, ssd_block_specs, ssd_dims


class MambaLM(LM):
    def layer_specs(self):
        cfg = self.cfg
        s = {}
        s.update(prefix_specs("ln", norm_specs(cfg.norm, cfg.d_model)))
        s.update(prefix_specs("mixer", ssd_block_specs(cfg)))
        return s

    def param_specs(self):
        cfg = self.cfg
        specs = {
            "embed/w": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                                 init="embed"),
        }
        specs.update(prefix_specs(
            "layers", {k: stacked(v, cfg.n_layers) for k, v in self.layer_specs().items()}))
        specs.update(prefix_specs("ln_f", norm_specs(cfg.norm, cfg.d_model)))
        if not cfg.tie_embeddings:
            specs["unembed/w"] = ParamSpec((cfg.d_model, cfg.vocab),
                                           ("embed", "vocab"),
                                           scale=cfg.d_model ** -0.5)
        return specs

    def _layer(self, p, x, positions, mode, state=None, conv=None):
        cfg = self.cfg
        h = apply_norm(cfg.norm, x, p, "ln")
        y, st, cv = ssd_block_apply(cfg, subtree_rel(p, "mixer"), h,
                                    state=state, conv_state=conv,
                                    impl=cfg.attn_impl)
        x = x + shard(y, ("batch", None, None))
        return x, st, cv

    def forward(self, params, batch):
        x = self._embed_inputs(params, batch)
        stacked_p = subtree(params, "layers")

        def body(x, layer_p):
            x, _, _ = self._layer(layer_p, x, None, "train")
            return x, None

        body = _maybe_remat(body, self.cfg.remat)
        x, _ = jax.lax.scan(body, x, stacked_p)
        x = apply_norm(self.cfg.norm, x, params, "ln_f")
        return x, jnp.zeros((), jnp.float32)

    # ------------------------------------------------------------ serving
    def cache_shape(self, batch_size: int, cache_len: int):
        cfg = self.cfg
        d_inner, nh, hd, ds = ssd_dims(cfg)
        conv_dim = d_inner + 2 * ds
        W = cfg.conv_width
        dt = jnp.dtype(cfg.dtype)
        return {
            "state": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch_size, nh, hd, ds), jnp.float32),
            "conv": jax.ShapeDtypeStruct(
                (cfg.n_layers, batch_size, W - 1, conv_dim), dt),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def cache_axes(self):
        return {
            "state": ("layers", "batch", "ssm_heads", None, None),
            "conv": ("layers", "batch", None, "rnn"),
            "pos": (),
        }

    def prefill(self, params, batch, cache_len: int | None = None):
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        stacked_p = subtree(params, "layers")

        def body(x, layer_p):
            x, st, cv = self._layer(layer_p, x, None, "prefill")
            return x, (st, cv)

        body = _maybe_remat(body, cfg.remat)
        x, (sts, cvs) = jax.lax.scan(body, x, stacked_p)
        x = apply_norm(cfg.norm, x, params, "ln_f")
        logits = jnp.einsum("bd,dv->bv", x[:, -1, :],
                            self._unembed(params).astype(x.dtype),
                            preferred_element_type=jnp.float32)
        cache = {"state": sts, "conv": cvs.astype(jnp.dtype(cfg.dtype)),
                 "pos": jnp.asarray(x.shape[1], jnp.int32)}
        return logits, cache

    def decode_step(self, params, cache, batch):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = params["embed/w"].astype(dt)[batch["token"]][:, None, :]
        stacked_p = subtree(params, "layers")

        def body(x, xs):
            layer_p, st, cv = xs
            x, st, cv = self._layer(layer_p, x, None, "decode",
                                    state=st, conv=cv)
            return x, (st, cv)

        x, (sts, cvs) = jax.lax.scan(
            body, x, (stacked_p, cache["state"], cache["conv"]))
        x = apply_norm(cfg.norm, x, params, "ln_f")
        logits = jnp.einsum("bd,dv->bv", x[:, 0, :],
                            self._unembed(params).astype(x.dtype),
                            preferred_element_type=jnp.float32)
        return logits, {"state": sts, "conv": cvs, "pos": cache["pos"] + 1}
