"""Loss functions.

``chunked_softmax_xent`` never materializes the full (B, S, V) logits tensor —
it scans over sequence chunks with a remat'd body, computing (B, chunk, V)
logits (vocab-sharded) per step. For 256k vocabularies at 4k×256 batch this is
the difference between ~4 GB and ~100s of MB of peak logits memory per device
(recorded as a beyond-paper memory optimization in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.modeling.layers import softcap


def chunked_softmax_xent(h, w_unembed, targets, mask, *, chunk: int = 1024,
                         cap: float = 0.0, impl: str = "onehot"):
    """h: (B,S,D); w_unembed: (D,V); targets/mask: (B,S). Returns (loss, denom).

    ``impl="gather"`` (§Perf): the target-logit lookup via take_along_axis —
    avoids the (B, chunk, V) f32 one-hot (3.3 GiB/device at 256k vocab),
    replacing it with a (B, chunk, 1) gather.
    """
    B, S, D = h.shape
    V = w_unembed.shape[1]
    c = min(chunk, S)
    while S % c:  # largest divisor of S not exceeding the requested chunk
        c -= 1
    nc = S // c

    hs = h.reshape(B, nc, c, D).transpose(1, 0, 2, 3)
    ts = targets.reshape(B, nc, c).transpose(1, 0, 2)
    ms = mask.reshape(B, nc, c).transpose(1, 0, 2)

    @jax.checkpoint
    def body(carry, xs):
        h_c, t_c, m_c = xs
        logits = jnp.einsum("bsd,dv->bsv", h_c, w_unembed,
                            preferred_element_type=jnp.float32)
        logits = softcap(logits, cap)
        logits = shard(logits, ("batch", None, "vocab"))
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        if impl == "gather":
            lt = jnp.take_along_axis(logits, t_c[..., None], axis=-1)[..., 0]
        else:
            onehot = jax.nn.one_hot(t_c, V, dtype=logits.dtype)
            lt = jnp.sum(logits * onehot, axis=-1)
        loss_sum = jnp.sum((lse - lt) * m_c)
        return (carry[0] + loss_sum, carry[1] + jnp.sum(m_c)), None

    init = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    if nc == 1:
        (loss_sum, denom), _ = body(init, (hs[0], ts[0], ms[0]))
    else:
        (loss_sum, denom), _ = jax.lax.scan(body, init, (hs, ts, ms))
    return loss_sum, denom


def full_softmax_xent(h, w_unembed, targets, mask, cap: float = 0.0):
    """Reference (unchunked) path — used by tests and the §Perf baseline."""
    logits = jnp.einsum("bsd,dv->bsv", h, w_unembed,
                        preferred_element_type=jnp.float32)
    logits = softcap(logits, cap)
    logits = shard(logits, ("batch", None, "vocab"))
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, w_unembed.shape[1], dtype=logits.dtype)
    lt = jnp.sum(logits * onehot, axis=-1)
    return jnp.sum((lse - lt) * mask), jnp.sum(mask)
