"""RG-LRU recurrent block (Griffin / RecurrentGemma).

The Real-Gated Linear Recurrent Unit:

    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t),  c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is elementwise-linear in h, hence associative — training uses
``jax.lax.associative_scan`` (the XLA path) or the Pallas ``linear_scan``
chunked kernel; decoding is a single fused state update.

The full Griffin recurrent *block* is: Wx → causal conv1d(width 4) → RG-LRU,
gated by a parallel GeLU branch, then an output projection.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.modeling.module import ParamSpec

RG_LRU_C = 8.0


def rglru_block_specs(cfg) -> dict[str, ParamSpec]:
    d, dr = cfg.d_model, cfg.d_rnn
    w = cfg.conv_width
    nb = getattr(cfg, "rglru_block_gates", 0)
    if nb:
        # Griffin §2.4: the recurrence/input gates use BLOCK-DIAGONAL weights.
        # Beyond fidelity, this kills the gate all-gather under tensor
        # parallelism: each shard's blocks contract entirely locally (§Perf).
        assert dr % nb == 0, (dr, nb)
        gate_a = ParamSpec((nb, dr // nb, dr // nb), ("rnn_blocks", None, None))
        gate_x = ParamSpec((nb, dr // nb, dr // nb), ("rnn_blocks", None, None))
    else:
        # dense (dr, dr) projections: contract over the replicated input dim,
        # keep the output dim sharded (one mesh axis per spec).
        gate_a = ParamSpec((dr, dr), (None, "rnn"))
        gate_x = ParamSpec((dr, dr), (None, "rnn"))
    return {
        "wx": ParamSpec((d, dr), ("embed", "rnn")),
        "wy": ParamSpec((d, dr), ("embed", "rnn")),   # GeLU gate branch
        "wo": ParamSpec((dr, d), ("rnn", "embed")),
        "conv/w": ParamSpec((w, dr), (None, "rnn")),
        "conv/b": ParamSpec((dr,), ("rnn",), init="zeros"),
        "gate_a/w": gate_a,
        "gate_a/b": ParamSpec((dr,), ("rnn",), init="zeros"),
        "gate_x/w": gate_x,
        "gate_x/b": ParamSpec((dr,), ("rnn",), init="zeros"),
        "lambda": ParamSpec((dr,), ("rnn",), init="ones"),
    }


def _gate_proj(u, w):
    """u: (B,S,Dr); w dense (Dr,Dr) or block-diagonal (nb, Dr/nb, Dr/nb)."""
    if w.ndim == 3:
        nb = w.shape[0]
        B, S, Dr = u.shape
        ub = u.reshape(B, S, nb, Dr // nb)
        out = jnp.einsum("bsnr,nrq->bsnq", ub, w)
        return out.reshape(B, S, Dr)
    return jnp.einsum("bsr,rq->bsq", u, w)


def _log_a(lam, r):
    # a_t = exp(-c · softplus(lambda) · r_t); computed in log space, fp32.
    return -RG_LRU_C * jax.nn.softplus(lam.astype(jnp.float32)) * r


def rglru_scan(x, a):
    """Associative scan of h_t = a_t h_{t-1} + x_t along axis 1. fp32 I/O."""

    def combine(l, r):
        a_l, b_l = l
        a_r, b_r = r
        return a_l * a_r, a_r * b_l + b_r

    a_out, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    del a_out
    return h


def causal_conv1d(x, w, b):
    """Depthwise causal temporal conv. x: (B,S,D); w: (W,D)."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + pad[:, i : i + x.shape[1], :] * w[i]
    return out + b


def rglru_block_apply(cfg, p, x, state=None, conv_state=None, impl="xla"):
    """Griffin recurrent block.

    Train/prefill: x (B,S,D), state None -> (y, final_state, final_conv_state).
    Decode: x (B,1,D) with carried (state (B,Dr) fp32, conv_state (B,W-1,Dr)).
    ``impl="pallas"`` runs the recurrence through the chunked linear_scan kernel.
    """
    dt = x.dtype
    u = jnp.einsum("bsd,dr->bsr", x, p["wx"].astype(dt))
    gate = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", x, p["wy"].astype(dt)),
                       approximate=True)

    W = p["conv/w"].shape[0]
    if conv_state is None:
        u_conv = causal_conv1d(u, p["conv/w"].astype(dt), p["conv/b"].astype(dt))
        new_conv_state = u[:, -(W - 1):, :] if u.shape[1] >= W - 1 else jnp.pad(
            u, ((0, 0), (W - 1 - u.shape[1], 0), (0, 0)))
    else:
        hist = jnp.concatenate([conv_state, u], axis=1)  # (B, W-1+1, Dr)
        u_conv = (
            jnp.einsum("bwr,wr->br", hist, p["conv/w"].astype(dt))
            + p["conv/b"].astype(dt)
        )[:, None, :]
        new_conv_state = hist[:, 1:, :]

    r = jax.nn.sigmoid(
        _gate_proj(u_conv, p["gate_a/w"]).astype(jnp.float32)
        + p["gate_a/b"].astype(jnp.float32))
    i = jax.nn.sigmoid(
        _gate_proj(u_conv, p["gate_x/w"]).astype(jnp.float32)
        + p["gate_x/b"].astype(jnp.float32))
    log_a = _log_a(p["lambda"], r)          # (B,S,Dr) fp32
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    inp = beta * i * u_conv.astype(jnp.float32)

    if state is None:
        if impl == "pallas":
            from repro.kernels.linear_scan import ops as ls_ops

            h, final_state = ls_ops.linear_scan(inp, a)
        else:
            h = rglru_scan(inp, a)                  # (B,S,Dr) fp32
            final_state = h[:, -1, :]
    else:
        h = a * state[:, None, :] + inp             # single step
        final_state = h[:, -1, :]

    y = (h.astype(dt) * gate)
    out = jnp.einsum("bsr,rd->bsd", y, p["wo"].astype(dt))
    return out, final_state, new_conv_state
