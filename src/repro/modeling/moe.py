"""Mixture-of-Experts layer (Gshard-style capacity-based dispatch/combine).

The one-hot dispatch einsum formulation is deliberately chosen over sort-based
routing: under GSPMD it shards cleanly — tokens over ("pod","data"), experts
over "model" — and the dispatch/combine einsums lower to all-to-alls on the
expert axis, which is the communication pattern expert parallelism needs.

Memory is controlled by grouping the sequence into ``cfg.moe_group``-token
groups: capacity C = group·top_k/E·capacity_factor, so the dispatch tensor is
(B, nG, g, E, C) ≈ tokens × E × C — bounded per group instead of per sequence.

Load-balancing auxiliary loss follows Switch/Gshard: E · Σ_e f_e · P_e.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.modeling.layers import activation, is_gated
from repro.modeling.module import ParamSpec


def moe_capacity(cfg) -> int:
    g, k, e = cfg.moe_group, cfg.top_k, cfg.n_experts
    c = math.ceil(g * k / e * cfg.capacity_factor)
    return max(4, int(math.ceil(c / 4) * 4))


def moe_specs(cfg) -> dict[str, ParamSpec]:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    # Expert parallelism shards the expert axis over "model"; the expert-
    # internal FF dim uses its own logical axis ("expert_mlp") so the two
    # never map to the same mesh axis (it shards only when experts cannot).
    specs = {
        "router/w": ParamSpec((d, e), ("embed", "experts")),
        "wo": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed")),
    }
    if is_gated(cfg.act):
        specs["wi_0"] = ParamSpec((e, d, f), ("experts", "embed", "expert_mlp"))
        specs["wi_1"] = ParamSpec((e, d, f), ("experts", "embed", "expert_mlp"))
    else:
        specs["wi"] = ParamSpec((e, d, f), ("experts", "embed", "expert_mlp"))
    return specs


def moe_apply(cfg, p: dict, x, shard_fn=None):
    """x: (B, S, D) -> (y, aux_loss). ``p`` holds this layer's MoE params.

    §Perf (``cfg.moe_batch_groups``): when S is tiny (decode: S=1), per-
    sequence groups of g=1 token waste E·C−1 of every expert buffer —
    utilization 1/(E·C). Grouping across the *batch* dim instead packs all
    B in-flight tokens into one capacity pool (C = ⌈B·K/E·cf⌉), the standard
    serving layout; per-step expert FLOPs drop ~E·C/(B·K/E·cf)×.
    """
    shard = shard_fn or (lambda a, axes: a)
    B, S, D = x.shape
    if getattr(cfg, "moe_batch_groups", False) and S < cfg.moe_group and B > 1:
        y, aux = _moe_apply_grouped(
            cfg, p, x.reshape(1, B * S, D), shard,
            batch_in_group=True)
        return y.reshape(B, S, D), aux
    return _moe_apply_grouped(cfg, p, x, shard, batch_in_group=False)


def _moe_apply_grouped(cfg, p: dict, x, shard, batch_in_group: bool):
    B, S, D = x.shape
    g = min(cfg.moe_group, S)
    while S % g:  # largest divisor of S not exceeding the requested group size
        g -= 1
    nG = S // g
    # with batch_in_group, the flattened token dim keeps the batch sharding
    tok_axes = (None, None, "batch") if batch_in_group else ("batch", None, None)
    E, K = cfg.n_experts, cfg.top_k
    if batch_in_group:
        # capacity from the ACTUAL pooled-token count (decode: g = B·S)
        c = math.ceil(g * K / E * cfg.capacity_factor)
        C = max(2, int(math.ceil(c / 2) * 2))
    else:
        C = moe_capacity(cfg)

    xg = x.reshape(B, nG, g, D)
    logits = jnp.einsum(
        "bngd,de->bnge", xg, p["router/w"], preferred_element_type=jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (B,nG,g,E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)  # (B,nG,g,K)
    if K > 1:
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
        )

    # ---- capacity assignment --------------------------------------------
    eoh = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (B,nG,g,K,E)
    # Position of each (token, k) assignment within its expert's buffer:
    # flatten (g, K) in token-major priority order and cumulative-sum.
    flat = eoh.reshape(B, nG, g * K, E)
    pos = jnp.cumsum(flat, axis=2) * flat - 1.0  # (B,nG,g*K,E)
    pos = pos.reshape(B, nG, g, K, E)
    within = (pos >= 0) & (pos < C)
    pos_idx = jnp.sum(pos * eoh, axis=-1)  # (B,nG,g,K) position for chosen expert
    keep = jnp.any(within & (eoh > 0), axis=-1)  # (B,nG,g,K)

    poh = jax.nn.one_hot(pos_idx, C, dtype=jnp.float32) * keep[..., None]
    # dispatch: (B,nG,g,E,C); combine adds the gate weight
    dispatch = jnp.einsum("bngke,bngkc->bngec", eoh, poh)
    combine = jnp.einsum("bngke,bngkc->bngec", eoh * gate_vals[..., None], poh)
    dispatch = shard(dispatch, tok_axes[:2] + (tok_axes[2], "experts", None))

    # ---- expert computation (E sharded over the model axis) --------------
    dt = x.dtype
    xe = jnp.einsum("bngec,bngd->bnecd", dispatch.astype(dt), xg)
    xe = shard(xe, (tok_axes[0], None, "experts", None, None))
    if is_gated(cfg.act):
        h = activation(
            cfg.act,
            jnp.einsum("bnecd,edf->bnecf", xe, p["wi_0"].astype(dt)),
            jnp.einsum("bnecd,edf->bnecf", xe, p["wi_1"].astype(dt)),
        )
    else:
        h = activation(cfg.act, jnp.einsum("bnecd,edf->bnecf", xe, p["wi"].astype(dt)))
    ye = jnp.einsum("bnecf,efd->bnecd", h.astype(dt), p["wo"].astype(dt))
    ye = shard(ye, (tok_axes[0], None, "experts", None, None))
    y = jnp.einsum("bngec,bnecd->bngd", combine.astype(x.dtype), ye)
    y = y.reshape(B, S, D)

    # ---- Switch-style load-balancing aux loss ----------------------------
    frac_tokens = jnp.mean(eoh[..., 0, :] if K == 1 else jnp.max(eoh, axis=3),
                           axis=(0, 1, 2))  # fraction routed per expert
    frac_probs = jnp.mean(probs, axis=(0, 1, 2))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y, aux
