"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert,
GQA kv=8, early fusion. [hf:meta-llama/Llama-4-Scout-17B-16E]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,             # shared-expert / dense dims
    vocab=202048,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=500000.0,
    n_experts=128,
    top_k=1,
    d_ff_expert=8192,
    shared_expert=True,
    capacity_factor=2.0,   # top-1 routing needs headroom (Switch-style)
)
