"""recurrentgemma-9b [hybrid] — Griffin: RG-LRU + local attention 1:2,
window 2048, MQA. [arXiv:2402.19427]

38 layers with pattern (rec, rec, attn): 12 full groups + (rec, rec) tail =
26 recurrent + 12 local-attention layers. Sub-quadratic ⇒ runs long_500k.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,          # MQA local attention
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    act="geglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    attn_window=2048,
    block_pattern=("rec", "rec", "attn"),
    d_rnn=4096,
    conv_width=4,
)
