"""olmoe-1b-7b [moe] — 64 experts top-8, expert d_ff=1024. [arXiv:2409.02060; hf]"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab=50304,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    n_experts=64,
    top_k=8,
    d_ff_expert=1024,
    capacity_factor=1.25,
)
