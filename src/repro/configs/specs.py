"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation — the dry-run lowers train/serve
steps against these. For decode cells the spec includes the KV/state cache of
``seq_len`` entries plus the one-token batch, per the assignment.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.modeling.registry import build_model


def _token_batch(cfg: ArchConfig, B: int, S: int, with_targets: bool):
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    i32 = jnp.int32
    f32 = jnp.float32
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.frame_feat_dim), f32)
        if with_targets:
            specs["mask"] = jax.ShapeDtypeStruct((B, S), f32)
            specs["targets"] = jax.ShapeDtypeStruct((B, S), i32)
        return specs
    if cfg.family == "vlm":
        V = cfg.vision_tokens
        specs["tokens"] = jax.ShapeDtypeStruct((B, S - V), i32)
        specs["vision_embeds"] = jax.ShapeDtypeStruct((B, V, cfg.vision_feat_dim), f32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    if with_targets:
        specs["targets"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["loss_mask"] = jax.ShapeDtypeStruct((B, S), f32)
    return specs


def input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """Returns (kind, specs) where specs matches the step function's signature:

    - train:   {batch}                      for train_step(params, batch)
    - prefill: {batch}                      for prefill_step(params, batch)
    - decode:  {batch: {token}, cache: …}   for serve_step(params, cache, batch)
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return "train", {"batch": _token_batch(cfg, B, S, with_targets=True)}
    if shape.kind == "prefill":
        return "prefill", {"batch": _token_batch(cfg, B, S, with_targets=False)}
    if shape.kind == "decode":
        model = build_model(cfg)
        cache = model.cache_shape(B, S)
        batch = {"token": jax.ShapeDtypeStruct((B,), jnp.int32)}
        return "decode", {"cache": cache, "batch": batch}
    raise ValueError(f"unknown shape kind {shape.kind!r}")
