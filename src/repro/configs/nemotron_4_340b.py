"""nemotron-4-340b [dense] — GQA (kv=8), squared-ReLU MLP. [arXiv:2402.16819]

At 340B dense this is the arch that REQUIRES FSDP weight sharding over the
data axis on a 256-chip pod (bf16 params alone are 42 GB/chip under pure
16-way tensor parallelism).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab=256000,
    act="sqrelu",          # squared ReLU, non-gated
    norm="layernorm",
    rope_theta=10000.0,
)
