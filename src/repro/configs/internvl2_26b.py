"""internvl2-26b [vlm] — InternViT frontend (STUB) + InternLM2-20B backbone.
[arXiv:2404.16821; hf]

The assignment specifies the transformer BACKBONE only; the vision frontend is
a stub — ``input_specs()`` provides precomputed patch embeddings
(B, vision_tokens, 3200) which the model projects and prepends to the token
stream.

vocab is padded 92553 -> 92672 (multiple of 16·128) so the vocabulary axis
shards over the 16-way model axis; padded logit rows are never targeted.
"""

from repro.configs.base import ArchConfig

REAL_VOCAB = 92553

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92672,           # padded from 92553 for model-axis divisibility
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    vision_tokens=1024,
    vision_feat_dim=3200,  # InternViT-6B hidden size
)
