"""Architecture registry: the 10 assigned configs + smoke-test reductions."""

from __future__ import annotations

from dataclasses import replace

from repro.configs.base import (
    ArchConfig,
    ShapeConfig,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    applicable_shapes,
)
from repro.configs import (
    gemma_2b,
    olmo_1b,
    nemotron_4_340b,
    llama3_2_1b,
    llama4_maverick,
    olmoe_1b_7b,
    internvl2_26b,
    recurrentgemma_9b,
    hubert_xlarge,
    mamba2_780m,
)

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        gemma_2b, olmo_1b, nemotron_4_340b, llama3_2_1b, llama4_maverick,
        olmoe_1b_7b, internvl2_26b, recurrentgemma_9b, hubert_xlarge,
        mamba2_780m,
    )
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests (one fwd/train step)."""
    cfg = get_config(name)
    kw = dict(
        n_layers=4 if cfg.family == "hybrid" else 2,
        d_model=64,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=128,
        q_chunk=16,
        loss_chunk=16,
        moe_group=16,
        remat="none",
        dtype="float32",
    )
    if cfg.n_heads:
        ratio = max(cfg.n_heads // max(cfg.n_kv_heads, 1), 1)
        kw["n_heads"] = 4
        kw["n_kv_heads"] = max(4 // min(ratio, 4), 1)
        kw["head_dim"] = 16
    if cfg.n_experts:
        kw["n_experts"] = 4
        kw["top_k"] = min(cfg.top_k, 2)
        kw["d_ff_expert"] = 64
    if cfg.family == "hybrid":
        kw["d_rnn"] = 64
        kw["attn_window"] = 16
    if cfg.family == "ssm":
        kw["ssm_state"] = 16
        kw["ssm_head_dim"] = 8
        kw["ssm_chunk"] = 8
    if cfg.vision_tokens:
        kw["vision_tokens"] = 8
        kw["vision_feat_dim"] = 32
    if cfg.frame_feat_dim:
        kw["frame_feat_dim"] = 16
    return replace(cfg, **kw)


__all__ = [
    "ArchConfig", "ShapeConfig", "SHAPES", "ARCHS",
    "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
    "get_config", "smoke_config", "applicable_shapes",
]
