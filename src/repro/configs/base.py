"""Architecture + shape configuration for the assigned model pool."""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # "dense" | "moe" | "vlm" | "hybrid" | "audio" | "ssm"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    act: str = "swiglu"            # "swiglu" | "geglu" | "sqrelu" | "gelu"
    norm: str = "rmsnorm"          # "rmsnorm" | "layernorm" | "np_layernorm"
    rope_theta: float = 10000.0
    pos_emb: str = "rope"          # "rope" | "sinusoidal" | "none"
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    shared_expert: bool = False
    # --- hybrid (Griffin / RG-LRU) ---
    attn_window: int = 0           # 0 = global attention; >0 = local window
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    d_rnn: int = 0
    conv_width: int = 4
    # --- SSM (Mamba-2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    # --- VLM ---
    vision_tokens: int = 0
    vision_feat_dim: int = 0
    # --- audio (encoder-only) ---
    frame_feat_dim: int = 0
    mask_prob: float = 0.08        # masked-prediction training (HuBERT)
    # --- runtime knobs (perf-relevant; §Perf iterates on these) ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    q_chunk: int = 512             # query chunking for flash-style attention
    loss_chunk: int = 1024         # sequence chunking for the softmax-xent loss
    moe_group: int = 256           # sequence group size for MoE dispatch
    capacity_factor: float = 1.25
    remat: str = "full"            # "none" | "dots" | "full"
    logits_softcap: float = 0.0
    tie_embeddings: bool = False
    scan_layers: bool = True
    attn_impl: str = "xla"         # "xla" | "pallas" (pallas targets real TPUs)
    # --- §Perf hillclimb knobs (defaults = paper-faithful baseline) ---
    loss_impl: str = "onehot"      # "onehot" | "gather" target-logit lookup
    banded_window: bool = False    # local attention: banded K/V slices (O(S·W))
    cp_attn: bool = False          # context parallelism: shard q-seq over model
    sp_acts: bool = False          # Megatron-style sequence-sharded residuals
    microbatch: int = 1            # grad-accumulation microbatches per step
    rglru_block_gates: int = 0     # 0=dense gates; N=block-diagonal (Griffin §2.4)
    serve_2d_ffn: bool = False     # serving: FFN/expert weights 2D-sharded
                                   # (model×data) — no per-step weight gathers
    moe_batch_groups: bool = False # decode: one capacity pool across the batch
    kv_quant: bool = False         # int8 KV cache (per-slot-head scales)

    # ------------------------------------------------------------- helpers
    @property
    def is_encoder_only(self) -> bool:
        return self.family == "audio"

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SSM state or local window.)"""
        return self.family in ("ssm", "hybrid")

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def with_updates(self, **kw) -> "ArchConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape × step-kind) cell of the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four assigned shape suites (LM shapes are seq_len × global_batch).
TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode")

SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def applicable_shapes(cfg: ArchConfig) -> dict[str, ShapeConfig | None]:
    """The 4 assigned cells for an arch; None = documented skip (DESIGN.md §6).

    - ``long_500k`` needs sub-quadratic attention → only SSM/hybrid run it;
    - encoder-only archs have no decode step → decode cells skipped.
    """
    cells: dict[str, ShapeConfig | None] = {}
    for name, s in SHAPES.items():
        if s.kind == "decode" and cfg.is_encoder_only:
            cells[name] = None
        elif name == "long_500k" and not cfg.sub_quadratic:
            cells[name] = None
        else:
            cells[name] = s
    return cells
