"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]

d_inner = 2·1536 = 3072, head_dim 64 ⇒ 48 SSD heads, state 128. O(1) decode
state ⇒ runs long_500k. vocab padded 50280 -> 50288 for divisibility.
"""

from repro.configs.base import ArchConfig

REAL_VOCAB = 50280

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,                # attention-free, no separate MLP stack
    vocab=50288,           # padded from 50280
    act="gelu",
    norm="rmsnorm",
    pos_emb="none",
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    conv_width=4,
)
