"""hubert-xlarge [audio] — encoder-only transformer backbone. [arXiv:2106.07447]

The CNN waveform frontend is a STUB: input_specs provides precomputed frame
features (B, S, 512). Training is masked prediction over a 504-entry codebook
(vocab padded to 512 for model-axis divisibility). Encoder-only ⇒ the decode
shape cells are documented skips.
"""

from repro.configs.base import ArchConfig

REAL_VOCAB = 504

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=512,             # padded from 504
    act="gelu",
    norm="layernorm",
    pos_emb="sinusoidal",  # conv-positional frontend stubbed
    frame_feat_dim=512,
    mask_prob=0.08,
)
