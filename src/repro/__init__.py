"""repro: Dynamic task placement for edge-cloud serverless platforms (Das et al., 2020),
rebuilt as a production-grade multi-pod JAX/TPU training + serving framework.

Subpackages (imported lazily — keep this module free of jax backend init so that
``repro.launch.dryrun`` can set XLA_FLAGS before any device is created):

- ``repro.core``        — the paper's contribution: perf models, Predictor/CIL, DecisionEngine, simulator
- ``repro.modeling``    — pure-JAX model zoo for the 10 assigned architectures
- ``repro.configs``     — architecture configs + shape suites + input_specs
- ``repro.distributed`` — sharding rules, mesh helpers, gradient compression
- ``repro.training``    — optimizer, train step, checkpointing, fault-tolerant loop
- ``repro.serving``     — KV cache, serve steps, executor catalog, placement service
- ``repro.kernels``     — Pallas TPU kernels (flash attention, decode, SSD, linear scan, GBRT)
- ``repro.launch``      — production mesh, multi-pod dry-run, train/serve entry points
"""

__version__ = "0.1.0"
