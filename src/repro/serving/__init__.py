"""Serving: step builders, live slice executors, and the placement service.

``engine``    — prefill/decode step builders + a batched generation loop.
``executors`` — the TPU-fleet executor pool: slice configs λ_m with real
                compiled-executable caching (cold start = real XLA compile),
                plus the always-on edge executor with a FIFO queue.
``placement`` — the paper's framework instantiated over the slice catalog:
                SliceTarget performance models, calibration (fit), the
                ``LiveBackend`` execution backend, and ``make_live_runtime``
                which wires it all into the unified
                ``repro.core.runtime.PlacementRuntime`` serve loop (the
                Table-V-analog benchmark path).
"""

from repro.serving.engine import make_decode_step, make_prefill_step, generate
from repro.serving.executors import SliceSpec, LiveExecutor, ExecutorPool
from repro.serving.placement import (
    SliceTarget,
    SliceCatalog,
    calibrate_catalog,
    build_slice_predictor,
    llm_workload,
    LiveBackend,
    LivePlacementServer,
    make_live_runtime,
)

__all__ = [
    "make_decode_step", "make_prefill_step", "generate",
    "SliceSpec", "LiveExecutor", "ExecutorPool",
    "SliceTarget", "SliceCatalog", "calibrate_catalog",
    "build_slice_predictor", "llm_workload", "LiveBackend",
    "LivePlacementServer", "make_live_runtime",
]
