"""Live slice executors: the TPU-fleet analog of the paper's containers.

A *slice config* λ_m is the fleet's counterpart of an AWS container memory
size: a number of chips (with tensor parallelism inside the slice), trading
cost for speed. This module runs REAL JAX executions on the local backend:

- **cold start** = the first dispatch to a slice pays the real XLA compile +
  parameter initialization (exactly the dominant TPU serving cold-start
  cost); subsequent dispatches reuse the cached executable and weights
  (**warm start**). Each ``LiveExecutor`` builds fresh jit wrappers, so a
  re-provisioned slice genuinely recompiles;
- **throughput model**: a task of n_tokens runs ``ceil(n_tokens / (chips ×
  tokens_per_step))`` genuine compiled decode steps — more chips ⇒
  proportionally fewer sequential steps, the first-order effect of
  tensor-parallel scaling. Every step is a real execution, so measured
  latencies carry real machine noise (the variance the paper's models absorb);
- **two clocks**: *durations* are wall-clock measurements of real work;
  *container lifecycle* (busy/idle/expired) runs on the workload's virtual
  arrival clock, so warm/cold dynamics match the Poisson arrivals exactly as
  the paper's simulator+prototype pair does;
- the **edge executor** is a 1-chip slice with a single-slot FIFO queue,
  always-resident executable, and zero marginal cost (the Greengrass
  long-lived function model).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import make_decode_step, make_prefill_step


@dataclass(frozen=True)
class SliceSpec:
    """One λ_m in the slice catalog."""

    name: str
    chips: int
    tokens_per_step: int = 16  # tokens retired per compiled step per chip
    is_edge: bool = False


@dataclass
class ExecutionRecord:
    feed_ms: float
    start_ms: float   # compile+init on cold, executable-lookup on warm
    comp_ms: float
    store_ms: float
    cold: bool
    queue_ms: float = 0.0

    @property
    def total_ms(self) -> float:
        return self.feed_ms + self.start_ms + self.comp_ms + self.store_ms + self.queue_ms


def _wall_ms() -> float:
    return time.monotonic() * 1e3


class LiveExecutor:
    """One container: a slice holding (or not) a resident compiled model."""

    def __init__(self, spec: SliceSpec, model_cfg, seed: int = 0):
        self.spec = spec
        self.model_cfg = model_cfg
        self.seed = seed
        self._compiled = None
        # virtual-clock lifecycle state (ms on the workload arrival clock)
        self.busy_until: float = 0.0
        self.last_completion: float = 0.0

    def is_warm(self) -> bool:
        return self._compiled is not None

    def evict(self):
        """Provider reclaimed the idle slice: drop executable + weights."""
        self._compiled = None

    def _ensure_compiled(self) -> tuple[float, bool]:
        """Returns (start_ms, cold). Cold pays real compile + init + warmup."""
        if self._compiled is not None:
            return 0.05, False  # executable lookup
        from repro.modeling.registry import build_model

        t0 = _wall_ms()
        model = build_model(self.model_cfg)
        params = model.init(jax.random.key(self.seed))
        prefill_fn = jax.jit(make_prefill_step(model, cache_len=None))
        decode_fn = jax.jit(make_decode_step(model))
        B, S = 1, 32
        toks = jnp.zeros((B, S), jnp.int32)
        logits, cache = prefill_fn(params, {"tokens": toks})
        logits, cache = decode_fn(params, cache,
                                  {"token": jnp.zeros((B,), jnp.int32)})
        jax.block_until_ready(logits)
        self._compiled = (prefill_fn, decode_fn, params, model)
        return _wall_ms() - t0, True

    def execute(self, n_tokens: int, payload_bytes: float) -> ExecutionRecord:
        """Run a task of ``n_tokens`` through real compiled steps."""
        start_ms, cold = self._ensure_compiled()
        prefill_fn, decode_fn, params, model = self._compiled

        t0 = _wall_ms()
        _ = jax.device_put(np.zeros(max(int(payload_bytes) // 4, 1), np.float32))
        feed_ms = _wall_ms() - t0

        steps = max(int(np.ceil(
            n_tokens / (self.spec.chips * self.spec.tokens_per_step))), 1)
        t0 = _wall_ms()
        B, S = 1, 32
        logits, cache = prefill_fn(params, {"tokens": jnp.zeros((B, S), jnp.int32)})
        tok = jnp.zeros((B,), jnp.int32)
        for _ in range(steps):
            logits, cache = decode_fn(params, cache, {"token": tok})
        jax.block_until_ready(logits)
        comp_ms = _wall_ms() - t0

        t0 = _wall_ms()
        _ = np.asarray(logits)
        store_ms = _wall_ms() - t0

        return ExecutionRecord(feed_ms=feed_ms, start_ms=start_ms,
                               comp_ms=comp_ms, store_ms=store_ms, cold=cold)


@dataclass
class ExecutorPool:
    """The fleet's actual container state (the provider's ground truth).

    Containers live/die on the *virtual* clock; work is measured for real.
    ``edges`` holds one always-resident single-slot executor per edge device
    (the multi-device generalization; ``edge``/``edge_free_at_ms`` survive as
    single-device aliases for the first device).
    """

    model_cfg: object
    specs: dict[str, SliceSpec]
    t_idl_ms: float = 120_000.0
    containers: dict[str, list[LiveExecutor]] = field(default_factory=dict)
    edges: dict[str, LiveExecutor] = field(default_factory=dict)
    edge_free_at: dict[str, float] = field(default_factory=dict)
    _seed: int = 0

    # ------------------------------------- deprecated single-edge conveniences
    @property
    def edge(self) -> LiveExecutor | None:
        return next(iter(self.edges.values()), None)

    @property
    def edge_names(self) -> tuple[str, ...]:
        return tuple(self.edges)

    @property
    def edge_free_at_ms(self) -> float:
        return self.edge_free_at[next(iter(self.edges))]

    @edge_free_at_ms.setter
    def edge_free_at_ms(self, value: float) -> None:
        self.edge_free_at[next(iter(self.edges))] = value

    # ------------------------------------------------------------ cloud side
    def _reap(self, name: str, now: float):
        pool = self.containers.get(name, [])
        for c in pool:
            if c.busy_until <= now and now - c.last_completion > self.t_idl_ms:
                c.evict()
        self.containers[name] = [c for c in pool if c.is_warm()
                                 or c.busy_until > now]

    def probe_cold(self, name: str, now: float) -> bool:
        """Would a dispatch at virtual time ``now`` cold-start? (No mutation.)"""
        pool = self.containers.get(name, [])
        return not any(
            c.busy_until <= now and now - c.last_completion <= self.t_idl_ms
            and c.is_warm() for c in pool)

    def execute_cloud(self, name: str, n_tokens: int, payload_bytes: float,
                      now: float) -> ExecutionRecord:
        self._reap(name, now)
        pool = self.containers.setdefault(name, [])
        idle = [c for c in pool if c.busy_until <= now and c.is_warm()]
        if idle:
            c = max(idle, key=lambda c: c.last_completion)  # AWS reuse order
        else:
            self._seed += 1
            c = LiveExecutor(self.specs[name], self.model_cfg, seed=self._seed)
            pool.append(c)
        rec = c.execute(n_tokens, payload_bytes)
        completion = now + rec.start_ms + rec.comp_ms
        c.busy_until = completion
        c.last_completion = completion
        return rec

    # ------------------------------------------------------------- edge side
    def execute_edge(self, n_tokens: int, payload_bytes: float,
                     arrival_ms: float, device: str | None = None) -> ExecutionRecord:
        device = device if device is not None else next(iter(self.edges))
        rec = self.edges[device].execute(n_tokens, payload_bytes)
        queue = max(self.edge_free_at[device] - arrival_ms, 0.0)
        self.edge_free_at[device] = arrival_ms + queue + rec.comp_ms
        rec.queue_ms = queue
        return rec

    def actual_edge_wait(self, arrival_ms: float, device: str | None = None) -> float:
        device = device if device is not None else next(iter(self.edges))
        return max(self.edge_free_at[device] - arrival_ms, 0.0)


def make_pool(model_cfg, specs: list[SliceSpec], t_idl_ms: float = 120_000.0,
              edge_spec: SliceSpec | None = None,
              edge_specs: list[SliceSpec] | None = None) -> ExecutorPool:
    """Build the provider-side pool. ``edge_specs`` provisions a multi-device
    edge fleet (one always-resident executor per device); ``edge_spec`` is the
    deprecated single-device spelling."""
    if edge_specs is None:
        edge_specs = [edge_spec or SliceSpec(name="edge", chips=1, is_edge=True)]
    pool = ExecutorPool(
        model_cfg=model_cfg,
        specs={s.name: s for s in specs if not s.is_edge},
        t_idl_ms=t_idl_ms,
        edges={s.name: LiveExecutor(s, model_cfg) for s in edge_specs},
        edge_free_at={s.name: 0.0 for s in edge_specs},
    )
    # each edge device's long-lived function is always resident (Sec. II-A.2):
    # every device pays its own one-time real compile at provisioning, never
    # during serving
    for ex in pool.edges.values():
        ex._ensure_compiled()
    return pool
