"""Live slice executors: the TPU-fleet analog of the paper's containers.

A *slice config* λ_m is the fleet's counterpart of an AWS container memory
size: a number of chips (with tensor parallelism inside the slice), trading
cost for speed. This module runs REAL JAX executions on the local backend:

- **cold start** = the first dispatch to a slice pays the real XLA compile +
  parameter initialization (exactly the dominant TPU serving cold-start
  cost); subsequent dispatches reuse the cached executable and weights
  (**warm start**). Each ``LiveExecutor`` builds fresh jit wrappers, so a
  re-provisioned slice genuinely recompiles;
- **throughput model**: a task of n_tokens runs ``ceil(n_tokens / (chips ×
  tokens_per_step))`` genuine compiled decode steps — more chips ⇒
  proportionally fewer sequential steps, the first-order effect of
  tensor-parallel scaling. Every step is a real execution, so measured
  latencies carry real machine noise (the variance the paper's models absorb);
- **two clocks**: *durations* are wall-clock measurements of real work;
  *container lifecycle* (busy/idle/expired) runs on the workload's virtual
  arrival clock, so warm/cold dynamics match the Poisson arrivals exactly as
  the paper's simulator+prototype pair does;
- the **edge executor** is a 1-chip slice with a single-slot FIFO queue,
  always-resident executable, and zero marginal cost (the Greengrass
  long-lived function model).

The CONCURRENT dispatch loop (``ExecutorPool.serve_concurrent``) is the live
half of the event-driven serving runtime: one dispatcher thread per target —
each edge device, each cloud config — pulls its dispatches in arrival order,
real executions overlap across targets, and completions land on one shared
queue *out of arrival order*. That out-of-orderness is why container
bookkeeping is a ``lease``/``land`` pair (a leased container's virtual
lifecycle is stale until its completion lands, so it is never reused or
reaped mid-flight) and why the idle-eviction sweep walks containers in
COMPLETION-TIME order — push order means nothing once completions interleave.
Cold compiles are guarded per executor (``LiveExecutor`` owns a lock), and
executors can be pinned to distinct jax devices so their streams genuinely
overlap (see ``repro.serving.engine.make_compiled_steps``).

``NetworkProfile`` (off by default) emulates the paper's WAN legs with real
wall-clock waits: cloud dispatches pay an upload on the feed leg, edge
dispatches an IoT result-upload on the store leg. Compute overlap is bounded
by local cores; overlapping these network waits with compute is exactly the
latency the event-driven driver exists to hide (paper Sec. II-A).
"""

from __future__ import annotations

import queue as queue_mod
import threading
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.engine import make_compiled_steps


@dataclass(frozen=True)
class SliceSpec:
    """One λ_m in the slice catalog."""

    name: str
    chips: int
    tokens_per_step: int = 16  # tokens retired per compiled step per chip
    is_edge: bool = False


@dataclass(frozen=True)
class NetworkProfile:
    """Emulated WAN link: ``base_ms + ms_per_byte × payload`` of REAL wait.

    The paper's upload (device → cloud) and IoT-upload (edge → cloud storage)
    legs are network time; the local testbed has none, so the pool can
    emulate them netem-style with genuine ``time.sleep`` waits. Off by
    default everywhere — parity tests and calibration run with zero network.
    """

    base_ms: float = 0.0
    ms_per_byte: float = 0.0

    def delay_ms(self, nbytes: float) -> float:
        return self.base_ms + self.ms_per_byte * float(nbytes)

    def transfer(self, nbytes: float) -> float:
        """Perform the emulated transfer (a real wall-clock wait); returns ms."""
        ms = self.delay_ms(nbytes)
        if ms > 0.0:
            time.sleep(ms / 1e3)
        return ms


@dataclass
class ExecutionRecord:
    feed_ms: float
    start_ms: float   # compile+init on cold, executable-lookup on warm
    comp_ms: float
    store_ms: float
    cold: bool
    queue_ms: float = 0.0

    @property
    def total_ms(self) -> float:
        return self.feed_ms + self.start_ms + self.comp_ms + self.store_ms + self.queue_ms


def _wall_ms() -> float:
    return time.monotonic() * 1e3


class LiveExecutor:
    """One container: a slice holding (or not) a resident compiled model.

    Thread-safe for the concurrent pool: the cold compile is guarded by a
    per-executor lock (a dispatch and a racing hedge can never double-compile
    the same container), and ``execute`` serializes on the same lock — one
    executor is one slot. ``device`` pins this executor's params (and so its
    executions) to one jax device; ``network`` adds the emulated WAN legs.
    """

    def __init__(self, spec: SliceSpec, model_cfg, seed: int = 0,
                 device=None, network: NetworkProfile | None = None):
        self.spec = spec
        self.model_cfg = model_cfg
        self.seed = seed
        self.device = device
        self.network = network
        self._compiled = None
        self._lock = threading.Lock()  # cold-compile + single-slot guard
        # virtual-clock lifecycle state (ms on the workload arrival clock)
        self.busy_until: float = 0.0
        self.last_completion: float = 0.0
        self.in_flight: bool = False  # leased by a concurrent dispatch

    def is_warm(self) -> bool:
        return self._compiled is not None

    def evict(self):
        """Provider reclaimed the idle slice: drop executable + weights."""
        self._compiled = None

    def _ensure_compiled(self) -> tuple[float, bool]:
        """Returns (start_ms, cold). Cold pays real compile + init + warmup.
        Guarded per executor: concurrent callers see exactly one compile."""
        if self._compiled is not None:
            return 0.05, False  # executable lookup
        with self._lock:
            return self._compile_locked()

    def _compile_locked(self) -> tuple[float, bool]:
        if self._compiled is not None:
            return 0.05, False  # a racing caller compiled while we waited
        t0 = _wall_ms()
        model, params, prefill_fn, decode_fn = make_compiled_steps(
            self.model_cfg, seed=self.seed, device=self.device)
        B, S = 1, 32
        toks = jnp.zeros((B, S), jnp.int32)
        logits, cache = prefill_fn(params, {"tokens": toks})
        logits, cache = decode_fn(params, cache,
                                  {"token": jnp.zeros((B,), jnp.int32)})
        jax.block_until_ready(logits)
        self._compiled = (prefill_fn, decode_fn, params, model)
        return _wall_ms() - t0, True

    def execute(self, n_tokens: int, payload_bytes: float) -> ExecutionRecord:
        """Run a task of ``n_tokens`` through real compiled steps."""
        with self._lock:
            start_ms, cold = self._compile_locked()
            prefill_fn, decode_fn, params, model = self._compiled

            t0 = _wall_ms()
            feed = np.zeros(max(int(payload_bytes) // 4, 1), np.float32)
            if self.device is not None:
                _ = jax.device_put(feed, self.device)
            else:
                _ = jax.device_put(feed)
            feed_ms = _wall_ms() - t0
            if self.network is not None and not self.spec.is_edge:
                feed_ms += self.network.transfer(payload_bytes)  # WAN upload

            steps = max(int(np.ceil(
                n_tokens / (self.spec.chips * self.spec.tokens_per_step))), 1)
            t0 = _wall_ms()
            B, S = 1, 32
            logits, cache = prefill_fn(params, {"tokens": jnp.zeros((B, S), jnp.int32)})
            tok = jnp.zeros((B,), jnp.int32)
            for _ in range(steps):
                logits, cache = decode_fn(params, cache, {"token": tok})
            jax.block_until_ready(logits)
            comp_ms = _wall_ms() - t0

            t0 = _wall_ms()
            _ = np.asarray(logits)
            store_ms = _wall_ms() - t0
            if self.network is not None and self.spec.is_edge:
                store_ms += self.network.transfer(payload_bytes)  # IoT upload

            return ExecutionRecord(feed_ms=feed_ms, start_ms=start_ms,
                                   comp_ms=comp_ms, store_ms=store_ms, cold=cold)


@dataclass
class _Dispatch:
    """One row of a concurrent dispatch plan (arrival-ordered per target)."""

    idx: int           # position in the plan == position in the result list
    target: str
    n_tokens: int
    payload_bytes: float
    arrival_ms: float


@dataclass
class ExecutorPool:
    """The fleet's actual container state (the provider's ground truth).

    Containers live/die on the *virtual* clock; work is measured for real.
    ``edges`` holds one always-resident single-slot executor per edge device
    (the multi-device generalization; ``edge``/``edge_free_at_ms`` survive as
    single-device aliases for the first device).

    Concurrent dispatch makes completions land OUT OF ARRIVAL ORDER, so all
    cloud container bookkeeping goes through ``lease``/``land``: a leased
    container is in flight — its virtual lifecycle fields are stale until its
    completion lands — and is never reused or reaped until then; the
    idle-eviction sweep (``_reap``) walks containers in completion-time
    order, never push order.
    """

    model_cfg: object
    specs: dict[str, SliceSpec]
    t_idl_ms: float = 120_000.0
    containers: dict[str, list[LiveExecutor]] = field(default_factory=dict)
    edges: dict[str, LiveExecutor] = field(default_factory=dict)
    edge_free_at: dict[str, float] = field(default_factory=dict)
    network: NetworkProfile | None = None
    devices: tuple = ()   # jax devices executors are round-robin pinned to
    _seed: int = 0
    _dev_i: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock)

    # ------------------------------------- deprecated single-edge conveniences
    @property
    def edge(self) -> LiveExecutor | None:
        return next(iter(self.edges.values()), None)

    @property
    def edge_names(self) -> tuple[str, ...]:
        return tuple(self.edges)

    @property
    def edge_free_at_ms(self) -> float:
        return self.edge_free_at[next(iter(self.edges))]

    @edge_free_at_ms.setter
    def edge_free_at_ms(self, value: float) -> None:
        self.edge_free_at[next(iter(self.edges))] = value

    # ------------------------------------------------------------ cloud side
    def _next_device(self):
        """Round-robin executor placement over the configured jax devices."""
        if not self.devices:
            return None
        dev = self.devices[self._dev_i % len(self.devices)]
        self._dev_i += 1
        return dev

    def _reap(self, name: str, now: float):
        """Idle-eviction sweep at virtual time ``now``.

        Under the concurrent driver completions land out of arrival order,
        so push order carries no meaning: each container is judged on its
        own LANDED completion time, and in-flight (leased) containers are
        never touched — their lifecycle fields are stale until ``land``
        runs, and evicting one would leak a warm executable mid-execution.
        The sweep also normalizes the pool list to completion-time order
        (that is presentation, not correctness: the per-container judgment
        is order-independent) so reuse picks and debug dumps read the same
        no matter how the landings interleaved.
        """
        pool = self.containers.get(name, [])
        keep = []
        for c in sorted(pool, key=lambda c: c.last_completion):
            if c.in_flight or c.busy_until > now:
                keep.append(c)  # running (wall clock) or busy (virtual clock)
            elif now - c.last_completion > self.t_idl_ms:
                c.evict()       # idle past its lifetime: provider reclaimed it
            else:
                keep.append(c)
        self.containers[name] = keep

    def probe_cold(self, name: str, now: float) -> bool:
        """Would a dispatch at virtual time ``now`` cold-start? (No mutation.)"""
        with self._lock:
            pool = self.containers.get(name, [])
            return not any(
                not c.in_flight and c.busy_until <= now
                and now - c.last_completion <= self.t_idl_ms
                and c.is_warm() for c in pool)

    def lease(self, name: str, now: float) -> LiveExecutor:
        """Check out a container for a dispatch arriving at ``now``: sweep the
        idle-expired, reuse the most-recently-completed idle warm container
        (AWS reuse order), else provision a fresh one. The lease marks it in
        flight until ``land``."""
        with self._lock:
            self._reap(name, now)
            pool = self.containers.setdefault(name, [])
            idle = [c for c in pool
                    if not c.in_flight and c.busy_until <= now and c.is_warm()]
            if idle:
                c = max(idle, key=lambda c: c.last_completion)
            else:
                self._seed += 1
                c = LiveExecutor(self.specs[name], self.model_cfg,
                                 seed=self._seed, device=self._next_device(),
                                 network=self.network)
                pool.append(c)
            c.in_flight = True
            return c

    def land(self, c: LiveExecutor, now: float, rec: ExecutionRecord) -> float:
        """Land a completion (possibly out of arrival order): apply the
        virtual lifecycle and release the lease. Returns the completion time
        on the virtual clock."""
        completion = now + rec.start_ms + rec.comp_ms
        with self._lock:
            c.busy_until = completion
            c.last_completion = completion
            c.in_flight = False
        return completion

    def release(self, c: LiveExecutor) -> None:
        """Release a lease whose execution FAILED: no completion to land, so
        the lifecycle fields stay as they were — the container goes back to
        the pool (still warm if it ever compiled) instead of leaking in
        flight forever."""
        with self._lock:
            c.in_flight = False

    def execute_cloud(self, name: str, n_tokens: int, payload_bytes: float,
                      now: float) -> ExecutionRecord:
        c = self.lease(name, now)
        try:
            rec = c.execute(n_tokens, payload_bytes)
        except BaseException:
            self.release(c)
            raise
        self.land(c, now, rec)
        return rec

    # ------------------------------------------------------------- edge side
    def execute_edge(self, n_tokens: int, payload_bytes: float,
                     arrival_ms: float, device: str | None = None) -> ExecutionRecord:
        device = device if device is not None else next(iter(self.edges))
        rec = self.edges[device].execute(n_tokens, payload_bytes)
        queue = max(self.edge_free_at[device] - arrival_ms, 0.0)
        self.edge_free_at[device] = arrival_ms + queue + rec.comp_ms
        rec.queue_ms = queue
        return rec

    def actual_edge_wait(self, arrival_ms: float, device: str | None = None) -> float:
        device = device if device is not None else next(iter(self.edges))
        return max(self.edge_free_at[device] - arrival_ms, 0.0)

    # ---------------------------------------------------- concurrent dispatch
    def serve_concurrent(self, plan: list[_Dispatch],
                         races: list[tuple[int, int]] | None = None,
                         ) -> list[ExecutionRecord | None]:
        """The real concurrent dispatch loop behind ``serve_async`` (live).

        One dispatcher thread per target — each edge device drives its
        single-slot executor, each cloud config drives its container pool —
        pulls that target's dispatches in arrival order; executions genuinely
        overlap across the edge fleet and the cloud slices; completions land
        on one shared queue in wall-clock order. ``races`` are hedge
        duplicate pairs ``(primary_idx, hedge_idx)``: the first leg to
        complete cancels its sibling if the sibling has not started yet
        (cancelled legs return ``None`` — they ran nowhere and bill nothing);
        a sibling already running is drained. Returns one entry per plan row.

        Same-config cloud dispatches serialize on their worker — a DELIBERATE
        divergence from the twin's instant scale-out: the virtual arrival
        clock is compressed relative to the wall clock, so scaling out per
        in-flight dispatch would provision (and REALLY compile) a container
        per near-simultaneous task. One worker per config bounds the real
        compile cost to the warm/cold dynamics the virtual lifecycle models;
        it also means a hedge leg can lose its race while still queued (see
        the README live-overlap caveats).
        """
        races = races or []
        results: list[ExecutionRecord | None] = [None] * len(plan)
        done: queue_mod.Queue = queue_mod.Queue()
        sibling = {}
        for p, h in races:
            sibling[p] = h
            sibling[h] = p
        state_lock = threading.Lock()
        started: set[int] = set()
        cancelled: set[int] = set()

        def try_start(i: int) -> bool:
            with state_lock:
                if i in cancelled:
                    return False
                started.add(i)
                return True

        def finished(i: int) -> None:
            sib = sibling.get(i)
            if sib is not None:
                with state_lock:
                    if sib not in started:
                        cancelled.add(sib)  # race lost before it began

        def run_one(d: _Dispatch) -> None:
            try:
                if not try_start(d.idx):
                    done.put((d.idx, None))  # cancelled: ran nowhere, bills nothing
                    return
                if d.target in self.edges:
                    rec = self.execute_edge(d.n_tokens, d.payload_bytes,
                                            d.arrival_ms, device=d.target)
                else:
                    rec = self.execute_cloud(d.target, d.n_tokens,
                                             d.payload_bytes, d.arrival_ms)
                finished(d.idx)
                done.put((d.idx, rec))
            except BaseException as e:  # surface worker failures to the caller
                done.put((d.idx, e))

        by_target: dict[str, list[_Dispatch]] = {}
        for d in plan:
            by_target.setdefault(d.target, []).append(d)

        def worker(rows: list[_Dispatch]) -> None:
            for d in rows:
                run_one(d)

        threads = {target: threading.Thread(target=worker, args=(rows,),
                                            daemon=True)
                   for target, rows in by_target.items()}
        for t in threads.values():
            t.start()
        expected = {target: len(rows) for target, rows in by_target.items()}
        received = {target: 0 for target in by_target}
        target_of = {d.idx: d.target for d in plan}
        failure: BaseException | None = None
        pending = len(plan)
        while pending:
            try:
                idx, rec = done.get(timeout=1.0)
            except queue_mod.Empty:
                # no completion in a full second: if a dispatcher thread died
                # without reporting all its rows, waiting any longer would
                # hang forever — name the dead worker instead
                dead = [target for target, t in threads.items()
                        if not t.is_alive()
                        and received[target] < expected[target]]
                if dead and done.empty():
                    raise RuntimeError(
                        f"dispatcher thread for target {dead[0]!r} died after "
                        f"{received[dead[0]]}/{expected[dead[0]]} completions "
                        f"({pending} dispatches still outstanding); the "
                        f"executor worker crashed outside a dispatch — check "
                        f"stderr for its traceback") from None
                continue
            pending -= 1
            received[target_of[idx]] += 1
            if isinstance(rec, BaseException):
                failure = failure or rec
            else:
                results[idx] = rec
        for t in threads.values():
            t.join()
        if failure is not None:
            raise failure
        return results


def make_pool(model_cfg, specs: list[SliceSpec], t_idl_ms: float = 120_000.0,
              edge_spec: SliceSpec | None = None,
              edge_specs: list[SliceSpec] | None = None,
              network: NetworkProfile | None = None,
              devices: tuple | None = None) -> ExecutorPool:
    """Build the provider-side pool. ``edge_specs`` provisions a multi-device
    edge fleet (one always-resident executor per device); ``edge_spec`` is the
    deprecated single-device spelling. ``devices`` (default: all jax devices
    when more than one is visible) spreads executors round-robin over jax
    devices so concurrent executions overlap; ``network`` switches on the
    emulated WAN legs."""
    if edge_specs is None:
        edge_specs = [edge_spec or SliceSpec(name="edge", chips=1, is_edge=True)]
    if devices is None:
        all_devs = tuple(jax.devices())
        devices = all_devs if len(all_devs) > 1 else ()
    pool = ExecutorPool(
        model_cfg=model_cfg,
        specs={s.name: s for s in specs if not s.is_edge},
        t_idl_ms=t_idl_ms,
        network=network,
        devices=tuple(devices),
    )
    pool.edges = {s.name: LiveExecutor(s, model_cfg,
                                       device=pool._next_device(),
                                       network=network)
                  for s in edge_specs}
    pool.edge_free_at = {s.name: 0.0 for s in edge_specs}
    # each edge device's long-lived function is always resident (Sec. II-A.2):
    # every device pays its own one-time real compile at provisioning, never
    # during serving
    for ex in pool.edges.values():
        ex._ensure_compiled()
    return pool
