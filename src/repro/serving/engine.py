"""Serving step builders + batched generation.

``make_prefill_step`` / ``make_decode_step`` return jit-able functions with
the signatures the dry-run lowers (and the executors compile):

    prefill_step(params, batch)        -> (logits (B, V), cache)
    decode_step(params, cache, batch)  -> (logits (B, V), cache)

``generate`` runs greedy/temperature decoding for a batch of prompts using
those steps — the end-to-end path the live serving benchmark measures.

``make_compiled_steps`` is the executor-facing entry the event-driven live
driver builds on: model + params + jitted steps in one call, with the params
optionally *pinned to one jax device*. Committed params make every step of
that executor run on its device, so a fleet of executors spread over
``--xla_force_host_platform_device_count`` host devices (or real accelerator
slices) genuinely overlaps when driven from concurrent dispatch threads —
the single shared default device would otherwise serialize their streams.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


def make_compiled_steps(model_cfg, seed: int = 0, device=None,
                        cache_len: int | None = None):
    """Build (model, params, prefill_fn, decode_fn) for one executor.

    ``device`` pins the params (and therefore every jitted step that consumes
    them) to one ``jax.Device``. Pass each concurrent executor its own device
    to let their executions overlap instead of queueing on the default
    device's stream.
    """
    from repro.modeling.registry import build_model

    model = build_model(model_cfg)
    params = model.init(jax.random.key(seed))
    if device is not None:
        params = jax.device_put(params, device)
    prefill_fn = jax.jit(make_prefill_step(model, cache_len=cache_len))
    decode_fn = jax.jit(make_decode_step(model))
    return model, params, prefill_fn, decode_fn


def make_prefill_step(model, cache_len: int | None = None):
    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len=cache_len)

    return prefill_step


def make_decode_step(model):
    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch)

    return decode_step


@partial(jax.jit, static_argnames=("temperature",))
def _sample(logits, key, temperature: float = 0.0):
    if temperature and temperature > 0:
        return jax.random.categorical(key, logits / temperature, axis=-1)
    return jnp.argmax(logits, axis=-1)


def generate(model, params, tokens, *, max_new_tokens: int, cache_len: int,
             temperature: float = 0.0, seed: int = 0,
             prefill_fn=None, decode_fn=None):
    """Greedy/temperature generation. tokens: (B, S) int32 prompt batch.

    Returns (B, max_new_tokens) int32. Pass pre-jitted ``prefill_fn`` /
    ``decode_fn`` to reuse compiled executables (the executors do).
    """
    prefill_fn = prefill_fn or jax.jit(make_prefill_step(model, cache_len))
    decode_fn = decode_fn or jax.jit(make_decode_step(model))
    key = jax.random.key(seed)

    logits, cache = prefill_fn(params, {"tokens": tokens})
    out = []
    for i in range(max_new_tokens):
        key, sub = jax.random.split(key)
        tok = _sample(logits, sub, temperature).astype(jnp.int32)
        out.append(tok)
        if i + 1 < max_new_tokens:
            logits, cache = decode_fn(params, cache, {"token": tok})
    return jnp.stack(out, axis=1)


def batch_prompts(prompts: list[np.ndarray], pad_to: int, pad_id: int = 0):
    """Left-pad a ragged prompt list into a (B, pad_to) batch."""
    B = len(prompts)
    out = np.full((B, pad_to), pad_id, np.int32)
    for i, p in enumerate(prompts):
        p = np.asarray(p, np.int32)[-pad_to:]
        out[i, pad_to - len(p):] = p
    return out
