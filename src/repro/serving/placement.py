"""The paper's placement framework over the TPU slice catalog.

This is the hardware adaptation of the paper's contribution (DESIGN.md §3):
the same Predictor / CIL / Decision Engine — ``repro.core`` is target-agnostic
— instantiated over slice executors instead of Lambda containers:

- ``calibrate_catalog`` reproduces Sec. IV-C's data collection against REAL
  executions: warm runs per (task, slice config) for the comp GBRT, a few real
  compile cycles per config for the cold-start model, feed/store samples;
- ``SliceTarget`` predicts the end-to-end latency components
  (feed → start → comp → store) and slice-seconds cost, per task or in one
  vectorized pass over a whole batch (``predict_components_batch``);
- ``LiveBackend`` implements the ``repro.core.runtime.ExecutionBackend``
  contract over the real executor pool: ``execute(task, target, now)`` runs a
  genuine compiled execution and bills slice-seconds; ``probe_cold`` asks the
  pool whether a dispatch would pay a real XLA compile; ``execute_async``
  runs a whole dispatch plan through the pool's CONCURRENT loop — one worker
  thread per edge device and per cloud config, hedge legs as first-class
  races — and returns the same struct-of-arrays ``ExecutionBatch`` as the
  twin, so ``serve_async`` stays object-free over a columnar
  ``DecisionBatch``; results aggregate into the same columnar
  ``RecordBatch``-backed ``SimulationResult`` as the twin;
- ``make_live_runtime`` wires catalog → predictor → Decision Engine →
  ``PlacementRuntime`` over a ``LiveBackend``: the SAME serve loop as the
  simulator, against real executions (paper Sec. VI-B analog — Table V falls
  out). ``LivePlacementServer`` is the deprecated thin wrapper around it.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.cil import ContainerInfoList
from repro.core.decision import DecisionEngine, Policy
from repro.core.gbrt import GBRT, GBRTConfig
from repro.core.perf_models import NormalModel, RidgeModel, _norm_ppf
from repro.core.predictor import (
    EDGE,
    EdgeFleet,
    Predictor,
    cloud_components_batch,
    edge_components_batch,
)
from repro.core.pricing import SlicePricing
from repro.core.records import (  # noqa: F401 — re-export
    RecordBatch,
    SimulationResult,
    TaskRecord,
)
from repro.core.faults import TRANSIENT, AdmissionPolicy, CircuitBreaker, RetryPolicy
from repro.core.runtime import ExecutionBatch, ExecutionOutcome, PlacementRuntime
from repro.core.workload import PoissonWorkload, TaskInput
from repro.serving.executors import (
    ExecutorPool,
    LiveExecutor,
    NetworkProfile,
    SliceSpec,
    _Dispatch,
    make_pool,
)

# The always-on edge device is resource-constrained relative to cloud slices
# (the paper's RPi-vs-Lambda gap): fewer tokens retired per compiled step.
EDGE_SPEC = SliceSpec("edge", chips=1, tokens_per_step=2, is_edge=True)


# --------------------------------------------------------------------- target
@dataclass
class SliceTarget:
    """Cloud-side slice config λ_m: T(k) = feed(k) + start(m) + comp(k,m) + store."""

    name: str
    chips: int
    feed_model: RidgeModel
    start_warm: NormalModel
    start_cold: NormalModel
    comp_model: GBRT        # features: (n_tokens, chips)
    store_model: NormalModel
    pricing: SlicePricing = field(default_factory=SlicePricing)
    comp_std_frac: float = 0.0
    is_edge: bool = False

    def predict_components(self, task, cold: bool, quantile: float | None = None):
        start = self.start_cold if cold else self.start_warm
        comp = float(self.comp_model.predict(
            np.array([[task.size, float(self.chips)]]))[0])
        if quantile is not None:
            z = _norm_ppf(quantile)
            comp = comp * (1.0 + z * self.comp_std_frac)
            start_ms = start.predict_quantile(quantile)
            store_ms = self.store_model.predict_quantile(quantile)
        else:
            start_ms = start.predict()
            store_ms = self.store_model.predict()
        return {
            "upld": max(float(self.feed_model.predict(task.bytes)), 0.0),
            "start": max(start_ms, 0.0),
            "comp": max(comp, 0.0),
            "store": max(store_ms, 0.0),
        }

    def predict_components_batch(self, sizes: np.ndarray, nbytes: np.ndarray,
                                 quantile: float | None = None) -> tuple[dict, dict]:
        return cloud_components_batch(
            sizes, nbytes, comp_feature=float(self.chips),
            comp_model=self.comp_model, upld_model=self.feed_model,
            start_warm=self.start_warm, start_cold=self.start_cold,
            store_model=self.store_model, comp_std_frac=self.comp_std_frac,
            quantile=quantile)

    def cost(self, comp_ms: float) -> float:
        return self.pricing.cost(comp_ms, self.chips)

    def cost_batch(self, comp_ms: np.ndarray) -> np.ndarray:
        return self.pricing.cost_batch(comp_ms, self.chips)

    def occupancy_ms(self, components: dict[str, float]) -> float:
        return components["upld"] + components["start"] + components["comp"]


@dataclass
class EdgeSliceTarget:
    """The always-on 1-chip slice: T(k) = comp(k) + store(k) (+ queue wait)."""

    comp_model: RidgeModel
    store_model: NormalModel
    comp_std_frac: float = 0.0
    name: str = EDGE
    is_edge: bool = True

    def predict_components(self, task, cold: bool = False,
                           quantile: float | None = None):
        comp = float(self.comp_model.predict(task.size))
        if quantile is not None:
            z = _norm_ppf(quantile)
            comp = comp * (1.0 + z * self.comp_std_frac)
            store = self.store_model.predict_quantile(quantile)
        else:
            store = self.store_model.predict()
        return {"comp": max(comp, 0.0), "iotup": 0.0, "store": max(store, 0.0)}

    def predict_components_batch(self, sizes: np.ndarray, nbytes: np.ndarray,
                                 quantile: float | None = None) -> tuple[dict, None]:
        return edge_components_batch(
            sizes, comp_model=self.comp_model, store_model=self.store_model,
            comp_std_frac=self.comp_std_frac, quantile=quantile)

    def cost(self, comp_ms: float) -> float:  # noqa: ARG002
        return 0.0  # amortized to zero, paper Sec. II-A.2b

    def cost_batch(self, comp_ms: np.ndarray) -> np.ndarray:
        return np.zeros(np.asarray(comp_ms).shape[0], dtype=np.float64)

    def occupancy_ms(self, components: dict[str, float]) -> float:
        return components["comp"]


# ------------------------------------------------------------------ catalog
@dataclass
class SliceCatalog:
    """Fitted models + specs for every slice config (the fleet's Φ)."""

    model_cfg: object
    specs: list[SliceSpec]
    feed: RidgeModel
    start_warm: NormalModel
    start_cold: NormalModel
    comp_cloud: GBRT
    store: NormalModel
    comp_edge: RidgeModel
    store_edge: NormalModel
    cloud_comp_std_frac: float
    edge_comp_std_frac: float
    pricing: SlicePricing = field(default_factory=SlicePricing)


def llm_workload(n: int, rate_per_s: float = 1.0, seed: int = 0,
                 mean_tokens: float = 96.0) -> list[TaskInput]:
    """LLM request stream: Poisson arrivals, lognormal generation lengths."""

    def sampler(rng: np.random.Generator):
        toks = float(np.clip(rng.lognormal(np.log(mean_tokens), 0.6), 8, 16384))
        return toks, toks * 4.0  # ~4 payload bytes per token

    return PoissonWorkload(rate_per_s=rate_per_s, size_sampler=sampler,
                           seed=seed).generate(n)


def calibrate_catalog(model_cfg, specs: list[SliceSpec], *,
                      n_tasks: int = 24, n_cold: int = 2, seed: int = 0,
                      pricing: SlicePricing | None = None,
                      mean_tokens: float = 96.0) -> SliceCatalog:
    """Paper Sec. IV-C against real executions: measure, fit, evaluate."""
    rng = np.random.default_rng(seed)
    cloud_specs = [s for s in specs if not s.is_edge]
    pricing = pricing or SlicePricing()

    # --- cold starts: real compile cycles per config ------------------------
    # warmup: the process's first compile pays one-time jax/backend init —
    # not a property of a slice cold start; burn it before measuring.
    warmup = LiveExecutor(cloud_specs[0], model_cfg, seed=99)
    warmup._ensure_compiled()
    warmup.evict()
    colds = []
    for s in cloud_specs:
        for i in range(n_cold):
            ex = LiveExecutor(s, model_cfg, seed=100 + i)
            start_ms, cold = ex._ensure_compiled()
            assert cold
            colds.append(start_ms)
            ex.evict()
    start_cold = NormalModel.fit(np.array(colds))

    # --- warm component measurements across (task, config) ------------------
    # calibration tasks must cover the serving size distribution (paper
    # Sec. IV-C trains on representative inputs)
    tok_samples = np.clip(rng.lognormal(np.log(mean_tokens), 0.6, n_tasks),
                          8, 16384)
    feats, comps, feeds, stores, warms = [], [], [], [], []
    edge_comps, edge_sizes, edge_stores = [], [], []
    warm_ex = {s.name: LiveExecutor(s, model_cfg, seed=7) for s in cloud_specs}
    for ex in warm_ex.values():
        ex._ensure_compiled()
    edge_ex = LiveExecutor(EDGE_SPEC, model_cfg)
    edge_ex._ensure_compiled()

    for t in tok_samples:
        nb = float(t) * 4.0
        for s in cloud_specs:
            rec = warm_ex[s.name].execute(int(t), nb)
            feats.append([float(t), float(s.chips)])
            comps.append(rec.comp_ms)
            feeds.append((nb, rec.feed_ms))
            stores.append(rec.store_ms)
            warms.append(rec.start_ms)
        erec = edge_ex.execute(int(t), nb)
        edge_sizes.append(float(t))
        edge_comps.append(erec.comp_ms)
        edge_stores.append(erec.store_ms)

    feats = np.array(feats)
    comps = np.array(comps)
    comp_cloud = GBRT.fit(feats, comps,
                          GBRTConfig(n_trees=60, max_depth=3, learning_rate=0.1))
    pred = comp_cloud.predict(feats)
    cloud_std = float(np.std((comps - pred) / np.maximum(pred, 1e-9)))

    feed = RidgeModel.fit(np.array([f[0] for f in feeds]),
                          np.array([f[1] for f in feeds]))
    comp_edge = RidgeModel.fit(np.array(edge_sizes), np.array(edge_comps))
    epred = comp_edge.predict(np.array(edge_sizes))
    edge_std = float(np.std((np.array(edge_comps) - epred) / np.maximum(epred, 1e-9)))

    return SliceCatalog(
        model_cfg=model_cfg, specs=list(specs),
        feed=feed,
        start_warm=NormalModel.fit(np.array(warms)),
        start_cold=start_cold,
        comp_cloud=comp_cloud,
        store=NormalModel.fit(np.array(stores)),
        comp_edge=comp_edge,
        store_edge=NormalModel.fit(np.array(edge_stores)),
        cloud_comp_std_frac=cloud_std,
        edge_comp_std_frac=edge_std,
        pricing=pricing,
    )


def _edge_fleet_names(n_edge_devices: int) -> list[str]:
    """Device naming: the single-device fleet keeps the paper's ``edge``."""
    if n_edge_devices <= 1:
        return [EDGE]
    return [f"{EDGE}{i}" for i in range(n_edge_devices)]


def build_slice_predictor(cat: SliceCatalog, t_idl_ms: float = 120_000.0,
                          quantile: float | None = None,
                          n_edge_devices: int = 1) -> Predictor:
    cloud_targets = [
        SliceTarget(
            name=s.name, chips=s.chips,
            feed_model=cat.feed, start_warm=cat.start_warm,
            start_cold=cat.start_cold, comp_model=cat.comp_cloud,
            store_model=cat.store, pricing=cat.pricing,
            comp_std_frac=cat.cloud_comp_std_frac,
        )
        for s in cat.specs if not s.is_edge
    ]
    fleet = EdgeFleet([
        EdgeSliceTarget(comp_model=cat.comp_edge, store_model=cat.store_edge,
                        comp_std_frac=cat.edge_comp_std_frac, name=name)
        for name in _edge_fleet_names(n_edge_devices)
    ])
    return Predictor(cloud_targets=cloud_targets, edge_fleet=fleet,
                     cil=ContainerInfoList(t_idl_ms=t_idl_ms),
                     quantile=quantile)


# ------------------------------------------------------------- live backend
class LiveBackend:
    """ExecutionBackend over the real executor pool (paper Sec. VI-B analog).

    Every ``execute`` runs genuine compiled steps: cloud dispatches bill
    slice-seconds and may pay a real XLA compile (cold start); edge dispatches
    are free and queue on their device's single-slot FIFO executor — the pool
    may hold a whole fleet of edge executors, one per device name.
    """

    def __init__(self, pool: ExecutorPool, pricing: SlicePricing,
                 edge_name: str = EDGE, map_failures: bool = False,
                 detect_ms: float = 5.0):
        self.pool = pool
        self.pricing = pricing
        self.edge_name = edge_name
        # failure-aware serving contract (see ``repro.core.faults``): with
        # ``map_failures`` on, a dispatch that raises comes back as a FAILED
        # ``ExecutionOutcome`` (transient, retryable) instead of propagating,
        # so ``PlacementRuntime``'s retry / failover / breaker loop drives
        # real executor errors exactly like the twin's injected ones.
        self.map_failures = map_failures
        self.detect_ms = detect_ms

    @property
    def edge_names(self) -> tuple[str, ...]:
        return self.pool.edge_names

    def probe_cold(self, target: str, now: float) -> bool:
        return self.pool.probe_cold(target, now)

    def execute(self, task: TaskInput, target: str, now: float) -> ExecutionOutcome:
        if not self.map_failures:
            return self._execute_raw(task, target, now)
        try:
            return self._execute_raw(task, target, now)
        except Exception:
            return ExecutionOutcome(
                latency_ms=self.detect_ms, cost=0.0, cold=False,
                completion_ms=now + self.detect_ms,
                failed=True, fail_kind=TRANSIENT)

    def _execute_raw(self, task: TaskInput, target: str,
                     now: float) -> ExecutionOutcome:
        if target in self.pool.edges:
            rec = self.pool.execute_edge(int(task.size), task.bytes, now,
                                         device=target)
            return ExecutionOutcome(latency_ms=rec.total_ms, cost=0.0,
                                    cold=False, completion_ms=now + rec.total_ms,
                                    queue_wait_ms=rec.queue_ms, exec_ms=rec.comp_ms)
        cold = self.pool.probe_cold(target, now)
        rec = self.pool.execute_cloud(target, int(task.size), task.bytes, now)
        chips = self.pool.specs[target].chips
        return ExecutionOutcome(latency_ms=rec.total_ms,
                                cost=self.pricing.cost(rec.comp_ms, chips),
                                cold=cold, completion_ms=now + rec.total_ms,
                                exec_ms=rec.start_ms + rec.comp_ms)

    # ---------------------------------------------------- concurrent driver
    def execute_async(self, tasks: list[TaskInput], targets: list[str],
                      races: list[tuple[int, int]] | None = None,
                      ) -> ExecutionBatch:
        """Run the dispatch plan through the pool's REAL concurrent loop.

        One dispatcher thread per target (edge device / cloud config), so
        fleet executions genuinely overlap on the wall clock; completions
        land out of arrival order and the pool's lease/land bookkeeping
        absorbs them. ``races`` are hedge pairs — the losing leg is cancelled
        when it never started (its row comes back cancelled: zero cost,
        infinite latency, ignored by the runtime's merge) or drained when it
        did. Returns the same struct-of-arrays ``ExecutionBatch`` the twin
        produces, so the async serve path stays object-free.
        """
        n = len(tasks)
        plan = [_Dispatch(idx=i, target=tg, n_tokens=int(t.size),
                          payload_bytes=t.bytes, arrival_ms=t.arrival_ms)
                for i, (t, tg) in enumerate(zip(tasks, targets))]
        recs = self.pool.serve_concurrent(plan, races=races)
        out = ExecutionBatch(
            latency_ms=np.full(n, np.inf), cost=np.zeros(n),
            cold=np.zeros(n, dtype=bool), completion_ms=np.full(n, np.inf),
            queue_wait_ms=np.zeros(n), exec_ms=np.zeros(n),
            cancelled=np.zeros(n, dtype=bool))
        for i, (t, tg, rec) in enumerate(zip(tasks, targets, recs)):
            if rec is None:
                out.cancelled[i] = True
                continue
            out.latency_ms[i] = rec.total_ms
            out.completion_ms[i] = t.arrival_ms + rec.total_ms
            if tg in self.pool.edges:
                out.queue_wait_ms[i] = rec.queue_ms
                out.exec_ms[i] = rec.comp_ms
            else:
                chips = self.pool.specs[tg].chips
                out.cost[i] = self.pricing.cost(rec.comp_ms, chips)
                out.cold[i] = rec.cold
                out.exec_ms[i] = rec.start_ms + rec.comp_ms
        return out


def make_live_runtime(cat: SliceCatalog, policy: Policy,
                      t_idl_ms: float = 120_000.0,
                      quantile: float | None = None,
                      n_edge_devices: int = 1,
                      network: NetworkProfile | None = None,
                      retry: RetryPolicy | None = None,
                      admission: AdmissionPolicy | None = None,
                      breaker: CircuitBreaker | None = None) -> PlacementRuntime:
    """Wire a calibrated catalog into the unified serve loop: catalog →
    Predictor → DecisionEngine → ``PlacementRuntime`` over a ``LiveBackend``.

    ``n_edge_devices > 1`` provisions a fleet of always-resident edge
    executors (named ``edge0..``), so the live prototype serves fleets with
    the same balancer-driven placement as the twin. The returned runtime
    exposes BOTH drivers: ``serve`` dispatches sequentially; ``serve_async``
    runs the pool's concurrent dispatch loop (one worker thread per edge
    device and per cloud config), overlapping real executions across the
    fleet. ``network`` switches on the emulated WAN legs (upload / IoT
    result-upload as real wall-clock waits) — the latency the async driver
    overlaps with compute.

    ``retry`` / ``admission`` / ``breaker`` switch on failure-aware serving
    (``repro.core.faults``): real executor exceptions come back as failed,
    retryable outcomes and the runtime retries / fails over / sheds with the
    exact same driver the twin uses. The failure-aware live driver dispatches
    sequentially (the retry loop needs each outcome before scheduling the
    next attempt); use the plain runtime for maximum-overlap serving."""
    edge_specs = [SliceSpec(name, chips=EDGE_SPEC.chips,
                            tokens_per_step=EDGE_SPEC.tokens_per_step,
                            is_edge=True)
                  for name in _edge_fleet_names(n_edge_devices)]
    pool = make_pool(cat.model_cfg, [s for s in cat.specs if not s.is_edge],
                     t_idl_ms=t_idl_ms, edge_specs=edge_specs, network=network)
    predictor = build_slice_predictor(cat, t_idl_ms=t_idl_ms, quantile=quantile,
                                      n_edge_devices=n_edge_devices)
    engine = DecisionEngine(predictor=predictor, policy=policy, edge_name=EDGE)
    backend = LiveBackend(pool, cat.pricing,
                          map_failures=retry is not None or breaker is not None)
    return PlacementRuntime(engine=engine, backend=backend, retry=retry,
                            admission=admission, breaker=breaker)


# --------------------------------------------------------------- live server
class LivePlacementServer:
    """The live prototype: real placement over real executions (Table V).

    Deprecated: thin wrapper over ``make_live_runtime`` — the serve loop is
    ``repro.core.runtime.PlacementRuntime``, shared with the simulator.
    """

    def __init__(self, cat: SliceCatalog, policy: Policy,
                 t_idl_ms: float = 120_000.0, quantile: float | None = None):
        self.cat = cat
        self.runtime = make_live_runtime(cat, policy, t_idl_ms=t_idl_ms,
                                         quantile=quantile)
        # back-compat aliases
        self.pool = self.runtime.backend.pool
        self.predictor = self.runtime.engine.predictor
        self.engine = self.runtime.engine

    def serve(self, tasks: list[TaskInput], batched: bool = True) -> SimulationResult:
        return self.runtime.serve(tasks, batched=batched)
