"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so with
scan-over-layers it under-reports FLOPs by ~n_layers× (verified empirically —
see EXPERIMENTS.md §Dry-run). This module re-derives roofline terms from the
post-optimization HLO text, multiplying through ``known_trip_count``:

- **dot FLOPs**: 2 · output_elems · contracted_elems per ``dot`` (including
  dots inside fusion computations);
- **elementwise FLOPs**: 1/output element for arithmetic ops (rough lower
  bound; dots dominate every model here);
- **HBM bytes**: per instruction, operand + output bytes; fusions count only
  their boundary (interior values live in registers/VMEM) — this approximates
  the traffic XLA's own model reports;
- **collective link-bytes per device**: per collective, the bytes the device
  *transmits* under a ring schedule:
  all-gather (g−1)·operand; reduce-scatter (g−1)/g·operand;
  all-reduce 2·(g−1)/g·operand; all-to-all (g−1)/g·operand;
  collective-permute 1·operand.

The per-device program is what the HLO text shows post-GSPMD, so all numbers
are per device; roofline terms divide by per-chip peak rates directly.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs", "sign",
    "compare", "select", "and", "or", "xor", "not", "clamp", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "remainder", "atan2", "cbrt",
    "logistic", "expm1", "log1p", "sine", "cosine", "tan", "erf", "is-finite",
    "reduce", "reduce-window", "map", "scatter", "exponential-minus-one",
}

_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "opt-barrier",
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
}

# Pure view/legalization ops: free on TPU (native bf16, layout-in-registers);
# XLA CPU materializes them, which must not pollute the roofline terms.
_VIEW_OPS = {"convert", "bitcast", "copy"}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# After comment stripping, the result type is either a (one-level) tuple or a
# single array/token; then the opcode, then '('.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\([^()]*\)|[\w\[\],{}/]+)\s*"
    r"([\w\-]+)\((.*)$")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _shape_bytes_elems(type_str: str) -> tuple[int, int]:
    """Total (bytes, elems) across all array shapes in a (possibly tuple) type."""
    total_b = total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        for d in dims.split(","):
            if d:
                elems *= int(d)
        total_e += elems
        total_b += elems * _DTYPE_BYTES[dt]
    return total_b, total_e


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # operand list + attributes
    out_bytes: int = 0
    out_elems: int = 0

    def operand_names(self) -> list[str]:
        # ``rest`` starts just after 'opcode(' — scan to the matching ')'
        depth, buf = 1, ""
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            buf += ch
        return re.findall(r"%([\w.\-]+)", buf)

    def attr(self, name: str) -> str | None:
        m = re.search(rf"{name}=([%\w.\-]+)", self.rest)
        return m.group(1).lstrip("%") if m else None

    def trip_count(self) -> int | None:
        # backend_config={"known_trip_count":{"n":"16"}, ...}
        m = re.search(r'known_trip_count\\?"?:?[^0-9]*(\d+)', self.rest)
        return int(m.group(1)) if m else None

    def group_size(self) -> int:
        # replica_groups=[2,4]<=[8]  (2 groups of 4)  |  {{0,1},{2,3}}
        m = re.search(r"replica_groups=\[(\d+),(\d+)\]", self.rest)
        if m:
            return int(m.group(2))
        m = re.search(r"replica_groups=\{\{([^}]*)\}", self.rest)
        if m:
            return len(m.group(1).split(","))
        return 1


def parse_hlo(text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    cur: list[Instr] | None = None
    entry = None
    for line in text.splitlines():
        line = _COMMENT_RE.sub("", line)  # strip /*index=5*/ etc.
        if cur is None:
            m = _COMP_START_RE.match(line.strip())
            if m:
                name = m.group(1)
                comps[name] = []
                cur = comps[name]
                if line.strip().startswith("ENTRY"):
                    entry = name
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, tstr, opcode, rest = m.groups()
            b, e = _shape_bytes_elems(tstr)
            cur.append(Instr(name=name, type_str=tstr, opcode=opcode,
                             rest=rest, out_bytes=b, out_elems=e))
    comps["__entry__"] = comps.get(entry, [])
    comps["__entry_name__"] = entry  # type: ignore
    return comps


@dataclass
class HloCosts:
    dot_flops: float = 0.0
    elem_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_link_bytes: float = 0.0
    collectives: dict = field(default_factory=dict)

    @property
    def flops(self) -> float:
        return self.dot_flops + self.elem_flops

    def add(self, other: "HloCosts", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.elem_flops += other.elem_flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.collective_link_bytes += other.collective_link_bytes * mult
        for k, v in other.collectives.items():
            self.collectives[k] = self.collectives.get(k, 0.0) + v * mult

    def to_dict(self) -> dict:
        return {
            "dot_flops": self.dot_flops, "elem_flops": self.elem_flops,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_link_bytes": self.collective_link_bytes,
            "collectives": dict(self.collectives),
        }


def _fusion_param_reads(callee_instrs: list[Instr]) -> dict[int, int]:
    """Bytes actually READ per parameter of a fused computation.

    A scan-over-layers body receives the full stacked weights / KV cache as a
    fusion operand but touches one dynamic-slice of it per trip; counting the
    full operand would overcount HBM traffic by ~n_layers×. If every consumer
    of a parameter is a slice-type op, charge the slice outputs (capped at the
    full size); any non-slice consumer charges the full parameter once.

    ``convert``/``bitcast``/``copy`` chains are treated as *views*: XLA CPU
    legalizes bf16 by round-tripping whole buffers through f32 converts that
    simply do not exist on TPU (native bf16), so consumption is classified by
    the op at the end of the view chain, not the view itself.
    """
    params: dict[str, tuple[int, int]] = {}
    for ins in callee_instrs:
        if ins.opcode == "parameter":
            m = re.match(r"\s*(\d+)", ins.rest)
            idx = int(m.group(1)) if m else len(params)
            params[ins.name] = (idx, ins.out_bytes)

    view_of: dict[str, str] = {}  # instr -> param it is a pure view of
    for ins in callee_instrs:
        if ins.opcode in _VIEW_OPS:
            ops = ins.operand_names()
            if ops:
                src = ops[0]
                root = view_of.get(src, src)
                if root in params:
                    view_of[ins.name] = root

    sliced: dict[int, int] = {}
    full_read: dict[int, bool] = {}
    for ins in callee_instrs:
        if ins.opcode == "parameter" or ins.opcode in _VIEW_OPS:
            continue
        for pos, o in enumerate(ins.operand_names()):
            root = view_of.get(o, o)
            if root not in params:
                continue
            idx, full = params[root]
            if ins.opcode in ("dynamic-slice", "slice", "gather"):
                sliced[idx] = min(sliced.get(idx, 0) + ins.out_bytes, full)
            elif ins.opcode == "dynamic-update-slice" and pos == 0:
                # target buffer of an in-place update: aliased, not read
                continue
            else:
                full_read[idx] = True
    reads: dict[int, int] = {}
    for name, (idx, full) in params.items():
        if full_read.get(idx):
            reads[idx] = full
        else:
            reads[idx] = sliced.get(idx, 0)
    return reads


def _fusion_out_bytes(ins: Instr, callee_instrs: list[Instr]) -> int:
    """Written bytes of a fusion: a DUS-rooted fusion (possibly behind view
    ops) writes only the update region of its aliased output buffer."""
    if callee_instrs:
        sym = {i.name: i for i in callee_instrs}
        root = callee_instrs[-1]
        hops = 0
        while root.opcode in _VIEW_OPS and hops < 8:
            ops = root.operand_names()
            nxt = sym.get(ops[0]) if ops else None
            if nxt is None:
                break
            root, hops = nxt, hops + 1
        if root.opcode == "dynamic-update-slice":
            ops = root.operand_names()
            upd = sym.get(ops[1]) if len(ops) > 1 else None
            if upd is not None:
                return upd.out_bytes
            return max(root.out_bytes // 8, 0)  # conservative fallback
    return ins.out_bytes


def _dot_flops(instr: Instr, symtab: dict[str, Instr]) -> float:
    ops = instr.operand_names()
    contracted = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    if m and ops:
        lhs = symtab.get(ops[0])
        if lhs is not None:
            shapes = _SHAPE_RE.findall(lhs.type_str)
            if shapes:
                dims = [int(d) for d in shapes[0][1].split(",") if d]
                for ci in m.group(1).split(","):
                    if ci and int(ci) < len(dims):
                        contracted *= dims[int(ci)]
    return 2.0 * instr.out_elems * contracted


def analyze_computation(name: str, comps: dict, memo: dict,
                        inside_fusion: bool = False) -> HloCosts:
    key = (name, inside_fusion)
    if key in memo:
        return memo[key]
    costs = HloCosts()
    instrs = comps.get(name, [])
    symtab = {i.name: i for i in instrs}
    for ins in instrs:
        op = ins.opcode
        if op.endswith("-done"):
            continue  # the matching -start already carries the cost
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES and not op.endswith("-done"):
            operand_bytes = 0
            for o in ins.operand_names():
                src = symtab.get(o)
                operand_bytes += src.out_bytes if src else 0
            if operand_bytes == 0:
                operand_bytes = ins.out_bytes
            g = max(ins.group_size(), 1)
            if base == "all-gather":
                link = operand_bytes * (g - 1)
            elif base == "all-reduce":
                link = operand_bytes * 2.0 * (g - 1) / g
            elif base in ("reduce-scatter", "all-to-all", "ragged-all-to-all"):
                link = operand_bytes * (g - 1) / g
            else:  # collective-permute / broadcast
                link = operand_bytes
            costs.collective_link_bytes += link
            costs.collectives[base] = costs.collectives.get(base, 0.0) + link
            if not inside_fusion:
                costs.hbm_bytes += operand_bytes + ins.out_bytes
            continue

        if op == "dot":
            costs.dot_flops += _dot_flops(ins, symtab)
            if not inside_fusion:
                opb = sum(symtab[o].out_bytes for o in ins.operand_names()
                          if o in symtab)
                costs.hbm_bytes += opb + ins.out_bytes
            continue

        if op == "fusion":
            callee = ins.attr("calls")
            if callee:
                costs.add(analyze_computation(callee, comps, memo,
                                              inside_fusion=True))
                callee_instrs = comps.get(callee, [])
                opb = sum(_fusion_param_reads(callee_instrs).values())
                outb = _fusion_out_bytes(ins, callee_instrs)
            else:
                opb = sum(symtab[o].out_bytes for o in ins.operand_names()
                          if o in symtab)
                outb = ins.out_bytes
            costs.hbm_bytes += opb + outb
            continue

        if op in ("dynamic-slice", "slice", "gather"):
            # read the slice, write the slice — not the full source buffer
            if not inside_fusion:
                costs.hbm_bytes += 2 * ins.out_bytes
            continue

        if op == "dynamic-update-slice":
            # in-place update: read+write the update region only
            if not inside_fusion:
                ops_ = ins.operand_names()
                upd = symtab.get(ops_[1]) if len(ops_) > 1 else None
                costs.hbm_bytes += 2 * (upd.out_bytes if upd else ins.out_bytes)
            continue

        if op == "while":
            trips = ins.trip_count() or 1
            body = ins.attr("body")
            cond = ins.attr("condition")
            if body:
                costs.add(analyze_computation(body, comps, memo), trips)
            if cond:
                costs.add(analyze_computation(cond, comps, memo), trips)
            continue

        if op in ("call", "async-start"):
            callee = ins.attr("to_apply") or ins.attr("calls")
            if callee:
                costs.add(analyze_computation(callee, comps, memo))
            continue

        if op == "conditional":
            branches = re.findall(r"(?:true_computation|false_computation|branch_computations=\{[^}]*)=?%([\w.\-]+)", ins.rest)
            sub = [analyze_computation(b, comps, memo) for b in branches if b in comps]
            if sub:
                worst = max(sub, key=lambda c: c.flops)
                costs.add(worst)
            continue

        if op in _VIEW_OPS and op != "copy":
            continue  # convert/bitcast: free on TPU (see _VIEW_OPS)

        if base in _ELEMENTWISE:
            costs.elem_flops += ins.out_elems
            if not inside_fusion and op not in _NO_TRAFFIC:
                opb = sum(symtab[o].out_bytes for o in ins.operand_names()
                          if o in symtab)
                costs.hbm_bytes += opb + ins.out_bytes
            continue

        if not inside_fusion and op not in _NO_TRAFFIC:
            # data movement ops (copy, dynamic-slice, broadcast, …)
            opb = sum(symtab[o].out_bytes for o in ins.operand_names()
                      if o in symtab)
            costs.hbm_bytes += opb + ins.out_bytes
    memo[key] = costs
    return costs


def analyze_hlo_text(text: str) -> dict:
    comps = parse_hlo(text)
    entry = comps.get("__entry_name__")
    costs = analyze_computation(entry, comps, memo={})
    return costs.to_dict()


def analyze_compiled(compiled) -> dict:
    """Full analysis bundle for one compiled executable (per-device numbers)."""
    out = {"hlo": analyze_hlo_text(compiled.as_text())}
    try:
        ca = compiled.cost_analysis()
        out["xla_cost_analysis"] = {
            k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "transcendentals", "utilization operand 0 {}")
        }
    except Exception as e:  # pragma: no cover
        out["xla_cost_analysis"] = {"error": str(e)}
    try:
        ma = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "generated_code_bytes": ma.generated_code_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_estimate": (ma.argument_size_in_bytes
                                    + ma.output_size_in_bytes
                                    + ma.temp_size_in_bytes
                                    - ma.alias_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        out["memory"] = {"error": str(e)}
    return out


def save_json(path: str, obj: dict):
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
