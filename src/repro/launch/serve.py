"""Serving launcher: the paper's dynamic task placement over live executors.

Calibrates per-slice performance models against REAL compiled executions
(paper Sec. IV-C), then serves a Poisson LLM request stream through the
Decision Engine (paper Alg. 1 / min-cost) against the live executor pool —
the Table-V live-prototype analog.

Example:
    PYTHONPATH=src python -m repro.launch.serve --policy minlat \
        --n 120 --rate 20 --cmax 0.004 --alpha 0.02
"""

from __future__ import annotations

import argparse

from repro.configs import smoke_config
from repro.core.decision import HedgedPolicy, MinCostPolicy, MinLatencyPolicy
from repro.serving.executors import SliceSpec
from repro.serving.placement import (
    calibrate_catalog,
    llm_workload,
    make_live_runtime,
)


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3.2-1b")
    p.add_argument("--policy", choices=("minlat", "mincost"), default="minlat")
    p.add_argument("--n", type=int, default=120)
    p.add_argument("--rate", type=float, default=20.0, help="requests/s")
    p.add_argument("--mean-tokens", type=float, default=256.0)
    p.add_argument("--cmax", type=float, default=0.004, help="$ per task")
    p.add_argument("--alpha", type=float, default=0.02)
    p.add_argument("--deadline-ms", type=float, default=400.0)
    p.add_argument("--quantile", type=float, default=None,
                   help="beyond-paper: predict this latency quantile (e.g. 0.95)")
    p.add_argument("--hedge-ms", type=float, default=None,
                   help="beyond-paper: hedged dispatch threshold")
    p.add_argument("--t-idl-s", type=float, default=60.0)
    p.add_argument("--chips", type=int, nargs="+", default=[2, 4, 8])
    p.add_argument("--calib-tasks", type=int, default=16)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = smoke_config(args.arch)
    specs = [SliceSpec(f"slice{c}", c) for c in args.chips]
    print(f"calibrating {len(specs)} slice configs on {cfg.name} "
          f"(real compiles — this takes a minute)...")
    cat = calibrate_catalog(cfg, specs, n_tasks=args.calib_tasks, seed=args.seed)
    print(f"  cold start: {cat.start_cold.mean:.0f}±{cat.start_cold.std:.0f} ms; "
          f"warm: {cat.start_warm.mean:.2f} ms")

    if args.policy == "minlat":
        policy = MinLatencyPolicy(c_max=args.cmax, alpha=args.alpha)
        if args.hedge_ms is not None:
            policy = HedgedPolicy(policy, hedge_threshold_ms=args.hedge_ms)
    else:
        policy = MinCostPolicy(deadline_ms=args.deadline_ms)

    tasks = llm_workload(args.n, rate_per_s=args.rate, seed=args.seed + 1,
                         mean_tokens=args.mean_tokens)
    runtime = make_live_runtime(cat, policy, t_idl_ms=args.t_idl_s * 1e3,
                                quantile=args.quantile)
    res = runtime.serve(tasks)

    print(f"\nserved n={res.n}")
    print(f"  avg actual latency   : {res.avg_actual_latency_ms:.1f} ms "
          f"(p95 {res.p95_actual_latency_ms:.1f}, p99 {res.p99_actual_latency_ms:.1f})")
    print(f"  latency pred error   : {res.latency_error_pct:.2f} %")
    print(f"  total actual cost    : ${res.total_actual_cost:.6f} "
          f"(pred err {res.cost_error_pct:.2f} %)")
    if args.policy == "minlat":
        print(f"  budget used          : {res.pct_budget_used:.1f} % "
              f"(violations {res.pct_cost_violated:.2f} %)")
    else:
        print(f"  deadline violations  : {res.pct_deadline_violated:.2f} % "
              f"(avg {res.avg_violation_ms:.1f} ms)")
    print(f"  warm/cold mismatches : {res.n_warm_cold_mismatches}/{res.n}")
    print(f"  edge executions      : {res.n_edge}/{res.n}")
    by = {}
    for r in res.records:
        by[r.target] = by.get(r.target, 0) + 1
    print(f"  placement histogram  : {dict(sorted(by.items()))}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
