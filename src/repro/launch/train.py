"""Training launcher: fault-tolerant loop on whatever devices the host has.

On the CPU dev box this trains reduced configs (--smoke) or small archs end to
end; on a fleet the same entry point runs under the production mesh (the
dry-run proves those configs compile). Features exercised here:

- checkpoint/restart (atomic keep-k, auto-resume from LATEST),
- failure injection + supervisor restart (--fail-at),
- gradient compression (--compression topk|int8),
- straggler watchdog (per-step EWMA, logged),
- deterministic counter-seeded data (bit-exact resume).

Example:
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 60 --ckpt-dir /tmp/ckpt --fail-at 25
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, smoke_config
from repro.distributed.compression import CompressionConfig
from repro.modeling.registry import build_model
from repro.training.data import make_pipeline
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import (
    FailureInjector,
    LoopConfig,
    run_with_restarts,
    train,
)


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3.2-1b")
    p.add_argument("--smoke", action="store_true",
                   help="reduced same-family config (CPU-sized)")
    p.add_argument("--width", type=int, default=0,
                   help="override d_model (0 = config default)")
    p.add_argument("--layers", type=int, default=0)
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=20)
    p.add_argument("--fail-at", type=int, default=None,
                   help="inject a failure at this step (tests restart)")
    p.add_argument("--compression", choices=("none", "topk", "int8"),
                   default="none")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    updates = {}
    if args.width:
        updates["d_model"] = args.width
    if args.layers:
        updates["n_layers"] = args.layers
    if updates:
        cfg = cfg.with_updates(**updates)

    model = build_model(cfg)
    print(f"arch={cfg.name} family={cfg.family} params={model.param_count():,} "
          f"devices={len(jax.devices())}")

    pipeline = make_pipeline(cfg, seq_len=args.seq, global_batch=args.batch,
                             seed=args.seed)
    loop_cfg = LoopConfig(
        steps=args.steps, log_every=max(args.steps // 10, 1),
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
        compression=CompressionConfig(scheme=args.compression),
    )
    opt_cfg = OptimizerConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                              decay_steps=args.steps)

    injector = FailureInjector(args.fail_at) if args.fail_at else None
    runner = run_with_restarts if injector else train
    result = runner(model, pipeline, loop_cfg, opt_cfg,
                    key=jax.random.key(args.seed), injector=injector,
                    log=print)
    print(f"done: step={result.final_step} loss[first→last]="
          f"{result.losses[0]:.4f}→{result.losses[-1]:.4f} "
          f"stragglers={result.straggler_steps} restarts={result.restarts}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
