"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).

Meshes:
- single-pod: (16, 16) = ("data", "model") — 256 chips (one v5e pod);
- multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips. The "pod"
  axis composes with "data" for gradient reduction; all cross-pod traffic is
  the DP all-reduce (optionally compressed, repro.distributed.compression).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (CPU dev box: 1 device) — smoke tests/examples."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
