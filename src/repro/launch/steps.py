"""Step construction for the dry-run and launchers (no jax-init side effects).

``build_cell`` assembles, for one (architecture × shape × mesh) cell:
- the step function (train / prefill / decode),
- abstract (ShapeDtypeStruct) arguments — zero allocation,
- explicit in_shardings for every argument,
so callers do ``jit(step, in_shardings=...).lower(*args).compile()``.

Sharding policy (DESIGN.md §5):
- params/opt by logical axes (make_rules); FSDP (weights' d_model over the
  data axes) switches on automatically above ``FSDP_PARAM_THRESHOLD`` params;
- batch over ("pod","data"), falling back to a divisible prefix (long_500k
  has global_batch=1 → replicated);
- KV caches by model.cache_axes(): KV-heads over "model" when divisible,
  otherwise KV-sequence over "model" (flash-decode sharding).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.configs.specs import input_specs
from repro.distributed.sharding import make_rules, param_shardings, spec_for
from repro.modeling.module import abstract_params
from repro.modeling.registry import build_model
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import make_train_step

# Above this many params, weights/optimizer shard over the data axes too.
FSDP_PARAM_THRESHOLD = 8_000_000_000


@dataclass
class Cell:
    arch: str
    shape: str
    kind: str                      # train | prefill | decode
    step: Callable
    args: tuple                    # abstract ShapeDtypeStructs
    in_shardings: tuple
    donate_argnums: tuple
    model: Any
    fsdp: bool
    rules: dict


def _batch_rule_for(B: int, mesh) -> tuple[str, ...] | None:
    """Largest prefix of ("pod","data") whose product divides B."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    # try the full product first, then single axes (largest first)
    singles = sorted(axes, key=lambda a: -mesh.shape[a])
    candidates = [tuple(axes)] + [(a,) for a in singles]
    for cand in candidates:
        size = 1
        for a in cand:
            size *= mesh.shape[a]
        if size > 1 and B % size == 0:
            return cand
    return None


def _tree_shardings(specs: dict, axes_map: Callable, rules, mesh) -> dict:
    return {k: NamedSharding(mesh, spec_for(axes_map(k, v), rules))
            for k, v in specs.items()}


def _batch_axes(_k, v):
    return ("batch",) + (None,) * (len(v.shape) - 1)


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
               fsdp: bool | None = None) -> Cell:
    model = build_model(cfg)
    kind, specs = input_specs(cfg, shape)
    serving = kind != "train"
    if fsdp is None:
        fsdp = model.param_count() > FSDP_PARAM_THRESHOLD
        if serving and getattr(cfg, "serve_2d_ffn", False):
            fsdp = False  # 2D weight sharding replaces FSDP gathers
    rules = make_rules(cfg, mesh, fsdp=fsdp, serving=serving)
    rules = dict(rules, batch=_batch_rule_for(shape.global_batch, mesh))
    replicated = NamedSharding(mesh, P())

    if kind == "train":
        pspecs = model.param_specs()
        params = abstract_params(pspecs, jnp.dtype(cfg.param_dtype))
        psh = param_shardings(pspecs, rules, mesh)
        opt = {"opt": {
            "m": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params),
            "v": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), params),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }}
        osh = {"opt": {"m": psh, "v": psh, "step": replicated}}
        batch = specs["batch"]
        bsh = _tree_shardings(batch, _batch_axes, rules, mesh)
        step = make_train_step(model, OptimizerConfig())
        return Cell(cfg.name, shape.name, kind, step,
                    (params, opt, batch), (psh, osh, bsh),
                    donate_argnums=(0, 1), model=model, fsdp=fsdp, rules=rules)

    serve_dtype = jnp.dtype(cfg.dtype)
    pspecs = model.param_specs()
    params = abstract_params(pspecs, serve_dtype)
    psh = param_shardings(pspecs, rules, mesh)

    if kind == "prefill":
        batch = specs["batch"]
        bsh = _tree_shardings(batch, _batch_axes, rules, mesh)

        def prefill_step(params, batch):
            return model.prefill(params, batch)

        return Cell(cfg.name, shape.name, kind, prefill_step,
                    (params, batch), (psh, bsh),
                    donate_argnums=(), model=model, fsdp=fsdp, rules=rules)

    # ---- decode ------------------------------------------------------------
    cache = specs["cache"]
    batch = specs["batch"]
    cache_axes = model.cache_axes()
    csh = {k: NamedSharding(mesh, spec_for(cache_axes[k], rules))
           for k in cache}
    bsh = _tree_shardings(batch, _batch_axes, rules, mesh)

    def decode_step(params, cache, batch):
        return model.decode_step(params, cache, batch)

    return Cell(cfg.name, shape.name, kind, decode_step,
                (params, cache, batch), (psh, csh, bsh),
                donate_argnums=(1,), model=model, fsdp=fsdp, rules=rules)
