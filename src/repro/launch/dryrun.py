import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks device count on first init).
# The dry-run proves the production distribution config is coherent without
# hardware: for every (architecture × shape × mesh) cell it lowers + compiles
# the real step function against ShapeDtypeStruct inputs, then records
# memory_analysis / cost_analysis / collective-bytes for §Dry-run + §Roofline.

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import ARCHS, SHAPES, applicable_shapes, get_config  # noqa: E402
from repro.distributed.sharding import sharding_ctx                     # noqa: E402
from repro.launch.hlo_analysis import analyze_compiled, save_json       # noqa: E402
from repro.launch.mesh import make_production_mesh                      # noqa: E402
from repro.launch.steps import build_cell                               # noqa: E402

OUT_DIR_DEFAULT = "experiments/dryrun"


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = OUT_DIR_DEFAULT, overrides: dict | None = None,
             tag: str = "") -> dict:
    """Lower + compile one cell on the production mesh; dump analyses."""
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_updates(**overrides)
    shape = SHAPES[shape_name]
    mesh_name = "multipod" if multi_pod else "pod"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cell = build_cell(cfg, shape, mesh)

    with mesh, sharding_ctx(mesh, cell.rules):
        jitted = jax.jit(cell.step, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate_argnums)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    result = {
        "arch": arch, "shape": shape_name, "kind": cell.kind,
        "mesh": mesh_name, "devices": int(mesh.size), "fsdp": cell.fsdp,
        "param_count": cell.model.param_count(),
        "active_param_count": getattr(cell.model, "active_param_count",
                                      cell.model.param_count)(),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    result.update(analyze_compiled(compiled))
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch}_{shape_name}_{mesh_name}{tag}.json"
    save_json(os.path.join(out_dir, fname), result)
    return result


def _fmt(result: dict) -> str:
    mem = result.get("memory", {})
    peak = mem.get("peak_bytes_estimate", 0) / 2**30
    coll = result.get("hlo", {}).get("collective_link_bytes", 0) / 2**30
    fl = result.get("hlo", {}).get("flops", 0) / 1e12
    return (f"{result['arch']:>26s} {result['shape']:<12s} {result['mesh']:<8s} "
            f"{result['kind']:<7s} peak/dev={peak:7.2f} GiB  "
            f"flops/dev={fl:9.3f} T  coll/dev={coll:7.3f} GiB  "
            f"compile={result['compile_s']:6.1f}s")


def iter_cells(archs=None, shapes=None):
    for arch in (archs or sorted(ARCHS)):
        cells = applicable_shapes(get_config(arch))
        for sname, s in cells.items():
            if shapes and sname not in shapes:
                continue
            yield arch, sname, s is None  # (arch, shape, skipped)


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", action="append", help="architecture id(s)")
    p.add_argument("--shape", action="append", choices=sorted(SHAPES),
                   help="shape cell(s)")
    p.add_argument("--mesh", choices=("pod", "multipod", "both"), default="both")
    p.add_argument("--all", action="store_true", help="all 40 cells")
    p.add_argument("--out-dir", default=OUT_DIR_DEFAULT)
    p.add_argument("--list", action="store_true", help="list cells and exit")
    p.add_argument("--set", action="append", default=[], metavar="K=V",
                   help="ArchConfig override for §Perf variants, e.g. "
                        "--set sp_acts=true --set microbatch=4")
    p.add_argument("--tag", default="", help="suffix for variant JSON files")
    args = p.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            overrides[k] = v.lower() == "true"
        else:
            try:
                overrides[k] = int(v)
            except ValueError:
                overrides[k] = v

    archs = args.arch or (sorted(ARCHS) if args.all else None)
    if archs is None:
        p.error("pass --arch <id> (repeatable) or --all")
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    if args.list:
        for arch, sname, skipped in iter_cells(archs, args.shape):
            print(f"{arch:>26s} {sname:<12s} {'SKIP (documented)' if skipped else 'run'}")
        return 0

    failures, n_run, n_skip = [], 0, 0
    for arch, sname, skipped in iter_cells(archs, args.shape):
        if skipped:
            n_skip += 1
            print(f"{arch:>26s} {sname:<12s} SKIP (documented: "
                  f"{'encoder-only' if get_config(arch).is_encoder_only else 'needs sub-quadratic attention'})")
            continue
        for mp in meshes:
            try:
                res = run_cell(arch, sname, multi_pod=mp, out_dir=args.out_dir,
                               overrides=overrides or None, tag=args.tag)
                print(_fmt(res), flush=True)
                n_run += 1
            except Exception:
                failures.append((arch, sname, "multipod" if mp else "pod"))
                print(f"{arch:>26s} {sname:<12s} {'multipod' if mp else 'pod':<8s} "
                      f"FAILED:\n{traceback.format_exc()}", flush=True)

    print(f"\ndry-run: {n_run} compiled, {n_skip} documented skips, "
          f"{len(failures)} failures")
    for f in failures:
        print(f"  FAILED: {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
