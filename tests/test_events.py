"""The event-driven serving runtime (ISSUE 4).

Covers:
- the ``EventHeap`` ordering contract (time order; completion < dispatch <
  arrival at ties; FIFO within identical (time, kind));
- ``SingleSlotWorker`` event simulation ≡ the ``fifo_starts`` recurrence;
- ``TwinBackend.execute_async`` bit-parity with ``execute_many`` (outcomes
  AND end state), including hedge dispatch lists;
- ``serve_async`` ≡ ``serve(batched=True)`` metric identity across
  MinCost / MinLatency / Hedged on 1- and 3-device fleets, object-free
  (``RecordBatch``) on the columnar path;
- ``DecisionBatch.rows_by_target`` partitioning (the per-target worker
  queues) and the graceful fallback for backends without an async driver.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.decision import (
    DecisionBatch,
    DecisionEngine,
    HedgedPolicy,
    MinCostPolicy,
    MinLatencyPolicy,
)
from repro.core.events import (
    ARRIVAL,
    COMPLETION,
    DISPATCH,
    EventHeap,
    SingleSlotWorker,
)
from repro.core.fit import build_fleet_predictor, build_predictor, fit_app
from repro.core.records import RecordBatch
from repro.core.recurrence import fifo_starts
from repro.core.runtime import PlacementRuntime, TwinBackend

CONFIGS = (1280, 1536, 1792)
FLEET = {"edge0": 1.0, "edge1": 1.0, "edge2": 0.6}


@pytest.fixture(scope="module")
def fd_setup():
    return fit_app("FD", seed=0, n_inputs=120, configs=CONFIGS)


# ------------------------------------------------------------- heap contract
def test_heap_pops_in_time_order():
    heap = EventHeap()
    for t in (5.0, 1.0, 3.0, 2.0, 4.0):
        heap.push(t, ARRIVAL, t)
    assert [e.time_ms for e in heap.drain()] == [1.0, 2.0, 3.0, 4.0, 5.0]


def test_heap_tie_order_completion_dispatch_arrival():
    """At one instant a completion frees capacity a dispatch/arrival may use —
    never the reverse — so kinds pop completion < dispatch < arrival."""
    heap = EventHeap()
    heap.push(7.0, ARRIVAL, "a")
    heap.push(7.0, COMPLETION, "c")
    heap.push(7.0, DISPATCH, "d")
    assert [e.payload for e in heap.drain()] == ["c", "d", "a"]


def test_heap_fifo_within_identical_time_and_kind():
    heap = EventHeap()
    for i in range(10):
        heap.push(1.0, COMPLETION, i)
    assert [e.payload for e in heap.drain()] == list(range(10))


def test_heap_push_while_draining_and_rejects_unknown_kind():
    heap = EventHeap()
    heap.push(0.0, ARRIVAL, "first")
    seen = []
    for ev in heap.drain():
        seen.append(ev.payload)
        if ev.payload == "first":
            heap.push(1.0, COMPLETION, "second")
    assert seen == ["first", "second"]
    with pytest.raises(ValueError, match="kind"):
        heap.push(0.0, 99, None)


def test_single_slot_worker_matches_fifo_starts():
    """The event-driven single-slot FIFO ≡ the cumsum recurrence, including
    ties (simultaneous arrivals) and idle gaps."""
    rng = np.random.default_rng(0)
    gaps = np.round(rng.exponential(50.0, size=200), 0)  # rounding forces ties
    nows = np.cumsum(gaps) - gaps[0]
    comp = np.round(rng.exponential(80.0, size=200) + 1.0, 1)
    ref_starts, ref_free = fifo_starts(25.0, nows, comp)

    heap = EventHeap()
    w = SingleSlotWorker(free_at=25.0)
    starts = np.empty(200)
    for i in range(200):
        heap.push(float(nows[i]), ARRIVAL, i)
    for ev in heap.drain():
        if ev.kind == ARRIVAL:
            got = w.arrive(ev.time_ms, ev.payload)
            if got is not None:
                heap.push(got[0], DISPATCH, got)
        elif ev.kind == DISPATCH:
            start, i = ev.payload
            starts[i] = start
            heap.push(start + float(comp[i]), COMPLETION, i)
        else:
            nxt = w.complete(ev.time_ms)
            if nxt is not None:
                heap.push(nxt[0], DISPATCH, nxt)
    np.testing.assert_array_equal(starts, ref_starts)
    assert w.free_at == ref_free


# ------------------------------------------------- twin event-driver parity
def _fleet_backend(twin, seed=11):
    return TwinBackend(twin, seed=seed, edge_names=tuple(FLEET),
                       edge_speed=FLEET)


def test_execute_async_bit_identical_to_execute_many(fd_setup):
    twin, models = fd_setup
    tasks = twin.workload(600, seed=2)
    eng = DecisionEngine(
        predictor=build_fleet_predictor(models, FLEET, configs=CONFIGS),
        policy=MinLatencyPolicy(c_max=1e-5, alpha=0.02))  # edge/cloud mix
    targets = [d.target for d in eng.place_many(tasks)]
    assert {tg for tg in targets} & set(FLEET), "need edge dispatches"
    assert {tg for tg in targets} - set(FLEET), "need cloud dispatches"

    b_many = _fleet_backend(twin)
    b_evts = _fleet_backend(twin)
    a = b_many.execute_many(tasks, targets)
    b = b_evts.execute_async(tasks, targets)
    for f in ("latency_ms", "cost", "cold", "completion_ms",
              "queue_wait_ms", "exec_ms"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), err_msg=f)
    # identical end state: FIFO horizons and the ground-truth container pools
    assert b_many.edge_free_at == b_evts.edge_free_at
    assert b_many.gt_cloud.pools.keys() == b_evts.gt_cloud.pools.keys()
    for cfg, pool in b_many.gt_cloud.pools.items():
        other = b_evts.gt_cloud.pools[cfg]
        assert [(c.busy_until, c.last_completion, c.expires_at) for c in pool] \
            == [(c.busy_until, c.last_completion, c.expires_at) for c in other]


def _runtime(twin, models, policy, fleet: bool, seed=17):
    if fleet:
        pred = build_fleet_predictor(models, FLEET, configs=CONFIGS)
        backend = _fleet_backend(twin, seed=seed)
    else:
        pred = build_predictor(models, configs=CONFIGS)
        backend = TwinBackend(twin, seed=seed)
    return PlacementRuntime(DecisionEngine(predictor=pred, policy=policy),
                            backend)


POLICIES = {
    "mincost": lambda: MinCostPolicy(deadline_ms=4500.0),
    "minlat": lambda: MinLatencyPolicy(c_max=2.97e-5, alpha=0.02),
    "hedged": lambda: HedgedPolicy(MinLatencyPolicy(c_max=8e-5, alpha=0.0),
                                   hedge_threshold_ms=1500.0),
}


@pytest.mark.parametrize("fleet", [False, True], ids=["1-device", "3-device"])
@pytest.mark.parametrize("policy", list(POLICIES))
def test_serve_async_metric_identical_to_batched_serve(fd_setup, policy, fleet):
    """The ISSUE-4 acceptance bar: serve_async ≡ serve(batched=True) on the
    twin — identical SimulationResult metrics and per-record outcomes."""
    twin, models = fd_setup
    tasks = twin.workload(250, seed=3)
    a = _runtime(twin, models, POLICIES[policy](), fleet).serve(tasks)
    b = _runtime(twin, models, POLICIES[policy](), fleet).serve_async(tasks)

    assert a.total_actual_cost == b.total_actual_cost
    assert a.total_predicted_cost == b.total_predicted_cost
    assert a.avg_actual_latency_ms == b.avg_actual_latency_ms
    assert a.p99_actual_latency_ms == b.p99_actual_latency_ms
    assert a.pct_deadline_violated == b.pct_deadline_violated
    assert a.pct_cost_violated == b.pct_cost_violated
    assert a.n_warm_cold_mismatches == b.n_warm_cold_mismatches
    assert [r.target for r in a.records] == [r.target for r in b.records]
    assert [r.hedged for r in a.records] == [r.hedged for r in b.records]
    np.testing.assert_array_equal(a.records.actual_latency_ms,
                                  b.records.actual_latency_ms)
    np.testing.assert_array_equal(a.records.completion_ms,
                                  b.records.completion_ms)
    if policy == "hedged":
        assert any(r.hedged for r in b.records), "scenario must hedge"
    if fleet:
        assert a.device_summaries() == b.device_summaries()


def test_serve_async_columnar_path_stays_object_free(fd_setup):
    twin, models = fd_setup
    tasks = twin.workload(120, seed=4)
    rt = _runtime(twin, models, MinLatencyPolicy(c_max=2.97e-5, alpha=0.02),
                  fleet=True)
    res = rt.serve_async(tasks)
    # columnar decisions + ExecutionBatch outcomes merge straight into the
    # columnar record store — no TaskRecord objects on the async path
    assert isinstance(res.records, RecordBatch)
    assert res.n == 120


def test_rows_by_target_partitions_the_batch(fd_setup):
    twin, models = fd_setup
    tasks = twin.workload(200, seed=5)
    eng = DecisionEngine(
        predictor=build_fleet_predictor(models, FLEET, configs=CONFIGS),
        policy=MinLatencyPolicy(c_max=2.97e-5, alpha=0.02))
    batch = eng.place_many(tasks)
    assert isinstance(batch, DecisionBatch)
    queues = batch.rows_by_target()
    # each worker queue is arrival-ordered; together they cover every row once
    for name, rows in queues.items():
        assert np.all(np.diff(rows) > 0)
        assert all(batch.names[batch.target_codes[r]] == name
                   for r in rows.tolist())
    merged = np.sort(np.concatenate(list(queues.values())))
    np.testing.assert_array_equal(merged, np.arange(len(batch)))


def test_completion_order_is_the_event_stream(fd_setup):
    """``RecordBatch.completion_order`` replays rows as the completion-event
    stream emitted them — sorted by completion time, stable on ties — and is
    a permutation of the arrival-ordered batch."""
    twin, models = fd_setup
    tasks = twin.workload(150, seed=7)
    res = _runtime(twin, models, MinLatencyPolicy(c_max=1e-5, alpha=0.02),
                   fleet=True).serve_async(tasks)
    order = res.records.completion_order()
    completions = res.records.completion_ms[order]
    assert np.all(np.diff(completions) >= 0.0)
    np.testing.assert_array_equal(np.sort(order), np.arange(res.n))
    # queueing makes completion order genuinely differ from arrival order
    assert not np.array_equal(order, np.arange(res.n))


def test_race_hedge_wins_attributes_execution_to_the_hedge():
    """When a concurrent driver cancels the PRIMARY leg (the hedge completed
    while the primary was still queued), the record must report the leg that
    actually ran — its target, actuals, and device occupancy — with the
    cancelled primary as the zero-occupancy duplicate."""
    from repro.core.predictor import Prediction, Predictor
    from repro.core.runtime import ExecutionBatch
    from repro.core.workload import TaskInput

    class _Tgt:
        def __init__(self, name, lat, cost, is_edge=False):
            self.name, self.is_edge = name, is_edge
            self._lat, self._cost = lat, cost

        def predict_components(self, task, cold=False, quantile=None):
            return {"comp": self._lat}

        def cost(self, comp_ms):
            return self._cost

        def occupancy_ms(self, components):
            return components["comp"]

    class _NoopBackend:
        def probe_cold(self, target, now):
            return False

        def execute(self, task, target, now):
            raise AssertionError("async path must not call execute()")

    eng = DecisionEngine(
        predictor=Predictor(cloud_targets=[_Tgt("fast", 100.0, 2.0),
                                           _Tgt("slow", 120.0, 1.5)],
                            edge_target=_Tgt("edge", 5000.0, 0.0, is_edge=True)),
        policy=HedgedPolicy(MinLatencyPolicy(c_max=4.0, alpha=0.0),
                            hedge_threshold_ms=50.0))
    rt = PlacementRuntime(eng, _NoopBackend())
    task = TaskInput(idx=0, arrival_ms=0.0, size=1.0, bytes=1.0)
    decisions = eng.place_many([task])
    (d,) = decisions
    assert d.target == "fast" and d.hedge_target == "slow"

    def run(d_tasks, d_targets, races):
        assert d_targets == ["fast", "slow"] and races == [(0, 1)]
        return ExecutionBatch(  # primary cancelled; hedge ran for real
            latency_ms=np.array([np.inf, 80.0]),
            cost=np.array([0.0, 1.5]),
            cold=np.array([False, True]),
            completion_ms=np.array([np.inf, 80.0]),
            queue_wait_ms=np.array([0.0, 0.0]),
            exec_ms=np.array([0.0, 75.0]),
            cancelled=np.array([True, False]))

    (rec,) = rt._race_decisions([task], decisions, run)
    assert rec.hedged and rec.target == "slow" and rec.hedge_target == "fast"
    assert rec.actual_latency_ms == 80.0 and rec.completion_ms == 80.0
    assert rec.actual_cost == 1.5          # only the leg that ran bills
    assert rec.actual_cold                 # the WINNING leg's cold compile
    assert rec.exec_ms == 75.0             # occupancy lands on the run target
    assert rec.hedge_exec_ms == 0.0        # the cancelled leg occupied nothing
    assert rec.predicted_cost == pytest.approx(3.5)   # decision-time two-leg bet
    assert rec.predicted_latency_ms == pytest.approx(100.0)


def test_serve_async_without_async_backend_falls_back(fd_setup):
    """A backend with no concurrent driver serves the identical plan
    synchronously — serve_async never requires execute_async."""
    twin, models = fd_setup
    tasks = twin.workload(60, seed=6)

    class SyncOnly:
        def __init__(self, inner):
            self.inner = inner

        def probe_cold(self, target, now):
            return self.inner.probe_cold(target, now)

        def execute(self, task, target, now):
            return self.inner.execute(task, target, now)

    a = _runtime(twin, models, MinLatencyPolicy(c_max=2.97e-5, alpha=0.02),
                 fleet=False).serve(tasks)
    rt = _runtime(twin, models, MinLatencyPolicy(c_max=2.97e-5, alpha=0.02),
                  fleet=False)
    rt.backend = SyncOnly(rt.backend)
    b = rt.serve_async(tasks)
    assert a.total_actual_cost == b.total_actual_cost
    assert [r.target for r in a.records] == [r.target for r in b.records]
