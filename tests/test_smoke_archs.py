"""Per-architecture smoke tests: one forward/train step on a REDUCED config.

The assignment requires, for each of the 10 archs, a smoke test instantiating
a reduced same-family config and running one forward/train step on CPU,
asserting output shapes + no NaNs. Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, applicable_shapes, get_config, smoke_config
from repro.modeling.registry import build_model
from repro.training.data import make_pipeline

ALL_ARCHS = sorted(ARCHS)


def _batch_for(cfg, B=2, S=32):
    pipe = make_pipeline(cfg, seq_len=S, global_batch=B, seed=0)
    batch = {k: jnp.asarray(v) for k, v in pipe.batch(0).items()}
    if cfg.family == "vlm":
        V = cfg.vision_tokens
        batch = {
            "tokens": batch["tokens"][:, : S - V],
            "targets": batch["targets"][:, :S],
            "loss_mask": batch["loss_mask"][:, :S],
            "vision_embeds": jnp.asarray(
                np.random.default_rng(0).normal(size=(B, V, cfg.vision_feat_dim)),
                jnp.float32),
        }
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_smoke(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch_for(cfg)

    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0
    # grads: same structure, finite, at least one nonzero
    nonzero = 0
    for k, g in grads.items():
        assert g.shape == params[k].shape, k
        assert np.all(np.isfinite(np.asarray(g, np.float32))), k
        nonzero += int(np.any(np.asarray(g) != 0))
    assert nonzero > len(grads) // 2


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    B, S = 2, 32
    batch = _batch_for(cfg, B, S)
    h, aux = model.forward(params, batch)
    assert h.shape == (B, S, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(h, np.float32)))


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS
                                  if not get_config(a).is_encoder_only])
def test_prefill_decode_smoke(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    B, S = 2, 24
    batch = {"tokens": jnp.zeros((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch = {
            "tokens": jnp.zeros((B, S - cfg.vision_tokens), jnp.int32),
            "vision_embeds": jnp.zeros((B, cfg.vision_tokens, cfg.vision_feat_dim)),
        }
    logits, cache = model.prefill(params, batch, cache_len=S + 4)
    assert logits.shape == (B, cfg.vocab)
    for _ in range(3):
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        logits, cache = model.decode_step(params, cache, {"token": tok})
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


def test_encoder_only_has_no_decode():
    cfg = smoke_config("hubert-xlarge")
    model = build_model(cfg)
    with pytest.raises(NotImplementedError):
        model.decode_step(None, None, None)


def test_applicable_shapes_cell_count():
    """40 assigned cells = 31 runnable + 9 documented skips."""
    total = runnable = 0
    for arch in ALL_ARCHS:
        cells = applicable_shapes(get_config(arch))
        assert len(cells) == 4
        total += 4
        runnable += sum(1 for v in cells.values() if v is not None)
    assert total == 40
    assert runnable == 31
    # encoder-only skips decode; only ssm/hybrid run long_500k
    hub = applicable_shapes(get_config("hubert-xlarge"))
    assert hub["decode_32k"] is None and hub["long_500k"] is None
    assert applicable_shapes(get_config("mamba2-780m"))["long_500k"] is not None
    assert applicable_shapes(get_config("gemma-2b"))["long_500k"] is None


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_param_specs_consistent(arch):
    """Full (non-reduced) configs: specs build, axes match shapes, counts sane."""
    cfg = get_config(arch)
    model = build_model(cfg)
    specs = model.param_specs()
    n = model.param_count()
    assert n > 100e6, f"{arch}: {n}"
    for path, s in specs.items():
        assert len(s.shape) == len(s.axes), path
    # MoE archs expose active < total params
    if cfg.n_experts:
        assert model.active_param_count() < n
