"""What-if capacity planner (ISSUE 6): candidate search over recorded traffic.

Covers:
- the seeded 3-candidate fleet fixture: ``plan()`` returns the
  verified-cheapest SLO-meeting configuration (fleet-2: fleet-1 is
  saturated and misses, fleet-3 meets but pays for capacity it doesn't
  need) — and the verdict is identical across sequential, thread, and
  process evaluation modes;
- successive halving prunes on prefixes but verifies the winner on the
  full trace, agreeing with grid search on the fixture;
- scoring arithmetic (fleet capacity cost, attainment, ranking order) on
  hand-built records;
- budget bisect (``budget_strategy="bisect"``): the winner's ``c_max`` is
  refined to the cheapest full-trace-verified SLO-meeting budget;
  min-cost winners are left untouched;
- candidate/policy/SLO validation errors.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.records import SimulationResult, TaskRecord
from repro.core.workload import PoissonWorkload, TaskInput
from repro.planner import (
    SLO,
    Candidate,
    Planner,
    PolicySpec,
    plan,
    score_candidate,
)
from repro.planner.candidates import fitted
from repro.trace import Trace, TraceError

CONFIGS = (1280, 1536, 1792, 2048)


@pytest.fixture(scope="module")
def stt_trace():
    """600 STT arrivals at 0.12/s: ~11 s edge compute ⇒ one device is
    saturated (util ≈ 1.3), two are stable — fleet size discriminates."""
    twin, _ = fitted("STT", seed=0, n_inputs=120, configs=CONFIGS)
    tasks = PoissonWorkload(rate_per_s=0.12, size_sampler=twin.sample_input,
                            seed=5).generate(600)
    return Trace.from_tasks(tasks, app="STT")


def _fixture_candidates():
    # c_max=0 keeps every task on the fleet, so the search is purely about
    # edge capacity vs its hourly price
    pol = PolicySpec(kind="min_latency", c_max=0.0)
    return [Candidate.make(f"fleet-{k}", k, policy=pol, cloud_configs=CONFIGS,
                           device_rate_per_hour=0.05) for k in (1, 2, 3)]


def _fixture_planner(trace):
    return Planner(trace, SLO(latency_ms=40_000.0, target=0.95),
                   fit_seed=0, n_inputs=120, fit_configs=CONFIGS)


def _score_key(s):
    return (s.candidate.name, s.n, s.total_cost, s.attainment,
            s.p99_latency_ms, s.mean_latency_ms, s.makespan_ms)


# ------------------------------------------------------------- the fixture
def test_plan_returns_verified_cheapest_slo_meeting_config(stt_trace):
    planner = _fixture_planner(stt_trace)
    res = planner.plan(_fixture_candidates(), strategy="grid", parallel=False)

    assert res.best.candidate.name == "fleet-2"
    assert res.best.meets_slo
    assert res.best.n == stt_trace.n  # verified on the FULL trace
    # verified-cheapest: nothing that meets the SLO is cheaper
    meeting = [s for s in res.scores if s.meets_slo]
    assert {s.candidate.name for s in meeting} == {"fleet-2", "fleet-3"}
    assert res.best.total_cost == min(s.total_cost for s in meeting)
    # the saturated single device misses by a mile
    worst = next(s for s in res.scores if s.candidate.name == "fleet-1")
    assert not worst.meets_slo and worst.attainment < 0.5


def test_plan_identical_across_execution_modes(stt_trace):
    planner = _fixture_planner(stt_trace)
    cands = _fixture_candidates()
    seq = planner.plan(cands, strategy="grid", parallel=False)
    thr = planner.plan(cands, strategy="grid", parallel=True)
    prc = planner.plan(cands, strategy="grid", parallel=True,
                       use_processes=True)
    assert (seq.mode, thr.mode, prc.mode) == ("sequential", "thread",
                                              "process")
    for other in (thr, prc):
        assert other.best.candidate.name == seq.best.candidate.name
        assert [_score_key(s) for s in other.scores] \
            == [_score_key(s) for s in seq.scores]


def test_halving_agrees_with_grid_and_verifies_on_full_trace(stt_trace):
    planner = _fixture_planner(stt_trace)
    grid = planner.plan(_fixture_candidates(), strategy="grid")
    halv = planner.plan(_fixture_candidates(), strategy="halving", rungs=3,
                        min_rung_n=100)
    assert halv.best.candidate.name == grid.best.candidate.name
    assert halv.best.n == stt_trace.n
    assert _score_key(halv.best) == _score_key(grid.best)
    # pruning actually happened, and replayed fewer task-evaluations
    assert halv.rungs and all(len(r["kept"]) < len(r["evaluated"])
                              for r in halv.rungs)
    assert halv.replayed_tasks < grid.replayed_tasks
    assert grid.replayed_tasks == stt_trace.n * 3


def test_plan_convenience_wrapper(stt_trace):
    res = plan(stt_trace, _fixture_candidates(),
               SLO(latency_ms=40_000.0, target=0.95), strategy="halving",
               rungs=2, min_rung_n=100, fit_configs=CONFIGS, n_inputs=120)
    assert res.best.candidate.name == "fleet-2"
    assert res.strategy == "halving"
    assert "best: fleet-2" in res.table()


def test_no_candidate_meets_slo_returns_best_attainment(stt_trace):
    planner = Planner(stt_trace, SLO(latency_ms=1.0, target=0.99),
                      fit_seed=0, n_inputs=120, fit_configs=CONFIGS)
    res = planner.plan(_fixture_candidates()[:2], strategy="grid")
    assert not res.best.meets_slo
    assert res.best.attainment == max(s.attainment for s in res.scores)


# ------------------------------------------------------------ budget bisect
@pytest.fixture(scope="module")
def ir_trace():
    """300 IR arrivals at 3/s on one edge device: busy enough that the
    per-task budget c_max decides how much work offloads to the cloud."""
    twin, _ = fitted("IR", seed=0, n_inputs=120, configs=CONFIGS)
    tasks = PoissonWorkload(rate_per_s=3.0, size_sampler=twin.sample_input,
                            seed=5).generate(300)
    return Trace.from_tasks(tasks, app="IR")


def _budget_planner(trace):
    return Planner(trace, SLO(latency_ms=2_000.0, target=0.9),
                   fit_seed=0, n_inputs=120, fit_configs=CONFIGS)


def test_budget_bisect_refines_winner_cheaper_still_meeting(ir_trace):
    """The winner's generous c_max leaves money on the table; bisect walks
    it down to the cheapest full-trace-verified budget that still meets."""
    pol = PolicySpec(kind="min_latency", c_max=2e-4)
    cands = [Candidate.make("one-edge", 1, policy=pol, cloud_configs=CONFIGS,
                            device_rate_per_hour=0.05)]
    planner = _budget_planner(ir_trace)
    base = planner.plan(cands)
    assert base.best.meets_slo
    ref = planner.plan(cands, budget_strategy="bisect", budget_iters=6)
    assert ref.best.meets_slo
    assert ref.best.total_cost <= base.best.total_cost
    assert ref.best.candidate.policy.c_max < pol.c_max
    assert ref.best.candidate.name == "one-edge"  # refined, same config
    probes = [r for r in ref.rungs if "budget_probe" in r]
    assert probes and all(p["c_max"] < pol.c_max for p in probes)
    # every probe replayed the FULL trace — never extrapolated
    assert ref.replayed_tasks == base.replayed_tasks * (1 + len(probes))


def test_budget_bisect_leaves_min_cost_winner_alone(ir_trace):
    pol = PolicySpec(kind="min_cost", deadline_ms=2_000.0)
    cands = [Candidate.make("mc", 1, policy=pol, cloud_configs=CONFIGS)]
    res = _budget_planner(ir_trace).plan(cands, budget_strategy="bisect")
    assert not any("budget_probe" in r for r in res.rungs)
    assert res.best.candidate.policy.c_max == pol.c_max


def test_budget_strategy_validation(ir_trace):
    with pytest.raises(ValueError, match="budget_strategy"):
        _budget_planner(ir_trace).plan(
            [Candidate.make("a", 1, cloud_configs=CONFIGS)],
            budget_strategy="newton")


# ------------------------------------------------------------------ scoring
def _fake_result(arrivals, completions, latencies, costs):
    recs = [TaskRecord(
        task=TaskInput(idx=i, arrival_ms=a, size=1.0, bytes=1.0),
        target="edge0", predicted_latency_ms=lat, predicted_cost=c,
        actual_latency_ms=lat, actual_cost=c, predicted_cold=False,
        actual_cold=False, allowed_cost=float("inf"), feasible=True,
        completion_ms=cm)
        for i, (a, cm, lat, c) in enumerate(
            zip(arrivals, completions, latencies, costs))]
    return SimulationResult(records=recs)


def test_score_candidate_arithmetic():
    cand = Candidate.make("c", {"edge0": 1.0, "edge1": 0.5},
                          device_rate_per_hour=0.10)
    # makespan: first arrival 0 → last completion 1.8e6 ms = 0.5 h
    res = _fake_result(arrivals=[0.0, 1000.0],
                       completions=[500.0, 1_800_000.0],
                       latencies=[100.0, 900.0], costs=[2e-6, 3e-6])
    slo = SLO(latency_ms=500.0, target=0.5)
    s = score_candidate(cand, {"STT": res}, slo)
    assert s.n == 2
    assert s.cloud_cost == pytest.approx(5e-6)
    # 0.10 $/h × 1.5 aggregate speed × 0.5 h
    assert s.fleet_cost == pytest.approx(0.075)
    assert s.total_cost == pytest.approx(0.075 + 5e-6)
    assert s.attainment == 0.5 and s.meets_slo
    assert s.per_app_attainment == {"STT": 0.5}
    assert s.makespan_ms == pytest.approx(1_800_000.0)


def test_ranking_prefers_meeting_then_cheapest():
    cand = Candidate.make("x", 1)
    slo = SLO(latency_ms=500.0, target=0.9)
    cheap_missing = score_candidate(cand, {"A": _fake_result(
        [0.0], [100.0], [1000.0], [1e-6])}, slo)
    costly_meeting = score_candidate(
        Candidate.make("y", 1, device_rate_per_hour=1.0), {"A": _fake_result(
            [0.0], [3_600_000.0], [100.0], [1e-6])}, slo)
    from repro.planner.search import _rank_key
    assert _rank_key(costly_meeting) < _rank_key(cheap_missing)


# --------------------------------------------------------------- validation
def test_candidate_and_policy_validation():
    with pytest.raises(ValueError, match="unknown policy kind"):
        PolicySpec(kind="yolo")
    with pytest.raises(ValueError, match="empty fleet"):
        Candidate(name="c", fleet=())
    with pytest.raises(ValueError, match="duplicate fleet devices"):
        Candidate(name="c", fleet=(("e0", 1.0), ("e0", 2.0)))
    with pytest.raises(ValueError, match="count must be >= 1"):
        Candidate.make("c", 0)
    assert Candidate.make("c", 2).fleet == (("edge0", 1.0), ("edge1", 1.0))
    assert PolicySpec(kind="min_cost", deadline_ms=5.0).build().deadline_ms == 5.0
    hedged = PolicySpec(kind="hedged", c_max=1e-5,
                        hedge_threshold_ms=100.0).build()
    assert hedged.hedge_threshold_ms == 100.0


def test_slo_validation():
    with pytest.raises(ValueError, match="target"):
        SLO(latency_ms=100.0, target=0.0)
    with pytest.raises(ValueError, match="latency"):
        SLO(latency_ms=0.0)


def test_planner_rejects_bad_inputs(stt_trace):
    planner = _fixture_planner(stt_trace)
    with pytest.raises(ValueError, match="duplicate candidate names"):
        planner.evaluate([Candidate.make("a", 1), Candidate.make("a", 2)])
    with pytest.raises(ValueError, match="no candidates"):
        planner.evaluate([])
    with pytest.raises(ValueError, match="unknown strategy"):
        planner.plan(_fixture_candidates(), strategy="bogus")
    with pytest.raises(TraceError, match="empty trace"):
        Planner(Trace.from_arrays([], [], [], app_names=("STT",)),
                SLO(latency_ms=1.0))
    with pytest.raises(TraceError, match="not a known application"):
        Planner(Trace.from_arrays([0.0], [1.0], [1.0],
                                  app_names=("mystery",)),
                SLO(latency_ms=1.0))


def test_unknown_app_in_fit_cache():
    with pytest.raises(ValueError, match="unknown app 'nope'"):
        fitted("nope")
