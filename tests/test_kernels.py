"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs. pure-jnp oracle.

Every kernel in repro.kernels is validated against its ref.py across a sweep
of shapes, GQA group sizes, masks, chunk sizes and dtypes, per the assignment.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.linear_scan.ops import linear_scan
from repro.kernels.linear_scan.ref import linear_scan_ref
from repro.kernels.gbrt_predict.ops import gbrt_predict
from repro.kernels.gbrt_predict.ref import gbrt_predict_ref
from repro.core.gbrt import GBRT, GBRTConfig

F32, BF16 = jnp.float32, jnp.bfloat16


def _tol(dt):
    return 3e-2 if dt == BF16 else 5e-5


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,Sq,H,Hkv,D", [
    (1, 64, 2, 1, 32),     # MQA
    (2, 128, 4, 2, 64),    # GQA
    (1, 96, 4, 4, 16),     # MHA, padded seq (96 -> 128 with bq=64? 96%32)
    (1, 256, 8, 1, 128),   # long-ish MQA
])
@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("dtype", [F32, BF16])
def test_flash_attention_sweep(B, Sq, H, Hkv, D, window, dtype, rng):
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Sq, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Sq, Hkv, D)), dtype)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=True, window=window)
    err = np.max(np.abs(np.asarray(out, np.float32) - np.asarray(ref, np.float32)))
    assert err < _tol(dtype), err


def test_flash_attention_bidirectional(rng):
    """Encoder (non-causal) path."""
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 32)), F32)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), F32)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 32)), F32)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=False)
    assert np.max(np.abs(np.asarray(out - ref))) < 5e-5


# ----------------------------------------------------------- decode attention
@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (2, 128, 4, 1, 32),
    (3, 200, 8, 2, 64),    # padded cache (200 % 64 != 0)
    (1, 64, 4, 4, 128),
])
@pytest.mark.parametrize("dtype", [F32, BF16])
def test_decode_attention_sweep(B, S, H, Hkv, D, dtype, rng):
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), dtype)
    lengths = jnp.asarray(rng.integers(1, S + 1, size=(B,)), jnp.int32)
    out = decode_attention(q, k, v, lengths, block_k=64)
    ref = decode_attention_ref(q, k, v, lengths)
    err = np.max(np.abs(np.asarray(out, np.float32) - np.asarray(ref, np.float32)))
    assert err < _tol(dtype), err


def test_decode_attention_length_one(rng):
    """Degenerate cache: only slot 0 valid → output == v[:, 0]."""
    B, S, H, D = 2, 32, 2, 16
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), F32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), F32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), F32)
    out = decode_attention(q, k, v, jnp.ones((B,), jnp.int32), block_k=16)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(v[:, 0]),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ ssd scan
@pytest.mark.parametrize("b,S,nh,hd,ds,chunk", [
    (1, 32, 2, 8, 4, 8),
    (2, 64, 4, 16, 16, 16),
    (1, 100, 2, 8, 8, 32),   # padded tail chunk
])
@pytest.mark.parametrize("dtype", [F32, BF16])
def test_ssd_scan_sweep(b, S, nh, hd, ds, chunk, dtype, rng):
    x = jnp.asarray(rng.normal(size=(b, S, nh, hd)), dtype)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, S, nh))) * 0.5, F32)
    A = jnp.asarray(-np.abs(rng.normal(size=(nh,))) - 0.1, F32)
    B_ = jnp.asarray(rng.normal(size=(b, S, ds)), dtype)
    C = jnp.asarray(rng.normal(size=(b, S, ds)), dtype)
    y, st = ssd(x, dt, A, B_, C, chunk=chunk)
    yr, sr = ssd_ref(x, dt, A, B_, C)
    ye = np.max(np.abs(np.asarray(y, np.float32) - np.asarray(yr, np.float32)))
    se = np.max(np.abs(np.asarray(st) - np.asarray(sr)))
    assert ye < (1e-1 if dtype == BF16 else 1e-3), ye
    assert se < (5e-2 if dtype == BF16 else 1e-3), se


def test_ssd_state_carried_across_chunks(rng):
    """Final state must equal the literal recurrence even with many chunks."""
    b, S, nh, hd, ds = 1, 64, 2, 4, 4
    x = jnp.asarray(rng.normal(size=(b, S, nh, hd)), F32)
    dt = jnp.asarray(np.full((b, S, nh), 0.3), F32)
    A = jnp.asarray([-0.5, -1.0], F32)
    B_ = jnp.asarray(rng.normal(size=(b, S, ds)), F32)
    C = jnp.asarray(rng.normal(size=(b, S, ds)), F32)
    _, st8 = ssd(x, dt, A, B_, C, chunk=8)
    _, st64 = ssd(x, dt, A, B_, C, chunk=64)
    np.testing.assert_allclose(np.asarray(st8), np.asarray(st64), atol=1e-4)


# --------------------------------------------------------------- linear scan
@pytest.mark.parametrize("B,S,D,chunk", [
    (1, 16, 8, 8), (2, 64, 32, 16), (1, 100, 16, 32), (3, 7, 4, 8),
])
def test_linear_scan_sweep(B, S, D, chunk, rng):
    x = jnp.asarray(rng.normal(size=(B, S, D)), F32)
    a = jnp.asarray(rng.uniform(0.1, 1.0, size=(B, S, D)), F32)
    y, st = linear_scan(x, a, chunk=chunk)
    yr, sr = linear_scan_ref(x, a)
    assert np.max(np.abs(np.asarray(y - yr))) < 1e-5
    assert np.max(np.abs(np.asarray(st - sr))) < 1e-5


def test_linear_scan_identity_decay(rng):
    """a == 1 everywhere → h is a running sum (prefix-sum check)."""
    x = jnp.asarray(rng.normal(size=(1, 32, 4)), F32)
    a = jnp.ones((1, 32, 4), F32)
    y, st = linear_scan(x, a, chunk=8)
    np.testing.assert_allclose(np.asarray(y), np.cumsum(np.asarray(x), axis=1),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- gbrt predict
@pytest.mark.parametrize("n_features,depth,n_trees", [(1, 2, 20), (2, 3, 50), (3, 4, 10)])
def test_gbrt_predict_sweep(n_features, depth, n_trees, rng):
    x = rng.normal(size=(400, n_features)) * 100.0
    y = x[:, 0] * 2.0 + np.sin(x[:, -1] / 30.0) * 10.0 + rng.normal(size=400)
    m = GBRT.fit(x, y, GBRTConfig(n_trees=n_trees, max_depth=depth))
    xq = rng.normal(size=(137, n_features)) * 100.0
    pk = gbrt_predict(m, xq, block_n=64)
    pr = gbrt_predict_ref(xq.astype(np.float32), m.features, m.thresholds,
                          m.leaves, depth=depth,
                          lr=m.config.learning_rate, base=m.base)
    np.testing.assert_allclose(pk, pr, rtol=1e-4, atol=1e-4)
    # and against the numpy production path
    np.testing.assert_allclose(pk, m.predict(xq), rtol=1e-4, atol=1e-4)


def test_gbrt_predict_multi_matches_per_config(rng):
    """The blocked multi-config launch (one grid over the padded operand
    stack) is BIT-identical per column to a per-config launch — including
    heterogeneous depths/tree counts and a repeated model (shared id)."""
    from repro.kernels.gbrt_predict.kernel import (
        gbrt_predict_blocked,
        gbrt_predict_multi,
    )
    from repro.kernels.gbrt_predict.ops import (
        kernel_operands,
        multi_kernel_operands,
    )

    models = []
    for depth, trees in [(2, 20), (3, 50), (4, 10)]:
        x = rng.normal(size=(300, 2)) * 100.0
        y = x[:, 0] * 2.0 + np.sin(x[:, 1] / 30.0) * 10.0
        models.append(GBRT.fit(x, y, GBRTConfig(n_trees=trees,
                                                max_depth=depth)))
    models.append(models[0])  # same model under two configs
    mems = [1280.0, 1536.0, 1792.0, 2048.0]
    sizes = (rng.normal(size=(256,)) * 100.0).astype(np.float32)

    F, TH, LV, LR, BASE, dmax = multi_kernel_operands(models)
    MEM = jnp.asarray(np.array([[m] for m in mems], np.float32))
    multi = np.asarray(gbrt_predict_multi(
        jnp.asarray(sizes[:, None]), MEM, LR, BASE, F, TH, LV,
        depth=dmax, block_n=64, interpret=True))
    assert multi.shape == (256, len(models))
    for c, (m, mem) in enumerate(zip(models, mems)):
        feats, thr, lvs = kernel_operands(m)
        x2 = np.stack([sizes, np.full(256, mem, np.float32)], axis=1)
        single = np.asarray(gbrt_predict_blocked(
            jnp.asarray(x2), feats, thr, lvs, depth=m.config.max_depth,
            lr=float(m.config.learning_rate), base=float(m.base),
            block_n=64, interpret=True))
        assert np.array_equal(multi[:, c], single), f"config {c}"


def test_gbrt_operand_caches(rng):
    """Kernel operands are hosted once per model identity (weakref-guarded —
    a refit-by-swap misses and re-hosts), for both the per-config and the
    stacked multi-config form."""
    from repro.kernels.gbrt_predict.ops import (
        kernel_operands,
        multi_kernel_operands,
    )

    x = rng.normal(size=(200, 1)) * 100.0
    y = x[:, 0] * 1.5
    m1 = GBRT.fit(x, y, GBRTConfig(n_trees=8, max_depth=2))
    ops1 = kernel_operands(m1)
    assert kernel_operands(m1) is ops1
    multi1 = multi_kernel_operands((m1, m1))
    assert multi_kernel_operands((m1, m1)) is multi1
    m2 = GBRT.fit(x, y, GBRTConfig(n_trees=8, max_depth=2))  # "refit"
    assert kernel_operands(m2) is not ops1
    assert multi_kernel_operands((m1, m2)) is not multi1
