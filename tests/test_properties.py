"""Hypothesis property tests on the system's invariants."""

from __future__ import annotations

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.cil import ContainerInfoList
from repro.core.decision import MinCostPolicy, MinLatencyPolicy
from repro.core.gbrt import GBRT, GBRTConfig
from repro.core.perf_models import NormalModel, RidgeModel, fit_ridge
from repro.core.predictor import Prediction
from repro.core.pricing import LambdaPricing
from repro.distributed.sharding import make_rules, spec_for
from repro.configs import ARCHS, get_config

import jax

finite = st.floats(min_value=0.0, max_value=1e6, allow_nan=False,
                   allow_infinity=False)


def _mk_preds(costs_lats):
    preds = {f"c{i}": Prediction(target=f"c{i}", latency_ms=l, cost=c,
                                 cold=False, components={"comp": l})
             for i, (c, l) in enumerate(costs_lats)}
    preds["edge"] = Prediction(target="edge", latency_ms=1e5, cost=0.0,
                               cold=False, components={"comp": 1e5})
    return preds


# ------------------------------------------------ Alg. 1 budget invariants
@given(
    costs=st.lists(st.tuples(finite, finite), min_size=1, max_size=8),
    tasks=st.integers(min_value=1, max_value=60),
    c_max=st.floats(min_value=1e-6, max_value=100.0),
    alpha=st.floats(min_value=0.0, max_value=1.0),
)
@settings(max_examples=60, deadline=None)
def test_surplus_never_negative_and_budget_respected(costs, tasks, c_max, alpha):
    """Paper Sec. III-B: edge costs 0 ⇒ surplus(k) ≥ 0 ∀k, and every chosen
    cost respects C(k) ≤ C_max + α·surplus(k)."""
    policy = MinLatencyPolicy(c_max=c_max, alpha=alpha)
    preds = _mk_preds(costs)
    for _ in range(tasks):
        allowed_before = policy.allowed
        name, _, allowed = policy.choose(preds)
        assert allowed == allowed_before
        assert preds[name].cost <= allowed + 1e-12
        policy.observe(preds[name])
        assert policy.surplus >= -1e-12


@given(
    costs=st.lists(st.tuples(finite, finite), min_size=1, max_size=8),
    deadline=finite,
)
@settings(max_examples=60, deadline=None)
def test_min_cost_choice_is_optimal(costs, deadline):
    """The chosen config is the min-cost element of the feasible set."""
    policy = MinCostPolicy(deadline_ms=deadline)
    preds = _mk_preds(costs)
    name, feasible, _ = policy.choose(preds)
    feas = {n: p for n, p in preds.items() if p.latency_ms <= deadline}
    if not feas:
        assert name == "edge" and not feasible
    else:
        assert preds[name].cost == min(p.cost for p in feas.values())


# ------------------------------------------------ FIFO segment recurrence
def _fifo_scalar(free, nows, comp):
    """The reference scalar recurrence: start = max(F, now); F = start + comp."""
    starts = []
    for now, c in zip(nows, comp):
        s = free if free > now else now
        starts.append(s)
        free = s + c
    return starts, free


@given(
    free=st.floats(min_value=0.0, max_value=1e5),
    gaps=st.lists(st.floats(min_value=0.0, max_value=1e5), min_size=1,
                  max_size=120),
    comps=st.data(),
)
@settings(max_examples=100, deadline=None)
def test_fifo_starts_equals_scalar_recurrence(free, gaps, comps):
    """``fifo_starts`` must be BIT-identical to the scalar FIFO recurrence on
    arbitrary arrival/compute streams — the parity guarantee the batched twin
    sampler, the predicted edge queues, and the columnar decision core all
    build on. Large gaps force many idle segments, covering the >32-segment
    scalar-tail path."""
    from repro.core.recurrence import fifo_starts

    nows = np.cumsum(np.asarray(gaps))
    comp = np.asarray(comps.draw(st.lists(
        st.floats(min_value=0.0, max_value=1e4),
        min_size=len(gaps), max_size=len(gaps))))
    starts_v, free_v = fifo_starts(free, nows, comp)
    starts_s, free_s = _fifo_scalar(free, nows.tolist(), comp.tolist())
    assert starts_v.tolist() == starts_s
    assert free_v == free_s


def test_fifo_starts_scalar_tail_past_32_idle_segments():
    """Deterministic cover for the >32-segment fallback: 50 arrivals, each
    after the previous completion, is 50 idle periods — one per task."""
    from repro.core.recurrence import fifo_starts

    nows = np.arange(50, dtype=np.float64) * 100.0
    comp = np.full(50, 1.0)
    starts_v, free_v = fifo_starts(0.0, nows, comp)
    starts_s, free_s = _fifo_scalar(0.0, nows.tolist(), comp.tolist())
    assert starts_v.tolist() == starts_s and free_v == free_s
    # and a mixed busy/idle stream crossing the segment limit
    nows2 = np.cumsum(np.tile([500.0, 0.1, 0.1], 40))
    comp2 = np.tile([5.0, 5.0, 5.0], 40)
    starts_v, free_v = fifo_starts(3.0, nows2, comp2)
    starts_s, free_s = _fifo_scalar(3.0, nows2.tolist(), comp2.tolist())
    assert starts_v.tolist() == starts_s and free_v == free_s


# ------------------------------------------------------------ CIL properties
@given(
    events=st.lists(
        st.tuples(st.floats(min_value=0, max_value=1e5),
                  st.floats(min_value=0, max_value=1e3)),
        min_size=1, max_size=40),
    t_idl=st.floats(min_value=10.0, max_value=1e5),
)
@settings(max_examples=50, deadline=None)
def test_cil_containers_never_double_booked(events, t_idl):
    """At any dispatch, the reused container must have been idle."""
    cil = ContainerInfoList(t_idl_ms=t_idl)
    now = 0.0
    for gap, dur in events:
        now += gap
        idle_before = cil.idle_containers("m", now)
        cold = cil.record_dispatch("m", now, now + dur)
        assert cold == (len(idle_before) == 0)
        for c in cil.containers["m"]:
            assert c.busy_until <= c.last_completion


# --------------------------------------------------------- pricing monotone
@given(ms=st.floats(min_value=0.1, max_value=1e6),
       mem=st.sampled_from([640, 1024, 1792, 3008]))
@settings(max_examples=50, deadline=None)
def test_billed_never_below_actual(ms, mem):
    p = LambdaPricing()
    assert p.billed_ms(ms) >= min(ms, round(ms)) or p.billed_ms(ms) == 100.0
    assert p.billed_ms(ms) % p.quantum_ms == 0
    assert p.cost(ms, mem) > 0


# ----------------------------------------------------------- model fitting
@given(
    theta0=st.floats(min_value=-100, max_value=100),
    theta1=st.floats(min_value=-10, max_value=10),
    n=st.integers(min_value=10, max_value=200),
)
@settings(max_examples=30, deadline=None)
def test_ridge_recovers_linear_function(theta0, theta1, n):
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 100, n)
    y = theta0 + theta1 * x
    m = RidgeModel.fit(x, y)
    pred = m.predict(x)
    assert np.allclose(pred, y, rtol=1e-4, atol=1e-3)


@given(q=st.floats(min_value=0.05, max_value=0.95))
@settings(max_examples=30, deadline=None)
def test_normal_quantiles_monotone(q):
    m = NormalModel(mean=100.0, std=10.0)
    assert m.predict_quantile(q) <= m.predict_quantile(min(q + 0.01, 0.96))
    assert abs(m.predict_quantile(0.5) - 100.0) < 0.1


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=1000))
def test_gbrt_beats_constant_predictor(seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, 10, size=(300, 2))
    y = 5 * x[:, 0] + np.square(x[:, 1]) + rng.normal(0, 0.2, 300)
    m = GBRT.fit(x, y, GBRTConfig(n_trees=40, max_depth=3))
    sse_model = float(np.sum((m.predict(x) - y) ** 2))
    sse_const = float(np.sum((y.mean() - y) ** 2))
    assert sse_model < 0.5 * sse_const


def test_gbrt_predict_jax_matches_numpy(rng):
    x = rng.uniform(0, 10, size=(200, 2))
    y = x[:, 0] * 3 + x[:, 1]
    m = GBRT.fit(x, y, GBRTConfig(n_trees=25, max_depth=3))
    np.testing.assert_allclose(np.asarray(m.predict_jax(x)), m.predict(x),
                               rtol=1e-4, atol=1e-4)


# -------------------------------------------------- event-heap ordering (ISSUE 4)
@given(
    events=st.lists(
        st.tuples(
            # a coarse grid of times forces heavy ties, incl. whole bursts of
            # simultaneous completions
            st.sampled_from([0.0, 1.0, 1.0, 2.5, 2.5, 2.5, 7.0, 1e6]),
            st.sampled_from([0, 1, 2]),  # COMPLETION, DISPATCH, ARRIVAL
        ),
        max_size=120,
    )
)
@settings(max_examples=100, deadline=None)
def test_event_heap_order_total_and_fifo_under_ties(events):
    """The async serve path is deterministic because heap order is total:
    nondecreasing time; completion < dispatch < arrival at equal times (a
    completion frees capacity a simultaneous arrival may use); FIFO (push
    order) within identical (time, kind) — simultaneous completions pop in
    the order they were scheduled."""
    from repro.core.events import EventHeap

    heap = EventHeap()
    for i, (t, kind) in enumerate(events):
        heap.push(t, kind, i)
    popped = list(heap.drain())

    assert len(popped) == len(events)
    keys = [(e.time_ms, e.kind, e.seq) for e in popped]
    assert keys == sorted(keys)  # the total order, verbatim
    # every event popped exactly once
    assert sorted(e.payload for e in popped) == list(range(len(events)))
    # FIFO within identical (time, kind): payloads == push indices, so each
    # tie group must come back strictly increasing
    groups: dict = {}
    for e in popped:
        groups.setdefault((e.time_ms, e.kind), []).append(e.payload)
    for seq in groups.values():
        assert seq == sorted(seq)


@given(
    gaps=st.lists(st.sampled_from([0.0, 0.0, 1.0, 5.0, 250.0]),
                  min_size=1, max_size=60),
    busy=st.data(),
    free0=st.sampled_from([0.0, 40.0]),
)
@settings(max_examples=60, deadline=None)
def test_single_slot_worker_equals_fifo_recurrence(gaps, busy, free0):
    """Event-driven single-slot FIFO ≡ ``fifo_starts`` (the cumsum form) on
    arbitrary arrival patterns with ties and idle gaps — the equivalence the
    twin's async edge workers rely on."""
    from repro.core.events import ARRIVAL, COMPLETION, DISPATCH, EventHeap, SingleSlotWorker
    from repro.core.recurrence import fifo_starts

    n = len(gaps)
    nows = np.cumsum(np.asarray(gaps))
    comp = np.asarray([busy.draw(st.sampled_from([0.5, 3.0, 120.0]))
                       for _ in range(n)])
    ref_starts, ref_free = fifo_starts(free0, nows, comp)

    heap = EventHeap()
    w = SingleSlotWorker(free_at=free0)
    starts = np.empty(n)
    for i in range(n):
        heap.push(float(nows[i]), ARRIVAL, i)
    for ev in heap.drain():
        if ev.kind == ARRIVAL:
            got = w.arrive(ev.time_ms, ev.payload)
            if got is not None:
                heap.push(got[0], DISPATCH, got)
        elif ev.kind == DISPATCH:
            start, i = ev.payload
            starts[i] = start
            heap.push(start + float(comp[i]), COMPLETION, i)
        else:
            nxt = w.complete(ev.time_ms)
            if nxt is not None:
                heap.push(nxt[0], DISPATCH, nxt)
    np.testing.assert_array_equal(starts, ref_starts)
    assert w.free_at == ref_free


# --------------------------------------- streaming serve bit-parity (ISSUE 5)
import functools  # noqa: E402


@functools.lru_cache(maxsize=None)
def _stream_setup():
    from repro.core.fit import fit_app
    from repro.core.workload import BurstyWorkload

    twin, models = fit_app("IR", seed=0, n_inputs=100, configs=(1280, 1536))
    tasks = BurstyWorkload(rate_per_s=4.0, size_sampler=twin.sample_input,
                           burst_multiplier=8.0, mean_quiet_s=10.0,
                           mean_burst_s=6.0, seed=13).generate(150)
    return twin, models, tasks


def _stream_runtime():
    from repro.core.decision import DecisionEngine, MinLatencyPolicy
    from repro.core.fit import build_fleet_predictor
    from repro.core.runtime import PlacementRuntime, TwinBackend

    twin, models, _ = _stream_setup()
    fleet = {"edge0": 1.0, "edge1": 0.7}
    pred = build_fleet_predictor(models, fleet, configs=(1280, 1536))
    eng = DecisionEngine(predictor=pred,
                         policy=MinLatencyPolicy(c_max=5e-6, alpha=0.05))
    return PlacementRuntime(eng, TwinBackend(
        twin, seed=11, edge_names=tuple(fleet), edge_speed=fleet))


@given(chunk_sizes=st.lists(st.integers(min_value=1, max_value=60),
                            min_size=1, max_size=12))
@settings(max_examples=20, deadline=None)
def test_serve_stream_equals_one_shot_for_random_chunking(chunk_sizes):
    """``serve_stream`` ≡ ``serve(batched=True)`` per record under ARBITRARY
    chunk boundaries — including chunk_size=1 and boundaries inside repair
    segments (the bursty edge/cloud oscillation forces repairs). The chunk
    sizes cycle, so one example covers many uneven boundary placements."""
    import itertools

    import repro.core.decision as decision_mod

    _, _, tasks = _stream_setup()
    old_chunk = decision_mod.COLUMNAR_CHUNK
    decision_mod.COLUMNAR_CHUNK = 32  # force mid-segment boundaries
    try:
        ref = _stream_runtime().serve(tasks, batched=True)

        def chunks():
            it, sizes = 0, itertools.cycle(chunk_sizes)
            while it < len(tasks):
                n = next(sizes)
                yield tasks[it:it + n]
                it += n

        res = _stream_runtime().serve_stream(chunks())
    finally:
        decision_mod.COLUMNAR_CHUNK = old_chunk
    assert list(res.records.targets) == list(ref.records.targets)
    for col in ("predicted_latency_ms", "predicted_cost", "actual_latency_ms",
                "actual_cost", "allowed_cost", "completion_ms",
                "queue_wait_ms", "predicted_cold", "actual_cold", "feasible"):
        assert np.array_equal(getattr(res.records, col),
                              getattr(ref.records, col)), col


@given(
    spec=st.lists(st.tuples(st.sampled_from(["a", "b", "c", "d"]),
                            st.sampled_from([None, "a", "c"]),
                            st.floats(min_value=0.0, max_value=1e6,
                                      allow_nan=False)),
              min_size=0, max_size=60),
    splits=st.lists(st.integers(min_value=1, max_value=9), min_size=1,
                    max_size=6),
)
@settings(max_examples=60, deadline=None)
def test_record_arena_equals_from_records(spec, splits):
    """Appending arbitrary per-chunk record slices into a ``RecordArena``
    must reproduce ``RecordBatch.from_records`` over the concatenation —
    growth, code remap, and hedge -1 passthrough included."""
    import itertools

    from repro.core.records import RecordArena, RecordBatch, TaskRecord
    from repro.core.workload import TaskInput

    records = []
    for i, (target, hedge, v) in enumerate(spec):
        records.append(TaskRecord(
            task=TaskInput(idx=i, arrival_ms=v, size=1.0, bytes=1.0),
            target=target, predicted_latency_ms=v * 0.5, predicted_cost=v,
            actual_latency_ms=v * 2, actual_cost=v * 3,
            predicted_cold=bool(i % 2), actual_cold=bool(i % 3),
            allowed_cost=v, feasible=bool(i % 5), completion_ms=v + 1,
            hedged=hedge is not None, queue_wait_ms=v * 0.1, exec_ms=v * 0.2,
            hedge_target=hedge, hedge_exec_ms=0.0))
    ref = RecordBatch.from_records(records)
    arena = RecordArena(keep_tasks=True, capacity=2)
    it, sizes = 0, itertools.cycle(splits)
    while it < len(records):
        n = next(sizes)
        arena.append(records[it:it + n])
        it += n
    got = arena.finish()
    assert len(got) == len(ref)
    assert list(got.targets) == list(ref.targets)
    assert got.hedge_codes.tolist() == [
        got.target_names.index(r.hedge_target) if r.hedge_target else -1
        for r in records]
    for col in ("predicted_latency_ms", "actual_cost", "allowed_cost",
                "completion_ms", "predicted_cold", "feasible", "hedged"):
        assert np.array_equal(getattr(got, col), getattr(ref, col)), col


# ------------------------------------------------------- sharding invariants
def test_rules_always_divisible_for_all_archs():
    """Every resolved rule must divide the corresponding tensor dims, for
    every assigned arch on both production mesh shapes (checked abstractly,
    via axis sizes, since the real 512-device mesh can't exist in tests)."""

    class FakeMesh:
        def __init__(self, shape):
            self.shape = shape

    from repro.modeling.registry import build_model

    for mesh in (FakeMesh({"data": 16, "model": 16}),
                 FakeMesh({"pod": 2, "data": 16, "model": 16})):
        for arch in ARCHS:
            cfg = get_config(arch)
            rules = make_rules(cfg, mesh, fsdp=True)
            model = build_model(cfg)
            for path, spec in model.param_specs().items():
                for dim, ax in zip(spec.shape, spec.axes):
                    r = rules.get(ax) if ax else None
                    if r:
                        size = 1
                        for a in r:
                            size *= mesh.shape[a]
                        assert dim % size == 0, (arch, path, ax, dim, size)


# ------------------------------------------------------ trace format (ISSUE 6)
@given(
    gaps=st.lists(st.floats(min_value=1e-3, max_value=1e5, allow_nan=False),
                  min_size=0, max_size=40),
    seed=st.integers(min_value=0, max_value=2**16),
    with_lat=st.booleans(),
)
@settings(max_examples=40, deadline=None)
def test_trace_disk_round_trip_bit_exact(gaps, seed, with_lat):
    """JSONL (shortest-repr floats) and NPZ round trips reproduce every
    column bit-exactly for arbitrary valid traces."""
    import os
    import tempfile

    from repro.trace import Trace, load

    rng = np.random.default_rng(seed)
    n = len(gaps)
    t = Trace.from_arrays(
        np.cumsum(np.array(gaps, dtype=np.float64)),
        rng.uniform(0.0, 1e7, n), rng.uniform(0.0, 1e7, n),
        app_codes=rng.integers(0, 2, n), app_names=("IR", "STT"),
        observed_latency_ms=rng.uniform(0.0, 1e6, n) if with_lat else None)
    with tempfile.TemporaryDirectory() as d:
        for name in ("t.jsonl", "t.npz"):
            p = os.path.join(d, name)
            t.save(p)
            assert load(p).equal(t)


@given(chunk_size=st.integers(min_value=1, max_value=200),
       prefix=st.integers(min_value=0, max_value=150))
@settings(max_examples=40, deadline=None)
def test_trace_workload_chunks_prefix_bit_exact(chunk_size, prefix):
    """``TraceWorkload.chunks`` over any chunk size / replay prefix yields
    exactly the trace's own columns — the workload-level half of the
    bit-identical replay guarantee (the serve-level half is pinned in
    tests/test_trace.py)."""
    from repro.trace import Trace, TraceWorkload

    _, _, tasks = _stream_setup()
    trace = Trace.from_tasks(tasks, app="IR")
    chunks = list(TraceWorkload(trace).chunks(n=prefix,
                                              chunk_size=chunk_size))
    cat = (lambda col: np.concatenate([getattr(c, col) for c in chunks])
           if chunks else np.zeros(0))
    p = trace.prefix(prefix)
    assert np.array_equal(cat("arrival_ms"), p.arrival_ms)
    assert np.array_equal(cat("size"), p.size)
    assert np.array_equal(cat("bytes"), p.bytes)
    assert np.array_equal(cat("idx") if chunks else np.zeros(0, np.int64),
                          np.arange(prefix, dtype=np.int64))
    assert all(len(c) <= chunk_size for c in chunks)


@given(seed=st.integers(min_value=0, max_value=2**16),
       n=st.integers(min_value=0, max_value=60))
@settings(max_examples=40, deadline=None)
def test_trace_split_merge_roundtrip(seed, n):
    """``merge(t.split_by_app())`` reproduces any multi-app trace exactly
    (strictly increasing arrivals ⇒ the stable interleave is unique)."""
    from repro.trace import Trace, merge

    rng = np.random.default_rng(seed)
    t = Trace.from_arrays(
        np.cumsum(rng.uniform(1e-3, 1e4, n)),
        rng.uniform(0.0, 1e6, n), rng.uniform(0.0, 1e6, n),
        app_codes=rng.integers(0, 3, n), app_names=("IR", "FD", "STT"),
        observed_latency_ms=rng.uniform(0.0, 1e5, n))
    assert merge(t.split_by_app()).equal(t)
