"""Live serving path: executors (real compiles), calibration, placement server.

These run REAL XLA compiles, so they're the slowest tests in the suite; sizes
are kept minimal.
"""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core.decision import MinCostPolicy, MinLatencyPolicy
from repro.modeling.registry import build_model
from repro.serving.engine import batch_prompts, generate
from repro.serving.executors import LiveExecutor, SliceSpec, make_pool
from repro.serving.placement import (
    LivePlacementServer,
    calibrate_catalog,
    llm_workload,
)

TINY = dict(n_layers=2, d_model=32, d_ff=64, vocab=64, n_heads=2,
            n_kv_heads=2, head_dim=16)


@pytest.fixture(scope="module")
def tiny_cfg():
    return smoke_config("llama3.2-1b").with_updates(**TINY)


def test_generate_loop(tiny_cfg):
    model = build_model(tiny_cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(2, 64, size=(2, 8)),
                       jnp.int32)
    out = generate(model, params, toks, max_new_tokens=5, cache_len=16)
    assert out.shape == (2, 5)
    assert np.all((np.asarray(out) >= 0) & (np.asarray(out) < 64))


def test_batch_prompts_left_pads():
    out = batch_prompts([np.array([1, 2, 3]), np.array([9])], pad_to=5)
    np.testing.assert_array_equal(out[0], [0, 0, 1, 2, 3])
    np.testing.assert_array_equal(out[1], [0, 0, 0, 0, 9])


def test_executor_cold_then_warm(tiny_cfg):
    ex = LiveExecutor(SliceSpec("s2", 2), tiny_cfg)
    r1 = ex.execute(32, 128.0)
    assert r1.cold and r1.start_ms > 50  # real compile takes real time
    r2 = ex.execute(32, 128.0)
    assert not r2.cold and r2.start_ms < 5
    # eviction forces a true recompile
    ex.evict()
    r3 = ex.execute(32, 128.0)
    assert r3.cold and r3.start_ms > 50


def test_more_chips_fewer_steps(tiny_cfg):
    e1 = LiveExecutor(SliceSpec("s1", 1, tokens_per_step=8), tiny_cfg)
    e4 = LiveExecutor(SliceSpec("s4", 4, tokens_per_step=8), tiny_cfg)
    e1.execute(8, 1.0)
    e4.execute(8, 1.0)  # warm both
    # 2048 tokens: 256 vs 64 real decode steps — a 4× work gap that stays
    # ordered even under background-load timing noise; take best-of-3.
    n = 2048
    r1 = min(e1.execute(n, 1.0).comp_ms for _ in range(3))
    r4 = min(e4.execute(n, 1.0).comp_ms for _ in range(3))
    assert r4 < r1, (r1, r4)


def test_pool_virtual_time_warm_cold(tiny_cfg):
    pool = make_pool(tiny_cfg, [SliceSpec("s2", 2)], t_idl_ms=1000.0)
    assert pool.probe_cold("s2", now=0.0)
    rec = pool.execute_cloud("s2", 16, 1.0, now=0.0)
    assert rec.cold
    done = rec.start_ms + rec.comp_ms
    # shortly after completion: warm
    assert not pool.probe_cold("s2", now=done + 10.0)
    # long after: provider reclaimed ⇒ cold, and the executable is re-compiled
    assert pool.probe_cold("s2", now=done + 10_000.0)
    rec2 = pool.execute_cloud("s2", 16, 1.0, now=done + 10_000.0)
    assert rec2.cold


def test_edge_fifo_queueing(tiny_cfg):
    pool = make_pool(tiny_cfg, [])
    r1 = pool.execute_edge(64, 1.0, arrival_ms=0.0)
    assert r1.queue_ms == 0.0
    # arrival while the first is (virtually) still running queues behind it
    r2 = pool.execute_edge(64, 1.0, arrival_ms=0.1)
    assert r2.queue_ms > 0.0


@pytest.mark.slow
def test_live_placement_server_end_to_end(tiny_cfg):
    """The Table-V analog at CI scale: placement + real execution + metrics."""
    specs = [SliceSpec("s2", 2, tokens_per_step=4),
             SliceSpec("s8", 8, tokens_per_step=4)]
    cat = calibrate_catalog(tiny_cfg, specs, n_tasks=6, n_cold=1, seed=0)
    assert cat.start_cold.mean > 100.0

    tasks = llm_workload(25, rate_per_s=40.0, seed=1, mean_tokens=128)
    srv = LivePlacementServer(cat, MinLatencyPolicy(c_max=0.01, alpha=0.05),
                              t_idl_ms=30_000.0)
    res = srv.serve(tasks)
    assert res.n == 25
    assert res.total_actual_cost <= 0.01 * 25  # aggregate budget respected
    assert np.isfinite(res.avg_actual_latency_ms)
    # The predictor should be in the right ballpark live (paper: 5.65%). At
    # CI scale the ops are sub-millisecond and both calibration and serving
    # measure real wall-clock, so the percentage error is machine-state noise
    # (observed 23%-230% across identical runs, in the seed code too); assert
    # an order-of-magnitude ballpark, which still catches unit/model bugs.
    ratio = res.avg_predicted_latency_ms / res.avg_actual_latency_ms
    assert 0.1 < ratio < 10.0, res.latency_error_pct
