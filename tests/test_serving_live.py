"""Live serving path: executors (real compiles), calibration, placement server.

These run REAL XLA compiles, so they're the slowest tests in the suite; sizes
are kept minimal.
"""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import smoke_config
from repro.core.decision import MinCostPolicy, MinLatencyPolicy
from repro.modeling.registry import build_model
from repro.serving.engine import batch_prompts, generate
from repro.serving.executors import (
    ExecutionRecord,
    LiveExecutor,
    NetworkProfile,
    SliceSpec,
    _Dispatch,
    make_pool,
)
from repro.serving.placement import (
    LivePlacementServer,
    calibrate_catalog,
    llm_workload,
    make_live_runtime,
)

TINY = dict(n_layers=2, d_model=32, d_ff=64, vocab=64, n_heads=2,
            n_kv_heads=2, head_dim=16)


@pytest.fixture(scope="module")
def tiny_cfg():
    return smoke_config("llama3.2-1b").with_updates(**TINY)


def test_generate_loop(tiny_cfg):
    model = build_model(tiny_cfg)
    params = model.init(jax.random.key(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(2, 64, size=(2, 8)),
                       jnp.int32)
    out = generate(model, params, toks, max_new_tokens=5, cache_len=16)
    assert out.shape == (2, 5)
    assert np.all((np.asarray(out) >= 0) & (np.asarray(out) < 64))


def test_batch_prompts_left_pads():
    out = batch_prompts([np.array([1, 2, 3]), np.array([9])], pad_to=5)
    np.testing.assert_array_equal(out[0], [0, 0, 1, 2, 3])
    np.testing.assert_array_equal(out[1], [0, 0, 0, 0, 9])


def test_executor_cold_then_warm(tiny_cfg):
    ex = LiveExecutor(SliceSpec("s2", 2), tiny_cfg)
    r1 = ex.execute(32, 128.0)
    assert r1.cold and r1.start_ms > 50  # real compile takes real time
    r2 = ex.execute(32, 128.0)
    assert not r2.cold and r2.start_ms < 5
    # eviction forces a true recompile
    ex.evict()
    r3 = ex.execute(32, 128.0)
    assert r3.cold and r3.start_ms > 50


def test_more_chips_fewer_steps(tiny_cfg):
    e1 = LiveExecutor(SliceSpec("s1", 1, tokens_per_step=8), tiny_cfg)
    e4 = LiveExecutor(SliceSpec("s4", 4, tokens_per_step=8), tiny_cfg)
    e1.execute(8, 1.0)
    e4.execute(8, 1.0)  # warm both
    # 2048 tokens: 256 vs 64 real decode steps — a 4× work gap that stays
    # ordered even under background-load timing noise; take best-of-3.
    n = 2048
    r1 = min(e1.execute(n, 1.0).comp_ms for _ in range(3))
    r4 = min(e4.execute(n, 1.0).comp_ms for _ in range(3))
    assert r4 < r1, (r1, r4)


def test_pool_virtual_time_warm_cold(tiny_cfg):
    pool = make_pool(tiny_cfg, [SliceSpec("s2", 2)], t_idl_ms=1000.0)
    assert pool.probe_cold("s2", now=0.0)
    rec = pool.execute_cloud("s2", 16, 1.0, now=0.0)
    assert rec.cold
    done = rec.start_ms + rec.comp_ms
    # shortly after completion: warm
    assert not pool.probe_cold("s2", now=done + 10.0)
    # long after: provider reclaimed ⇒ cold, and the executable is re-compiled
    assert pool.probe_cold("s2", now=done + 10_000.0)
    rec2 = pool.execute_cloud("s2", 16, 1.0, now=done + 10_000.0)
    assert rec2.cold


def test_edge_fifo_queueing(tiny_cfg):
    pool = make_pool(tiny_cfg, [])
    r1 = pool.execute_edge(64, 1.0, arrival_ms=0.0)
    assert r1.queue_ms == 0.0
    # arrival while the first is (virtually) still running queues behind it
    r2 = pool.execute_edge(64, 1.0, arrival_ms=0.1)
    assert r2.queue_ms > 0.0


# ------------------------------------------- out-of-order completion landing
def _landed(pool, name, c, arrival_ms, busy_ms, warm=True):
    """Land a synthetic completion on a leased container (unit-level stand-in
    for a real execution finishing — lets the test place completions at exact
    virtual times and in exact landing order)."""
    if warm:
        c._compiled = ("stub",) * 4  # resident executable, no real compile
    pool.land(c, arrival_ms, ExecutionRecord(
        feed_ms=0.0, start_ms=0.0, comp_ms=busy_ms, store_ms=0.0, cold=False))


def test_pool_reap_protects_in_flight_containers(tiny_cfg):
    """ISSUE-4 bugfix regression: a leased (in-flight) container carries STALE
    virtual lifecycle fields until its completion lands — the idle-eviction
    sweep must never evict or drop it (the old push-order sweep dropped it,
    leaking the warm executable mid-execution)."""
    pool = make_pool(tiny_cfg, [SliceSpec("s2", 2)], t_idl_ms=1_000.0,
                     edge_specs=[])
    c = pool.lease("s2", 0.0)
    assert c.in_flight and c.last_completion == 0.0  # stale until land
    # a much later dispatch sweeps while c is still executing: its stale
    # lifecycle says "idle since t=0, long expired" — it must survive
    pool._reap("s2", now=50_000.0)
    assert c in pool.containers["s2"]
    _landed(pool, "s2", c, arrival_ms=50_000.0, busy_ms=100.0)
    assert not c.in_flight
    # now warm and reusable at its landed completion time
    assert not pool.probe_cold("s2", now=50_150.0)
    assert pool.lease("s2", 50_150.0) is c


def test_pool_eviction_sweeps_completion_order_not_push_order(tiny_cfg):
    """ISSUE-4 bugfix regression: completions land out of arrival order under
    the concurrent driver, so push order says nothing about idle time — the
    sweep must judge each container by its landed completion time."""
    pool = make_pool(tiny_cfg, [SliceSpec("s2", 2)], t_idl_ms=1_000.0,
                     edge_specs=[])
    a = pool.lease("s2", 0.0)   # pushed first
    b = pool.lease("s2", 0.0)   # pushed second (a is in flight)
    # completions land in REVERSE push order: b first (busy far into the
    # virtual future), then a (already idle since t=500)
    _landed(pool, "s2", b, arrival_ms=0.0, busy_ms=5_000.0)  # completes 5000
    _landed(pool, "s2", a, arrival_ms=0.0, busy_ms=500.0)    # completes  500
    # at t=1600: a has idled 1100 > t_idl → reclaimed; b is still busy
    pool._reap("s2", now=1_600.0)
    assert pool.containers["s2"] == [b]
    assert not a.is_warm(), "expired container must drop its executable"
    # at t=6200: b idled 1200 > t_idl → reclaimed too
    pool._reap("s2", now=6_200.0)
    assert pool.containers["s2"] == []


def test_pool_failed_execution_releases_the_lease(tiny_cfg, monkeypatch):
    """A dispatch that raises mid-execution must not leak its lease: the
    container returns to the pool (lifecycle untouched) instead of staying
    in flight forever and forcing a cold start on every later dispatch."""
    pool = make_pool(tiny_cfg, [SliceSpec("s2", 2)], t_idl_ms=60_000.0,
                     edge_specs=[])
    boom = RuntimeError("transient executor failure")
    monkeypatch.setattr(LiveExecutor, "execute",
                        lambda self, n, b: (_ for _ in ()).throw(boom))
    with pytest.raises(RuntimeError, match="transient"):
        pool.execute_cloud("s2", 16, 1.0, now=0.0)
    (c,) = pool.containers["s2"]
    assert not c.in_flight, "failed execution must release the lease"
    monkeypatch.undo()
    _landed(pool, "s2", c, arrival_ms=10.0, busy_ms=100.0)
    assert pool.lease("s2", 500.0) is c  # warm and reusable after recovery


def test_pool_mru_reuse_follows_landed_completions(tiny_cfg):
    """Reuse picks the most-recently-COMPLETED idle container (AWS order),
    judged on landed completion times, not lease order."""
    pool = make_pool(tiny_cfg, [SliceSpec("s2", 2)], t_idl_ms=60_000.0,
                     edge_specs=[])
    a = pool.lease("s2", 0.0)
    b = pool.lease("s2", 0.0)
    _landed(pool, "s2", b, arrival_ms=0.0, busy_ms=100.0)   # completes 100
    _landed(pool, "s2", a, arrival_ms=0.0, busy_ms=900.0)   # completes 900
    assert pool.lease("s2", 2_000.0) is a  # MRU = a despite b landing... first


# ---------------------------------------------------- concurrent dispatch
def test_serve_concurrent_matches_targets_and_queues(tiny_cfg):
    """The concurrent loop serves every dispatch on its own target with the
    same per-device virtual FIFO accounting as the sequential path."""
    pool = make_pool(tiny_cfg, [SliceSpec("s2", 2, tokens_per_step=4)],
                     edge_specs=[SliceSpec(f"edge{i}", 1, tokens_per_step=4,
                                           is_edge=True) for i in range(2)])
    plan = [
        _Dispatch(0, "edge0", 64, 16.0, 0.0),
        _Dispatch(1, "edge1", 64, 16.0, 0.0),
        _Dispatch(2, "s2", 32, 16.0, 0.0),
        _Dispatch(3, "edge0", 64, 16.0, 0.1),  # queues behind dispatch 0
    ]
    recs = pool.serve_concurrent(plan)
    assert all(r is not None for r in recs)
    assert recs[3].queue_ms > 0.0, "virtual FIFO wait must survive concurrency"
    assert recs[2].cold  # first dispatch to s2 pays the real compile
    # per-device FIFO accounting: edge0's horizon is the SUM of its two
    # executions (dispatch 3 queued behind 0), edge1's is its single one —
    # an identity on the records, not a wall-clock race between devices
    # (real execution times of tiny ops jitter by 2x under suite load)
    assert pool.edge_free_at["edge0"] == pytest.approx(
        recs[0].comp_ms + recs[3].comp_ms)
    assert pool.edge_free_at["edge1"] == pytest.approx(recs[1].comp_ms)
    assert recs[3].queue_ms == pytest.approx(recs[0].comp_ms - 0.1)


def test_serve_concurrent_cancels_unstarted_race_loser(tiny_cfg):
    """Hedge races are first-class: when the primary completes while the
    hedge leg is still queued behind its target's backlog, the loser is
    cancelled — it ran nowhere and bills nothing."""
    pool = make_pool(tiny_cfg, [SliceSpec("s2", 2, tokens_per_step=4)],
                     edge_specs=[SliceSpec("edge", 1, tokens_per_step=4,
                                           is_edge=True)])
    plan = [
        _Dispatch(0, "s2", 6_000, 16.0, 0.0),   # long head-of-line blocker
        _Dispatch(1, "edge", 8, 16.0, 1.0),     # primary: tiny, finishes fast
        _Dispatch(2, "s2", 6_000, 16.0, 1.0),   # hedge: queued behind 0
    ]
    recs = pool.serve_concurrent(plan, races=[(1, 2)])
    assert recs[0] is not None and recs[1] is not None
    assert recs[2] is None, "queued race loser must be cancelled"


@pytest.mark.slow
def test_live_async_serve_overlaps_and_serves_all(tiny_cfg):
    """serve_async over the real pool: every task served, finite metrics,
    fleet device accounting intact — the live half of the ISSUE-4 driver."""
    specs = [SliceSpec("s2", 2, tokens_per_step=4),
             SliceSpec("s8", 8, tokens_per_step=4)]
    cat = calibrate_catalog(tiny_cfg, specs, n_tasks=6, n_cold=1, seed=0)
    tasks = llm_workload(24, rate_per_s=40.0, seed=2, mean_tokens=128)
    rt = make_live_runtime(cat, MinLatencyPolicy(c_max=0.01, alpha=0.05),
                           t_idl_ms=30_000.0, n_edge_devices=3,
                           network=NetworkProfile(base_ms=2.0))
    res = rt.serve_async(tasks)
    assert res.n == 24
    assert np.isfinite(res.avg_actual_latency_ms)
    assert res.total_actual_cost <= 0.01 * 24
    assert sum(s.n_tasks for s in res.device_summaries().values()) == res.n_edge


@pytest.mark.slow
def test_live_placement_server_end_to_end(tiny_cfg):
    """The Table-V analog at CI scale: placement + real execution + metrics."""
    specs = [SliceSpec("s2", 2, tokens_per_step=4),
             SliceSpec("s8", 8, tokens_per_step=4)]
    cat = calibrate_catalog(tiny_cfg, specs, n_tasks=6, n_cold=1, seed=0)
    assert cat.start_cold.mean > 100.0

    tasks = llm_workload(25, rate_per_s=40.0, seed=1, mean_tokens=128)
    srv = LivePlacementServer(cat, MinLatencyPolicy(c_max=0.01, alpha=0.05),
                              t_idl_ms=30_000.0)
    res = srv.serve(tasks)
    assert res.n == 25
    assert res.total_actual_cost <= 0.01 * 25  # aggregate budget respected
    assert np.isfinite(res.avg_actual_latency_ms)
    # The predictor should be in the right ballpark live (paper: 5.65%). At
    # CI scale the ops are sub-millisecond and both calibration and serving
    # measure real wall-clock, so the percentage error is machine-state noise
    # (observed 23%-230% across identical runs, in the seed code too); assert
    # an order-of-magnitude ballpark, which still catches unit/model bugs.
    ratio = res.avg_predicted_latency_ms / res.avg_actual_latency_ms
    assert 0.1 < ratio < 10.0, res.latency_error_pct
