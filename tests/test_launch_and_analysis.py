"""Launch-layer cell construction + HLO analyzer unit tests (host mesh)."""

from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, smoke_config
from repro.distributed.sharding import make_rules, sharding_ctx, spec_for
from repro.launch.hlo_analysis import analyze_hlo_text, parse_hlo
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import _batch_rule_for, build_cell


# --------------------------------------------------------------- build_cell
@pytest.mark.parametrize("shape_name,kind", [
    ("train_4k", "train"), ("prefill_32k", "prefill"), ("decode_32k", "decode"),
])
def test_build_cell_structure(shape_name, kind):
    """Cells assemble abstract args + shardings without allocating; the host
    mesh (1 device) stands in for the production mesh in tests."""
    cfg = get_config("llama3.2-1b")
    mesh = make_host_mesh()
    cell = build_cell(cfg, SHAPES[shape_name], mesh)
    assert cell.kind == kind
    assert len(cell.args) == len(cell.in_shardings)
    for leaf in jax.tree.leaves(cell.args):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_build_cell_lowers_on_host_mesh():
    """A reduced config actually lowers+compiles through the cell machinery."""
    cfg = smoke_config("llama3.2-1b")
    from repro.configs.base import ShapeConfig

    shape = ShapeConfig("tiny_train", seq_len=32, global_batch=2, kind="train")
    mesh = make_host_mesh()
    cell = build_cell(cfg, shape, mesh)
    with mesh, sharding_ctx(mesh, cell.rules):
        compiled = jax.jit(cell.step, in_shardings=cell.in_shardings,
                           donate_argnums=cell.donate_argnums
                           ).lower(*cell.args).compile()
    assert compiled.cost_analysis() is not None


def test_batch_rule_fallback():
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    assert _batch_rule_for(256, FakeMesh()) == ("pod", "data")
    assert _batch_rule_for(16, FakeMesh()) == ("data",)  # 16 % 32 != 0
    assert _batch_rule_for(1, FakeMesh()) is None        # replicated


def test_serving_2d_rules():
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cfg = get_config("llama4-maverick-400b-a17b").with_updates(serve_2d_ffn=True)
    r_train = make_rules(cfg, FakeMesh(), serving=False)
    r_serve = make_rules(cfg, FakeMesh(), serving=True)
    assert r_train["expert_mlp"] is None          # experts own "model"
    assert r_serve["expert_mlp"] == ("data",)     # 2-D: expert-FF over data
    assert r_serve["mlp"] == ("model", "data")


# ------------------------------------------------------------- HLO analyzer
SYNTH_HLO = """
HloModule synth

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups=[16,16]<=[256], to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%z, %a)
  %w = (s32[], f32[8,8]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_analyzer_trip_count_multiplication():
    r = analyze_hlo_text(SYNTH_HLO)
    # one 8x8x8 dot per trip, 4 trips: 2*8*8*8*4 = 4096 FLOPs
    assert r["dot_flops"] == pytest.approx(4096)
    # all-reduce of 256B per trip over group size 16: 2*(15/16)*256*4 trips
    assert r["collective_link_bytes"] == pytest.approx(2 * 15 / 16 * 256 * 4)


def test_analyzer_parses_tuple_types_and_comments():
    txt = SYNTH_HLO.replace("%t0 = (s32[], f32[8,8]{1,0}) tuple",
                            "%t0 = (s32[], /*index=5*/f32[8,8]{1,0}) tuple")
    comps = parse_hlo(txt)
    assert comps["__entry_name__"] is not None
    names = {i.opcode for i in comps["__entry__"]}
    assert "while" in names


# ------------------------------------------------------------ cp attention
def test_cp_attention_matches_plain(rng):
    import jax.numpy as jnp

    from repro.modeling.attention import chunked_attention, cp_chunked_attention

    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    for window in (0, 24):
        a = chunked_attention(q, k, v, causal=True, window=window, q_chunk=16)
        b = cp_chunked_attention(q, k, v, causal=True, window=window,
                                 q_chunk=16, ways=4)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                                   atol=2e-5)


def test_cp_attention_grad_matches(rng):
    import jax.numpy as jnp

    from repro.modeling.attention import chunked_attention, cp_chunked_attention

    q = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    g1 = jax.grad(lambda q: chunked_attention(q, k, v, q_chunk=8).sum())(q)
    g2 = jax.grad(lambda q: cp_chunked_attention(q, k, v, q_chunk=8,
                                                 ways=2).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4,
                               atol=1e-5)
