"""Unit tests for the paper's core: pricing, CIL, Predictor, Decision Engine."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.cil import ContainerInfoList
from repro.core.decision import (
    DecisionEngine,
    HedgedPolicy,
    MinCostPolicy,
    MinLatencyPolicy,
)
from repro.core.perf_models import NormalModel, RidgeModel
from repro.core.predictor import Prediction, Predictor
from repro.core.pricing import EdgePricing, LambdaPricing, SlicePricing
from repro.core.workload import TaskInput


# ---------------------------------------------------------------- pricing
def test_lambda_pricing_quantization():
    p = LambdaPricing()
    # paper Sec. VI-A: 98 ms -> billed 100 ms; 101 ms -> billed 200 ms
    assert p.billed_ms(98) == 100
    assert p.billed_ms(101) == 200
    assert p.billed_ms(100) == 100
    assert p.billed_ms(0.2) == 100  # rounds to 1 ms then up to quantum
    # cost proportional to memory
    assert p.cost(100, 2048) == pytest.approx(2 * p.cost(100, 1024))


def test_edge_pricing_zero():
    assert EdgePricing().cost(123456.0) == 0.0


def test_slice_pricing_per_second_quantum():
    sp = SlicePricing(chip_hour_rate=3.6, quantum_s=1.0)
    # 3.6 $/chip-h = 0.001 $/chip-s; 1.5 s on 4 chips → billed 2 s → $0.008
    assert sp.cost(1500.0, 4) == pytest.approx(0.008)


# -------------------------------------------------------------------- CIL
def test_cil_warm_cold_lifecycle():
    cil = ContainerInfoList(t_idl_ms=1000.0)
    assert not cil.will_warm_start("m", now=0.0)
    cold = cil.record_dispatch("m", now=0.0, completion_time=50.0)
    assert cold
    # while busy: no idle container → another dispatch would cold-start
    assert not cil.will_warm_start("m", now=25.0)
    second_cold = cil.record_dispatch("m", now=25.0, completion_time=60.0)
    assert second_cold
    assert cil.count("m") == 2
    # after completion, within T_idl: warm
    assert cil.will_warm_start("m", now=100.0)
    assert not cil.record_dispatch("m", now=100.0, completion_time=140.0)
    # past T_idl: container reaped → cold again
    assert not cil.will_warm_start("m", now=140.0 + 1001.0)
    assert cil.record_dispatch("m", now=140.0 + 1001.0, completion_time=2000.0)


def test_cil_reuses_most_recent_completion():
    cil = ContainerInfoList(t_idl_ms=1e9)
    cil.record_dispatch("m", 0.0, 10.0)
    cil.record_dispatch("m", 0.0, 20.0)  # second container, completes later
    idle = cil.idle_containers("m", now=100.0)
    assert idle[0].last_completion == 20.0  # paper's empirical reuse order


# -------------------------------------------------------- predictor helpers
class _StubTarget:
    def __init__(self, name, latency, cost, is_edge=False):
        self.name = name
        self.is_edge = is_edge
        self._lat, self._cost = latency, cost

    def predict_components(self, task, cold=False, quantile=None):
        comps = {"comp": self._lat + (500.0 if cold else 0.0)}
        return comps

    def cost(self, comp_ms):
        return self._cost

    def occupancy_ms(self, components):
        return components["comp"]


def _preds(entries):
    return {
        name: Prediction(target=name, latency_ms=lat, cost=cost, cold=False,
                         components={"comp": lat})
        for name, lat, cost in entries
    }


# ---------------------------------------------------------- decision engine
def test_min_cost_picks_cheapest_feasible():
    policy = MinCostPolicy(deadline_ms=100.0)
    preds = _preds([("a", 90, 5.0), ("b", 80, 3.0), ("c", 200, 1.0),
                    ("edge", 99, 0.0)])
    name, feasible, _ = policy.choose(preds)
    assert name == "edge" and feasible  # cheapest among deadline-feasible


def test_min_cost_falls_back_to_edge_queue():
    policy = MinCostPolicy(deadline_ms=10.0)
    preds = _preds([("a", 90, 5.0), ("edge", 99, 0.0)])
    name, feasible, _ = policy.choose(preds)
    assert name == "edge" and not feasible  # paper Sec. V-B: M = ∅ → queue


def test_min_latency_respects_budget_and_banks_surplus():
    policy = MinLatencyPolicy(c_max=2.0, alpha=0.5)
    preds = _preds([("fast", 10, 5.0), ("mid", 50, 1.5), ("edge", 100, 0.0)])
    name, _, allowed = policy.choose(preds)
    assert name == "mid"           # fast exceeds budget
    policy.observe(preds[name])
    assert policy.surplus == pytest.approx(0.5)
    # banked surplus expands the budget: allowed = 2.0 + 0.5*0.5 = 2.25
    assert policy.allowed == pytest.approx(2.25)


def test_min_latency_alpha_zero_never_expands():
    policy = MinLatencyPolicy(c_max=1.0, alpha=0.0)
    preds = _preds([("fast", 10, 1.5), ("edge", 100, 0.0)])
    for _ in range(10):
        name, _, allowed = policy.choose(preds)
        policy.observe(preds[name])
        assert name == "edge"
        assert allowed == 1.0


def test_min_latency_invalid_alpha():
    with pytest.raises(ValueError):
        MinLatencyPolicy(c_max=1.0, alpha=1.5)


def test_hedged_policy_hedges_only_over_threshold():
    inner = MinLatencyPolicy(c_max=10.0, alpha=0.0)
    policy = HedgedPolicy(inner, hedge_threshold_ms=50.0)
    preds = _preds([("slow", 100, 1.0), ("primary", 80, 2.0), ("edge", 500, 0.0)])
    name, _, _ = policy.choose(preds)
    assert name == "primary"  # min-latency within budget
    # primary is over the 50 ms hedge threshold → a backup within 1.5× latency
    # and remaining budget is hedged ("slow": 100 < 120, cost 1 ≤ 8)
    assert policy.last_hedge is not None and policy.last_hedge[0] == "slow"

    preds_fast = _preds([("fast", 30, 1.0), ("edge", 500, 0.0)])
    policy.choose(preds_fast)
    assert policy.last_hedge is None  # under threshold: no hedge


# ------------------------------------------------------ predictor integration
def test_predictor_cold_then_warm_roundtrip():
    tgt = _StubTarget("m", latency=100.0, cost=1.0)
    pred = Predictor(cloud_targets=[tgt], edge_target=None,
                     cil=ContainerInfoList(t_idl_ms=1e6))
    task = TaskInput(idx=0, arrival_ms=0.0, size=1.0, bytes=1.0)
    out = pred.predict(task, now=0.0)
    assert out["m"].cold and out["m"].latency_ms == 600.0
    pred.update_cil("m", now=0.0, prediction=out["m"])
    # container released at 600; a dispatch at t=1000 sees it warm
    out2 = pred.predict(task, now=1000.0)
    assert not out2["m"].cold and out2["m"].latency_ms == 100.0


def test_engine_place_records_decision():
    """Decision recording is opt-in: a long-running serve must not accumulate
    every PlacementDecision forever (ISSUE 3 memory fix)."""
    tgt = _StubTarget("m", latency=10.0, cost=1.0)
    edge = _StubTarget("edge", latency=1000.0, cost=0.0, is_edge=True)
    pred = Predictor(cloud_targets=[tgt], edge_target=edge)
    eng = DecisionEngine(predictor=pred, policy=MinLatencyPolicy(c_max=5.0),
                         record_decisions=True)
    task = TaskInput(idx=7, arrival_ms=0.0, size=1.0, bytes=1.0)
    d = eng.place(task, now=0.0)
    assert d.task_idx == 7
    assert d.target == "m"
    assert len(eng.decisions) == 1

    eng_off = DecisionEngine(predictor=Predictor(cloud_targets=[tgt],
                                                 edge_target=edge),
                             policy=MinLatencyPolicy(c_max=5.0))
    eng_off.place(task, now=0.0)
    assert eng_off.decisions == []  # default: no unbounded growth
